"""AOT compile path: train tiny models, lower forwards to HLO text, write
artifacts/ for the rust runtime. Runs ONCE via `make artifacts`; python is
never on the request path.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 rust crate binds) rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids, so text
round-trips cleanly. See /opt/xla-example/README.md.

Artifacts written (all consumed by rust/src/runtime/):
  {target,draft}_s{S}.hlo.txt      forward graphs, ref attention
  target_pallas_s{S_small}.hlo.txt forward with the L1 Pallas kernel inlined
  {target,draft}_params.bin        f32 LE weights, concatenated param_order
  meta.json                        configs, param tables, artifact index,
                                   train stats, corpus profiles
  golden.json                      pinned logits for cross-language checks
"""

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus
from .model import (
    CONFIGS,
    MAX_POSITIONS,
    VOCAB_SIZE,
    causal_mask,
    forward,
    make_forward_fn,
    param_order,
    param_shapes,
)
from .train import train_all

SEQ_SMALL = 320   # 256 prefix budget + 64-token trees (Tables 1-3 regime)
SEQ_LARGE = 1024  # 256 prefix budget + 768-token trees (Table 4 regime)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 64-bit-id workaround)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(cfg, seq_len: int, attn_impl: str) -> str:
    fn, specs = make_forward_fn(cfg, seq_len, attn_impl)
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def flatten_params(cfg, params) -> np.ndarray:
    """Concatenate all weights (param_order) into one f32 vector."""
    chunks = [np.asarray(params[n], np.float32).ravel() for n in param_order(cfg)]
    return np.concatenate(chunks)


def param_table(cfg):
    """[{name, shape, offset, size}] — the rust loader's slicing map."""
    table, offset = [], 0
    shapes = param_shapes(cfg)
    for name in param_order(cfg):
        shape = shapes[name]
        size = int(np.prod(shape))
        table.append(
            {"name": name, "shape": list(shape), "offset": offset, "size": size}
        )
        offset += size
    return table


def golden_logits(params_by_role, seq_len=SEQ_SMALL):
    """Pinned forward outputs so rust can verify its PJRT wiring end-to-end."""
    tokens = (np.arange(seq_len, dtype=np.int32) * 7 + 3) % VOCAB_SIZE
    positions = np.arange(seq_len, dtype=np.int32)
    mask = np.asarray(causal_mask(seq_len))
    out = {
        "tokens_formula": "(7*i + 3) % vocab",
        "seq_len": seq_len,
        "positions": "arange",
        "mask": "causal",
    }
    for role, params in params_by_role.items():
        logits = forward(
            params, CONFIGS[role], jnp.asarray(tokens), jnp.asarray(positions),
            jnp.asarray(mask),
        )
        last = np.asarray(logits)[-1]
        out[role] = {
            "last_row_first8": [float(x) for x in last[:8]],
            "last_row_argmax": int(last.argmax()),
            "last_row_sum": float(last.sum()),
        }
    return out


def file_sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--skip-train",
        action="store_true",
        help="random-init weights (CI smoke only; acceptance rates collapse)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    t0 = time.time()

    if args.skip_train:
        from .model import init_params

        target_params = init_params(CONFIGS["target"], jax.random.PRNGKey(0))
        draft_params = init_params(CONFIGS["draft"], jax.random.PRNGKey(1))
        train_stats = {"skipped": True}
    else:
        print("[aot] training models ...")
        target_params, draft_params, train_stats = train_all()

    params_by_role = {"target": target_params, "draft": draft_params}
    artifacts = []

    # --- weights ---
    for role, params in params_by_role.items():
        path = os.path.join(args.out_dir, f"{role}_params.bin")
        flatten_params(CONFIGS[role], params).tofile(path)
        artifacts.append(os.path.basename(path))
        print(f"[aot] wrote {path} ({os.path.getsize(path)} bytes)")

    # --- HLO graphs ---
    graph_index = []
    jobs = [
        ("target", SEQ_SMALL, "ref"),
        ("draft", SEQ_SMALL, "ref"),
        ("target", SEQ_LARGE, "ref"),
        ("draft", SEQ_LARGE, "ref"),
        ("target", SEQ_SMALL, "pallas"),
    ]
    for role, seq, impl in jobs:
        suffix = f"_pallas_s{seq}" if impl == "pallas" else f"_s{seq}"
        fname = f"{role}{suffix}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        print(f"[aot] lowering {fname} ...")
        text = lower_model(CONFIGS[role], seq, impl)
        with open(path, "w") as f:
            f.write(text)
        graph_index.append(
            {
                "file": fname,
                "role": role,
                "seq_len": seq,
                "attn_impl": impl,
                "num_params": len(param_order(CONFIGS[role])),
            }
        )
        artifacts.append(fname)
        print(f"[aot]   {len(text)} chars")

    # --- golden outputs ---
    print("[aot] computing golden logits ...")
    golden = golden_logits(params_by_role)
    with open(os.path.join(args.out_dir, "golden.json"), "w") as f:
        json.dump(golden, f, indent=1)
    artifacts.append("golden.json")

    # --- meta (the make sentinel; write LAST) ---
    meta = {
        "vocab_size": VOCAB_SIZE,
        "max_positions": MAX_POSITIONS,
        "seq_small": SEQ_SMALL,
        "seq_large": SEQ_LARGE,
        "models": {
            role: {
                "dim": cfg.dim,
                "layers": cfg.layers,
                "heads": cfg.heads,
                "mlp_mult": cfg.mlp_mult,
                "params": param_table(cfg),
                "total_f32": sum(e["size"] for e in param_table(cfg)),
            }
            for role, cfg in CONFIGS.items()
        },
        "graphs": graph_index,
        "train": train_stats,
        "corpus_profiles": {
            name: {
                "seed": p.seed,
                "sticky_mass": p.sticky_mass,
                "skew": p.skew,
                "vocab": corpus.VOCAB_SIZE,
            }
            for name, p in corpus.PROFILES.items()
        },
        "sha256": {
            a: file_sha256(os.path.join(args.out_dir, a)) for a in artifacts
        },
        "build_seconds": round(time.time() - t0, 1),
    }
    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"[aot] done in {meta['build_seconds']}s -> {args.out_dir}/meta.json")


if __name__ == "__main__":
    main()
