"""Seeded synthetic corpora with dataset-like entropy profiles.

The paper evaluates on C4 (en), OpenWebText and CNN-DailyMail. We cannot ship
those datasets, so we substitute three seeded Markov-chain corpora whose
*entropy profiles* are separated the way the real datasets are separated
(DESIGN.md §3): `cnn` is low-entropy/repetitive (summarization prose),
`c4` is medium, `owt` is high-entropy web text.

CRITICAL INVARIANT: this generator is implemented twice — here (to train the
models) and in `rust/src/data/markov.rs` (to sample serving prompts). Both
use the same SplitMix64 stream and the same sampling logic so that, for the
same (profile, seed), python and rust produce byte-identical token streams.
`python/tests/test_corpus.py` pins golden values; `rust/src/data/markov.rs`
unit tests pin the SAME golden values.
"""

from dataclasses import dataclass

import numpy as np

VOCAB_SIZE = 512
# Number of "sticky" preferred successors per state in the Markov table.
_NUM_SUCC = 8

_MASK64 = (1 << 64) - 1


def splitmix64(state: int):
    """One SplitMix64 step. Returns (new_state, output). Matches rust/src/util/rng.rs."""
    state = (state + 0x9E3779B97F4A7C15) & _MASK64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK64
    z = z ^ (z >> 31)
    return state, z


class SplitMix64:
    """Tiny deterministic RNG, bit-identical with the rust implementation."""

    def __init__(self, seed: int):
        self.state = seed & _MASK64

    def next_u64(self) -> int:
        self.state, z = splitmix64(self.state)
        return z

    def next_f64(self) -> float:
        # 53-bit mantissa trick, same as rust side.
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def next_below(self, n: int) -> int:
        # Simple modulo draw; bias is irrelevant at our vocab sizes and it is
        # the easiest contract to keep identical across languages.
        return self.next_u64() % n


@dataclass(frozen=True)
class Profile:
    """A dataset profile = Markov-chain shape parameters."""

    name: str
    seed: int
    # Probability mass concentrated on the _NUM_SUCC preferred successors.
    sticky_mass: float
    # Temperature-ish skew among the preferred successors (1.0 = uniform).
    skew: float


# Entropy ordering: cnn < c4 < owt (repetitive news < web crawl < open web).
PROFILES = {
    "cnn": Profile("cnn", seed=0xC44_0001, sticky_mass=0.92, skew=2.0),
    "c4": Profile("c4", seed=0xC44_0002, sticky_mass=0.80, skew=1.3),
    "owt": Profile("owt", seed=0xC44_0003, sticky_mass=0.62, skew=1.0),
}


def successor_table(profile: Profile):
    """Preferred-successor table + per-rank weights for one profile.

    Returns (succ[int vocab x _NUM_SUCC], rank_mass[_NUM_SUCC]). Deterministic
    in the profile seed only. The rust port must reproduce this exactly.
    """
    rng = SplitMix64(profile.seed)
    succ = np.zeros((VOCAB_SIZE, _NUM_SUCC), dtype=np.int64)
    for s in range(VOCAB_SIZE):
        for j in range(_NUM_SUCC):
            succ[s, j] = rng.next_below(VOCAB_SIZE)
    # rank weights: w_j ∝ skew^{-j}, scaled to sticky_mass in total.
    w = np.array([profile.skew ** (-j) for j in range(_NUM_SUCC)])
    w = w / w.sum() * profile.sticky_mass
    return succ, w


def next_token(state: int, succ, rank_mass, sticky_mass: float, rng: SplitMix64) -> int:
    """Sample the next token of the chain. Mirrors rust data::markov::next_token."""
    u = rng.next_f64()
    if u < sticky_mass:
        # Walk the rank masses.
        acc = 0.0
        for j in range(succ.shape[1]):
            acc += rank_mass[j]
            if u < acc:
                return int(succ[state, j])
        return int(succ[state, -1])
    # Uniform exploration over the whole vocab.
    return rng.next_below(VOCAB_SIZE)


def generate(profile_name: str, n_tokens: int, stream_seed: int = 1):
    """Generate `n_tokens` tokens of the given profile as an int64 array."""
    profile = PROFILES[profile_name]
    succ, rank_mass = successor_table(profile)
    rng = SplitMix64(profile.seed ^ (stream_seed * 0x9E3779B97F4A7C15) & _MASK64)
    out = np.zeros(n_tokens, dtype=np.int64)
    state = rng.next_below(VOCAB_SIZE)
    for i in range(n_tokens):
        state = next_token(state, succ, rank_mass, profile.sticky_mass, rng)
        out[i] = state
    return out


def batches(profile_name: str, n_batches: int, batch: int, seq: int, stream_seed: int = 1):
    """Yield (batch, seq+1) int arrays for LM training (inputs + shifted targets)."""
    toks = generate(profile_name, n_batches * batch * (seq + 1), stream_seed)
    toks = toks.reshape(n_batches, batch, seq + 1)
    for i in range(n_batches):
        yield toks[i]
