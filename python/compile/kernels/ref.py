"""Pure-jnp oracles for the L1 kernel and model building blocks.

Everything here is the *reference semantics*; the Pallas kernel in
`tree_attention.py` and the rust engine are both validated against these
functions. Keep this file boring and obviously-correct.
"""

import jax.numpy as jnp

NEG_INF = -1e9


def masked_attention_ref(q, k, v, mask):
    """Dense masked attention, the oracle for the Pallas tree kernel.

    Args:
      q, k, v: [heads, seq, head_dim] float arrays.
      mask: [seq, seq] — 1.0 where query i may attend to key j, else 0.0.
            (Tree attention: j is an ancestor of i, or both in the prefix
            with j <= i — the rust side builds it, we only consume it.)

    Returns:
      [heads, seq, head_dim] attention output.
    """
    head_dim = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, dtype=q.dtype))
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    scores = jnp.where(mask[None, :, :] > 0, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    denom = probs.sum(axis=-1, keepdims=True)
    probs = probs / jnp.maximum(denom, 1e-20)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def rms_norm_ref(x, weight, eps=1e-5):
    """RMSNorm (Llama-style), oracle for model.rms_norm."""
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(var + eps)) * weight


def softmax_ref(logits, axis=-1):
    z = logits - logits.max(axis=axis, keepdims=True)
    e = jnp.exp(z)
    return e / e.sum(axis=axis, keepdims=True)


def block_occupancy_ref(mask, block_q, block_k):
    """[nq, nk] bool — True where the mask tile has any nonzero entry.

    This is the paper's block-count object (Table 5, Fig 8/9): the number of
    True entries is the number of attention blocks a block-sparse kernel must
    compute. The rust `tree::blocks` module reimplements this for the bench.
    """
    s_q, s_k = mask.shape
    nq, nk = s_q // block_q, s_k // block_k
    tiles = mask[: nq * block_q, : nk * block_k].reshape(nq, block_q, nk, block_k)
    return tiles.any(axis=(1, 3))
