"""L1 — Pallas block-sparse tree attention kernel.

The paper's Appendix-C contribution is a Triton FlashAttention variant that
takes an *arbitrary* tree attention mask and skips score blocks whose mask
tile is entirely zero. DySpec's DFS token reorder then minimizes the number
of non-zero tiles, so the kernel does proportionally less work.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): Triton's
threadblock/shared-memory scheme becomes a Pallas grid over
(head, q_block) with BlockSpec-staged VMEM tiles; the kv dimension is an
in-kernel `lax.fori_loop` whose carries (running max / denominator /
weighted-V accumulator) are the Pallas analogue of Triton's register
accumulators; the tile-skip predicate is an occupancy table (one `any()`
per tile, computed in the traced graph) consumed with `lax.cond`, so dead
tiles cost a branch instead of a matmul — on a real TPU, Mosaic prunes the
corresponding DMA + MXU work. We run `interpret=True` — mandatory for
CPU-PJRT — so correctness is exercised here and *efficiency* is reported
through the hardware-independent block-count metric, exactly the paper's
own proxy (Table 5, Fig 8/9).
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e9


def block_occupancy(mask, block_q, block_k):
    """[nq, nk] int32 occupancy table (traced; part of the lowered graph).

    Entry (i, j) is 1 iff the (block_q x block_k) mask tile (i, j) contains
    any attendable position. Its sum is the paper's "block count" metric.
    """
    s_q, s_k = mask.shape
    nq, nk = s_q // block_q, s_k // block_k
    tiles = mask.reshape(nq, block_q, nk, block_k)
    return (tiles.max(axis=(1, 3)) > 0).astype(jnp.int32)


def _tree_attn_kernel(occ_ref, q_ref, k_ref, v_ref, mask_ref, o_ref,
                      *, block_k, num_kv, scale):
    """One (head, q_block) grid step: online softmax over kv blocks.

    occ_ref:  [1, num_kv] occupancy row for this q block.
    q_ref:    [block_q, head_dim] Q tile for this (head, q_block).
    k_ref:    [seq, head_dim] full K for this head (tiles sliced in-loop).
    v_ref:    [seq, head_dim] full V for this head.
    mask_ref: [block_q, seq] mask rows for this q block.
    o_ref:    [block_q, head_dim] output tile.
    """
    q = q_ref[...]
    block_q, head_dim = q.shape

    def body(j, carry):
        m_prev, l_prev, acc_prev = carry

        def compute(_):
            k = pl.load(k_ref, (pl.dslice(j * block_k, block_k), slice(None)))
            v = pl.load(v_ref, (pl.dslice(j * block_k, block_k), slice(None)))
            mask = pl.load(mask_ref, (slice(None), pl.dslice(j * block_k, block_k)))
            s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
            s = jnp.where(mask > 0, s, NEG_INF)
            m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
            # Rows that are still fully masked keep m == NEG_INF; shift by a
            # safe pivot so exp() stays finite and their p rows are zeroed.
            pivot = jnp.maximum(m_cur, NEG_INF / 2)
            p = jnp.where(mask > 0, jnp.exp(s - pivot), 0.0)
            alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - pivot))
            l_cur = l_prev * alpha + p.sum(axis=-1, keepdims=True)
            acc_cur = acc_prev * alpha + jnp.dot(
                p, v, preferred_element_type=jnp.float32
            )
            return m_cur, l_cur, acc_cur

        # The block-sparsity payoff: tiles with zero occupancy cost a branch.
        return lax.cond(occ_ref[0, j] > 0, compute, lambda _: carry, operand=None)

    m0 = jnp.full((block_q, 1), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q, 1), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, head_dim), dtype=jnp.float32)
    _, l_fin, acc_fin = lax.fori_loop(0, num_kv, body, (m0, l0, acc0))
    o_ref[...] = (acc_fin / jnp.maximum(l_fin, 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def tree_attention(q, k, v, mask, block_q=32, block_k=32):
    """Block-sparse tree attention.

    Args:
      q, k, v: [heads, seq, head_dim] f32.
      mask: [seq, seq] f32 — 1.0 where query i attends to key j, 0 otherwise.
      block_q, block_k: tile sizes (the paper uses 32; must divide seq).

    Returns:
      [heads, seq, head_dim] f32, matching `ref.masked_attention_ref` on all
      rows with at least one attendable key (fully-masked rows return 0).
    """
    heads, seq, head_dim = q.shape
    assert seq % block_q == 0 and seq % block_k == 0, (seq, block_q, block_k)
    num_q = seq // block_q
    num_kv = seq // block_k
    scale = 1.0 / (head_dim ** 0.5)
    occ = block_occupancy(mask, block_q, block_k)  # [num_q, num_kv]

    kernel = functools.partial(
        _tree_attn_kernel, block_k=block_k, num_kv=num_kv, scale=scale
    )
    grid = (heads, num_q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, num_kv), lambda h, qb: (qb, 0)),
            pl.BlockSpec((None, block_q, head_dim), lambda h, qb: (h, qb, 0)),
            pl.BlockSpec((None, seq, head_dim), lambda h, qb: (h, 0, 0)),
            pl.BlockSpec((None, seq, head_dim), lambda h, qb: (h, 0, 0)),
            pl.BlockSpec((block_q, seq), lambda h, qb: (qb, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, head_dim), lambda h, qb: (h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((heads, seq, head_dim), q.dtype),
        interpret=True,
    )(occ, q, k, v, mask)
