"""L2 — GPT-style transformer in JAX (target + draft variants).

This is the compute graph that `aot.py` lowers to HLO text for the rust
runtime. It stands in for the paper's Llama2 targets / JF-68M draft
(DESIGN.md §3): the DySpec algorithm only consumes per-position (draft,
target) distribution pairs, so any pair of trained LMs with bounded KL
reproduces the relevant behaviour.

Architecture (Llama-flavoured, positions learned so we avoid RoPE's
dynamic-slice churn in fixed-shape AOT graphs):

    tok_emb[V, d] + pos_emb[S_max, d]
    N x { RMSNorm -> MHA(tree mask) -> residual;
          RMSNorm -> GELU MLP (4d)  -> residual }
    RMSNorm -> logits = x @ tok_emb.T        (weight tying)

Every forward takes an explicit [S, S] attention mask and [S] position ids;
the rust side is responsible for building causal masks (autoregressive /
prefill) and tree masks (speculative verification). One HLO artifact is
exported per (model, S, attention-impl) triple.

Attention impl is switchable: "ref" (fused jnp, what XLA optimizes best on
CPU) or "pallas" (the L1 block-sparse kernel, lowered into the same HLO).
Both are exported; rust integration tests check they agree.
"""

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp

from .kernels.ref import masked_attention_ref, rms_norm_ref
from .kernels.tree_attention import tree_attention

VOCAB_SIZE = 512
MAX_POSITIONS = 1024


@dataclass(frozen=True)
class ModelConfig:
    """Static architecture description (also serialized into meta.json)."""

    name: str
    vocab: int
    dim: int
    layers: int
    heads: int
    mlp_mult: int = 4

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads


# The two model roles. The scale substitution is documented in DESIGN.md §3;
# dims chosen so that target/draft FLOP ratio is ~8x (the JF68M->7B pairing's
# regime is then dialed in with the rust LatencyModel).
TARGET_CONFIG = ModelConfig("target", VOCAB_SIZE, dim=256, layers=4, heads=8)
DRAFT_CONFIG = ModelConfig("draft", VOCAB_SIZE, dim=128, layers=2, heads=4)

CONFIGS = {"target": TARGET_CONFIG, "draft": DRAFT_CONFIG}

# Parameter layout: a flat name -> array dict with a DETERMINISTIC ordering
# (param_order). The rust runtime feeds buffers positionally in this order;
# aot.py records names+shapes+offsets in meta.json.


def param_order(cfg: ModelConfig):
    names = ["tok_emb", "pos_emb"]
    for i in range(cfg.layers):
        names += [
            f"l{i}.attn_norm",
            f"l{i}.wq",
            f"l{i}.wk",
            f"l{i}.wv",
            f"l{i}.wo",
            f"l{i}.mlp_norm",
            f"l{i}.w_up",
            f"l{i}.w_down",
        ]
    names.append("final_norm")
    return names


def param_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    d, m = cfg.dim, cfg.dim * cfg.mlp_mult
    shapes = {
        "tok_emb": (cfg.vocab, d),
        "pos_emb": (MAX_POSITIONS, d),
        "final_norm": (d,),
    }
    for i in range(cfg.layers):
        shapes.update(
            {
                f"l{i}.attn_norm": (d,),
                f"l{i}.wq": (d, d),
                f"l{i}.wk": (d, d),
                f"l{i}.wv": (d, d),
                f"l{i}.wo": (d, d),
                f"l{i}.mlp_norm": (d,),
                f"l{i}.w_up": (d, m),
                f"l{i}.w_down": (m, d),
            }
        )
    return shapes


def init_params(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    """Scaled-normal init; norms start at 1."""
    shapes = param_shapes(cfg)
    params = {}
    for name in param_order(cfg):
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0]
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) * (fan_in ** -0.5)
            )
    return params


def _attention(params, cfg: ModelConfig, i: int, x, mask, attn_impl: str):
    """Multi-head attention over an explicit mask."""
    s = x.shape[0]
    q = x @ params[f"l{i}.wq"]
    k = x @ params[f"l{i}.wk"]
    v = x @ params[f"l{i}.wv"]

    def split(t):  # [S, d] -> [heads, S, head_dim]
        return t.reshape(s, cfg.heads, cfg.head_dim).transpose(1, 0, 2)

    qh, kh, vh = split(q), split(k), split(v)
    if attn_impl == "pallas":
        out = tree_attention(qh, kh, vh, mask, block_q=32, block_k=32)
    else:
        out = masked_attention_ref(qh, kh, vh, mask)
    out = out.transpose(1, 0, 2).reshape(s, cfg.dim)
    return out @ params[f"l{i}.wo"]


def forward(params, cfg: ModelConfig, tokens, positions, mask, attn_impl="ref"):
    """Logits for every position.

    Args:
      params: name -> array dict (see param_shapes).
      tokens: [S] int32 token ids (pad arbitrary; pad rows just get ignored).
      positions: [S] int32 position ids into pos_emb (prefix: 0..P-1;
                 tree node at depth t: P+t).
      mask: [S, S] f32, 1.0 = may attend. Must give every live row at least
            one attendable key (rust guarantees: every row attends to itself).
      attn_impl: "ref" | "pallas".

    Returns: [S, vocab] f32 logits.
    """
    x = params["tok_emb"][tokens] + params["pos_emb"][positions]
    for i in range(cfg.layers):
        h = rms_norm_ref(x, params[f"l{i}.attn_norm"])
        x = x + _attention(params, cfg, i, h, mask, attn_impl)
        h = rms_norm_ref(x, params[f"l{i}.mlp_norm"])
        h = jax.nn.gelu(h @ params[f"l{i}.w_up"]) @ params[f"l{i}.w_down"]
        x = x + h
    x = rms_norm_ref(x, params["final_norm"])
    return x @ params["tok_emb"].T


def make_forward_fn(cfg: ModelConfig, seq_len: int, attn_impl="ref"):
    """A fixed-shape forward suitable for jax.jit().lower().

    Signature: (*flat_params, tokens[S] i32, positions[S] i32,
                mask[S,S] f32) -> (logits[S, V] f32,)
    Flat params follow param_order(cfg) so the rust runtime can feed
    positionally. Returns (fn, example ShapeDtypeStructs).
    """
    names = param_order(cfg)
    shapes = param_shapes(cfg)

    def fn(*args):
        flat = args[: len(names)]
        tokens, positions, mask = args[len(names):]
        params = dict(zip(names, flat))
        return (forward(params, cfg, tokens, positions, mask, attn_impl),)

    specs = [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in names]
    specs += [
        jax.ShapeDtypeStruct((seq_len,), jnp.int32),
        jax.ShapeDtypeStruct((seq_len,), jnp.int32),
        jax.ShapeDtypeStruct((seq_len, seq_len), jnp.float32),
    ]
    return fn, specs


def causal_mask(seq_len: int):
    return jnp.tril(jnp.ones((seq_len, seq_len), jnp.float32))


def loss_fn(params, cfg: ModelConfig, batch, attn_impl="ref"):
    """Next-token cross-entropy over a [B, S+1] batch (teacher forcing)."""
    inputs = batch[:, :-1]
    targets = batch[:, 1:]
    s = inputs.shape[1]
    mask = causal_mask(s)
    positions = jnp.arange(s, dtype=jnp.int32)

    def one(seq):
        return forward(params, cfg, seq, positions, mask, attn_impl)

    logits = jax.vmap(one)(inputs)  # [B, S, V]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


def distill_loss_fn(draft_params, target_params, batch, attn_impl="ref"):
    """KL(target || draft) on teacher logits — trains the draft to
    approximate the target (paper Eq. 1's bounded-KL premise)."""
    inputs = batch[:, :-1]
    s = inputs.shape[1]
    mask = causal_mask(s)
    positions = jnp.arange(s, dtype=jnp.int32)

    def one_t(seq):
        return forward(target_params, TARGET_CONFIG, seq, positions, mask, attn_impl)

    def one_d(seq):
        return forward(draft_params, DRAFT_CONFIG, seq, positions, mask, attn_impl)

    t_logits = jax.lax.stop_gradient(jax.vmap(one_t)(inputs))
    d_logits = jax.vmap(one_d)(inputs)
    t_logp = jax.nn.log_softmax(t_logits, axis=-1)
    d_logp = jax.nn.log_softmax(d_logits, axis=-1)
    return (jnp.exp(t_logp) * (t_logp - d_logp)).sum(-1).mean()
