"""Build-time training: target LM on the synthetic corpus + draft distill.

Runs once inside `make artifacts` (seeded, CPU, ~1-2 minutes). Produces the
weight arrays that `aot.py` serializes next to the lowered HLO. The point is
NOT model quality per se — it is producing a (draft, target) pair whose KL
divergence is small-but-nonzero (paper Eq. 1), with realistic entropy
profiles, so that acceptance-rate behaviour matches the paper's regime.

Adam is hand-rolled (~20 lines) to keep the build path dependency-free
beyond jax itself.
"""

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import (
    DRAFT_CONFIG,
    TARGET_CONFIG,
    distill_loss_fn,
    init_params,
    loss_fn,
)

BATCH = 16
SEQ = 64
TARGET_STEPS = 240
DISTILL_STEPS = 240
LR = 3e-3


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros(())}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1.0
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t)
    vhat_scale = 1.0 / (1 - b2 ** t)
    params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return params, {"m": m, "v": v, "t": t}


def _mixed_pool(tokens_per_profile: int, stream_seed: int):
    """Training pool: equal parts of the three dataset profiles."""
    pools = [
        corpus.generate(name, tokens_per_profile, stream_seed)
        for name in ("cnn", "c4", "owt")
    ]
    return np.concatenate(pools)


def _sample_batch(pool, rng, batch=BATCH, seq=SEQ):
    starts = rng.integers(0, len(pool) - seq - 1, size=batch)
    return np.stack([pool[s : s + seq + 1] for s in starts]).astype(np.int32)


def train_target(pool, log=print):
    params = init_params(TARGET_CONFIG, jax.random.PRNGKey(0))
    state = adam_init(params)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, TARGET_CONFIG, batch)
        params, state = adam_update(params, grads, state, LR)
        return params, state, loss

    rng = np.random.default_rng(12345)
    t0 = time.time()
    first = last = None
    for i in range(TARGET_STEPS):
        batch = jnp.asarray(_sample_batch(pool, rng))
        params, state, loss = step(params, state, batch)
        if i == 0:
            first = float(loss)
        last = float(loss)
        if i % 40 == 0:
            log(f"  target step {i:4d} loss {float(loss):.4f}")
    log(
        f"  target: loss {first:.4f} -> {last:.4f} "
        f"({TARGET_STEPS} steps, {time.time() - t0:.1f}s)"
    )
    assert last < first, "target LM failed to learn the corpus"
    return params, {"first_loss": first, "last_loss": last}


def train_draft(target_params, pool, log=print):
    params = init_params(DRAFT_CONFIG, jax.random.PRNGKey(1))
    state = adam_init(params)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(distill_loss_fn)(
            params, target_params, batch
        )
        params, state = adam_update(params, grads, state, LR)
        return params, state, loss

    rng = np.random.default_rng(54321)
    t0 = time.time()
    first = last = None
    for i in range(DISTILL_STEPS):
        batch = jnp.asarray(_sample_batch(pool, rng))
        params, state, loss = step(params, state, batch)
        if i == 0:
            first = float(loss)
        last = float(loss)
        if i % 40 == 0:
            log(f"  draft step {i:4d} KL {float(loss):.4f}")
    log(
        f"  draft: KL(T||D) {first:.4f} -> {last:.4f} "
        f"({DISTILL_STEPS} steps, {time.time() - t0:.1f}s)"
    )
    assert last < first, "draft distillation failed to reduce KL"
    return params, {"first_kl": first, "last_kl": last}


@functools.lru_cache(maxsize=1)
def _cached_pool():
    return _mixed_pool(60_000, stream_seed=7)


def train_all(log=print):
    """Train both models; returns (target_params, draft_params, stats)."""
    pool = _cached_pool()
    log(f"corpus pool: {len(pool)} tokens (3 profiles)")
    target_params, tstats = train_target(pool, log)
    draft_params, dstats = train_draft(target_params, pool, log)
    return target_params, draft_params, {"target": tstats, "draft": dstats}
