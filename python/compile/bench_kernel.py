"""Table 5 kernel-time companion: times the Pallas block-sparse kernel
(interpret mode) against the dense jnp reference on random tree masks, with
and without DFS-equivalent reordering, reporting block counts alongside.

Interpret-mode timings are STRUCTURE-ONLY evidence (python dispatch
dominates; see DESIGN.md §Hardware-Adaptation) — the hardware-independent
result is the block-count reduction, which the rust bench reproduces
exactly (`cargo bench --bench table5_attention`).

Usage: python -m compile.bench_kernel [--sizes 256,512] [--trials 3]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.ref import masked_attention_ref
from .kernels.tree_attention import block_occupancy, tree_attention


def random_tree_parents(n, rng):
    return [None if i == 0 else int(rng.integers(0, i)) for i in range(n)]


def mask_from_parents(parents, order):
    n = len(parents)
    pos = {node: i for i, node in enumerate(order)}
    mask = np.zeros((n, n), np.float32)
    for node in range(n):
        i = pos[node]
        mask[i, i] = 1.0
        p = parents[node]
        while p is not None:
            mask[i, pos[p]] = 1.0
            p = parents[p]
    return mask


def dfs_order(parents):
    children = {}
    for i, p in enumerate(parents):
        if p is not None:
            children.setdefault(p, []).append(i)
    out, stack = [], [0]
    while stack:
        node = stack.pop()
        out.append(node)
        for c in reversed(children.get(node, [])):
            stack.append(c)
    return out


def time_fn(fn, *args, trials=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(trials):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / trials


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="256,512")
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--head-dim", type=int, default=32)
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",")]
    rng = np.random.default_rng(0)

    print(f"{'size':>6} {'reorder':>8} {'blocks':>7} {'pallas_s':>9} {'ref_s':>8}")
    for size in sizes:
        parents = random_tree_parents(size, rng)
        q, k, v = [
            jnp.asarray(rng.normal(size=(args.heads, size, args.head_dim)), jnp.float32)
            for _ in range(3)
        ]
        for reorder in (False, True):
            order = dfs_order(parents) if reorder else list(range(size))
            mask = jnp.asarray(mask_from_parents(parents, order))
            blocks = int(block_occupancy(mask, 32, 32).sum())
            t_pallas = time_fn(
                lambda q=q, k=k, v=v, m=mask: tree_attention(q, k, v, m),
                trials=args.trials,
            )
            t_ref = time_fn(
                lambda q=q, k=k, v=v, m=mask: masked_attention_ref(q, k, v, m),
                trials=args.trials,
            )
            print(
                f"{size:>6} {str(reorder):>8} {blocks:>7} {t_pallas:>9.4f} {t_ref:>8.4f}"
            )


if __name__ == "__main__":
    main()
