"""L2 model semantics: shapes, causality, tree-mask behaviour, pallas parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    CONFIGS,
    DRAFT_CONFIG,
    TARGET_CONFIG,
    VOCAB_SIZE,
    causal_mask,
    forward,
    init_params,
    loss_fn,
    make_forward_fn,
    param_order,
    param_shapes,
)

S = 64


@pytest.fixture(scope="module")
def draft_params():
    return init_params(DRAFT_CONFIG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def target_params():
    return init_params(TARGET_CONFIG, jax.random.PRNGKey(1))


def _inputs(seq=S, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, VOCAB_SIZE, seq), jnp.int32)
    positions = jnp.arange(seq, dtype=jnp.int32)
    return tokens, positions


def test_forward_shape(draft_params):
    tokens, positions = _inputs()
    logits = forward(draft_params, DRAFT_CONFIG, tokens, positions, causal_mask(S))
    assert logits.shape == (S, VOCAB_SIZE)
    assert bool(jnp.isfinite(logits).all())


def test_causality(draft_params):
    """Changing token t must not change logits at positions < t."""
    tokens, positions = _inputs(seed=1)
    mask = causal_mask(S)
    base = forward(draft_params, DRAFT_CONFIG, tokens, positions, mask)
    t = 40
    mutated = tokens.at[t].set((tokens[t] + 1) % VOCAB_SIZE)
    out = forward(draft_params, DRAFT_CONFIG, mutated, positions, mask)
    np.testing.assert_allclose(
        np.asarray(base[:t]), np.asarray(out[:t]), atol=1e-5
    )
    assert not np.allclose(np.asarray(base[t]), np.asarray(out[t]))


def test_tree_mask_isolates_branches(draft_params):
    """Two sibling branches after a shared prefix must not see each other:
    the logits of branch A are unchanged when branch B's token mutates."""
    prefix = 8
    seq = 12  # prefix + 4 tree slots: A1 A2 B1 B2
    tokens, _ = _inputs(seq, seed=2)
    positions = jnp.asarray(
        list(range(prefix)) + [prefix, prefix + 1, prefix, prefix + 1], jnp.int32
    )
    mask = np.zeros((seq, seq), np.float32)
    mask[:prefix, :prefix] = np.tril(np.ones((prefix, prefix)))
    for i in range(prefix, seq):
        mask[i, :prefix] = 1.0
        mask[i, i] = 1.0
    mask[prefix + 1, prefix] = 1.0      # A2 -> A1
    mask[prefix + 3, prefix + 2] = 1.0  # B2 -> B1
    mask = jnp.asarray(mask)

    base = forward(draft_params, DRAFT_CONFIG, tokens, positions, mask)
    mutated = tokens.at[prefix + 2].set((tokens[prefix + 2] + 5) % VOCAB_SIZE)  # B1
    out = forward(draft_params, DRAFT_CONFIG, mutated, positions, mask)
    # A-branch rows and the prefix unchanged:
    np.testing.assert_allclose(
        np.asarray(base[: prefix + 2]), np.asarray(out[: prefix + 2]), atol=1e-5
    )
    # B rows change:
    assert not np.allclose(np.asarray(base[prefix + 2]), np.asarray(out[prefix + 2]))


def test_tree_mask_equals_chain_when_tree_is_a_path(draft_params):
    """A tree that is a single chain == plain causal decoding (the rust
    engine's temp-0 equivalence test relies on this)."""
    tokens, positions = _inputs(seed=3)
    chain = forward(draft_params, DRAFT_CONFIG, tokens, positions, causal_mask(S))
    # Same structure expressed as "prefix + path tree".
    prefix = 32
    mask = np.zeros((S, S), np.float32)
    mask[:prefix, :prefix] = np.tril(np.ones((prefix, prefix)))
    for i in range(prefix, S):
        mask[i, : i + 1] = 1.0
    tree = forward(draft_params, DRAFT_CONFIG, tokens, positions, jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(chain), np.asarray(tree), atol=1e-5)


def test_pallas_and_ref_models_agree(draft_params):
    tokens, positions = _inputs()
    mask = causal_mask(S)
    ref = forward(draft_params, DRAFT_CONFIG, tokens, positions, mask, "ref")
    pal = forward(draft_params, DRAFT_CONFIG, tokens, positions, mask, "pallas")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal), atol=3e-4, rtol=1e-3)


def test_param_order_matches_shapes():
    for cfg in CONFIGS.values():
        order = param_order(cfg)
        shapes = param_shapes(cfg)
        assert set(order) == set(shapes)
        assert len(order) == len(set(order))
        assert order == param_order(cfg)  # stable


def test_make_forward_fn_specs(target_params):
    fn, specs = make_forward_fn(TARGET_CONFIG, 64)
    n_params = len(param_order(TARGET_CONFIG))
    assert len(specs) == n_params + 3
    assert specs[-1].shape == (64, 64)
    # And it actually traces:
    lowered = jax.jit(fn).lower(*specs)
    assert lowered is not None


def test_loss_decreases_direction(draft_params):
    """Sanity: loss_fn is ~log(V) at init on random tokens."""
    rng = np.random.default_rng(0)
    batch = jnp.asarray(rng.integers(0, VOCAB_SIZE, (2, 33)), jnp.int32)
    loss = float(loss_fn(draft_params, DRAFT_CONFIG, batch))
    assert 0.5 * np.log(VOCAB_SIZE) < loss < 2.0 * np.log(VOCAB_SIZE)
