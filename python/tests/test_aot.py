"""AOT lowering path: HLO text generation + param-table invariants.

Keeps to tiny shapes so the suite stays fast; the full-size artifacts are
built by `make artifacts` and separately smoke-checked by the rust runtime
tests against golden.json.
"""

import json
import os

import jax
import numpy as np

from compile.aot import flatten_params, lower_model, param_table, to_hlo_text
from compile.model import (
    CONFIGS,
    DRAFT_CONFIG,
    init_params,
    make_forward_fn,
    param_order,
)


def test_lower_draft_tiny_seq_produces_hlo_text():
    text = lower_model(DRAFT_CONFIG, 64, "ref")
    assert text.startswith("HloModule")
    # One HLO entry parameter per weight + tokens + positions + mask
    # (sub-computations also declare parameters; count ENTRY only).
    entry = text[text.index("ENTRY") :]
    n_entry_params = sum(
        1 for line in entry.splitlines() if " parameter(" in line
    )
    n_expected = len(param_order(DRAFT_CONFIG)) + 3
    assert n_entry_params == n_expected, n_entry_params


def test_lower_pallas_variant_produces_hlo_text():
    text = lower_model(DRAFT_CONFIG, 64, "pallas")
    assert text.startswith("HloModule")
    # interpret-mode pallas lowers to plain HLO (while loops), NOT a
    # Mosaic custom-call — that is what makes it CPU-PJRT loadable.
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_param_table_is_contiguous():
    for cfg in CONFIGS.values():
        table = param_table(cfg)
        offset = 0
        for entry in table:
            assert entry["offset"] == offset
            assert entry["size"] == int(np.prod(entry["shape"]))
            offset += entry["size"]


def test_flatten_params_round_trip():
    params = init_params(DRAFT_CONFIG, jax.random.PRNGKey(0))
    flat = flatten_params(DRAFT_CONFIG, params)
    table = param_table(DRAFT_CONFIG)
    assert flat.shape == (sum(e["size"] for e in table),)
    # Slicing by the table recovers each weight.
    for entry in table:
        w = np.asarray(params[entry["name"]], np.float32).ravel()
        got = flat[entry["offset"] : entry["offset"] + entry["size"]]
        np.testing.assert_array_equal(got, w)


def test_artifacts_if_built_are_consistent():
    """When artifacts/ exists (post `make artifacts`), validate the index."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    meta_path = os.path.join(art, "meta.json")
    if not os.path.exists(meta_path):
        import pytest

        pytest.skip("artifacts not built")
    with open(meta_path) as f:
        meta = json.load(f)
    for g in meta["graphs"]:
        assert os.path.exists(os.path.join(art, g["file"])), g["file"]
    for role in ("target", "draft"):
        path = os.path.join(art, f"{role}_params.bin")
        want = meta["models"][role]["total_f32"] * 4
        assert os.path.getsize(path) == want
