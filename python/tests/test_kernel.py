"""L1 kernel vs oracle — the CORE correctness signal for the Pallas kernel.

Hypothesis sweeps shapes, dtypes and mask structures; every case asserts
allclose against the dense-reference oracle in kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import block_occupancy_ref, masked_attention_ref
from compile.kernels.tree_attention import block_occupancy, tree_attention


def random_tree_mask(rng, seq, prefix):
    """Causal prefix + random token-tree tail, like the rust engine builds."""
    mask = np.zeros((seq, seq), np.float32)
    mask[:prefix, :prefix] = np.tril(np.ones((prefix, prefix)))
    parents = {}
    for i in range(prefix, seq):
        # Attach to a random earlier tree node (or the prefix root).
        parents[i] = int(rng.integers(prefix - 1, i))
        mask[i, i] = 1.0
        j = i
        while j >= prefix:
            j = parents[j]
            mask[i, j] = 1.0
        mask[i, : prefix] = np.tril(np.ones(prefix))[prefix - 1]  # sees full prefix
    return mask


def _mk_qkv(rng, heads, seq, head_dim, dtype=np.float32):
    return [
        jnp.asarray(rng.normal(size=(heads, seq, head_dim)), dtype)
        for _ in range(3)
    ]


@settings(max_examples=12, deadline=None)
@given(
    heads=st.sampled_from([1, 2, 4]),
    seq_blocks=st.integers(2, 6),
    head_dim=st.sampled_from([8, 16, 32]),
    block=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_random_tree(heads, seq_blocks, head_dim, block, seed):
    seq = seq_blocks * block
    rng = np.random.default_rng(seed)
    q, k, v = _mk_qkv(rng, heads, seq, head_dim)
    prefix = max(1, seq // 2)
    mask = jnp.asarray(random_tree_mask(rng, seq, prefix))
    out = tree_attention(q, k, v, mask, block_q=block, block_k=block)
    ref = masked_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-4)


@settings(max_examples=8, deadline=None)
@given(
    seq_blocks=st.integers(2, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_causal(seq_blocks, seed):
    seq = seq_blocks * 32
    rng = np.random.default_rng(seed)
    q, k, v = _mk_qkv(rng, 2, seq, 16)
    mask = jnp.asarray(np.tril(np.ones((seq, seq), np.float32)))
    out = tree_attention(q, k, v, mask)
    ref = masked_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-4)


def test_kernel_block_diagonal_mask():
    """Disjoint diagonal blocks — heavy sparsity, many skipped tiles."""
    seq, block = 128, 32
    rng = np.random.default_rng(0)
    q, k, v = _mk_qkv(rng, 2, seq, 16)
    mask = np.zeros((seq, seq), np.float32)
    for b in range(seq // block):
        s = slice(b * block, (b + 1) * block)
        mask[s, s] = np.tril(np.ones((block, block)))
    mask = jnp.asarray(mask)
    out = tree_attention(q, k, v, mask)
    ref = masked_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-4)
    # Exactly the diagonal tiles are occupied.
    occ = np.asarray(block_occupancy(mask, block, block))
    assert occ.sum() == seq // block


def test_fully_masked_rows_return_zero():
    """Rows with no attendable key must not produce NaNs (pad rows)."""
    seq = 64
    rng = np.random.default_rng(1)
    q, k, v = _mk_qkv(rng, 1, seq, 8)
    mask = np.tril(np.ones((seq, seq), np.float32))
    mask[40:, :] = 0.0  # dead pad rows
    out = np.asarray(tree_attention(q, k, v, jnp.asarray(mask)))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out[0, 40:], 0.0)


def test_occupancy_matches_ref():
    rng = np.random.default_rng(2)
    mask = (rng.random((96, 96)) < 0.05).astype(np.float32)
    got = np.asarray(block_occupancy(jnp.asarray(mask), 32, 32)).astype(bool)
    want = np.asarray(block_occupancy_ref(jnp.asarray(mask), 32, 32))
    np.testing.assert_array_equal(got, want)


def test_block_sizes_must_divide_seq():
    rng = np.random.default_rng(3)
    q, k, v = _mk_qkv(rng, 1, 48, 8)
    mask = jnp.ones((48, 48), jnp.float32)
    with pytest.raises(AssertionError):
        tree_attention(q, k, v, mask, block_q=32, block_k=32)


def test_kernel_is_jittable_and_deterministic():
    rng = np.random.default_rng(4)
    q, k, v = _mk_qkv(rng, 2, 64, 16)
    mask = jnp.asarray(np.tril(np.ones((64, 64), np.float32)))
    f = jax.jit(lambda q, k, v, m: tree_attention(q, k, v, m))
    a = np.asarray(f(q, k, v, mask))
    b = np.asarray(f(q, k, v, mask))
    np.testing.assert_array_equal(a, b)
