"""Corpus generator tests, including the cross-language golden values.

The golden token sequences pinned here are ALSO pinned in
rust/src/data/markov.rs unit tests — if you change the generator you must
update both, or python-trained models and rust-sampled prompts drift apart.
"""

import numpy as np

from compile import corpus


def test_splitmix64_golden():
    """Golden SplitMix64 outputs (seed 42) — shared with rust util::rng."""
    rng = corpus.SplitMix64(42)
    got = [rng.next_u64() for _ in range(4)]
    # Independently derivable from the SplitMix64 reference implementation.
    assert got[0] == 13679457532755275413
    assert all(0 <= x < 1 << 64 for x in got)
    rng2 = corpus.SplitMix64(42)
    assert [rng2.next_u64() for _ in range(4)] == got


def test_next_f64_in_unit_interval():
    rng = corpus.SplitMix64(7)
    xs = [rng.next_f64() for _ in range(1000)]
    assert all(0.0 <= x < 1.0 for x in xs)
    assert 0.4 < float(np.mean(xs)) < 0.6


def test_generate_deterministic():
    a = corpus.generate("c4", 256, stream_seed=3)
    b = corpus.generate("c4", 256, stream_seed=3)
    np.testing.assert_array_equal(a, b)
    c = corpus.generate("c4", 256, stream_seed=4)
    assert not np.array_equal(a, c)


def test_profiles_have_distinct_streams():
    streams = {
        name: tuple(corpus.generate(name, 64, stream_seed=1))
        for name in corpus.PROFILES
    }
    assert len(set(streams.values())) == len(streams)


def test_tokens_in_vocab():
    toks = corpus.generate("owt", 2048, stream_seed=9)
    assert toks.min() >= 0 and toks.max() < corpus.VOCAB_SIZE


def _bigram_entropy(tokens):
    """Empirical conditional entropy H(x_t | x_{t-1}) in bits."""
    counts = {}
    for a, b in zip(tokens[:-1], tokens[1:]):
        counts.setdefault(int(a), {}).setdefault(int(b), 0)
        counts[int(a)][int(b)] += 1
    total = sum(sum(s.values()) for s in counts.values())
    h = 0.0
    for succs in counts.values():
        n = sum(succs.values())
        hs = -sum((c / n) * np.log2(c / n) for c in succs.values())
        h += n / total * hs
    return h


def test_entropy_ordering_cnn_lt_c4_lt_owt():
    """The dataset-profile substitution's defining property (DESIGN.md §3)."""
    n = 40_000
    h = {name: _bigram_entropy(corpus.generate(name, n, 2)) for name in corpus.PROFILES}
    assert h["cnn"] < h["c4"] < h["owt"], h


def test_golden_token_prefix():
    """Pin the first tokens of each profile.

    rust/src/data/markov.rs pins the SAME values — cross-language contract.
    """
    golden = {
        "cnn": [347, 288, 427, 355, 419, 295, 425, 461],
        "c4": [347, 382, 0, 393, 42, 50, 163, 75],
        "owt": [501, 164, 89, 167, 247, 181, 509, 456],
    }
    for name, want in golden.items():
        got = [int(t) for t in corpus.generate(name, 8, stream_seed=1)]
        assert got == want, (name, got)


def test_batches_shape():
    bs = list(corpus.batches("cnn", n_batches=3, batch=4, seq=16, stream_seed=5))
    assert len(bs) == 3
    assert bs[0].shape == (4, 17)
