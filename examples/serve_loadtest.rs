//! Serving load test: starts the coordinator + TCP server in-process,
//! replays a Poisson request trace through real client connections using
//! protocol-v1 streaming, and reports throughput, latency percentiles
//! (TTFT is the CLIENT-OBSERVED first chunk arrival) and backpressure
//! counts — the end-to-end driver for the serving layer (DESIGN.md
//! deliverable (b), §Serving API v1 and §Transport).
//!
//!   cargo run --release --example serve_loadtest -- \
//!       [requests] [rate_rps] [workers] [scheduler] \
//!       [--reactor-threads N] [--max-conns N] [--outbox N] \
//!       [--cancel-every N] [--route affinity|rr] [--kill-worker N] \
//!       [--prompt-len-mix short:N,long:M] [--prefill-chunk N]
//!
//! `scheduler` is `fcfs` (default) or `continuous` — the latter runs the
//! step-level batcher (`sched/`), so one worker multiplexes many
//! connections into shared verification dispatches. The transport flags
//! exercise the reactor: every connection is served by a fixed pool of
//! `--reactor-threads` event loops (server threads stay O(pool) however
//! many connections arrive), `--max-conns` bounds admission, `--outbox`
//! bounds per-connection buffering. `--cancel-every N` cancels every Nth
//! request after its first chunk and checks the stream terminates with
//! finish="cancelled" — the streamed + cancelled mix the CI reactor
//! smoke step drives at 64 connections.
//!
//! `workers` > 1 runs the router tier: `--route` picks prefix-affinity
//! (default) or round-robin placement, the post-drain report prints the
//! per-worker routed-request skew (parsed back out of the Prometheus
//! `dyspec_worker_*` series), and under rr a healthy worker that served
//! zero requests fails the run. `--kill-worker N` kills worker N halfway
//! through the trace: its in-flight requests must settle as
//! finish="cancelled" (counted as kill casualties, not failures), its
//! gauges must drain to zero, and the survivors must absorb the rest —
//! the CI routed-conformance step drives this at 4 workers.
//!
//! `--prompt-len-mix short:N,long:M` replays a mixed pool — N 64-token
//! chatter prompts plus M 1024-token cold prompts, interleaved by the
//! trace — and `--prefill-chunk C` turns on chunked prefill
//! (`prefill_chunk=C`, `prefill_budget=C`) so the long prompts enter the
//! continuous batch as C-token rows instead of stalling it; the
//! post-drain check requires `dyspec_prefill_tokens_in_flight` back at
//! zero. Compare:
//!
//!   cargo run --release --example serve_loadtest -- 48 40 1 fcfs
//!   cargo run --release --example serve_loadtest -- 48 40 1 continuous
//!   cargo run --release --example serve_loadtest -- \
//!       32 100 1 continuous --prompt-len-mix short:12,long:4 \
//!       --prefill-chunk 256
//!   cargo run --release --example serve_loadtest -- \
//!       64 400 2 continuous --reactor-threads 4 --cancel-every 4
//!   cargo run --release --example serve_loadtest -- \
//!       64 200 4 fcfs --route affinity --kill-worker 2

use std::sync::Arc;

use dyspec::config::{Config, SchedKind};
use dyspec::coordinator::{Coordinator, GenParams, ModelFactory};
use dyspec::data::prompts::PromptSet;
use dyspec::data::trace::RequestTrace;
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::models::LogitModel;
use dyspec::server::{Client, Server};
use dyspec::util::json::Json;
use dyspec::util::Histogram;

/// Positional args + `--key value` flags, hand-rolled so positionals
/// keep their historical order regardless of flag placement.
fn parse_args() -> (Vec<String>, std::collections::BTreeMap<String, String>) {
    let mut positional = Vec::new();
    let mut flags = std::collections::BTreeMap::new();
    let mut it = std::env::args().skip(1);
    while let Some(arg) = it.next() {
        if let Some(name) = arg.strip_prefix("--") {
            let value = it.next().unwrap_or_else(|| {
                eprintln!("missing value for --{name}");
                std::process::exit(2);
            });
            flags.insert(name.to_string(), value);
        } else {
            positional.push(arg);
        }
    }
    (positional, flags)
}

fn flag<T: std::str::FromStr>(
    flags: &std::collections::BTreeMap<String, String>,
    name: &str,
    default: T,
) -> T {
    match flags.get(name) {
        None => default,
        Some(v) => v.parse().unwrap_or_else(|_| {
            eprintln!("bad value for --{name}: {v}");
            std::process::exit(2);
        }),
    }
}

/// `short:N,long:M` (either key optional, any order).
fn parse_mix(spec: &str) -> Option<(usize, usize)> {
    let (mut short, mut long) = (0usize, 0usize);
    for part in spec.split(',') {
        let (k, v) = part.split_once(':')?;
        match k.trim() {
            "short" => short = v.trim().parse().ok()?,
            "long" => long = v.trim().parse().ok()?,
            _ => return None,
        }
    }
    Some((short, long))
}

/// Value of an unlabelled series in a Prometheus text exposition
/// (`name value`), or -1 when the series is absent.
fn prom_gauge(text: &str, name: &str) -> f64 {
    text.lines()
        .find_map(|l| l.strip_prefix(name)?.strip_prefix(' '))
        .and_then(|v| v.parse().ok())
        .unwrap_or(-1.0)
}

/// What one client thread observed for its request.
enum Outcome {
    /// (e2e seconds, ttft seconds, tokens received)
    Served(f64, f64, usize),
    /// Cancelled or rejected because its worker was killed mid-run —
    /// expected collateral in `--kill-worker` mode, a failure otherwise.
    Casualty,
    Failed,
}

fn main() {
    let (positional, flags) = parse_args();
    let n_requests: usize =
        positional.first().and_then(|s| s.parse().ok()).unwrap_or(48);
    let rate: f64 = positional.get(1).and_then(|s| s.parse().ok()).unwrap_or(40.0);
    let workers: usize = positional.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);
    let scheduler = positional
        .get(3)
        .and_then(|s| SchedKind::parse(s))
        .unwrap_or(SchedKind::Fcfs);
    let reactor_threads: usize = flag(&flags, "reactor-threads", 2);
    let max_conns: usize = flag(&flags, "max-conns", 1024);
    let outbox_frames: usize = flag(&flags, "outbox", 1024);
    // Every Nth request is cancelled after its first chunk (0 = never).
    let cancel_every: usize = flag(&flags, "cancel-every", 0);
    let route = flags
        .get("route")
        .cloned()
        .unwrap_or_else(|| "affinity".to_string());
    // Kill this worker halfway through the trace (absent = never).
    let kill_worker: Option<usize> =
        flags.get("kill-worker").map(|v| match v.parse() {
            Ok(w) => w,
            Err(_) => {
                eprintln!("bad value for --kill-worker: {v}");
                std::process::exit(2);
            }
        });
    let kill_mode = kill_worker.is_some();
    // Mixed prompt pool: "short:N,long:M" => N 64-token + M 1024-token
    // prompts, picked by the trace's prompt index.
    let prompt_mix: Option<(usize, usize)> =
        flags.get("prompt-len-mix").map(|spec| {
            parse_mix(spec).unwrap_or_else(|| {
                eprintln!("bad value for --prompt-len-mix: {spec} (want short:N,long:M)");
                std::process::exit(2);
            })
        });
    let prefill_chunk: usize = flag(&flags, "prefill-chunk", 0);

    let mut cfg = Config::new();
    cfg.server.workers = workers;
    cfg.server.addr = "127.0.0.1:0".into();
    cfg.server.reactor_threads = reactor_threads;
    cfg.server.max_conns = max_conns;
    cfg.server.outbox_frames = outbox_frames;
    cfg.engine.tree_budget = 24;
    cfg.sched.kind = scheduler;
    cfg.sched.max_active = 16;
    cfg.engine.prefill_chunk = prefill_chunk;
    cfg.sched.prefill_budget = prefill_chunk;
    cfg.set("route", &route).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    // Canonical spelling ("rr" however the flag spelled round-robin).
    let route = cfg.route.mode.name().to_string();

    let factory: ModelFactory = Arc::new(|| {
        let spec = SimSpec::for_dataset("c4", 1.2, 77);
        let (d, t) = SimModel::pair(spec);
        (Box::new(d) as Box<dyn LogitModel>, Box::new(t) as Box<dyn LogitModel>)
    });
    let coord = Arc::new(Coordinator::start(cfg.clone(), factory));
    // Keep a handle for the mid-run `--kill-worker` injection.
    let server = Server::bind(&cfg.server.addr, coord.clone()).expect("bind");
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || {
        let _ = server.run();
    });

    let pool: Vec<Vec<u32>> = match prompt_mix {
        None => {
            let set = PromptSet::by_name("c4", 8, 64, 5).unwrap();
            (0..set.len()).map(|i| set.get(i).to_vec()).collect()
        }
        Some((short, long)) => {
            let shorts = PromptSet::by_name("c4", short.max(1), 64, 5).unwrap();
            let longs = PromptSet::by_name("c4", long.max(1), 1024, 6).unwrap();
            (0..shorts.len())
                .map(|i| shorts.get(i).to_vec())
                .chain((0..longs.len()).map(|i| longs.get(i).to_vec()))
                .collect()
        }
    };
    let trace = RequestTrace::poisson(n_requests, rate, pool.len(), 64, 0.6, 9);
    if let Some((short, long)) = prompt_mix {
        println!(
            "prompt mix: {short} short (64 tok) + {long} long (1024 tok), prefill_chunk={prefill_chunk}"
        );
    }
    println!(
        "replaying {} requests at {:.0} rps over {} workers ({} scheduler, {route} routing, {} reactor threads, cancel-every={})  -> {addr}",
        trace.len(),
        rate,
        workers,
        scheduler.name(),
        reactor_threads,
        cancel_every,
    );

    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for (idx, ev) in trace.events.clone().into_iter().enumerate() {
        let addr = addr.clone();
        let prompt: Vec<u32> = pool[ev.prompt_idx % pool.len()].clone();
        let cancel_this = cancel_every > 0 && (idx + 1) % cancel_every == 0;
        handles.push(std::thread::spawn(move || {
            let wait = ev.at_secs - t0.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(wait));
            }
            let sent = std::time::Instant::now();
            let mut client = match Client::connect(&addr) {
                Ok(c) => c,
                Err(_) => return Outcome::Failed,
            };
            let params = GenParams::simple(ev.max_new_tokens, ev.temperature);
            if cancel_this {
                // Streamed + cancelled: first chunk, cancel, then require
                // the terminal frame to carry finish="cancelled". The
                // request is effectively unbounded so the cancel cannot
                // lose a race against natural completion (which would
                // read as a spurious failure).
                let mut run = || -> Option<(f64, f64, usize)> {
                    let params =
                        GenParams::simple(1_000_000, ev.temperature);
                    client.submit(1, &prompt, &params, true).ok()?;
                    let mut tokens = 0usize;
                    let mut cancelled = false;
                    let mut first = None;
                    loop {
                        let frame = client.read_frame().ok()?;
                        match frame.event.as_str() {
                            "chunk" => {
                                if first.is_none() {
                                    first =
                                        Some(sent.elapsed().as_secs_f64());
                                }
                                tokens += frame.tokens().len();
                                if !cancelled {
                                    client.cancel(1).ok()?;
                                    cancelled = true;
                                }
                            }
                            "done" => {
                                let finish = frame
                                    .finish()
                                    .map(|f| f.name())
                                    .unwrap_or("?");
                                if finish != "cancelled" {
                                    eprintln!(
                                        "request {idx}: expected cancelled, got {finish}"
                                    );
                                    return None;
                                }
                                let e2e = sent.elapsed().as_secs_f64();
                                return Some((
                                    e2e,
                                    first.unwrap_or(e2e),
                                    tokens,
                                ));
                            }
                            _ => return None,
                        }
                    }
                };
                return match run() {
                    Some((e2e, first, tokens)) => {
                        Outcome::Served(e2e, first, tokens)
                    }
                    // A cancel stream cut short by a killed worker (error
                    // frame or dropped connection) is kill collateral.
                    None if kill_mode => Outcome::Casualty,
                    None => Outcome::Failed,
                };
            }
            let mut first = None;
            match client.generate_stream(1, &prompt, &params, |_| {
                if first.is_none() {
                    first = Some(sent.elapsed().as_secs_f64());
                }
            }) {
                Ok((tokens, done)) => {
                    if done.finish().map(|f| f.name()) == Some("cancelled") {
                        // Nobody cancels on this path: the worker was
                        // killed with the request in flight.
                        if kill_mode {
                            Outcome::Casualty
                        } else {
                            Outcome::Failed
                        }
                    } else {
                        let e2e = sent.elapsed().as_secs_f64();
                        Outcome::Served(e2e, first.unwrap_or(e2e), tokens.len())
                    }
                }
                // A killed worker rejects queued submissions ("queue
                // closed") and drops in-flight streams; both count as
                // casualties only when a kill was actually injected.
                Err(_) if kill_mode => Outcome::Casualty,
                Err(_) => Outcome::Failed,
            }
        }));
    }

    // Mid-run scrape: the Prometheus surface must answer while the
    // reactor is under load, not only at drain (CI drives this path).
    let midrun_lines = {
        let mut scraper = Client::connect(&addr).expect("metrics conn");
        let text = scraper.metrics().expect("mid-run metrics scrape");
        assert!(
            text.contains("# TYPE dyspec_round_stage_seconds summary"),
            "mid-run exposition missing the stage summary"
        );
        text.lines().count()
    };

    // Worker-death injection: wait until roughly half the trace has been
    // submitted, then kill the target. The router stops placing new
    // requests there, cancels its tracked ones, and the coordinator
    // joins the worker thread before kill_worker returns.
    if let Some(k) = kill_worker {
        let half = trace.events.last().map(|e| e.at_secs / 2.0).unwrap_or(0.0);
        let elapsed = t0.elapsed().as_secs_f64();
        if half > elapsed {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                half - elapsed,
            ));
        }
        assert!(coord.kill_worker(k), "worker {k} was not killable");
        println!("killed worker {k} at t={:.2}s", t0.elapsed().as_secs_f64());
    }

    let mut lat = Histogram::new();
    let mut ttft = Histogram::new();
    let mut total_tokens = 0usize;
    let mut failures = 0usize;
    let mut casualties = 0usize;
    for h in handles {
        match h.join().expect("client thread") {
            Outcome::Served(e2e, first, tokens) => {
                lat.record(e2e);
                ttft.record(first);
                total_tokens += tokens;
            }
            Outcome::Casualty => casualties += 1,
            Outcome::Failed => failures += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "done in {wall:.2}s: {} ok / {failures} failed / {casualties} kill casualties | {:.0} tokens/s | e2e p50 {:.3}s p99 {:.3}s | ttft p50 {:.3}s p99 {:.3}s",
        lat.len(),
        total_tokens as f64 / wall,
        lat.p50(),
        lat.p99(),
        ttft.p50(),
        ttft.p99(),
    );

    let mut client = Client::connect(&addr).expect("stats conn");
    let stats = client.stats().unwrap();
    println!("server metrics: {}", stats.to_string());
    let gauge = |key: &str| {
        stats.get(key).and_then(Json::as_f64).unwrap_or(-1.0)
    };
    println!(
        "transport: {} event-loop threads, {} open conns, {} outbox frames, {} backpressure closes, {} rejected",
        gauge("transport_threads"),
        gauge("open_conns"),
        gauge("outbox_frames"),
        gauge("backpressure_closed"),
        gauge("conns_rejected"),
    );
    if prefill_chunk > 0 {
        println!(
            "chunked prefill: {} chunk rows, {} prompt tokens",
            gauge("prefill_chunks"),
            gauge("prefill_tokens"),
        );
    }
    // Post-drain scrape: the in-flight gauges must return to zero once
    // every request finished and every client connection is gone — the
    // one allowed remainder is this scraper's own connection. Teardown
    // is observed asynchronously by the reactor, so stragglers get a
    // bounded window to be swept before this counts as a failure.
    let mut want: Vec<(String, f64)> = [
        ("dyspec_open_conns", 1.0),
        ("dyspec_outbox_frames", 0.0),
        ("dyspec_tokens_in_flight", 0.0),
        ("dyspec_prefill_tokens_in_flight", 0.0),
        ("dyspec_queue_depth", 0.0),
        ("dyspec_cache_resident_blocks", 0.0),
    ]
    .into_iter()
    .map(|(n, v)| (n.to_string(), v))
    .collect();
    // Every worker's router gauges must also drain — a killed worker's
    // additionally proves cancellation settled each tracked request.
    for w in 0..workers {
        want.push((format!("dyspec_worker_queue_depth{{worker=\"{w}\"}}"), 0.0));
        want.push((format!("dyspec_worker_inflight{{worker=\"{w}\"}}"), 0.0));
    }
    if let Some(k) = kill_worker {
        want.push((format!("dyspec_worker_alive{{worker=\"{k}\"}}"), 0.0));
    }
    let mut undrained: Vec<String> = Vec::new();
    let mut prom = String::new();
    for _ in 0..40 {
        prom = client.metrics().expect("post-drain metrics scrape");
        undrained = want
            .iter()
            .filter(|(name, v)| prom_gauge(&prom, name) != *v)
            .map(|(name, v)| {
                format!("{name} = {} (want {v})", prom_gauge(&prom, name))
            })
            .collect();
        if undrained.is_empty() {
            println!(
                "prometheus exposition: {midrun_lines} lines mid-run, {} lines post-drain, gauges drained",
                prom.lines().count()
            );
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    for line in &undrained {
        eprintln!("gauge not drained: {line}");
    }

    // Per-worker placement skew, read back off the public Prometheus
    // surface exactly as a dashboard would. Under round-robin every
    // healthy worker must have served traffic; affinity is allowed to
    // concentrate (that is the point), so it only reports.
    let series = |name: &str, w: usize| {
        prom_gauge(&prom, &format!("dyspec_worker_{name}{{worker=\"{w}\"}}"))
    };
    let routed: Vec<f64> = (0..workers).map(|w| series("routed_total", w)).collect();
    let alive: Vec<f64> = (0..workers).map(|w| series("alive", w)).collect();
    let spilled: Vec<f64> =
        (0..workers).map(|w| series("spilled_total", w)).collect();
    println!(
        "per-worker routed {routed:?} | spilled {spilled:?} | alive {alive:?} | route={route}"
    );
    let mut starved = 0usize;
    if route == "rr" {
        for w in 0..workers {
            if alive[w] == 1.0 && routed[w] <= 0.0 {
                eprintln!("healthy worker {w} served zero requests under rr");
                starved += 1;
            }
        }
    }

    client.shutdown().expect("shutdown");
    server_thread.join().unwrap();
    if failures > 0 || !undrained.is_empty() || starved > 0 {
        eprintln!(
            "{failures} requests failed, {} gauges undrained, {starved} workers starved",
            undrained.len()
        );
        std::process::exit(1);
    }
}
