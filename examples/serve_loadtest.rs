//! Serving load test: starts the coordinator + TCP server in-process,
//! replays a Poisson request trace through real client connections using
//! protocol-v1 streaming, and reports throughput, latency percentiles
//! (TTFT is the CLIENT-OBSERVED first chunk arrival) and backpressure
//! counts — the end-to-end driver for the serving layer (DESIGN.md
//! deliverable (b) and §Serving API v1).
//!
//!   cargo run --release --example serve_loadtest -- \
//!       [requests] [rate_rps] [workers] [scheduler]
//!
//! `scheduler` is `fcfs` (default) or `continuous` — the latter runs the
//! step-level batcher (`sched/`), so one worker multiplexes many
//! connections into shared verification dispatches. Compare:
//!
//!   cargo run --release --example serve_loadtest -- 48 40 1 fcfs
//!   cargo run --release --example serve_loadtest -- 48 40 1 continuous

use std::sync::Arc;

use dyspec::config::{Config, SchedKind};
use dyspec::coordinator::{Coordinator, GenParams, ModelFactory};
use dyspec::data::prompts::PromptSet;
use dyspec::data::trace::RequestTrace;
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::models::LogitModel;
use dyspec::server::{Client, Server};
use dyspec::util::Histogram;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(48);
    let rate: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(40.0);
    let workers: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);
    let scheduler = args
        .get(3)
        .and_then(|s| SchedKind::parse(s))
        .unwrap_or(SchedKind::Fcfs);

    let mut cfg = Config::new();
    cfg.server.workers = workers;
    cfg.server.addr = "127.0.0.1:0".into();
    cfg.engine.tree_budget = 24;
    cfg.sched.kind = scheduler;
    cfg.sched.max_active = 16;

    let factory: ModelFactory = Arc::new(|| {
        let spec = SimSpec::for_dataset("c4", 1.2, 77);
        let (d, t) = SimModel::pair(spec);
        (Box::new(d) as Box<dyn LogitModel>, Box::new(t) as Box<dyn LogitModel>)
    });
    let coord = Arc::new(Coordinator::start(cfg.clone(), factory));
    let server = Server::bind(&cfg.server.addr, coord).expect("bind");
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || {
        let _ = server.run();
    });

    let prompts = PromptSet::by_name("c4", 8, 64, 5).unwrap();
    let trace = RequestTrace::poisson(n_requests, rate, prompts.len(), 64, 0.6, 9);
    println!(
        "replaying {} requests at {:.0} rps over {} workers ({} scheduler) -> {addr}",
        trace.len(),
        rate,
        workers,
        scheduler.name()
    );

    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for ev in trace.events.clone() {
        let addr = addr.clone();
        let prompt: Vec<u32> = prompts.get(ev.prompt_idx).to_vec();
        handles.push(std::thread::spawn(move || {
            let wait = ev.at_secs - t0.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(wait));
            }
            let sent = std::time::Instant::now();
            let mut client = Client::connect(&addr).ok()?;
            let params =
                GenParams::simple(ev.max_new_tokens, ev.temperature);
            let mut first = None;
            let (tokens, _done) = client
                .generate_stream(1, &prompt, &params, |_| {
                    if first.is_none() {
                        first = Some(sent.elapsed().as_secs_f64());
                    }
                })
                .ok()?;
            let e2e = sent.elapsed().as_secs_f64();
            Some((e2e, first.unwrap_or(e2e), tokens.len()))
        }));
    }

    let mut lat = Histogram::new();
    let mut ttft = Histogram::new();
    let mut total_tokens = 0usize;
    let mut failures = 0usize;
    for h in handles {
        match h.join().expect("client thread") {
            Some((e2e, first, tokens)) => {
                lat.record(e2e);
                ttft.record(first);
                total_tokens += tokens;
            }
            None => failures += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "done in {wall:.2}s: {} ok / {failures} failed | {:.0} tokens/s | e2e p50 {:.3}s p99 {:.3}s | ttft p50 {:.3}s p99 {:.3}s",
        lat.len(),
        total_tokens as f64 / wall,
        lat.p50(),
        lat.p99(),
        ttft.p50(),
        ttft.p99(),
    );

    let mut client = Client::connect(&addr).expect("stats conn");
    println!("server metrics: {}", client.stats().unwrap().to_string());
    client.shutdown().expect("shutdown");
    server_thread.join().unwrap();
}
