//! Quickstart: the end-to-end path a new user runs first.
//!
//! Loads the AOT-compiled draft/target transformers (`make artifacts`),
//! verifies the PJRT wiring against the python golden outputs, then serves
//! one prompt from each dataset profile with DySpec speculative decoding
//! and prints acceptance + latency against the autoregressive baseline.
//!
//!   cargo run --release --example quickstart

use dyspec::config::{EngineConfig, PolicyKind};
use dyspec::data::prompts::PromptSet;
use dyspec::engine::SpecEngine;
use dyspec::models::hlo::HloModel;
use dyspec::models::LogitModel;
use dyspec::runtime::artifacts::{Artifacts, Role};
use dyspec::runtime::PjrtRuntime;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let arts = Artifacts::load("artifacts")
        .map_err(|e| format!("{e} (run `make artifacts` first)"))?;
    let mut rt = PjrtRuntime::cpu()?;
    let seq = arts.seq_small();
    println!("PJRT platform: {} | vocab {} | seq {}", rt.platform(), arts.vocab_size(), seq);

    // The paper's protocol scaled down: 64-token prompt, 48 generated,
    // budget 16 (full-size runs live in the bench harness).
    let prompt_len = 64;
    let max_new = 48;

    for dataset in ["cnn", "c4", "owt"] {
        let prompts = PromptSet::by_name(dataset, 1, prompt_len, 7).unwrap();
        let mut results = Vec::new();
        for policy in [PolicyKind::DySpec, PolicyKind::Baseline] {
            let draft = HloModel::load(&mut rt, &arts, Role::Draft, seq, false)?;
            let target = HloModel::load(&mut rt, &arts, Role::Target, seq, false)?;
            let cfg = EngineConfig {
                policy,
                tree_budget: 16,
                max_new_tokens: max_new,
                target_temp: 0.6,
                seed: 11,
                ..EngineConfig::default()
            };
            let mut engine =
                SpecEngine::new(Box::new(draft), Box::new(target), cfg, None);
            let t = std::time::Instant::now();
            let stats = engine.generate(prompts.get(0));
            results.push((policy, stats, t.elapsed().as_secs_f64()));
        }
        let (_, spec_stats, spec_wall) = &results[0];
        let (_, base_stats, base_wall) = &results[1];
        println!(
            "{dataset:>4}: dyspec {:.2} tok/step, {:.1} tok/s | baseline {:.1} tok/s | speedup {:.2}x",
            spec_stats.mean_emitted_per_step(),
            spec_stats.tokens.len() as f64 / spec_wall,
            base_stats.tokens.len() as f64 / base_wall,
            (base_wall / base_stats.tokens.len() as f64)
                / (spec_wall / spec_stats.tokens.len() as f64),
        );
    }
    println!("\nquickstart OK — see `dyspec bench --experiment table1` for the paper tables");
    Ok(())
}
