//! Streaming quickstart for serving API v1 (DESIGN.md §Serving API v1):
//! starts an in-process server (sim backend, continuous scheduler), then
//! over ONE connection
//!
//!   1. streams a request chunk-by-chunk as speculation rounds land,
//!   2. multiplexes a second request between the first one's frames,
//!   3. cancels a long-running request mid-stream and shows the
//!      finish="cancelled" done frame.
//!
//!   cargo run --release --example streaming

use std::sync::Arc;

use dyspec::config::{Config, SchedKind};
use dyspec::coordinator::{Coordinator, GenParams, ModelFactory};
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::models::LogitModel;
use dyspec::server::{Client, Server};

fn main() {
    let mut cfg = Config::new();
    cfg.server.addr = "127.0.0.1:0".into();
    cfg.sched.kind = SchedKind::Continuous;
    cfg.engine.tree_budget = 16;

    let factory: ModelFactory = Arc::new(|| {
        let spec = SimSpec::for_dataset("c4", 1.2, 77);
        let (d, t) = SimModel::pair(spec);
        (
            Box::new(d) as Box<dyn LogitModel>,
            Box::new(t) as Box<dyn LogitModel>,
        )
    });
    let coord = Arc::new(Coordinator::start(cfg.clone(), factory));
    let server = Server::bind(&cfg.server.addr, coord).expect("bind");
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || {
        let _ = server.run();
    });

    let mut client = Client::connect(&addr).expect("connect");

    // 1. One streamed generation, chunks printed as rounds land.
    println!("--- streamed request (req 1) ---");
    let params = GenParams {
        seed: Some(7),
        ..GenParams::simple(48, 0.6)
    };
    let (tokens, done) = client
        .generate_stream(1, &[3, 1, 4, 1, 5], &params, |frame| {
            println!(
                "  chunk round={} tokens={:?}",
                frame.body.get("round").and_then(|v| v.as_usize()).unwrap_or(0),
                frame.tokens()
            );
        })
        .expect("stream");
    println!(
        "  done: {} tokens, finish={}\n",
        tokens.len(),
        done.finish().map(|f| f.name()).unwrap_or("?")
    );

    // 2. Two requests multiplexed on this one connection.
    println!("--- multiplexed requests (req 2 + 3) ---");
    client
        .submit(2, &[9, 2, 6], &GenParams::simple(24, 0.6), true)
        .unwrap();
    client
        .submit(3, &[5, 3, 5], &GenParams::simple(24, 0.6), true)
        .unwrap();
    let mut done_count = 0;
    while done_count < 2 {
        let frame = client.read_frame().expect("frame");
        match frame.event.as_str() {
            "chunk" => println!(
                "  req {} chunk: {} tokens",
                frame.req_id.unwrap(),
                frame.tokens().len()
            ),
            "done" => {
                println!("  req {} done", frame.req_id.unwrap());
                done_count += 1;
            }
            other => panic!("unexpected event {other}"),
        }
    }

    // 3. Cancel a long request after its second chunk.
    println!("\n--- cancellation (req 4) ---");
    client
        .submit(4, &[1, 2, 3], &GenParams::simple(100_000, 0.6), true)
        .unwrap();
    let mut chunks = 0;
    loop {
        let frame = client.read_frame().expect("frame");
        match frame.event.as_str() {
            "chunk" => {
                chunks += 1;
                if chunks == 2 {
                    println!("  cancelling after chunk 2...");
                    client.cancel(4).unwrap();
                }
            }
            "done" => {
                println!(
                    "  done: finish={} after {} tokens (of 100000 asked)",
                    frame.finish().map(|f| f.name()).unwrap_or("?"),
                    frame
                        .body
                        .get("tokens_total")
                        .and_then(|v| v.as_usize())
                        .unwrap_or(0)
                );
                break;
            }
            other => panic!("unexpected event {other}"),
        }
    }

    client.shutdown().expect("shutdown");
    server_thread.join().unwrap();
    println!("\nstreaming example OK");
}
