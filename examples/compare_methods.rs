//! Compare every draft-tree policy on the same workload — the paper's
//! Table-1 contest in miniature, over the sim backend so it runs in
//! seconds. Prints accepted tokens/step, virtual latency per token in the
//! 7B regime, draft dispatch counts, and tree shapes.
//!
//!   cargo run --release --example compare_methods -- [budget] [temp]

use dyspec::config::{EngineConfig, LatencyRegime, PolicyKind};
use dyspec::data::prompts::PromptSet;
use dyspec::engine::stats::RunAggregate;
use dyspec::engine::SpecEngine;
use dyspec::models::sim::{SimModel, SimSpec};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let budget: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(64);
    let temp: f32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.0);
    let regime = LatencyRegime::pair_7b();
    let prompts = PromptSet::by_name("c4", 6, 128, 3).unwrap();

    println!(
        "policy           tok/step  lat/token   draft_dispatches  mean_tree  (budget {budget}, temp {temp}, 7b regime)"
    );
    for policy in PolicyKind::all() {
        let spec = SimSpec::for_dataset("c4", 1.2, 42);
        let (draft, target) = SimModel::pair(spec);
        let cfg = EngineConfig {
            policy,
            tree_budget: budget,
            target_temp: temp,
            max_new_tokens: 128,
            seed: 5,
            ..EngineConfig::default()
        };
        let mut engine = SpecEngine::new(Box::new(draft), Box::new(target), cfg, Some(regime));
        let mut agg = RunAggregate::default();
        let mut dispatches = 0u64;
        for p in prompts.iter() {
            let stats = engine.generate(p);
            dispatches += stats.total_draft_dispatches();
            agg.add(&stats);
        }
        println!(
            "{:<16} {:>7.2}  {:>9.5}  {:>16}  {:>9.1}",
            policy.name(),
            agg.emitted_per_step(),
            agg.virtual_latency_per_token(),
            dispatches,
            agg.mean_tree_size(),
        );
    }
}
