//! Tree explorer: build draft trees with every policy and inspect their
//! structure — layer widths, depth, estimate distribution, attention-mask
//! block counts under each token order (paper Appendix C).
//!
//!   cargo run --release --example tree_explorer -- [budget] [noise] [threshold]

use dyspec::config::{EngineConfig, PolicyKind};
use dyspec::draft::make_policy;
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::tree::{
    block_count, dfs_order, hpd_order, insertion_order, TokenTree, TreeMask,
};
use dyspec::util::Rng;

fn describe(name: &str, tree: &TokenTree) {
    let widths = tree.layer_widths();
    println!("--- {name}: {} nodes, depth {} ---", tree.size(), tree.depth());
    println!("  layer widths: {widths:?}");
    println!(
        "  Σ estimates (expected accepted bound): {:.3}",
        tree.total_estimate()
    );
    for (label, order) in [
        ("insertion", insertion_order(tree)),
        ("dfs", dfs_order(tree)),
        ("hpd", hpd_order(tree)),
    ] {
        let mask = TreeMask::from_tree(tree, &order);
        println!(
            "  {label:<9} order: {} mask blocks (32x32), {} attend bits",
            block_count(&mask, 32),
            mask.count_ones()
        );
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let budget: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(64);
    let noise: f32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1.2);
    let threshold: f64 = args
        .get(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0 / budget as f64);

    let spec = SimSpec::for_dataset("owt", noise, 42);
    let prefix: Vec<u32> = (0..16).map(|i| (i * 37 + 5) % 512).collect();
    println!(
        "budget={budget} noise={noise} threshold={threshold} (owt profile)\n"
    );

    for policy_kind in [
        PolicyKind::DySpec,
        PolicyKind::DySpecThreshold,
        PolicyKind::Sequoia,
        PolicyKind::SpecInfer,
        PolicyKind::Chain,
    ] {
        let cfg = EngineConfig {
            policy: policy_kind,
            tree_budget: budget,
            threshold,
            max_depth: 48,
            ..EngineConfig::default()
        };
        let (mut draft, _) = SimModel::pair(spec);
        let mut rng = Rng::new(7);
        let policy = make_policy(policy_kind);
        let tree = policy.build(&mut draft, &prefix, &cfg, &mut rng);
        describe(policy_kind.name(), &tree);
        println!();
    }
}
