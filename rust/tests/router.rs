//! Router-tier integration (DESIGN.md §Router Tier): single-worker
//! bit-identity against the bare engine, prefix stickiness across
//! reconnects at four workers, spill accounting under a hot shard,
//! worker-death failover with gauges draining to zero, and the route
//! benchmark's acceptance criterion.

use std::sync::Arc;

use dyspec::bench::experiments::{run_experiment, ExpOpts};
use dyspec::config::Config;
use dyspec::coordinator::{Coordinator, FinishReason, GenEvent, GenParams, ModelFactory};
use dyspec::engine::SpecEngine;
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::models::LogitModel;

const SIM_NOISE: f32 = 1.2;
const SIM_SEED: u64 = 42;

fn factory() -> ModelFactory {
    Arc::new(|| {
        let spec = SimSpec::for_dataset("c4", SIM_NOISE, SIM_SEED);
        let (d, t) = SimModel::pair(spec);
        (
            Box::new(d) as Box<dyn LogitModel>,
            Box::new(t) as Box<dyn LogitModel>,
        )
    })
}

fn cfg(workers: usize) -> Config {
    let mut cfg = Config::new();
    cfg.server.workers = workers;
    cfg.server.queue_capacity = 64;
    cfg.engine.tree_budget = 16;
    cfg
}

/// Tokens for (prompt, seed) served through a one-worker coordinator in
/// the given route mode.
fn coord_tokens(route: &str, prompt: &[u32], seed: u64) -> Vec<u32> {
    let mut c = cfg(1);
    c.set("route", route).unwrap();
    let coord = Coordinator::start(c, factory());
    let params = GenParams {
        seed: Some(seed),
        ..GenParams::simple(48, 0.6)
    };
    let resp = coord
        .try_submit(prompt.to_vec(), params)
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(resp.worker, 0);
    coord.shutdown();
    resp.tokens
}

/// The differential the router refactor is pinned by: at one worker the
/// ring short-circuits before any hashing, so the coordinator must
/// produce the same bytes as the bare fcfs engine — in either route mode.
#[test]
fn single_worker_routing_is_bit_identical_to_the_bare_engine() {
    let prompt: Vec<u32> = (0..32).collect();
    for seed in [1u64, 77, 4096] {
        // Today's pipeline: the engine exactly as the fcfs worker builds
        // it, with the same per-request overrides applied.
        let c = cfg(1);
        let spec = SimSpec::for_dataset("c4", SIM_NOISE, SIM_SEED);
        let (d, t) = SimModel::pair(spec);
        let mut engine = SpecEngine::new(
            Box::new(d),
            Box::new(t),
            c.engine.clone(),
            c.regime,
        )
        .with_cache(&c.cache)
        .with_adapt(&c.adapt);
        engine.cfg.target_temp = 0.6;
        engine.cfg.max_new_tokens = 48;
        engine.reseed(seed);
        let bare = engine.generate(&prompt).tokens;
        assert_eq!(bare.len(), 48);

        let affinity = coord_tokens("affinity", &prompt, seed);
        let rr = coord_tokens("rr", &prompt, seed);
        assert_eq!(
            affinity, bare,
            "affinity @ 1 worker diverged from the bare engine (seed {seed})"
        );
        assert_eq!(
            rr, bare,
            "rr @ 1 worker diverged from the bare engine (seed {seed})"
        );
    }
}

/// Every request sharing a routed prefix lands on the same worker, no
/// matter how many separate submissions ("reconnects") carry it.
#[test]
fn affinity_is_sticky_for_a_prefix_group_across_reconnects() {
    let mut c = cfg(4);
    c.set("route_prefix_len", "16").unwrap();
    let coord = Coordinator::start(c, factory());
    for g in 0..3u32 {
        let prefix: Vec<u32> = (0..16).map(|i| 1000 * (g + 1) + i).collect();
        let expect = coord.router().route(&prefix).unwrap().worker;
        let mut seen = Vec::new();
        for salt in 0..4u32 {
            // Distinct suffix past `route_prefix_len`: a fresh request
            // (new connection, new tail) with the same routed prefix.
            let mut p = prefix.clone();
            p.push(90_000 + salt);
            let resp = coord.generate(p, 8, 0.0).unwrap();
            assert_eq!(resp.tokens.len(), 8);
            seen.push(resp.worker);
        }
        assert!(
            seen.iter().all(|&w| w == expect),
            "group {g} scattered across workers: {seen:?} (owner {expect})"
        );
    }
    coord.shutdown();
}

/// A hot prefix shard past `route_max_depth` spills onto the least-loaded
/// survivors; the spills are counted (globally and on the absorbing
/// shards) and every request still completes.
#[test]
fn hot_shard_spills_to_survivors_and_accounts_for_it() {
    let mut c = cfg(4);
    c.set("route_prefix_len", "8").unwrap();
    c.set("route_max_depth", "1").unwrap();
    let coord = Coordinator::start(c, factory());
    let prefix: Vec<u32> = (0..8).map(|i| 7000 + i).collect();
    let owner = coord.router().route(&prefix).unwrap().worker;
    let handles: Vec<_> = (0..16u32)
        .map(|i| {
            let mut p = prefix.clone();
            p.push(90_000 + i);
            coord.try_submit(p, GenParams::simple(64, 0.6)).unwrap()
        })
        .collect();
    for h in handles {
        assert_eq!(h.wait().unwrap().tokens.len(), 64);
    }
    let spilled = coord.metrics.router_spilled();
    assert!(spilled > 0, "hot shard never spilled at max_depth=1");
    let stats = coord.router().worker_stats();
    assert_eq!(
        stats.iter().map(|w| w.spilled).sum::<u64>(),
        spilled,
        "per-shard spill counts disagree with the global counter"
    );
    assert_eq!(stats.iter().map(|w| w.routed).sum::<u64>(), 16);
    assert!(stats[owner].routed >= 1, "owner served none of its prefix");
    assert_eq!(coord.metrics.completed(), 16);
    coord.shutdown();
}

/// Killing a worker cancels its queued + in-flight requests promptly
/// (each stream still terminates with a `Done`), drains its gauges to
/// zero on the Prometheus surface, and re-owns its prefixes to a
/// survivor that keeps serving them.
#[test]
fn worker_death_fails_over_and_drains_its_gauges() {
    let mut c = cfg(4);
    c.set("route_prefix_len", "8").unwrap();
    let coord = Coordinator::start(c, factory());
    let prefix: Vec<u32> = (0..8).map(|i| 5000 + i).collect();
    let owner = coord.router().route(&prefix).unwrap().worker;
    // One request demonstrably in flight plus two queued behind it, all
    // on the doomed shard (fcfs serves one at a time per worker).
    let handles: Vec<_> = (0..3u32)
        .map(|i| {
            let mut p = prefix.clone();
            p.push(90_000 + i);
            coord
                .try_submit(p, GenParams::simple(10_000, 0.6))
                .unwrap()
        })
        .collect();
    match handles[0].events.recv().unwrap() {
        GenEvent::Chunk { .. } => {}
        GenEvent::Done(_) => panic!("10k-token request finished instantly"),
    }
    assert!(coord.kill_worker(owner));
    assert!(!coord.kill_worker(owner), "second kill must be a no-op");
    for h in handles {
        let resp = h.wait().expect("killed worker dropped a stream");
        assert_eq!(resp.finish, FinishReason::Cancelled);
        assert!(resp.tokens.len() < 10_000);
    }
    // Gauges drained and the death is visible on the scrape surface.
    let stats = &coord.router().worker_stats()[owner];
    assert!(!stats.alive);
    assert_eq!((stats.queued, stats.inflight), (0, 0));
    let prom = coord.prometheus();
    assert!(
        prom.contains(&format!("dyspec_worker_alive{{worker=\"{owner}\"}} 0\n")),
        "dead worker not visible in exposition"
    );
    // The prefix re-owns deterministically to a survivor and still serves.
    let d = coord.router().route(&prefix).unwrap();
    assert_ne!(d.worker, owner);
    let resp = coord.generate(prefix, 8, 0.0).unwrap();
    assert_eq!(resp.tokens.len(), 8);
    assert_eq!(resp.worker, d.worker);
    assert!(coord.metrics.router_failover() >= 1);
    coord.shutdown();
}

/// The BENCH_route acceptance criterion, on a miniature workload:
/// affinity's cache hit rate is no worse than rr's at 4 workers while
/// its prefix locality is strictly higher.
#[test]
fn route_benchmark_meets_the_acceptance_criterion() {
    let opts = ExpOpts {
        prompts: 2,
        max_new_tokens: 16,
        out: None,
        ..ExpOpts::default()
    };
    let tables = run_experiment("route", &opts).unwrap();
    let t = &tables[0];
    assert_eq!(t.rows.len(), 4, "expected 1/4 workers x affinity/rr rows");
    assert_eq!((t.rows[2][0].as_str(), t.rows[2][1].as_str()), ("4", "affinity"));
    assert_eq!((t.rows[3][0].as_str(), t.rows[3][1].as_str()), ("4", "rr"));
    let cell = |r: usize, c: usize| t.rows[r][c].parse::<f64>().unwrap();
    assert!(
        cell(2, 4) + 1e-9 >= cell(3, 4),
        "affinity hit rate {} below rr {} at 4 workers",
        cell(2, 4),
        cell(3, 4)
    );
    assert!(
        cell(2, 6) > cell(3, 6),
        "affinity locality {} not above rr {}",
        cell(2, 6),
        cell(3, 6)
    );
}
