//! Serving API v1 end-to-end (ISSUE 3): streaming equivalence over real
//! TCP, multiplexed connections, cancellation leak-freedom, and the
//! legacy-compat shim. The wire-grammar unit tests live in
//! `server/protocol.rs`; this file drives real sockets.

use std::sync::Arc;
use std::time::{Duration, Instant};

use dyspec::config::{CacheConfig, Config, SchedKind};
use dyspec::coordinator::{Coordinator, GenParams, ModelFactory};
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::models::LogitModel;
use dyspec::server::{Client, Server};
use dyspec::util::json::Json;

fn sim_factory() -> ModelFactory {
    Arc::new(|| {
        let spec = SimSpec::new(64, 2.0, 0.8, 9);
        let (d, t) = SimModel::pair(spec);
        (
            Box::new(d) as Box<dyn LogitModel>,
            Box::new(t) as Box<dyn LogitModel>,
        )
    })
}

fn start_server(
    kind: SchedKind,
    cache: bool,
) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let mut cfg = Config::new();
    cfg.server.workers = 1;
    cfg.engine.tree_budget = 8;
    cfg.sched.kind = kind;
    cfg.sched.max_active = 8;
    cfg.sched.idle_tick_ms = 2;
    cfg.cache = CacheConfig {
        enabled: cache,
        block_tokens: 4,
        max_blocks: 256,
        ..CacheConfig::default()
    };
    let coord = Arc::new(Coordinator::start(cfg, sim_factory()));
    let server = Server::bind("127.0.0.1:0", coord).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        let _ = server.run();
    });
    (addr, handle)
}

fn shutdown(addr: &std::net::SocketAddr, handle: std::thread::JoinHandle<()>) {
    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.shutdown().unwrap();
    handle.join().unwrap();
}

/// Poll the stats surface until `pred` holds (the serving layer retires
/// asynchronously) or the deadline passes.
fn poll_stats<F: Fn(&Json) -> bool>(
    addr: &std::net::SocketAddr,
    pred: F,
) -> Json {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let mut c = Client::connect(&addr.to_string()).unwrap();
        let snap = c.stats().unwrap();
        if pred(&snap) {
            return snap;
        }
        assert!(
            Instant::now() < deadline,
            "stats never converged: {}",
            snap.to_string()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn stat(snap: &Json, key: &str) -> u64 {
    snap.get(key).and_then(Json::as_usize).unwrap_or(0) as u64
}

/// The acceptance criterion: for a fixed seed, the concatenation of
/// `chunk` events is bit-identical to the one-shot `tokens` array — on
/// both schedulers, with the KV cache on and off.
#[test]
fn streamed_chunks_equal_one_shot_tokens_on_both_schedulers() {
    for kind in [SchedKind::Fcfs, SchedKind::Continuous] {
        for cache in [true, false] {
            let (addr, handle) = start_server(kind, cache);
            let mut client = Client::connect(&addr.to_string()).unwrap();
            let params = GenParams {
                seed: Some(4242),
                ..GenParams::simple(24, 0.6)
            };
            let mut chunk_frames = 0usize;
            let (streamed, done) = client
                .generate_stream(1, &[3, 1, 4], &params, |_| {
                    chunk_frames += 1;
                })
                .unwrap();
            assert_eq!(streamed.len(), 24, "{kind} cache={cache}");
            assert!(chunk_frames > 1, "single-chunk stream proves nothing");
            assert!(
                done.tokens().is_empty(),
                "streamed done frame repeats tokens"
            );
            assert_eq!(
                done.body.get("tokens_total").unwrap().as_usize(),
                Some(24)
            );

            let (oneshot, _) = client
                .generate_oneshot(2, &[3, 1, 4], &params)
                .unwrap();
            assert_eq!(
                streamed, oneshot,
                "{kind} cache={cache}: streamed != one-shot"
            );
            shutdown(&addr, handle);
        }
    }
}

/// One connection, many in-flight requests: frames interleave and every
/// request completes independently.
#[test]
fn one_connection_multiplexes_interleaved_streams() {
    let (addr, handle) = start_server(SchedKind::Continuous, true);
    let mut client = Client::connect(&addr.to_string()).unwrap();
    const N: u64 = 4;
    for req_id in 1..=N {
        client
            .submit(
                req_id,
                &[req_id as u32, 2, 3],
                &GenParams::simple(16, 0.6),
                true,
            )
            .unwrap();
    }
    let mut tokens = vec![Vec::new(); N as usize + 1];
    let mut done = 0;
    while done < N {
        let frame = client.read_frame().unwrap();
        let rid = frame.req_id.expect("frame without req_id") as usize;
        assert!(rid >= 1 && rid <= N as usize, "unknown req_id {rid}");
        match frame.event.as_str() {
            "chunk" => tokens[rid].extend(frame.tokens()),
            "done" => done += 1,
            other => panic!("unexpected event {other}"),
        }
    }
    for rid in 1..=N as usize {
        assert_eq!(tokens[rid].len(), 16, "req {rid} incomplete");
    }
    shutdown(&addr, handle);
}

/// Mid-stream cancel: the stream ends with finish="cancelled" carrying
/// only the chunks already emitted, and the scheduler slot + cache
/// residency are released (gauges return to zero while the server idles).
#[test]
fn cancel_mid_stream_releases_slots_and_cache_blocks() {
    let (addr, handle) = start_server(SchedKind::Continuous, true);
    let mut client = Client::connect(&addr.to_string()).unwrap();
    client
        .submit(7, &[1, 2, 3], &GenParams::simple(100_000, 0.6), true)
        .unwrap();
    let mut streamed = 0usize;
    let mut cancelled_at = 0usize;
    loop {
        let frame = client.read_frame().unwrap();
        match frame.event.as_str() {
            "chunk" => {
                streamed += frame.tokens().len();
                if cancelled_at == 0 {
                    client.cancel(7).unwrap();
                    cancelled_at = streamed;
                }
            }
            "done" => {
                assert_eq!(
                    frame.finish().map(|f| f.name()),
                    Some("cancelled")
                );
                assert_eq!(
                    frame.body.get("tokens_total").unwrap().as_usize(),
                    Some(streamed),
                    "done total != streamed chunks"
                );
                assert!(
                    streamed < 100_000,
                    "cancelled stream ran to completion"
                );
                break;
            }
            other => panic!("unexpected event {other}"),
        }
    }
    // Leak-freedom over the stats surface: the cancelled request frees its
    // slot (tokens_in_flight gauge) and its KV residency (block gauge).
    let snap = poll_stats(&addr, |s| {
        stat(s, "cancelled") == 1
            && stat(s, "tokens_in_flight") == 0
            && stat(s, "cache_resident_blocks") == 0
    });
    assert_eq!(stat(&snap, "completed"), 0);
    // The slot is genuinely reusable: a fresh request completes.
    let mut client2 = Client::connect(&addr.to_string()).unwrap();
    let (tokens, _) = client2
        .generate_oneshot(1, &[5, 6], &GenParams::simple(8, 0.6))
        .unwrap();
    assert_eq!(tokens.len(), 8);
    shutdown(&addr, handle);
}

/// A client dropping mid-generate must cancel its in-flight work — the
/// fix for the disconnect satellite: no request runs to completion for a
/// peer that is gone, and the connection thread must not panic.
#[test]
fn disconnect_mid_stream_cancels_in_flight_requests() {
    let (addr, handle) = start_server(SchedKind::Continuous, true);
    {
        let mut client = Client::connect(&addr.to_string()).unwrap();
        client
            .submit(1, &[9, 8, 7], &GenParams::simple(100_000, 0.6), true)
            .unwrap();
        // Wait for generation to actually start...
        let frame = client.read_frame().unwrap();
        assert_eq!(frame.event, "chunk");
        // ...then vanish without a cancel.
        drop(client);
    }
    let snap = poll_stats(&addr, |s| {
        stat(s, "cancelled") == 1
            && stat(s, "tokens_in_flight") == 0
            && stat(s, "cache_resident_blocks") == 0
    });
    assert_eq!(stat(&snap, "completed"), 0);
    // The server is still healthy for new connections.
    let mut client = Client::connect(&addr.to_string()).unwrap();
    let (tokens, _) = client
        .generate_oneshot(1, &[5, 6], &GenParams::simple(8, 0.6))
        .unwrap();
    assert_eq!(tokens.len(), 8);
    shutdown(&addr, handle);
}

/// The disconnect fix covers the LEGACY blocking path too: a v0 client
/// vanishing mid-generate must not leave its request running to
/// completion on the worker.
#[test]
fn disconnect_mid_legacy_generate_cancels_the_request() {
    let (addr, handle) = start_server(SchedKind::Fcfs, true);
    {
        let mut client = Client::connect(&addr.to_string()).unwrap();
        client
            .send_line(r#"{"prompt":[1,2,3],"max_new_tokens":1000000}"#)
            .unwrap();
        // Give the worker a moment to start, then vanish.
        std::thread::sleep(Duration::from_millis(50));
    }
    let snap = poll_stats(&addr, |s| stat(s, "cancelled") == 1);
    assert_eq!(stat(&snap, "completed"), 0);
    // The worker slot is free again.
    let mut client = Client::connect(&addr.to_string()).unwrap();
    let (tokens, _) = client
        .generate_oneshot(1, &[5, 6], &GenParams::simple(8, 0.6))
        .unwrap();
    assert_eq!(tokens.len(), 8);
    shutdown(&addr, handle);
}

/// Cancelling while the request still sits in the admission queue (FCFS,
/// one worker busy) never runs the generation at all.
#[test]
fn cancel_while_queued_skips_generation() {
    let (addr, handle) = start_server(SchedKind::Fcfs, true);
    let mut client = Client::connect(&addr.to_string()).unwrap();
    // Occupy the single worker, then queue a second request and cancel it.
    client
        .submit(1, &[1, 2], &GenParams::simple(600, 0.6), true)
        .unwrap();
    client
        .submit(2, &[3, 4], &GenParams::simple(600, 0.6), false)
        .unwrap();
    client.cancel(2).unwrap();
    client.cancel(1).unwrap();
    let mut finishes = Vec::new();
    let mut cancelled_tokens = None;
    while finishes.len() < 2 {
        let frame = client.read_frame().unwrap();
        if frame.event == "done" {
            if frame.req_id == Some(2) {
                cancelled_tokens =
                    frame.body.get("tokens_total").and_then(Json::as_usize);
            }
            finishes.push(frame.finish().map(|f| f.name()).unwrap());
        }
    }
    assert!(finishes.iter().all(|&f| f == "cancelled"));
    assert_eq!(cancelled_tokens, Some(0), "queued cancel still generated");
    shutdown(&addr, handle);
}

/// Protocol errors over the wire: unknown cancel ids are silently
/// ignored (idempotent fire-and-forget), bad envelopes get terminal
/// error frames, legacy garbage still gets the legacy error object —
/// and none of them poison the connection.
#[test]
fn error_frames_and_legacy_shim_coexist() {
    let (addr, handle) = start_server(SchedKind::Fcfs, true);
    let mut client = Client::connect(&addr.to_string()).unwrap();

    // Unknown cancel target: no reply at all — the very next frame on
    // the connection is the stats snapshot, not a stray error.
    client.cancel(99).unwrap();
    let stats = client.stats().unwrap();
    assert!(stats.get("admitted").is_some());
    assert!(stats.get("req_id").is_none());

    // Enveloped request with an empty prompt: error frame with the id.
    client
        .send_line(r#"{"v":1,"req_id":5,"prompt":[]}"#)
        .unwrap();
    let frame = client.read_frame().unwrap();
    assert_eq!((frame.req_id, frame.event.as_str()), (Some(5), "error"));

    // Wrong-typed field in a v1 envelope: the parse fails, but the error
    // frame still carries the envelope's req_id so that request's stream
    // gets its terminal frame.
    client
        .send_line(r#"{"v":1,"req_id":6,"prompt":[1],"temperature":"warm"}"#)
        .unwrap();
    let frame = client.read_frame().unwrap();
    assert_eq!((frame.req_id, frame.event.as_str()), (Some(6), "error"));

    // Legacy parse error: un-multiplexed error object.
    let reply = client.send_raw("not json at all").unwrap();
    assert!(reply.get("error").is_some());
    assert!(reply.get("req_id").is_none());

    // Duplicate in-flight req_id is rejected without killing the first
    // (the first cannot finish on its own: effectively unbounded).
    client
        .submit(8, &[1, 2], &GenParams::simple(1_000_000, 0.6), true)
        .unwrap();
    client
        .submit(8, &[1, 2], &GenParams::simple(4, 0.6), false)
        .unwrap();
    let mut saw_dup_error = false;
    let mut saw_done = false;
    while !(saw_dup_error && saw_done) {
        let frame = client.read_frame().unwrap();
        match frame.event.as_str() {
            "error" => {
                assert_eq!(frame.req_id, Some(8));
                if !saw_dup_error {
                    saw_dup_error = true;
                    // Now put the original out of its misery.
                    client.cancel(8).unwrap();
                }
            }
            "done" => {
                assert_eq!(frame.req_id, Some(8));
                saw_done = true;
            }
            _ => {}
        }
    }

    // The connection still serves the legacy one-shot after all of that.
    let tokens = client.generate(&[1, 2, 3], 6, 0.6).unwrap();
    assert_eq!(tokens.len(), 6);
    shutdown(&addr, handle);
}

/// Per-request params travel the wire: stop_tokens end the stream with
/// finish="stop", token_budget caps the speculated trees, drafter
/// switches the policy (FCFS honors it per request).
#[test]
fn per_request_params_apply_over_the_wire() {
    let (addr, handle) = start_server(SchedKind::Fcfs, true);
    let mut client = Client::connect(&addr.to_string()).unwrap();

    // Learn the seeded stream, then stop at its third token.
    let params = GenParams {
        seed: Some(77),
        ..GenParams::simple(16, 0.6)
    };
    let (tokens, _) = client.generate_oneshot(1, &[4, 5], &params).unwrap();
    let stop = tokens[2];
    let first_hit = tokens.iter().position(|&t| t == stop).unwrap();
    let stop_params = GenParams {
        stop_tokens: vec![stop],
        ..params.clone()
    };
    let (stopped, done) =
        client.generate_oneshot(2, &[4, 5], &stop_params).unwrap();
    assert_eq!(done.finish().map(|f| f.name()), Some("stop"));
    assert_eq!(stopped, tokens[..first_hit + 1].to_vec());

    // token_budget=1 degrades toward chain-width trees: the request still
    // completes exactly.
    let capped = GenParams {
        token_budget: Some(1),
        drafter: Some(dyspec::config::PolicyKind::Chain),
        ..params.clone()
    };
    let (tokens, done) = client.generate_oneshot(3, &[4, 5], &capped).unwrap();
    assert_eq!(tokens.len(), 16);
    assert_eq!(done.finish().map(|f| f.name()), Some("length"));
    shutdown(&addr, handle);
}
