//! Differential wall for the KV prefix cache (ISSUE 2 tentpole):
//!
//!   1. model level — cached incremental scoring is BIT-IDENTICAL to
//!      from-scratch `score_tree` / `score_forest` for any resident mark;
//!   2. engine level — multi-round generation with the cache on vs off
//!      emits identical token streams for all four drafters, and every
//!      dispatch past a sequence's first round bills strictly fewer
//!      verify positions than uncached scoring would;
//!   3. batcher level — same identity under forest batching, and it
//!      survives evictions forcing re-scoring under a tiny block budget.

use std::sync::{mpsc, Arc};
use std::time::Instant;

use dyspec::config::{CacheConfig, Config, EngineConfig, PolicyKind, SchedKind};
use dyspec::coordinator::{
    CancelToken, GenEvent, GenParams, Metrics, Request,
};
use dyspec::draft::make_policy;
use dyspec::engine::SpecEngine;
use dyspec::models::sim::{Role, SimModel, SimSpec};
use dyspec::models::{ForestItem, LogitModel};
use dyspec::sched::Batcher;
use dyspec::tree::dfs_order;
use dyspec::util::Rng;

const POLICIES: [PolicyKind; 4] = [
    PolicyKind::DySpec,
    PolicyKind::Sequoia,
    PolicyKind::SpecInfer,
    PolicyKind::Chain,
];

fn sim_pair(seed: u64) -> (SimModel, SimModel) {
    SimModel::pair(SimSpec::new(64, 2.0, 1.0, seed))
}

/// 1a. `score_tree_incremental` must return bit-identical rows to
/// `score_tree` for every drafter's tree shape, both roles, and any
/// resident mark — including marks past the prefix (clamped).
#[test]
fn incremental_rows_bit_identical_to_from_scratch() {
    let prefix: Vec<u32> = vec![3, 1, 4, 1, 5, 9, 2, 6];
    for policy_kind in POLICIES {
        let policy = make_policy(policy_kind);
        let cfg = EngineConfig {
            tree_budget: 12,
            ..EngineConfig::default()
        };
        let (mut draft, _) = sim_pair(42);
        let mut rng = Rng::new(7);
        let tree = policy.build(&mut draft, &prefix, &cfg, &mut rng);
        let order = dfs_order(&tree);
        for role in [Role::Draft, Role::Target] {
            let spec = SimSpec::new(64, 2.0, 1.0, 42);
            let mut scratch = SimModel::new(spec, role);
            let mut incremental = SimModel::new(spec, role);
            let want = scratch.score_tree(&prefix, &tree, &order);
            for cached in [0usize, 1, prefix.len() - 1, prefix.len(), 99] {
                let got = incremental
                    .score_tree_incremental(&prefix, cached, &tree, &order);
                assert_eq!(
                    got, want,
                    "{policy_kind}: rows diverge at cached_len {cached}"
                );
            }
        }
    }
}

/// 1b. Forest batching with per-item resident marks must equal per-item
/// from-scratch scoring.
#[test]
fn forest_with_resident_marks_bit_identical() {
    let prefixes: Vec<Vec<u32>> = vec![vec![3, 1, 4], vec![2, 7, 1, 8, 2], vec![9, 9]];
    let cfg = EngineConfig {
        tree_budget: 8,
        ..EngineConfig::default()
    };
    let policy = make_policy(PolicyKind::DySpec);
    let mut trees = Vec::new();
    for (i, p) in prefixes.iter().enumerate() {
        let (mut draft, _) = sim_pair(5);
        let mut rng = Rng::new(i as u64);
        trees.push(policy.build(&mut draft, p, &cfg, &mut rng));
    }
    let orders: Vec<Vec<usize>> = trees.iter().map(dfs_order).collect();

    let (_, mut scratch) = sim_pair(31);
    let want: Vec<Vec<Vec<f32>>> = (0..3)
        .map(|i| scratch.score_tree(&prefixes[i], &trees[i], &orders[i]))
        .collect();

    let (_, mut batched) = sim_pair(31);
    let items: Vec<ForestItem<'_>> = (0..3)
        .map(|i| ForestItem {
            prefix: &prefixes[i],
            cached_len: [0usize, 2, 99][i],
            tree: &trees[i],
            order: &orders[i],
        })
        .collect();
    let got = batched.score_forest(&items);
    assert_eq!(got, want, "forest rows diverge under resident marks");
}

fn engine_run(
    policy: PolicyKind,
    cache: &CacheConfig,
    seed: u64,
) -> dyspec::engine::GenerationStats {
    let (draft, target) = sim_pair(99);
    let cfg = EngineConfig {
        policy,
        tree_budget: 10,
        max_new_tokens: 32,
        target_temp: 0.6,
        draft_temp: 0.6,
        seed,
        ..EngineConfig::default()
    };
    let mut e = SpecEngine::new(Box::new(draft), Box::new(target), cfg, None)
        .with_cache(cache);
    e.generate(&[3, 1, 4, 1, 5])
}

/// 2. Multi-round generation: identical streams cache on vs off for all
/// four drafters, and the ISSUE acceptance criterion — every dispatch
/// past the first bills strictly fewer positions than uncached.
#[test]
fn engine_streams_identical_and_warm_rounds_bill_strictly_less() {
    let on = CacheConfig::default();
    let off = CacheConfig {
        enabled: false,
        ..CacheConfig::default()
    };
    for policy in POLICIES {
        for seed in 0..3u64 {
            let warm = engine_run(policy, &on, seed);
            let cold = engine_run(policy, &off, seed);
            assert_eq!(
                warm.tokens, cold.tokens,
                "{policy} seed {seed}: cache changed the stream"
            );
            assert_eq!(warm.steps.len(), cold.steps.len());
            assert!(warm.steps.len() >= 2, "{policy}: need multiple rounds");
            assert_eq!(warm.steps[0].cached_positions, 0);
            for (k, (w, c)) in
                warm.steps.iter().zip(&cold.steps).enumerate().skip(1)
            {
                assert!(
                    w.billed_positions < c.billed_positions,
                    "{policy} seed {seed} step {k}: warm {} !< cold {}",
                    w.billed_positions,
                    c.billed_positions
                );
                assert!(w.cached_positions > 0);
                assert_eq!(c.cached_positions, 0);
            }
        }
    }
}

fn batcher_tokens(
    policy: PolicyKind,
    cache: CacheConfig,
    n_seqs: u64,
) -> (Vec<Vec<u32>>, u64) {
    let mut cfg = Config::new();
    cfg.engine.policy = policy;
    cfg.engine.tree_budget = 8;
    cfg.engine.seed = 5;
    cfg.sched.kind = SchedKind::Continuous;
    cfg.sched.max_active = 16;
    cfg.sched.global_budget = 8 * n_seqs as usize;
    cfg.cache = cache;
    let (d, t) = sim_pair(17);
    let mut b = Batcher::new(
        0,
        cfg,
        Box::new(d),
        Box::new(t),
        Arc::new(Metrics::new()),
    );
    let rxs: Vec<mpsc::Receiver<GenEvent>> = (0..n_seqs)
        .map(|i| {
            let (tx, rx) = mpsc::channel();
            b.admit(Request {
                id: i + 1,
                prompt: vec![10 + i as u32, 2, 3],
                params: GenParams::simple(20, 0.6),
                submitted_at: Instant::now(),
                cancel: CancelToken::new(),
                events: Box::new(tx),
                trace: 0,
            });
            rx
        })
        .collect();
    while b.active() > 0 {
        b.step();
    }
    let evictions = b.cache().stats().evictions;
    assert_eq!(b.cache().used_blocks(), 0, "blocks leaked after Done");
    let wait_tokens = |rx: &mpsc::Receiver<GenEvent>| loop {
        match rx.recv().expect("request dropped") {
            GenEvent::Done(resp) => return resp.tokens,
            GenEvent::Chunk { .. } => continue,
        }
    };
    (rxs.iter().map(wait_tokens).collect(), evictions)
}

/// 3a. Forest batching: identical streams cache on vs off for every
/// drafter (greedy cross-request allocator AND the fair-split path).
#[test]
fn batched_streams_identical_cache_on_vs_off() {
    for policy in POLICIES {
        let (warm, _) = batcher_tokens(policy, CacheConfig::default(), 3);
        let (cold, _) = batcher_tokens(
            policy,
            CacheConfig {
                enabled: false,
                ..CacheConfig::default()
            },
            3,
        );
        assert_eq!(warm, cold, "{policy}: cache changed batched streams");
    }
}

/// 3b. A tiny block budget forces evictions mid-run (residency drops to
/// zero, sequences re-score from scratch) — streams must still be
/// identical, and the run must actually have evicted.
#[test]
fn eviction_forced_rescoring_keeps_streams_identical() {
    let tiny = CacheConfig {
        enabled: true,
        block_tokens: 4,
        max_blocks: 3, // far below 4 sequences' residency needs
        ..CacheConfig::default()
    };
    let (warm, evictions) = batcher_tokens(PolicyKind::DySpec, tiny, 4);
    assert!(evictions > 0, "budget never forced an eviction");
    let (cold, _) = batcher_tokens(
        PolicyKind::DySpec,
        CacheConfig {
            enabled: false,
            ..CacheConfig::default()
        },
        4,
    );
    assert_eq!(warm, cold, "eviction-forced re-scoring changed streams");
}
