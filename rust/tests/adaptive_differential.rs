//! Differential wall for the online-adaptive policy (ISSUE 7 tentpole):
//! `policy_mode=adaptive` with exactly one registered drafter must be
//! `policy_mode=static` by construction — the controller short-circuits
//! before ever reading the estimator (DESIGN.md §Adaptive Policy), so the
//! same requests driven through coordinators identical except for the
//! mode produce bit-identical event streams — tokens, per-round chunks
//! with their `RoundStats`, step counts and finish reasons — across both
//! schedulers × cache on/off. Both the explicit singleton list and the
//! empty list (which registers the configured policy) are pinned.
//!
//! With two competing drafters the adaptive side must actually adapt:
//! every registered drafter gets explored, requests still complete
//! exactly, and the Prometheus exposition carries the controller's
//! per-drafter estimate series.

use std::sync::Arc;

use dyspec::config::{Config, SchedKind};
use dyspec::coordinator::{
    Coordinator, FinishReason, GenEvent, GenParams, ModelFactory, RoundStats,
};
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::models::LogitModel;

const MAX_NEW: usize = 20;
const SEEDS: [u64; 3] = [2, 5, 11];

fn sim_factory() -> ModelFactory {
    Arc::new(|| {
        let spec = SimSpec::new(64, 2.0, 0.8, 99);
        let (d, t) = SimModel::pair(spec);
        (
            Box::new(d) as Box<dyn LogitModel>,
            Box::new(t) as Box<dyn LogitModel>,
        )
    })
}

/// `adaptive: None` = static mode; `Some(list)` = adaptive mode over the
/// comma-separated drafter list ("" registers the configured policy).
fn cfg(sched: SchedKind, cache: bool, adaptive: Option<&str>) -> Config {
    let mut cfg = Config::new();
    cfg.server.workers = 1; // one worker: request order is deterministic
    cfg.server.queue_capacity = 8;
    cfg.engine.tree_budget = 8;
    cfg.engine.max_new_tokens = MAX_NEW;
    cfg.sched.kind = sched;
    cfg.cache.enabled = cache;
    if let Some(drafters) = adaptive {
        cfg.set("policy_mode", "adaptive").expect("mode key");
        if !drafters.is_empty() {
            cfg.set("adapt_drafters", drafters).expect("drafter key");
        }
    }
    cfg
}

/// Everything a client can observe about one request's stream.
#[derive(Debug, PartialEq)]
struct Stream {
    tokens: Vec<u32>,
    chunks: Vec<(Vec<u32>, RoundStats)>,
    steps: usize,
    finish: FinishReason,
}

/// Drive `SEEDS` requests sequentially (each drained before the next is
/// submitted, so scheduling is identical on every run) and return the
/// observed streams plus the final Prometheus exposition.
fn run(cfg: Config) -> (Vec<Stream>, String) {
    let coord = Coordinator::start(cfg, sim_factory());
    let mut streams = Vec::new();
    for (i, &seed) in SEEDS.iter().enumerate() {
        let params = GenParams {
            max_new_tokens: MAX_NEW,
            temperature: 0.6,
            seed: Some(seed),
            stop_tokens: Vec::new(),
            drafter: None,
            token_budget: None,
        };
        let prompt = vec![3, 1, 4, 1 + i as u32];
        let handle = coord.try_submit(prompt, params).expect("submit");
        let mut chunks = Vec::new();
        let resp = loop {
            match handle.events.recv().expect("worker dropped request") {
                GenEvent::Chunk { tokens, stats } => {
                    chunks.push((tokens, stats))
                }
                GenEvent::Done(resp) => break resp,
            }
        };
        streams.push(Stream {
            tokens: resp.tokens,
            chunks,
            steps: resp.steps,
            finish: resp.finish,
        });
    }
    let prom = coord.prometheus();
    coord.shutdown();
    (streams, prom)
}

/// The tentpole equivalence: adaptive mode with one registered drafter
/// (explicit singleton AND implicit via the empty list) is bit-identical
/// to static mode on both schedulers, cache on and off.
#[test]
fn adaptive_singleton_is_bit_identical_to_static() {
    for sched in [SchedKind::Fcfs, SchedKind::Continuous] {
        for cache in [true, false] {
            let (stat, _) = run(cfg(sched, cache, None));
            for drafters in ["dyspec", ""] {
                let (adap, _) = run(cfg(sched, cache, Some(drafters)));
                assert_eq!(
                    stat, adap,
                    "{sched:?} cache={cache} drafters={drafters:?}: \
                     adaptive singleton diverged from static"
                );
            }
            for s in &stat {
                assert_eq!(s.finish, FinishReason::Length);
                assert_eq!(s.tokens.len(), MAX_NEW);
                let rejoined: Vec<u32> = s
                    .chunks
                    .iter()
                    .flat_map(|(t, _)| t.iter().copied())
                    .collect();
                assert_eq!(rejoined, s.tokens, "chunks do not reassemble");
            }
        }
    }
}

/// With competing drafters the controller explores every cold arm while
/// requests still complete exactly, and `{"cmd":"metrics"}` exposes the
/// per-drafter estimates the selection runs on.
#[test]
fn adaptive_multi_drafter_explores_and_exposes_estimates() {
    for sched in [SchedKind::Fcfs, SchedKind::Continuous] {
        let (streams, prom) = run(cfg(sched, true, Some("dyspec,chain")));
        for s in &streams {
            assert_eq!(s.finish, FinishReason::Length);
            assert_eq!(s.tokens.len(), MAX_NEW, "{sched:?}: short stream");
        }
        for series in [
            "# TYPE dyspec_adaptive_drafter_estimate gauge",
            "dyspec_adaptive_drafter_estimate{drafter=\"dyspec\"}",
            "dyspec_adaptive_drafter_estimate{drafter=\"chain\"}",
            "dyspec_adaptive_drafter_samples_total{drafter=\"dyspec\"}",
            "dyspec_adaptive_drafter_samples_total{drafter=\"chain\"}",
        ] {
            assert!(
                prom.contains(series),
                "{sched:?}: exposition missing {series}\n{prom}"
            );
        }
    }
}
