//! Differential wall for the observability layer (ISSUE 6 tentpole):
//! tracing must be provably free when disabled and invisible when
//! enabled. The same requests, driven through coordinators identical
//! except for `trace = on|off`, must produce bit-identical event
//! streams — tokens, per-round chunks with their `RoundStats`, step
//! counts and finish reasons — across both schedulers × cache on/off.
//!
//! The traced side additionally has to actually observe: spans recorded
//! for every round, each carrying the admission-minted trace id, with
//! nothing dropped; the untraced side records no spans but still feeds
//! the always-on stage/acceptance counters and renders a Prometheus
//! exposition.

use std::sync::Arc;

use dyspec::config::{Config, SchedKind};
use dyspec::coordinator::{
    Coordinator, FinishReason, GenEvent, GenParams, ModelFactory, RoundStats,
};
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::models::LogitModel;
use dyspec::util::json::Json;

const MAX_NEW: usize = 20;
const SEEDS: [u64; 3] = [2, 5, 11];

fn sim_factory() -> ModelFactory {
    Arc::new(|| {
        let spec = SimSpec::new(64, 2.0, 0.8, 99);
        let (d, t) = SimModel::pair(spec);
        (
            Box::new(d) as Box<dyn LogitModel>,
            Box::new(t) as Box<dyn LogitModel>,
        )
    })
}

fn cfg(sched: SchedKind, cache: bool, trace: bool) -> Config {
    let mut cfg = Config::new();
    cfg.server.workers = 1; // one worker: request order is deterministic
    cfg.server.queue_capacity = 8;
    cfg.engine.tree_budget = 8;
    cfg.engine.max_new_tokens = MAX_NEW;
    cfg.sched.kind = sched;
    cfg.cache.enabled = cache;
    cfg.obs.trace = trace;
    cfg
}

/// Everything a client can observe about one request's stream.
#[derive(Debug, PartialEq)]
struct Stream {
    tokens: Vec<u32>,
    chunks: Vec<(Vec<u32>, RoundStats)>,
    steps: usize,
    finish: FinishReason,
}

/// Drive `SEEDS` requests sequentially (each drained before the next is
/// submitted, so scheduling is identical on every run) and return the
/// observed streams plus the coordinator's trace dump and exposition.
fn run(cfg: Config) -> (Vec<Stream>, Json, String) {
    let coord = Coordinator::start(cfg, sim_factory());
    let mut streams = Vec::new();
    for (i, &seed) in SEEDS.iter().enumerate() {
        let params = GenParams {
            max_new_tokens: MAX_NEW,
            temperature: 0.6,
            seed: Some(seed),
            stop_tokens: Vec::new(),
            drafter: None,
            token_budget: None,
        };
        let prompt = vec![3, 1, 4, 1 + i as u32];
        let handle = coord.try_submit(prompt, params).expect("submit");
        let mut chunks = Vec::new();
        let resp = loop {
            match handle.events.recv().expect("worker dropped request") {
                GenEvent::Chunk { tokens, stats } => {
                    chunks.push((tokens, stats))
                }
                GenEvent::Done(resp) => break resp,
            }
        };
        streams.push(Stream {
            tokens: resp.tokens,
            chunks,
            steps: resp.steps,
            finish: resp.finish,
        });
    }
    let dump = coord.trace_json();
    let prom = coord.prometheus();
    coord.shutdown();
    (streams, dump, prom)
}

fn spans(dump: &Json) -> &[Json] {
    dump.get("spans").and_then(Json::as_arr).unwrap_or(&[])
}

/// The tentpole property: the client-visible stream is bit-identical
/// with tracing on and off, for both schedulers, with the cache on and
/// off — observability is provably free where it claims to be.
#[test]
fn streams_are_bit_identical_with_tracing_on_and_off() {
    for sched in [SchedKind::Fcfs, SchedKind::Continuous] {
        for cache in [true, false] {
            let (off, off_dump, _) = run(cfg(sched, cache, false));
            let (on, on_dump, _) = run(cfg(sched, cache, true));
            assert_eq!(
                off, on,
                "{sched:?} cache={cache}: tracing changed the stream"
            );
            for s in &off {
                assert_eq!(s.finish, FinishReason::Length);
                assert_eq!(s.tokens.len(), MAX_NEW);
                let rejoined: Vec<u32> = s
                    .chunks
                    .iter()
                    .flat_map(|(t, _)| t.iter().copied())
                    .collect();
                assert_eq!(rejoined, s.tokens, "chunks do not reassemble");
            }
            // Off: the recorder stays empty. On: one span per
            // (round, stage), every one tagged with a minted trace id.
            assert!(spans(&off_dump).is_empty(), "untraced run kept spans");
            let on_spans = spans(&on_dump);
            let rounds: usize = on.iter().map(|s| s.chunks.len()).sum();
            assert_eq!(
                on_spans.len(),
                rounds * 5,
                "{sched:?} cache={cache}: expected 5 spans per round"
            );
            for span in on_spans {
                let trace =
                    span.get("trace").and_then(Json::as_str).unwrap_or("");
                assert_eq!(trace.len(), 16, "span missing its trace id");
                assert_ne!(trace, "0000000000000000");
            }
            assert_eq!(
                on_dump.get("dropped").and_then(Json::as_f64),
                Some(0.0),
                "flight recorder overflowed in a tiny run"
            );
        }
    }
}

/// Counters are always-on (tracing only gates spans): both runs render
/// a Prometheus exposition with populated stage and acceptance series,
/// and the gauges drain to zero once the coordinator is idle.
#[test]
fn exposition_is_populated_with_tracing_off() {
    let (_, dump, prom) = run(cfg(SchedKind::Continuous, true, false));
    assert!(spans(&dump).is_empty());
    for series in [
        "# TYPE dyspec_round_stage_seconds summary",
        "dyspec_round_stage_seconds{stage=\"draft\",quantile=\"0.5\"}",
        "dyspec_round_stage_seconds_count{stage=\"commit\"}",
        "dyspec_accept_depth_proposed_total{drafter=\"dyspec\"",
        "dyspec_accept_prob_proposed_total{drafter=\"dyspec\"",
        "# TYPE dyspec_total_tokens gauge",
        // Radix prefix-cache series render (zero-valued here: the run
        // keeps `cache.radix` at its off default).
        "# TYPE dyspec_radix_lookups gauge",
        "# TYPE dyspec_radix_hits gauge",
        "# TYPE dyspec_radix_hit_rate gauge",
        "# TYPE dyspec_radix_warm_tokens gauge",
        "# TYPE dyspec_radix_nodes gauge",
        "# TYPE dyspec_radix_depth gauge",
        "# TYPE dyspec_radix_shared_blocks gauge",
    ] {
        assert!(prom.contains(series), "exposition missing: {series}\n{prom}");
    }
    // Every sequence finished before shutdown: in-flight gauges are back
    // to zero in the same exposition a scraper would see post-drain.
    for line in ["dyspec_tokens_in_flight 0\n", "dyspec_queue_depth 0\n"] {
        assert!(prom.contains(line), "gauge not drained: {line}\n{prom}");
    }
}
