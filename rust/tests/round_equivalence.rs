//! Differential wall for the unified round pipeline (ISSUE 5 tentpole):
//! FCFS and continuous-with-one-slot are the SAME implementation behind
//! two admission policies, so driving one request through
//! `SpecEngine::generate_streamed` and through a one-slot `Batcher` must
//! produce bit-identical token streams, per-round `RoundStats`, and
//! billed positions — across seeds × drafters × cache on/off.
//!
//! The two front ends seed their per-request sampling streams differently
//! (the engine's `reseed`, the batcher's per-sequence derivation), so the
//! test aligns them by construction: it solves for the batcher engine
//! seed that makes the sequence rng equal the FCFS engine rng for a given
//! request seed. The constants below mirror `engine::SpecEngine::reseed`
//! and `sched::sequence::Sequence::new` / `sched::batcher::Batcher::new`;
//! if either seeding scheme changes, the stream-identity assertions fail
//! loudly and this mirror must be updated with it.

use std::sync::{mpsc, Arc};
use std::time::Instant;

use dyspec::config::{
    CacheConfig, Config, EngineConfig, PolicyKind, SchedKind,
};
use dyspec::coordinator::{
    CancelToken, FinishReason, GenEvent, GenParams, Metrics, Request,
    RoundStats,
};
use dyspec::engine::SpecEngine;
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::sched::Batcher;

const POLICIES: [PolicyKind; 6] = [
    PolicyKind::DySpec,
    PolicyKind::DySpecThreshold,
    PolicyKind::Sequoia,
    PolicyKind::SpecInfer,
    PolicyKind::Chain,
    PolicyKind::Baseline,
];

const VOCAB: usize = 64;
const PROMPT: [u32; 3] = [3, 1, 4];
const MAX_NEW: usize = 24;
const TREE_BUDGET: usize = 8;
const TEMP: f32 = 0.6;

/// `SpecEngine::new`/`reseed` salt.
const ENGINE_SALT: u64 = 0x0DD5_9EC0_0000_0001;
/// `Batcher::new` seed salt.
const BATCHER_SALT: u64 = 0x5EED_BA7C_0000_0001;
/// `Sequence::new` explicit-seed mixer.
const SEED_MIX: u64 = 0x9E37_79B9_7F4A_7C15;

/// The batcher engine seed that gives a request carrying `req_seed` the
/// SAME sampling stream the FCFS engine uses after `reseed(req_seed)`:
///   engine rng   = Rng::new(req_seed ^ ENGINE_SALT)
///   sequence rng = Rng::new((engine_seed ^ BATCHER_SALT)
///                           ^ req_seed.wrapping_mul(SEED_MIX))
fn batcher_engine_seed(req_seed: u64) -> u64 {
    BATCHER_SALT
        ^ req_seed.wrapping_mul(SEED_MIX)
        ^ req_seed
        ^ ENGINE_SALT
}

fn sim_pair(seed: u64) -> (SimModel, SimModel) {
    SimModel::pair(SimSpec::new(VOCAB, 2.0, 1.0, seed))
}

/// One request's observable round/stream trace, identical fields on both
/// front ends.
#[derive(Debug, PartialEq)]
struct Trace {
    tokens: Vec<u32>,
    chunks: Vec<(Vec<u32>, RoundStats)>,
    finish: FinishReason,
}

fn fcfs_trace(policy: PolicyKind, cache: &CacheConfig, seed: u64) -> Trace {
    let (draft, target) = sim_pair(99);
    let cfg = EngineConfig {
        policy,
        tree_budget: TREE_BUDGET,
        max_new_tokens: MAX_NEW,
        target_temp: TEMP,
        draft_temp: 0.6,
        ..EngineConfig::default()
    };
    let mut e = SpecEngine::new(Box::new(draft), Box::new(target), cfg, None)
        .with_cache(cache);
    e.reseed(seed);
    let mut chunks = Vec::new();
    let (stats, finish) = e.generate_streamed(&PROMPT, None, |ev| {
        if let GenEvent::Chunk { tokens, stats } = ev {
            chunks.push((tokens, stats));
        }
    });
    assert_eq!(e.cache().used_blocks(), 0, "fcfs leaked residency");
    Trace {
        tokens: stats.tokens,
        chunks,
        finish,
    }
}

fn continuous_trace(
    policy: PolicyKind,
    cache: &CacheConfig,
    seed: u64,
) -> Trace {
    let (draft, target) = sim_pair(99);
    let mut cfg = Config::new();
    cfg.engine.policy = policy;
    cfg.engine.tree_budget = TREE_BUDGET;
    cfg.engine.seed = batcher_engine_seed(seed);
    cfg.sched.kind = SchedKind::Continuous;
    cfg.sched.max_active = 1;
    cfg.sched.global_budget = 0; // inherit tree_budget, exactly like FCFS
    cfg.cache = cache.clone();
    let mut b = Batcher::new(
        0,
        cfg,
        Box::new(draft),
        Box::new(target),
        Arc::new(Metrics::new()),
    );
    let (tx, rx) = mpsc::channel();
    b.admit(Request {
        id: 4242,
        prompt: PROMPT.to_vec(),
        params: GenParams {
            max_new_tokens: MAX_NEW,
            temperature: TEMP,
            seed: Some(seed),
            stop_tokens: Vec::new(),
            // Exercise the per-request override path too (homogeneous
            // batch of one): must resolve to the same policy.
            drafter: Some(policy),
            token_budget: None,
        },
        submitted_at: Instant::now(),
        cancel: CancelToken::new(),
        events: Box::new(tx),
        trace: 0,
    });
    while b.active() > 0 {
        b.step();
    }
    assert_eq!(b.cache().used_blocks(), 0, "batcher leaked residency");
    let mut chunks = Vec::new();
    loop {
        match rx.recv().expect("request dropped") {
            GenEvent::Chunk { tokens, stats } => chunks.push((tokens, stats)),
            GenEvent::Done(resp) => {
                return Trace {
                    tokens: resp.tokens,
                    chunks,
                    finish: resp.finish,
                };
            }
        }
    }
}

/// The tentpole property: identical token streams, round stats, and
/// billed/cached positions on both front ends, for every drafter, with
/// the KV cache on and off, across seeds.
#[test]
fn fcfs_equals_continuous_with_one_slot() {
    let on = CacheConfig::default();
    let off = CacheConfig {
        enabled: false,
        ..CacheConfig::default()
    };
    for policy in POLICIES {
        for cache in [&on, &off] {
            for seed in 0..4u64 {
                let f = fcfs_trace(policy, cache, seed);
                let c = continuous_trace(policy, cache, seed);
                assert_eq!(
                    f.tokens, c.tokens,
                    "{policy} seed {seed} cache={}: token streams diverged",
                    cache.enabled
                );
                assert_eq!(
                    f.chunks.len(),
                    c.chunks.len(),
                    "{policy} seed {seed}: round counts diverged"
                );
                for (k, (fc, cc)) in
                    f.chunks.iter().zip(&c.chunks).enumerate()
                {
                    assert_eq!(
                        fc, cc,
                        "{policy} seed {seed} cache={} round {k}: \
                         chunk/RoundStats diverged",
                        cache.enabled
                    );
                }
                assert_eq!(f.finish, c.finish);
                assert_eq!(f.finish, FinishReason::Length);
                assert_eq!(f.tokens.len(), MAX_NEW);
                // Chunks reassemble the stream exactly.
                let rejoined: Vec<u32> = f
                    .chunks
                    .iter()
                    .flat_map(|(t, _)| t.iter().copied())
                    .collect();
                assert_eq!(rejoined, f.tokens);
            }
        }
    }
}

/// Warm rounds bill strictly fewer positions than cold ones on BOTH front
/// ends, and the per-round bills agree pairwise — the cache residency
/// protocol lives inside the shared pipeline, not in either caller.
#[test]
fn billed_positions_agree_and_shrink_with_residency() {
    let on = CacheConfig::default();
    let f = fcfs_trace(PolicyKind::DySpec, &on, 7);
    let c = continuous_trace(PolicyKind::DySpec, &on, 7);
    assert!(f.chunks.len() >= 2, "need multiple rounds");
    for ((_, fs), (_, cs)) in f.chunks.iter().zip(&c.chunks) {
        assert_eq!(fs.billed_positions, cs.billed_positions);
        assert_eq!(fs.cached_positions, cs.cached_positions);
    }
    assert_eq!(f.chunks[0].1.cached_positions, 0, "cold start cannot hit");
    for (_, s) in &f.chunks[1..] {
        assert!(s.cached_positions > 0, "no residency after round 1");
    }
}

/// Per-request `token_budget` caps the speculated tree identically on
/// both front ends (FCFS clamps the engine budget; the batcher clamps the
/// per-sequence cap inside the allocator — one pipeline, one result).
#[test]
fn token_budget_cap_is_scheduler_independent() {
    let cache = CacheConfig::default();
    let seed = 3u64;

    // FCFS front end, the way the worker applies the cap
    // (coordinator/worker.rs: tree_budget = min(tree_budget, cap)).
    let f = {
        let (draft, target) = sim_pair(99);
        let cfg = EngineConfig {
            policy: PolicyKind::DySpec,
            tree_budget: TREE_BUDGET.min(2),
            max_new_tokens: MAX_NEW,
            target_temp: TEMP,
            draft_temp: 0.6,
            ..EngineConfig::default()
        };
        let mut e =
            SpecEngine::new(Box::new(draft), Box::new(target), cfg, None)
                .with_cache(&cache);
        e.reseed(seed);
        let mut chunks = Vec::new();
        let (stats, _) = e.generate_streamed(&PROMPT, None, |ev| {
            if let GenEvent::Chunk { tokens, stats } = ev {
                chunks.push((tokens, stats));
            }
        });
        (stats.tokens, chunks)
    };

    // Continuous front end: same cap via the per-request token_budget.
    let c = {
        let (draft, target) = sim_pair(99);
        let mut cfg = Config::new();
        cfg.engine.policy = PolicyKind::DySpec;
        cfg.engine.tree_budget = TREE_BUDGET;
        cfg.engine.seed = batcher_engine_seed(seed);
        cfg.sched.kind = SchedKind::Continuous;
        cfg.sched.max_active = 1;
        // The shared budget must not out-offer the request's own cap for
        // the comparison to be exact: a one-slot batcher offers
        // max(global, 1) and the cap clamps the tree.
        cfg.sched.global_budget = 2;
        cfg.cache = cache.clone();
        let mut b = Batcher::new(
            0,
            cfg,
            Box::new(draft),
            Box::new(target),
            Arc::new(Metrics::new()),
        );
        let (tx, rx) = mpsc::channel();
        b.admit(Request {
            id: 7,
            prompt: PROMPT.to_vec(),
            params: GenParams {
                max_new_tokens: MAX_NEW,
                temperature: TEMP,
                seed: Some(seed),
                stop_tokens: Vec::new(),
                drafter: None,
                token_budget: Some(2),
            },
            submitted_at: Instant::now(),
            cancel: CancelToken::new(),
            events: Box::new(tx),
            trace: 0,
        });
        while b.active() > 0 {
            b.step();
        }
        let mut chunks = Vec::new();
        loop {
            match rx.recv().expect("request dropped") {
                GenEvent::Chunk { tokens, stats } => {
                    chunks.push((tokens, stats))
                }
                GenEvent::Done(resp) => break (resp.tokens, chunks),
            }
        }
    };

    assert_eq!(f.0, c.0, "token streams diverged under token_budget cap");
    assert_eq!(f.1, c.1, "round stats diverged under token_budget cap");
    for (_, s) in &f.1 {
        assert!(s.tree_size <= 2, "cap exceeded: {}", s.tree_size);
    }
}

/// The engine now applies the batcher's Drain rule (the final round with
/// one token remaining takes a bare verification row), which means a
/// 1-token generation samples straight from the target. Guard the
/// unbiasedness of a REAL first-layer tree the way
/// `rust/tests/unbiasedness.rs` does, but with `max_new_tokens = 2` so
/// the first token still comes from a speculated tree: its distribution
/// must match target-only decoding.
#[test]
fn first_token_from_a_real_tree_remains_unbiased() {
    const HIST_VOCAB: usize = 16;
    const RUNS: usize = 3000;
    let hist = |policy: PolicyKind, salt: u64| -> Vec<f64> {
        let mut counts = vec![0usize; HIST_VOCAB];
        for seed in 0..RUNS as u64 {
            let spec = SimSpec::new(HIST_VOCAB, 2.0, 1.0, 99);
            let (draft, target) = SimModel::pair(spec);
            let cfg = EngineConfig {
                policy,
                tree_budget: 6,
                max_new_tokens: 2, // round 1 sees remaining=2: a real tree
                target_temp: 0.6,
                draft_temp: 0.6,
                seed: seed ^ salt,
                max_depth: 4,
                ..EngineConfig::default()
            };
            let mut e = SpecEngine::new(
                Box::new(draft),
                Box::new(target),
                cfg,
                None,
            );
            counts[e.generate(&[3, 1, 4]).tokens[0] as usize] += 1;
        }
        counts.iter().map(|&c| c as f64 / RUNS as f64).collect()
    };
    let tv = |p: &[f64], q: &[f64]| -> f64 {
        0.5 * p.iter().zip(q).map(|(a, b)| (a - b).abs()).sum::<f64>()
    };
    let reference = hist(PolicyKind::Baseline, 7777);
    let floor = tv(&reference, &hist(PolicyKind::Baseline, 1234));
    for policy in [PolicyKind::DySpec, PolicyKind::Chain] {
        let d = tv(&reference, &hist(policy, 0));
        assert!(
            d < (3.0 * floor).max(0.06),
            "{policy}: first-token TV {d:.4} vs floor {floor:.4} — \
             BIASED OUTPUT FROM A REAL TREE"
        );
    }
}
