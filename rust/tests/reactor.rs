//! Reactor transport end-to-end (ISSUE 4): a fixed pool of event-loop
//! threads serves many concurrent connections with no per-connection
//! threads, admission control refuses connections over `max_conns`,
//! outbox backpressure disconnects clients that stop draining, and mass
//! disconnects leak nothing (slots, KV residency, outbox frames, open
//! connections all return to zero). The decoder's byte-boundary
//! invariants are unit-tested in `server/protocol.rs`; this file drives
//! real sockets.

use std::io::Write;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use dyspec::config::{CacheConfig, Config, SchedKind};
use dyspec::coordinator::{Coordinator, GenParams, ModelFactory};
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::models::LogitModel;
use dyspec::server::{Client, Server};
use dyspec::util::json::Json;

fn sim_factory() -> ModelFactory {
    Arc::new(|| {
        let spec = SimSpec::new(64, 2.0, 0.8, 9);
        let (d, t) = SimModel::pair(spec);
        (
            Box::new(d) as Box<dyn LogitModel>,
            Box::new(t) as Box<dyn LogitModel>,
        )
    })
}

struct ServerOpts {
    workers: usize,
    reactor_threads: usize,
    max_conns: usize,
    outbox_frames: usize,
}

impl Default for ServerOpts {
    fn default() -> Self {
        Self {
            workers: 2,
            reactor_threads: 4,
            max_conns: 1024,
            outbox_frames: 1024,
        }
    }
}

fn start_server(
    opts: ServerOpts,
) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let mut cfg = Config::new();
    cfg.server.workers = opts.workers;
    cfg.server.reactor_threads = opts.reactor_threads;
    cfg.server.max_conns = opts.max_conns;
    cfg.server.outbox_frames = opts.outbox_frames;
    cfg.engine.tree_budget = 8;
    cfg.sched.kind = SchedKind::Continuous;
    cfg.sched.max_active = 64;
    cfg.sched.idle_tick_ms = 2;
    cfg.cache = CacheConfig {
        enabled: true,
        block_tokens: 4,
        max_blocks: 4096,
        ..CacheConfig::default()
    };
    let coord = Arc::new(Coordinator::start(cfg, sim_factory()));
    let server = Server::bind("127.0.0.1:0", coord).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        let _ = server.run();
    });
    (addr, handle)
}

fn shutdown(addr: &std::net::SocketAddr, handle: std::thread::JoinHandle<()>) {
    let deadline = Instant::now() + Duration::from_secs(10);
    // Admission control may briefly refuse the shutdown connection after
    // a mass disconnect; retry until the slot frees.
    loop {
        let mut c = Client::connect(&addr.to_string()).unwrap();
        if c.shutdown().is_ok() {
            break;
        }
        assert!(Instant::now() < deadline, "shutdown never admitted");
        std::thread::sleep(Duration::from_millis(20));
    }
    handle.join().unwrap();
}

/// Poll the stats surface until `pred` holds (the serving layer retires
/// asynchronously) or the deadline passes. Transient failures — e.g.
/// the polling connection itself refused while `max_conns` slots drain
/// — are retried, not fatal.
fn poll_stats<F: Fn(&Json) -> bool>(
    addr: &std::net::SocketAddr,
    secs: u64,
    pred: F,
) -> Json {
    let deadline = Instant::now() + Duration::from_secs(secs);
    let mut last = String::from("(no snapshot yet)");
    loop {
        if let Ok(mut c) = Client::connect(&addr.to_string()) {
            if let Ok(snap) = c.stats() {
                if pred(&snap) {
                    return snap;
                }
                last = snap.to_string();
            }
        }
        assert!(
            Instant::now() < deadline,
            "stats never converged: {last}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn stat(snap: &Json, key: &str) -> u64 {
    snap.get(key).and_then(Json::as_usize).unwrap_or(0) as u64
}

/// The soak acceptance criterion: 64 concurrent streamed requests, one
/// per connection, complete over a 4-thread reactor pool — the server
/// reports exactly 4 transport threads while all 64 connections are
/// open (threads are O(pool), not O(connections)) — and once the
/// clients disconnect every transport/scheduler/cache gauge returns to
/// zero.
#[test]
fn soak_64_connections_over_a_4_thread_pool() {
    const CONNS: usize = 64;
    const TOKENS: usize = 24;
    let (addr, handle) = start_server(ServerOpts::default());

    let barrier = Arc::new(Barrier::new(CONNS + 1));
    let clients: Vec<_> = (0..CONNS)
        .map(|k| {
            let addr = addr.to_string();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                // Hold the connection open until every peer connected.
                barrier.wait();
                let params = GenParams {
                    seed: Some(k as u64),
                    ..GenParams::simple(TOKENS, 0.6)
                };
                let mut chunks = 0usize;
                let (tokens, done) = client
                    .generate_stream(1, &[k as u32 + 1, 2, 3], &params, |_| {
                        chunks += 1;
                    })
                    .unwrap();
                assert_eq!(done.finish().map(|f| f.name()), Some("length"));
                assert!(chunks >= 1);
                tokens.len()
            })
        })
        .collect();

    // All 64 connections are open and idle: the transport still runs on
    // exactly 4 event-loop threads (the stats connection is the +1).
    let snap = poll_stats(&addr, 10, |s| stat(s, "open_conns") >= CONNS as u64);
    assert_eq!(stat(&snap, "transport_threads"), 4);
    barrier.wait();

    let mut total = 0usize;
    for c in clients {
        total += c.join().expect("client thread");
    }
    assert_eq!(total, CONNS * TOKENS, "not every stream completed");

    // Leak-freedom after mass completion + disconnect, over the stats
    // surface: connections, outbox frames, scheduler slots and KV
    // residency all drain to zero; every request completed.
    let snap = poll_stats(&addr, 10, |s| {
        stat(s, "open_conns") <= 1 // the polling connection itself
            && stat(s, "outbox_frames") == 0
            && stat(s, "tokens_in_flight") == 0
            && stat(s, "cache_resident_blocks") == 0
    });
    assert_eq!(stat(&snap, "completed"), CONNS as u64);
    assert_eq!(stat(&snap, "cancelled"), 0);
    assert_eq!(stat(&snap, "backpressure_closed"), 0);
    shutdown(&addr, handle);
}

/// Mass disconnect mid-stream: every connection vanishes without a
/// cancel; the reactor observes EOF and releases every slot and KV
/// block — nothing runs to completion for a peer that is gone.
#[test]
fn mass_disconnect_releases_all_slots_and_residency() {
    const CONNS: usize = 64;
    let (addr, handle) = start_server(ServerOpts::default());
    let clients: Vec<_> = (0..CONNS)
        .map(|k| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                client
                    .submit(
                        1,
                        &[k as u32 + 1, 2, 3],
                        &GenParams::simple(1_000_000, 0.6),
                        true,
                    )
                    .unwrap();
                // Wait for generation to actually start...
                let frame = client.read_frame().unwrap();
                assert_eq!(frame.event, "chunk");
                // ...then vanish without a cancel.
            })
        })
        .collect();
    for c in clients {
        c.join().expect("client thread");
    }
    let snap = poll_stats(&addr, 20, |s| {
        stat(s, "cancelled") == CONNS as u64
            && stat(s, "tokens_in_flight") == 0
            && stat(s, "cache_resident_blocks") == 0
            && stat(s, "outbox_frames") == 0
            && stat(s, "open_conns") <= 1
    });
    assert_eq!(stat(&snap, "completed"), 0);
    // The server is still healthy for new work.
    let mut client = Client::connect(&addr.to_string()).unwrap();
    let (tokens, _) = client
        .generate_oneshot(1, &[5, 6], &GenParams::simple(8, 0.6))
        .unwrap();
    assert_eq!(tokens.len(), 8);
    shutdown(&addr, handle);
}

/// Mid-frame disconnect (ISSUE 7): peers that vanish with a partial
/// frame buffered in the incremental decoder — half an envelope line
/// with no terminating newline, a split v1 envelope whose second half
/// never arrives, even a lone `{` — must be reaped without a panic or a
/// leaked slot, and must never materialize as a request. A peer that
/// disconnects mid-chunk-stream (frames still queued in its outbox) is
/// the write-side variant: its sequence is cancelled and its outbox
/// frames dropped, not flushed to a dead socket.
#[test]
fn mid_frame_disconnect_leaks_nothing() {
    let (addr, handle) = start_server(ServerOpts::default());

    // Read-side: three shapes of torn input, dropped without a newline.
    for partial in [
        "{\"v\":1,\"req_id\":7,\"prompt\":[1,2",
        "{",
        "{\"prompt\":[1,2,3],\"max_new_tokens\"",
    ] {
        let mut client = Client::connect(&addr.to_string()).unwrap();
        client.writer_mut().write_all(partial.as_bytes()).unwrap();
        client.writer_mut().flush().unwrap();
        drop(client); // EOF with the fragment still in the decoder
    }

    // Write-side: start a long stream, read one chunk so generation is
    // live and the outbox is in use, then vanish mid-stream.
    let mut streamer = Client::connect(&addr.to_string()).unwrap();
    streamer
        .submit(1, &[9, 8, 7], &GenParams::simple(1_000_000, 0.6), true)
        .unwrap();
    let frame = streamer.read_frame().unwrap();
    assert_eq!(frame.event, "chunk");
    drop(streamer);

    // Every fragment peer and the streamer drain away: no request was
    // ever admitted for a torn frame (only the streamer's one, which
    // the disconnect cancelled), and all transport/scheduler/cache
    // gauges zero out.
    let snap = poll_stats(&addr, 20, |s| {
        stat(s, "open_conns") <= 1
            && stat(s, "outbox_frames") == 0
            && stat(s, "tokens_in_flight") == 0
            && stat(s, "cache_resident_blocks") == 0
            && stat(s, "cancelled") == 1
    });
    assert_eq!(stat(&snap, "completed"), 0);
    assert_eq!(stat(&snap, "admitted"), 1, "a torn frame became a request");

    // The reactor is still healthy for well-formed work.
    let mut client = Client::connect(&addr.to_string()).unwrap();
    let (tokens, _) = client
        .generate_oneshot(1, &[5, 6], &GenParams::simple(8, 0.6))
        .unwrap();
    assert_eq!(tokens.len(), 8);
    shutdown(&addr, handle);
}

/// Admission control: the connection after `max_conns` is refused with
/// an error line instead of consuming server state, and slots free up
/// when connections close.
#[test]
fn admission_control_refuses_connections_over_max_conns() {
    let (addr, handle) = start_server(ServerOpts {
        max_conns: 2,
        ..ServerOpts::default()
    });
    let mut c1 = Client::connect(&addr.to_string()).unwrap();
    let snap = c1.stats().unwrap(); // round-trip: c1 is registered
    assert_eq!(stat(&snap, "open_conns"), 1);
    let mut c2 = Client::connect(&addr.to_string()).unwrap();
    assert_eq!(stat(&c2.stats().unwrap(), "open_conns"), 2);

    let mut c3 = Client::connect(&addr.to_string()).unwrap();
    let reply = c3.read_json().unwrap();
    assert_eq!(
        reply.get("error").and_then(Json::as_str),
        Some("server at capacity")
    );
    assert!(c3.read_json().is_err(), "refused connection stayed open");

    // Both held connections still work, and the refusal was counted.
    let snap = c1.stats().unwrap();
    assert!(stat(&snap, "conns_rejected") >= 1);
    let tokens = c2.generate(&[1, 2], 4, 0.6).unwrap();
    assert_eq!(tokens.len(), 4);

    // Freeing a slot re-admits new connections.
    drop(c1);
    drop(c2);
    poll_stats(&addr, 10, |s| stat(s, "open_conns") <= 1);
    shutdown(&addr, handle);
}

/// Backpressure: a client that submits an effectively-unbounded stream
/// and never drains its socket is disconnected once its outbox cap is
/// hit — its request is cancelled, residency freed, and the event is
/// counted — instead of the server buffering frames without bound.
#[test]
fn non_draining_client_is_closed_by_outbox_backpressure() {
    let (addr, handle) = start_server(ServerOpts {
        outbox_frames: 8,
        ..ServerOpts::default()
    });
    let mut stuck = Client::connect(&addr.to_string()).unwrap();
    stuck
        .submit(1, &[1, 2, 3], &GenParams::simple(100_000_000, 0.6), true)
        .unwrap();
    // Never read a frame: kernel buffers fill, then the 8-frame outbox,
    // then the server must cut us off.
    let snap = poll_stats(&addr, 60, |s| {
        stat(s, "backpressure_closed") >= 1
            && stat(s, "cancelled") >= 1
            && stat(s, "tokens_in_flight") == 0
            && stat(s, "cache_resident_blocks") == 0
    });
    assert_eq!(stat(&snap, "completed"), 0);
    drop(stuck);
    // A well-behaved client is unaffected afterwards.
    let mut client = Client::connect(&addr.to_string()).unwrap();
    let (tokens, _) = client
        .generate_oneshot(1, &[5, 6], &GenParams::simple(8, 0.6))
        .unwrap();
    assert_eq!(tokens.len(), 8);
    shutdown(&addr, handle);
}

/// The legacy FIFO is bounded (at `outbox_frames`): a v0 client that
/// pipelines far beyond the cap gets explicit `legacy pipeline full`
/// errors for the overflow instead of growing server memory without
/// limit — and every line still gets exactly one reply.
#[test]
fn legacy_pipeline_is_bounded() {
    const LINES: usize = 30;
    const CAP: usize = 8;
    let (addr, handle) = start_server(ServerOpts {
        outbox_frames: CAP,
        ..ServerOpts::default()
    });
    let mut client = Client::connect(&addr.to_string()).unwrap();
    // One burst: all 30 lines land in the decoder before the replies
    // (64-token generations) can drain the FIFO.
    let burst: String = (0..LINES)
        .map(|i| {
            format!("{{\"prompt\":[{},2,3],\"max_new_tokens\":64}}\n", i + 1)
        })
        .collect();
    client.writer_mut().write_all(burst.as_bytes()).unwrap();
    client.writer_mut().flush().unwrap();

    let mut successes = 0usize;
    let mut rejected = 0usize;
    for _ in 0..LINES {
        let reply = client.read_json().unwrap();
        match reply.get("error").and_then(Json::as_str) {
            Some(msg) => {
                assert_eq!(msg, "legacy pipeline full");
                rejected += 1;
            }
            None => {
                assert_eq!(
                    reply
                        .get("tokens")
                        .and_then(Json::as_arr)
                        .map(|a| a.len()),
                    Some(64)
                );
                successes += 1;
            }
        }
    }
    // 1 active + CAP queued are guaranteed through; anything more only
    // if generations completed mid-burst. The cap must have bitten.
    assert!(successes >= CAP + 1, "only {successes} legacy successes");
    assert!(rejected >= 1, "30 pipelined lines never hit the cap of 8");
    assert_eq!(successes + rejected, LINES);
    shutdown(&addr, handle);
}

/// Legacy pipelining keeps its v0 contract on the reactor: two
/// un-enveloped requests sent back to back get their one-shot replies
/// in submission order (one legacy request in flight at a time), while
/// a v1 envelope interleaved between them is served concurrently.
#[test]
fn pipelined_legacy_requests_reply_in_order() {
    let (addr, handle) = start_server(ServerOpts::default());
    let mut client = Client::connect(&addr.to_string()).unwrap();
    client
        .send_line(r#"{"prompt":[1,2,3],"max_new_tokens":5}"#)
        .unwrap();
    client
        .send_line(r#"{"prompt":[4,5,6],"max_new_tokens":7}"#)
        .unwrap();
    // A v1 envelope sent after both legacy lines: it must not be stuck
    // behind the legacy FIFO (the old transport's reader blocked here).
    client
        .send_line(r#"{"v":1,"req_id":9,"prompt":[7,8],"max_new_tokens":3}"#)
        .unwrap();

    let mut legacy_lengths = Vec::new();
    let mut v1_len = None;
    while legacy_lengths.len() < 2 || v1_len.is_none() {
        let frame = client.read_frame().unwrap();
        match frame.req_id {
            Some(9) => {
                assert_eq!(frame.event, "done");
                v1_len = Some(frame.tokens().len());
            }
            None => legacy_lengths.push(frame.tokens().len()),
            other => panic!("unexpected frame for req {other:?}"),
        }
    }
    // Submission order, not completion order: 5 then 7.
    assert_eq!(legacy_lengths, vec![5, 7]);
    assert_eq!(v1_len, Some(3));
    shutdown(&addr, handle);
}
