//! Differential wall for chunked prefill (ISSUE 10 tentpole):
//!
//!   1. engine level (the FCFS scheduler is a batch-of-1 engine) — token
//!      streams are BIT-IDENTICAL chunking on vs off for all four
//!      drafters × cache on/off × radix on/off: chunk rows consume no rng
//!      draws and sim logits are residency-independent, so chunking only
//!      re-times the prompt computation;
//!   2. billing — with the cache on, chunking never re-bills a prompt
//!      position: total computed positions match the one-shot run
//!      exactly;
//!   3. radix composition — chunks publish into the shared prefix tree,
//!      so a chunked prefill warm-starts later sharers exactly like a
//!      one-shot prefill does;
//!   4. batcher level (continuous scheduler) — same stream identity under
//!      the step loop, chunking on vs off.
//!
//! Identity is pinned on single-request workloads: with co-batched
//! sequences the budget split intentionally re-times speculation (that is
//! the point of the feature), so cross-sequence forests differ by design.

use std::sync::{mpsc, Arc};
use std::time::Instant;

use dyspec::config::{CacheConfig, Config, EngineConfig, PolicyKind, SchedKind};
use dyspec::coordinator::{CancelToken, GenEvent, GenParams, Metrics, Request};
use dyspec::engine::SpecEngine;
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::sched::Batcher;

const POLICIES: [PolicyKind; 4] = [
    PolicyKind::DySpec,
    PolicyKind::Sequoia,
    PolicyKind::SpecInfer,
    PolicyKind::Chain,
];

fn sim_pair(seed: u64) -> (SimModel, SimModel) {
    SimModel::pair(SimSpec::new(64, 2.0, 1.0, seed))
}

fn cache_cfg(enabled: bool, radix: bool) -> CacheConfig {
    CacheConfig {
        enabled,
        radix,
        block_tokens: 4,
        radix_min_tokens: 4,
        ..CacheConfig::default()
    }
}

/// One generation over a 37-token prompt (not block-aligned on purpose:
/// the chunk walk exercises both the round-down and the tail).
fn engine_run(
    policy: PolicyKind,
    cache: &CacheConfig,
    chunk: usize,
    seed: u64,
) -> dyspec::engine::GenerationStats {
    let (draft, target) = sim_pair(99);
    let cfg = EngineConfig {
        policy,
        tree_budget: 10,
        max_new_tokens: 24,
        target_temp: 0.6,
        draft_temp: 0.6,
        seed,
        prefill_chunk: chunk,
        ..EngineConfig::default()
    };
    let mut e = SpecEngine::new(Box::new(draft), Box::new(target), cfg, None)
        .with_cache(cache);
    e.reseed(seed ^ 0xF00D);
    let prompt: Vec<u32> =
        (0..37u32).map(|k| (k * 7 + seed as u32) % 64).collect();
    e.generate(&prompt)
}

/// 1+2. The full engine matrix: drafters × cache × radix × seeds. Streams
/// identical, the extra steps are exactly the chunk rounds, and (cache
/// on) the total computed positions match one-shot.
#[test]
fn engine_streams_identical_chunking_on_vs_off_full_matrix() {
    for policy in POLICIES {
        for cache_on in [true, false] {
            for radix in [true, false] {
                if radix && !cache_on {
                    continue; // radix is a cache feature; inert otherwise
                }
                for seed in 0..2u64 {
                    let cache = cache_cfg(cache_on, radix);
                    let off = engine_run(policy, &cache, 0, seed);
                    let on = engine_run(policy, &cache, 8, seed);
                    assert_eq!(
                        on.tokens, off.tokens,
                        "{policy} cache={cache_on} radix={radix} seed \
                         {seed}: chunking changed the stream"
                    );
                    let chunks = on.total_prefill_chunks() as usize;
                    assert!(chunks > 0, "{policy}: chunking never engaged");
                    assert_eq!(off.total_prefill_chunks(), 0);
                    assert_eq!(on.steps.len(), off.steps.len() + chunks);
                    if cache_on {
                        assert_eq!(
                            on.total_billed_positions(),
                            off.total_billed_positions(),
                            "{policy} radix={radix} seed {seed}: chunking \
                             re-billed prompt positions"
                        );
                    }
                }
            }
        }
    }
}

/// 3. Radix composition: generation 1 prefills (chunked or one-shot) and
/// retires; generation 2 — always one-shot — shares the whole 36-token
/// (9-block) prompt except its final token. The second admission must not
/// be able to tell HOW the first prefilled: same warm-start grant, same
/// stream. That is the "chunks publish into the radix tree" guarantee.
#[test]
fn chunked_prefill_publishes_into_radix_for_later_sharers() {
    let run = |first_chunk: usize| {
        let (draft, target) = sim_pair(99);
        let cfg = EngineConfig {
            policy: PolicyKind::DySpec,
            tree_budget: 10,
            max_new_tokens: 16,
            target_temp: 0.6,
            draft_temp: 0.6,
            seed: 7,
            prefill_chunk: first_chunk,
            ..EngineConfig::default()
        };
        let mut e =
            SpecEngine::new(Box::new(draft), Box::new(target), cfg, None)
                .with_cache(&cache_cfg(true, true));
        let shared: Vec<u32> = (0..36u32).map(|k| (k * 5 + 3) % 64).collect();
        let mut first = shared.clone();
        first.push(7);
        e.reseed(0xF00D);
        let g1 = e.generate(&first);
        // The sharer always prefills one-shot; only the PUBLISHER varies.
        e.cfg.prefill_chunk = 0;
        let mut second = shared;
        second.push(8);
        e.reseed(0xF00D);
        let g2 = e.generate(&second);
        (g1, g2)
    };
    let (off1, off2) = run(0);
    let (on1, on2) = run(8);
    assert!(on1.total_prefill_chunks() > 0, "first run never chunked");
    assert_eq!(off1.total_prefill_chunks(), 0);
    assert_eq!(on1.tokens, off1.tokens);
    assert_eq!(on2.tokens, off2.tokens, "publisher mode changed the sharer");
    let warm = on2.total_warm_start_tokens();
    assert_eq!(
        warm,
        off2.total_warm_start_tokens(),
        "chunked publication granted a different warm start"
    );
    assert!(warm >= 36, "sharer did not warm-start off the chunked prefill");
    assert_eq!(
        on2.total_billed_positions(),
        off2.total_billed_positions(),
        "sharer billed differently depending on publisher mode"
    );
}

/// One single-request continuous-batcher run (the identity workload).
fn batcher_run(policy: PolicyKind, cache: CacheConfig, chunk: usize) -> Vec<u32> {
    let mut cfg = Config::new();
    cfg.engine.policy = policy;
    cfg.engine.tree_budget = 8;
    cfg.engine.seed = 5;
    cfg.engine.prefill_chunk = chunk;
    cfg.sched.kind = SchedKind::Continuous;
    cfg.sched.max_active = 16;
    cfg.sched.global_budget = 8;
    cfg.sched.prefill_budget = chunk;
    cfg.cache = cache;
    let (d, t) = sim_pair(17);
    let mut b = Batcher::new(
        0,
        cfg,
        Box::new(d),
        Box::new(t),
        Arc::new(Metrics::new()),
    );
    let (tx, rx) = mpsc::channel();
    let prompt: Vec<u32> = (0..40u32).map(|k| (k * 3 + 2) % 64).collect();
    b.admit(Request {
        id: 1,
        prompt,
        params: GenParams::simple(16, 0.6),
        submitted_at: Instant::now(),
        cancel: CancelToken::new(),
        events: Box::new(tx),
        trace: 0,
    });
    while b.active() > 0 {
        b.step();
    }
    loop {
        match rx.recv().expect("request dropped") {
            GenEvent::Done(resp) => return resp.tokens,
            GenEvent::Chunk { .. } => continue,
        }
    }
}

/// 4. Continuous scheduler, cache on/off × radix on/off × all drafters:
/// the chunked step loop emits the same stream as one-shot admission.
#[test]
fn batched_streams_identical_chunking_on_vs_off_full_matrix() {
    for policy in POLICIES {
        for cache_on in [true, false] {
            for radix in [true, false] {
                if radix && !cache_on {
                    continue;
                }
                let off = batcher_run(policy, cache_cfg(cache_on, radix), 0);
                let on = batcher_run(policy, cache_cfg(cache_on, radix), 8);
                assert_eq!(
                    on, off,
                    "{policy} cache={cache_on} radix={radix}: chunking \
                     changed the batched stream"
                );
            }
        }
    }
}
