//! Differential wall for the cross-request radix prefix cache (ISSUE 9
//! tentpole):
//!
//!   1. engine level — back-to-back generations sharing a prompt prefix
//!      emit BIT-IDENTICAL token streams radix on vs off, for all four
//!      drafters × cache on/off (radix is billing/residency only; the
//!      sampling stream never observes it);
//!   2. the ISSUE acceptance criterion — a second request sharing a
//!      ≥1-block prefix with a RETIRED first request starts with nonzero
//!      resident tokens (warm start) and bills strictly fewer computed
//!      positions than the first;
//!   3. batcher level — same stream identity under forest batching,
//!      including a tiny block budget that forces evictions against
//!      pinned radix paths, and staged admission where the radix hit
//!      actually lands.

use std::sync::{mpsc, Arc};
use std::time::Instant;

use dyspec::config::{CacheConfig, Config, EngineConfig, PolicyKind, SchedKind};
use dyspec::coordinator::{
    CancelToken, GenEvent, GenParams, Metrics, Request,
};
use dyspec::engine::SpecEngine;
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::sched::Batcher;

const POLICIES: [PolicyKind; 4] = [
    PolicyKind::DySpec,
    PolicyKind::Sequoia,
    PolicyKind::SpecInfer,
    PolicyKind::Chain,
];

fn sim_pair(seed: u64) -> (SimModel, SimModel) {
    SimModel::pair(SimSpec::new(64, 2.0, 1.0, seed))
}

fn radix_cfg(enabled: bool, radix: bool) -> CacheConfig {
    CacheConfig {
        enabled,
        radix,
        block_tokens: 4,
        radix_min_tokens: 4,
        ..CacheConfig::default()
    }
}

/// Two sequential generations on ONE engine, prompts sharing an 8-token
/// (2-block) prefix, each reseeded for per-request determinism. With
/// radix on the second admission starts warm; the streams must not care.
fn engine_pair(
    policy: PolicyKind,
    cache: &CacheConfig,
    seed: u64,
) -> Vec<dyspec::engine::GenerationStats> {
    let (draft, target) = sim_pair(99);
    let cfg = EngineConfig {
        policy,
        tree_budget: 10,
        max_new_tokens: 24,
        target_temp: 0.6,
        draft_temp: 0.6,
        seed,
        ..EngineConfig::default()
    };
    let mut e = SpecEngine::new(Box::new(draft), Box::new(target), cfg, None)
        .with_cache(cache);
    let shared = [3u32, 1, 4, 1, 5, 9, 2, 6];
    [vec![7u32], vec![8u32]]
        .into_iter()
        .map(|suffix| {
            let mut prompt = shared.to_vec();
            prompt.extend_from_slice(&suffix);
            e.reseed(seed ^ 0xF00D);
            e.generate(&prompt)
        })
        .collect()
}

/// 1. Radix on vs off is stream-invariant for every drafter, with the
/// KV cache on AND off (radix with the cache off is inert but must not
/// perturb anything either).
#[test]
fn streams_identical_radix_on_vs_off_all_drafters() {
    for policy in POLICIES {
        for cache_on in [true, false] {
            for seed in 0..2u64 {
                let off = engine_pair(policy, &radix_cfg(cache_on, false), seed);
                let on = engine_pair(policy, &radix_cfg(cache_on, true), seed);
                for (k, (a, b)) in on.iter().zip(&off).enumerate() {
                    assert_eq!(
                        a.tokens, b.tokens,
                        "{policy} cache={cache_on} seed {seed} req {k}: \
                         radix changed the stream"
                    );
                    assert_eq!(a.steps.len(), b.steps.len());
                }
                if !cache_on {
                    // Inert: no lookups may have been recorded.
                    let warm: u64 = on
                        .iter()
                        .map(|g| g.total_warm_start_tokens())
                        .sum();
                    assert_eq!(warm, 0, "radix ran with the cache off");
                }
            }
        }
    }
}

/// 2. The acceptance criterion: the first request retires, the second
/// shares a 2-block prefix — it must start resident at that prefix
/// (nonzero warm start, cached positions on its FIRST step) and bill
/// strictly fewer computed positions, both than its own radix-off twin
/// (identical stream, so the comparison is exact) and than the first
/// request's cold admission.
#[test]
fn second_request_starts_warm_and_bills_strictly_less() {
    for policy in POLICIES {
        let on = engine_pair(policy, &radix_cfg(true, true), 5);
        let off = engine_pair(policy, &radix_cfg(true, false), 5);
        let (first, second) = (&on[0], &on[1]);
        assert_eq!(first.steps[0].warm_start_tokens, 0, "{policy}: cold tree");
        assert_eq!(first.steps[0].cached_positions, 0);
        let warm = second.steps[0].warm_start_tokens;
        assert_eq!(
            warm, 8,
            "{policy}: second request must warm-start at the shared 2-block \
             prefix, got {warm}"
        );
        assert!(
            second.steps[0].cached_positions >= 8,
            "{policy}: warm start not billed as cached fetches"
        );
        // Exact twin comparison (same stream, same trees): the warm start
        // converts exactly `warm` first-step computed positions into
        // cached fetches.
        assert_eq!(
            second.steps[0].billed_positions + warm,
            off[1].steps[0].billed_positions,
            "{policy}: warm start did not shrink the first-step bill"
        );
        assert!(
            second.total_billed_positions() < off[1].total_billed_positions(),
            "{policy}: warm request billed {} !< its cold twin {}",
            second.total_billed_positions(),
            off[1].total_billed_positions()
        );
        // Cross-request comparison: computed PREFIX positions on the first
        // step (the bill minus the verification rows, which depend only on
        // the tree) collapse from the full 9-token prompt to the 1
        // unshared token.
        let prefix_billed = |s: &dyspec::engine::StepStats| {
            s.billed_positions - s.tree_size
        };
        assert_eq!(prefix_billed(&first.steps[0]), 9, "{policy}");
        assert_eq!(prefix_billed(&second.steps[0]), 1, "{policy}");
        assert!(
            second.steps[0].billed_positions
                < first.steps[0].billed_positions,
            "{policy}: warm first step billed {} !< cold first step {}",
            second.steps[0].billed_positions,
            first.steps[0].billed_positions
        );
    }
}

fn batcher_run(
    policy: PolicyKind,
    cache: CacheConfig,
    n_seqs: u64,
    staged: bool,
) -> (Vec<Vec<u32>>, u64, u64) {
    let mut cfg = Config::new();
    cfg.engine.policy = policy;
    cfg.engine.tree_budget = 8;
    cfg.engine.seed = 5;
    cfg.sched.kind = SchedKind::Continuous;
    cfg.sched.max_active = 16;
    cfg.sched.global_budget = 8 * n_seqs as usize;
    cfg.cache = cache;
    let (d, t) = sim_pair(17);
    let mut b = Batcher::new(
        0,
        cfg,
        Box::new(d),
        Box::new(t),
        Arc::new(Metrics::new()),
    );
    let admit = |b: &mut Batcher, i: u64| {
        let (tx, rx) = mpsc::channel();
        // 8 shared tokens (2 blocks at block_tokens=4) + unique tail.
        let mut prompt = vec![3u32, 1, 4, 1, 5, 9, 2, 6];
        prompt.push(20 + i as u32);
        b.admit(Request {
            id: i + 1,
            prompt,
            params: GenParams::simple(16, 0.6),
            submitted_at: Instant::now(),
            cancel: CancelToken::new(),
            events: Box::new(tx),
            trace: 0,
        });
        rx
    };
    let rxs: Vec<mpsc::Receiver<GenEvent>> = (0..n_seqs)
        .map(|i| {
            if staged {
                // Drain the previous request completely before admitting
                // the next: every admission past the first then resolves
                // against a tree of RETIRED sequences only.
                while b.active() > 0 {
                    b.step();
                }
            }
            admit(&mut b, i)
        })
        .collect();
    while b.active() > 0 {
        b.step();
    }
    let evictions = b.cache().stats().evictions;
    let radix_hits = b.cache().radix_stats().hits;
    let wait_tokens = |rx: &mpsc::Receiver<GenEvent>| loop {
        match rx.recv().expect("request dropped") {
            GenEvent::Done(resp) => return resp.tokens,
            GenEvent::Chunk { .. } => continue,
        }
    };
    (rxs.iter().map(wait_tokens).collect(), evictions, radix_hits)
}

/// 3a. Forest batching (concurrent admissions): identical streams radix
/// on vs off for every drafter.
#[test]
fn batched_streams_identical_radix_on_vs_off() {
    for policy in POLICIES {
        let (on, _, _) = batcher_run(policy, radix_cfg(true, true), 3, false);
        let (off, _, _) =
            batcher_run(policy, radix_cfg(true, false), 3, false);
        assert_eq!(on, off, "{policy}: radix changed batched streams");
    }
}

/// 3b. Staged admission: each request retires before the next arrives,
/// so every later admission warm-starts off the shared radix tree — and
/// the streams still match the radix-off run exactly.
#[test]
fn staged_admissions_hit_the_radix_tree_without_changing_streams() {
    let (on, _, hits) =
        batcher_run(PolicyKind::DySpec, radix_cfg(true, true), 4, true);
    let (off, _, off_hits) =
        batcher_run(PolicyKind::DySpec, radix_cfg(true, false), 4, true);
    assert_eq!(on, off, "staged radix reuse changed streams");
    assert_eq!(hits, 3, "every admission past the first must warm-start");
    assert_eq!(off_hits, 0, "radix off must never record a hit");
}

/// 3c. A tiny block budget forces evictions against live pinned radix
/// paths mid-run — streams must still be identical to radix off, and the
/// run must actually have evicted.
#[test]
fn eviction_pressure_with_pinned_paths_keeps_streams_identical() {
    let tiny = CacheConfig {
        max_blocks: 3, // far below 4 sequences' residency needs
        ..radix_cfg(true, true)
    };
    let (on, evictions, _) = batcher_run(PolicyKind::DySpec, tiny, 4, false);
    let tiny_off = CacheConfig {
        max_blocks: 3,
        ..radix_cfg(true, false)
    };
    let (off, _, _) = batcher_run(PolicyKind::DySpec, tiny_off, 4, false);
    assert_eq!(on, off, "pressure-forced eviction changed streams");
    assert!(evictions > 0, "budget never forced an eviction");
}
