//! Cross-module integration over the sim backend: engine determinism,
//! budget scaling, policy contracts under long generations, and the
//! serving coordinator under concurrency.

use std::sync::Arc;

use dyspec::config::{Config, EngineConfig, LatencyRegime, PolicyKind};
use dyspec::coordinator::{Coordinator, GenParams, ModelFactory};
use dyspec::engine::SpecEngine;
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::models::LogitModel;

fn engine(policy: PolicyKind, budget: usize, seed: u64) -> SpecEngine {
    let spec = SimSpec::for_dataset("c4", 1.2, 42);
    let (draft, target) = SimModel::pair(spec);
    let cfg = EngineConfig {
        policy,
        tree_budget: budget,
        max_new_tokens: 64,
        target_temp: 0.6,
        seed,
        ..EngineConfig::default()
    };
    SpecEngine::new(Box::new(draft), Box::new(target), cfg, Some(LatencyRegime::pair_7b()))
}

#[test]
fn generation_is_deterministic_per_seed() {
    let prompt: Vec<u32> = (0..32).collect();
    let a = engine(PolicyKind::DySpec, 32, 9).generate(&prompt);
    let b = engine(PolicyKind::DySpec, 32, 9).generate(&prompt);
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.steps.len(), b.steps.len());
    let c = engine(PolicyKind::DySpec, 32, 10).generate(&prompt);
    assert_ne!(a.tokens, c.tokens, "different seeds should differ at temp 0.6");
}

#[test]
fn larger_budget_never_fewer_tokens_per_step_on_average() {
    let prompt: Vec<u32> = (0..64).collect();
    let mut prev = 0.0;
    for budget in [4usize, 16, 64] {
        let mut total = 0.0;
        for seed in 0..4u64 {
            total += engine(PolicyKind::DySpec, budget, seed)
                .generate(&prompt)
                .mean_emitted_per_step();
        }
        let mean = total / 4.0;
        assert!(
            mean + 0.35 >= prev,
            "budget {budget}: tokens/step regressed {mean:.2} < {prev:.2}"
        );
        prev = prev.max(mean);
    }
}

#[test]
fn all_policies_complete_long_generation() {
    let prompt: Vec<u32> = (0..128).map(|i| i % 512).collect();
    for policy in PolicyKind::all() {
        let stats = engine(policy, 64, 3).generate(&prompt);
        assert_eq!(stats.tokens.len(), 64, "{policy}");
        assert!(stats.tokens.iter().all(|&t| (t as usize) < 512));
        // virtual latency ledger is populated under a regime
        assert!(stats.total_virtual_secs() > 0.0, "{policy}");
    }
}

#[test]
fn draft_dispatches_stay_sublinear_in_budget() {
    // Paper §4.3-4.4: the textbook greedy pays O(N) draft dispatches per
    // step. Our lazy drafting (§Perf L3.1) plus the layered threshold
    // variant must both stay well under one dispatch per speculated token.
    let prompt: Vec<u32> = (0..64).collect();
    for policy in [PolicyKind::DySpec, PolicyKind::DySpecThreshold] {
        let stats = engine(policy, 64, 5).generate(&prompt);
        let per_step = stats.total_draft_dispatches() as f64 / stats.steps.len() as f64;
        let tree = stats.mean_tree_size();
        assert!(
            per_step < 0.75 * tree + 2.0,
            "{policy}: {per_step:.1} dispatches/step for mean tree {tree:.1}"
        );
    }
}

#[test]
fn coordinator_sustains_concurrent_load() {
    let factory: ModelFactory = Arc::new(|| {
        let spec = SimSpec::for_dataset("c4", 1.2, 7);
        let (d, t) = SimModel::pair(spec);
        (Box::new(d) as Box<dyn LogitModel>, Box::new(t) as Box<dyn LogitModel>)
    });
    let mut cfg = Config::new();
    cfg.server.workers = 4;
    cfg.server.queue_capacity = 64;
    cfg.engine.tree_budget = 16;
    let coord = Coordinator::start(cfg, factory);
    let rxs: Vec<_> = (0..32)
        .map(|i| {
            coord
                .try_submit(vec![i, 1, 2], GenParams::simple(32, 0.6))
                .unwrap()
        })
        .collect();
    for h in rxs {
        let resp = h.wait().unwrap();
        assert_eq!(resp.tokens.len(), 32);
    }
    assert_eq!(coord.metrics.completed(), 32);
    assert_eq!(coord.metrics.total_tokens(), 32 * 32);
    assert!(coord.metrics.tokens_per_sec() > 0.0);
    coord.shutdown();
}
