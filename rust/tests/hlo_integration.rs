//! Integration over the REAL artifacts: PJRT loading, golden agreement,
//! pallas-vs-ref graph parity, tree scoring consistency, and an end-to-end
//! speculative generation on the trained transformer pair. All tests skip
//! (with a notice) when `make artifacts` has not run.

use dyspec::config::{EngineConfig, PolicyKind};
use dyspec::engine::SpecEngine;
use dyspec::models::hlo::HloModel;
use dyspec::models::LogitModel;
use dyspec::runtime::artifacts::{Artifacts, GraphKey, Role};
use dyspec::runtime::PjrtRuntime;
use dyspec::tree::{dfs_order, TokenTree, ROOT};
use dyspec::util::math::argmax;
use dyspec::util::json::Json;

fn arts() -> Option<Artifacts> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Artifacts::load(dir) {
        Ok(a) => Some(a),
        Err(_) => {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn golden_forward_matches_python() {
    let Some(arts) = arts() else { return };
    let golden = arts.golden().unwrap();
    let seq = golden.get("seq_len").and_then(Json::as_usize).unwrap();
    let vocab = arts.vocab_size();
    let mut rt = PjrtRuntime::cpu().unwrap();
    let tokens: Vec<i32> = (0..seq as i32).map(|i| (7 * i + 3) % vocab as i32).collect();
    let positions: Vec<i32> = (0..seq as i32).collect();
    let mask = dyspec::tree::mask::causal_f32(seq, seq);
    for role in [Role::Target, Role::Draft] {
        let model = rt
            .load(&arts, GraphKey { role, seq_len: seq, pallas: false })
            .unwrap();
        let logits = model.forward(&tokens, &positions, &mask).unwrap();
        let last = &logits[(seq - 1) * vocab..seq * vocab];
        let want_argmax = golden
            .at(&[role.name(), "last_row_argmax"])
            .and_then(Json::as_usize)
            .unwrap();
        assert_eq!(argmax(last), want_argmax, "{}", role.name());
        let want8 = golden
            .at(&[role.name(), "last_row_first8"])
            .and_then(Json::as_arr)
            .unwrap();
        for (i, w) in want8.iter().enumerate() {
            let w = w.as_f64().unwrap() as f32;
            assert!((last[i] - w).abs() < 2e-3, "{} logit {i}: {} vs {w}", role.name(), last[i]);
        }
    }
}

#[test]
fn pallas_graph_matches_ref_graph() {
    // The L1 kernel lowered INTO the L2 graph must agree with the fused
    // reference attention graph — proving the three layers compose.
    let Some(arts) = arts() else { return };
    let seq = arts.seq_small();
    let vocab = arts.vocab_size();
    let mut rt = PjrtRuntime::cpu().unwrap();
    let tokens: Vec<i32> = (0..seq as i32).map(|i| (11 * i + 5) % vocab as i32).collect();
    let positions: Vec<i32> = (0..seq as i32).collect();
    let mask = dyspec::tree::mask::causal_f32(seq / 2, seq);
    let ref_model = rt
        .load(&arts, GraphKey { role: Role::Target, seq_len: seq, pallas: false })
        .unwrap();
    let pallas_model = rt
        .load(&arts, GraphKey { role: Role::Target, seq_len: seq, pallas: true })
        .unwrap();
    let a = ref_model.forward(&tokens, &positions, &mask).unwrap();
    let b = pallas_model.forward(&tokens, &positions, &mask).unwrap();
    let live = seq / 2 * vocab;
    let max_diff = a[..live]
        .iter()
        .zip(&b[..live])
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 5e-3, "pallas vs ref max diff {max_diff}");
}

#[test]
fn score_tree_consistent_with_next_logits() {
    // The single-dispatch tree-masked forward must equal per-path causal
    // forwards — the correctness of tree attention + position wiring.
    let Some(arts) = arts() else { return };
    let mut rt = PjrtRuntime::cpu().unwrap();
    let seq = arts.seq_small();
    let mut model = HloModel::load(&mut rt, &arts, Role::Draft, seq, false).unwrap();
    let prefix: Vec<u32> = (0..12).map(|i| (i * 29 + 3) % 512).collect();

    let mut tree = TokenTree::new(*prefix.last().unwrap(), vec![]);
    let a = tree.add_child(ROOT, 100, 0.9);
    let b = tree.add_child(a, 200, 0.8);
    let c = tree.add_child(ROOT, 300, 0.3);
    let order = dfs_order(&tree);
    let rows = model.score_tree(&prefix, &tree, &order);
    assert_eq!(rows.len(), 4);

    // Compare each row against the plain causal forward of its path.
    let mut check = |row: &Vec<f32>, ctx: &[u32]| {
        let want = model.next_logits(ctx);
        let max_diff = row
            .iter()
            .zip(&want)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 2e-3, "tree row vs causal diff {max_diff}");
    };
    check(&rows[0], &prefix);
    let mut ctx = prefix.clone();
    ctx.push(100);
    let row_a = order.iter().position(|&id| id == a).unwrap() + 1;
    check(&rows[row_a], &ctx);
    ctx.push(200);
    let row_b = order.iter().position(|&id| id == b).unwrap() + 1;
    check(&rows[row_b], &ctx);
    let mut ctx_c = prefix.clone();
    ctx_c.push(300);
    let row_c = order.iter().position(|&id| id == c).unwrap() + 1;
    check(&rows[row_c], &ctx_c);
}

#[test]
fn end_to_end_speculative_generation_on_trained_models() {
    let Some(arts) = arts() else { return };
    let mut rt = PjrtRuntime::cpu().unwrap();
    let seq = arts.seq_small();
    let draft = HloModel::load(&mut rt, &arts, Role::Draft, seq, false).unwrap();
    let target = HloModel::load(&mut rt, &arts, Role::Target, seq, false).unwrap();
    let cfg = EngineConfig {
        policy: PolicyKind::DySpec,
        tree_budget: 12,
        max_new_tokens: 24,
        target_temp: 0.0,
        seed: 3,
        ..EngineConfig::default()
    };
    let mut engine = SpecEngine::new(Box::new(draft), Box::new(target), cfg, None);
    let prompt = dyspec::data::prompts::PromptSet::by_name("cnn", 1, 48, 5).unwrap();
    let stats = engine.generate(prompt.get(0));
    assert_eq!(stats.tokens.len(), 24);
    // The trained draft must actually help: > 1.5 tokens per step.
    assert!(
        stats.mean_emitted_per_step() > 1.5,
        "trained pair only {:.2} tokens/step",
        stats.mean_emitted_per_step()
    );

    // Cross-check against autoregressive target-only decoding at temp 0.
    let target2 = HloModel::load(&mut rt, &arts, Role::Target, seq, false).unwrap();
    let draft2 = HloModel::load(&mut rt, &arts, Role::Draft, seq, false).unwrap();
    let cfg2 = EngineConfig {
        policy: PolicyKind::Baseline,
        max_new_tokens: 24,
        target_temp: 0.0,
        seed: 3,
        ..EngineConfig::default()
    };
    let mut ar = SpecEngine::new(Box::new(draft2), Box::new(target2), cfg2, None);
    let ar_stats = ar.generate(prompt.get(0));
    assert_eq!(
        stats.tokens, ar_stats.tokens,
        "speculative output != greedy target output at temp 0"
    );
}
