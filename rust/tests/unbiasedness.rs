//! THE correctness property of speculative decoding (paper §2, Appendix
//! A.3): for ANY draft-tree policy, the emitted token distribution must
//! equal target-only decoding. We measure total-variation distance between
//! empirical first-token distributions over many seeded runs on a small
//! vocab, for every policy and both temperatures, and compare against a
//! same-size baseline-vs-baseline TV (the sampling-noise floor).

use std::sync::{mpsc, Arc};
use std::time::Instant;

use dyspec::config::{Config, EngineConfig, PolicyKind, SchedKind};
use dyspec::coordinator::{Metrics, Request, Response};
use dyspec::engine::SpecEngine;
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::sched::Batcher;

const VOCAB: usize = 16;
const RUNS: usize = 4000;

/// Empirical distribution of the FIRST generated token across seeds.
fn first_token_hist(policy: PolicyKind, temp: f32, seed_salt: u64) -> Vec<f64> {
    let mut counts = vec![0usize; VOCAB];
    for seed in 0..RUNS as u64 {
        let spec = SimSpec::new(VOCAB, 2.0, 1.0, 99); // fixed world
        let (draft, target) = SimModel::pair(spec);
        let cfg = EngineConfig {
            policy,
            tree_budget: 6,
            max_new_tokens: 1,
            target_temp: temp,
            draft_temp: 0.6,
            seed: seed ^ seed_salt,
            max_depth: 4,
            ..EngineConfig::default()
        };
        let mut engine = SpecEngine::new(Box::new(draft), Box::new(target), cfg, None);
        let out = engine.generate(&[3, 1, 4]);
        counts[out.tokens[0] as usize] += 1;
    }
    counts.iter().map(|&c| c as f64 / RUNS as f64).collect()
}

fn tv(p: &[f64], q: &[f64]) -> f64 {
    0.5 * p.iter().zip(q).map(|(a, b)| (a - b).abs()).sum::<f64>()
}

#[test]
fn all_policies_match_target_distribution_at_temp_06() {
    let reference = first_token_hist(PolicyKind::Baseline, 0.6, 7777);
    // Sampling-noise floor: two independent baseline populations.
    let floor = tv(&reference, &first_token_hist(PolicyKind::Baseline, 0.6, 1234));
    for policy in [
        PolicyKind::DySpec,
        PolicyKind::DySpecThreshold,
        PolicyKind::Sequoia,
        PolicyKind::SpecInfer,
        PolicyKind::Chain,
    ] {
        let hist = first_token_hist(policy, 0.6, 0);
        let d = tv(&reference, &hist);
        assert!(
            d < (3.0 * floor).max(0.05),
            "{policy}: TV {d:.4} vs noise floor {floor:.4} — BIASED OUTPUT"
        );
    }
}

#[test]
fn all_policies_exactly_greedy_at_temp_0() {
    // temp 0: target is deterministic; every policy must emit the SAME
    // greedy continuation as the baseline, token for token.
    let reference = {
        let spec = SimSpec::new(VOCAB, 2.0, 1.0, 99);
        let (draft, target) = SimModel::pair(spec);
        let cfg = EngineConfig {
            policy: PolicyKind::Baseline,
            max_new_tokens: 24,
            target_temp: 0.0,
            seed: 1,
            ..EngineConfig::default()
        };
        let mut e = SpecEngine::new(Box::new(draft), Box::new(target), cfg, None);
        e.generate(&[3, 1, 4]).tokens
    };
    for policy in [
        PolicyKind::DySpec,
        PolicyKind::DySpecThreshold,
        PolicyKind::Sequoia,
        PolicyKind::SpecInfer,
        PolicyKind::Chain,
    ] {
        for seed in 0..5u64 {
            let spec = SimSpec::new(VOCAB, 2.0, 1.0, 99);
            let (draft, target) = SimModel::pair(spec);
            let cfg = EngineConfig {
                policy,
                tree_budget: 8,
                max_new_tokens: 24,
                target_temp: 0.0,
                seed,
                ..EngineConfig::default()
            };
            let mut e = SpecEngine::new(Box::new(draft), Box::new(target), cfg, None);
            let tokens = e.generate(&[3, 1, 4]).tokens;
            assert_eq!(tokens, reference, "{policy} seed {seed} diverged at temp 0");
        }
    }
}

/// Unbiasedness must survive continuous batching: co-batched sequences
/// share the per-dispatch budget (so each tree's SHAPE depends on the other
/// sequences' draws), but Algorithm 3 is unbiased conditioned on any tree,
/// so each sequence's marginal output distribution must still equal
/// target-only decoding. Four co-batched sequences with the same prompt;
/// empirical first-token distribution vs the baseline reference.
#[test]
fn continuous_batching_preserves_first_token_distribution() {
    const BATCH: usize = 4;
    const ROUNDS: usize = RUNS / BATCH;

    let mut counts = vec![0usize; VOCAB];
    for round in 0..ROUNDS as u64 {
        let spec = SimSpec::new(VOCAB, 2.0, 1.0, 99); // same fixed world
        let (draft, target) = SimModel::pair(spec);
        let mut cfg = Config::new();
        cfg.engine = EngineConfig {
            policy: PolicyKind::DySpec,
            tree_budget: 6,
            max_new_tokens: 2, // 2 so the first token comes from a real tree
            target_temp: 0.6,
            draft_temp: 0.6,
            seed: round,
            max_depth: 4,
            ..EngineConfig::default()
        };
        cfg.sched.kind = SchedKind::Continuous;
        cfg.sched.max_active = BATCH;
        cfg.sched.global_budget = 6 * BATCH;

        let mut batcher = Batcher::new(
            0,
            cfg,
            Box::new(draft),
            Box::new(target),
            Arc::new(Metrics::new()),
        );
        let rxs: Vec<mpsc::Receiver<Response>> = (0..BATCH as u64)
            .map(|i| {
                let (tx, rx) = mpsc::channel();
                batcher.admit(Request {
                    id: round * BATCH as u64 + i + 1,
                    prompt: vec![3, 1, 4],
                    max_new_tokens: 2,
                    temperature: 0.6,
                    submitted_at: Instant::now(),
                    respond: tx,
                });
                rx
            })
            .collect();
        while batcher.active() > 0 {
            batcher.step();
        }
        for rx in rxs {
            let resp = rx.recv().unwrap();
            counts[resp.tokens[0] as usize] += 1;
        }
    }
    let n = (ROUNDS * BATCH) as f64;
    let hist: Vec<f64> = counts.iter().map(|&c| c as f64 / n).collect();

    let reference = first_token_hist(PolicyKind::Baseline, 0.6, 7777);
    let floor = tv(
        &reference,
        &first_token_hist(PolicyKind::Baseline, 0.6, 1234),
    );
    let d = tv(&reference, &hist);
    assert!(
        d < (3.0 * floor).max(0.05),
        "batched TV {d:.4} vs noise floor {floor:.4} — BIASED OUTPUT UNDER BATCHING"
    );
}

#[test]
fn second_token_distribution_unbiased_for_dyspec() {
    // Deeper check: the SECOND token's conditional distribution also
    // matches (guards against bias leaking through accepted prefixes).
    let hist = |policy: PolicyKind, salt: u64| -> Vec<f64> {
        let mut counts = vec![0usize; VOCAB];
        for seed in 0..RUNS as u64 {
            let spec = SimSpec::new(VOCAB, 2.0, 1.0, 55);
            let (draft, target) = SimModel::pair(spec);
            let cfg = EngineConfig {
                policy,
                tree_budget: 6,
                max_new_tokens: 2,
                target_temp: 0.6,
                seed: seed ^ salt,
                max_depth: 4,
                ..EngineConfig::default()
            };
            let mut e = SpecEngine::new(Box::new(draft), Box::new(target), cfg, None);
            let out = e.generate(&[9, 2]);
            counts[out.tokens[1] as usize] += 1;
        }
        counts.iter().map(|&c| c as f64 / RUNS as f64).collect()
    };
    let reference = hist(PolicyKind::Baseline, 31);
    let floor = tv(&reference, &hist(PolicyKind::Baseline, 77));
    let d = tv(&reference, &hist(PolicyKind::DySpec, 0));
    assert!(
        d < (3.0 * floor).max(0.06),
        "second-token TV {d:.4} vs floor {floor:.4}"
    );
}
