//! THE correctness property of speculative decoding (paper §2, Appendix
//! A.3): for ANY draft-tree policy, the emitted token distribution must
//! equal target-only decoding. We measure total-variation distance between
//! empirical first-token distributions over many seeded runs on a small
//! vocab, for every policy and both temperatures, and compare against a
//! same-size baseline-vs-baseline TV (the sampling-noise floor).

use std::sync::{mpsc, Arc};
use std::time::Instant;

use dyspec::config::{
    CacheConfig, Config, EngineConfig, LatencyRegime, PolicyKind, SchedKind,
};
use dyspec::coordinator::{
    CancelToken, GenEvent, GenParams, Metrics, Request,
};
use dyspec::engine::SpecEngine;
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::sched::Batcher;

/// Drain a request's event stream to its final response tokens.
fn wait_tokens(rx: &mpsc::Receiver<GenEvent>) -> Vec<u32> {
    loop {
        match rx.recv().expect("request dropped") {
            GenEvent::Done(resp) => return resp.tokens,
            GenEvent::Chunk { .. } => continue,
        }
    }
}

const VOCAB: usize = 16;
const RUNS: usize = 4000;

/// Empirical distribution of the FIRST generated token across seeds.
fn first_token_hist(policy: PolicyKind, temp: f32, seed_salt: u64) -> Vec<f64> {
    let mut counts = vec![0usize; VOCAB];
    for seed in 0..RUNS as u64 {
        let spec = SimSpec::new(VOCAB, 2.0, 1.0, 99); // fixed world
        let (draft, target) = SimModel::pair(spec);
        let cfg = EngineConfig {
            policy,
            tree_budget: 6,
            max_new_tokens: 1,
            target_temp: temp,
            draft_temp: 0.6,
            seed: seed ^ seed_salt,
            max_depth: 4,
            ..EngineConfig::default()
        };
        let mut engine = SpecEngine::new(Box::new(draft), Box::new(target), cfg, None);
        let out = engine.generate(&[3, 1, 4]);
        counts[out.tokens[0] as usize] += 1;
    }
    counts.iter().map(|&c| c as f64 / RUNS as f64).collect()
}

fn tv(p: &[f64], q: &[f64]) -> f64 {
    0.5 * p.iter().zip(q).map(|(a, b)| (a - b).abs()).sum::<f64>()
}

#[test]
fn all_policies_match_target_distribution_at_temp_06() {
    let reference = first_token_hist(PolicyKind::Baseline, 0.6, 7777);
    // Sampling-noise floor: two independent baseline populations.
    let floor = tv(&reference, &first_token_hist(PolicyKind::Baseline, 0.6, 1234));
    for policy in [
        PolicyKind::DySpec,
        PolicyKind::DySpecThreshold,
        PolicyKind::Sequoia,
        PolicyKind::SpecInfer,
        PolicyKind::Chain,
    ] {
        let hist = first_token_hist(policy, 0.6, 0);
        let d = tv(&reference, &hist);
        assert!(
            d < (3.0 * floor).max(0.05),
            "{policy}: TV {d:.4} vs noise floor {floor:.4} — BIASED OUTPUT"
        );
    }
}

#[test]
fn all_policies_exactly_greedy_at_temp_0() {
    // temp 0: target is deterministic; every policy must emit the SAME
    // greedy continuation as the baseline, token for token.
    let reference = {
        let spec = SimSpec::new(VOCAB, 2.0, 1.0, 99);
        let (draft, target) = SimModel::pair(spec);
        let cfg = EngineConfig {
            policy: PolicyKind::Baseline,
            max_new_tokens: 24,
            target_temp: 0.0,
            seed: 1,
            ..EngineConfig::default()
        };
        let mut e = SpecEngine::new(Box::new(draft), Box::new(target), cfg, None);
        e.generate(&[3, 1, 4]).tokens
    };
    for policy in [
        PolicyKind::DySpec,
        PolicyKind::DySpecThreshold,
        PolicyKind::Sequoia,
        PolicyKind::SpecInfer,
        PolicyKind::Chain,
    ] {
        for seed in 0..5u64 {
            let spec = SimSpec::new(VOCAB, 2.0, 1.0, 99);
            let (draft, target) = SimModel::pair(spec);
            let cfg = EngineConfig {
                policy,
                tree_budget: 8,
                max_new_tokens: 24,
                target_temp: 0.0,
                seed,
                ..EngineConfig::default()
            };
            let mut e = SpecEngine::new(Box::new(draft), Box::new(target), cfg, None);
            let tokens = e.generate(&[3, 1, 4]).tokens;
            assert_eq!(tokens, reference, "{policy} seed {seed} diverged at temp 0");
        }
    }
}

/// Unbiasedness must survive continuous batching: co-batched sequences
/// share the per-dispatch budget (so each tree's SHAPE depends on the other
/// sequences' draws), but Algorithm 3 is unbiased conditioned on any tree,
/// so each sequence's marginal output distribution must still equal
/// target-only decoding. Four co-batched sequences with the same prompt;
/// empirical first-token distribution vs the baseline reference.
#[test]
fn continuous_batching_preserves_first_token_distribution() {
    const BATCH: usize = 4;
    const ROUNDS: usize = RUNS / BATCH;

    let mut counts = vec![0usize; VOCAB];
    for round in 0..ROUNDS as u64 {
        let spec = SimSpec::new(VOCAB, 2.0, 1.0, 99); // same fixed world
        let (draft, target) = SimModel::pair(spec);
        let mut cfg = Config::new();
        cfg.engine = EngineConfig {
            policy: PolicyKind::DySpec,
            tree_budget: 6,
            max_new_tokens: 2, // 2 so the first token comes from a real tree
            target_temp: 0.6,
            draft_temp: 0.6,
            seed: round,
            max_depth: 4,
            ..EngineConfig::default()
        };
        cfg.sched.kind = SchedKind::Continuous;
        cfg.sched.max_active = BATCH;
        cfg.sched.global_budget = 6 * BATCH;

        let mut batcher = Batcher::new(
            0,
            cfg,
            Box::new(draft),
            Box::new(target),
            Arc::new(Metrics::new()),
        );
        let rxs: Vec<mpsc::Receiver<GenEvent>> = (0..BATCH as u64)
            .map(|i| {
                let (tx, rx) = mpsc::channel();
                batcher.admit(Request {
                    id: round * BATCH as u64 + i + 1,
                    prompt: vec![3, 1, 4],
                    params: GenParams::simple(2, 0.6),
                    submitted_at: Instant::now(),
                    cancel: CancelToken::new(),
                    events: Box::new(tx),
                    trace: 0,
                });
                rx
            })
            .collect();
        while batcher.active() > 0 {
            batcher.step();
        }
        for rx in rxs {
            counts[wait_tokens(&rx)[0] as usize] += 1;
        }
    }
    let n = (ROUNDS * BATCH) as f64;
    let hist: Vec<f64> = counts.iter().map(|&c| c as f64 / n).collect();

    let reference = first_token_hist(PolicyKind::Baseline, 0.6, 7777);
    let floor = tv(
        &reference,
        &first_token_hist(PolicyKind::Baseline, 0.6, 1234),
    );
    let d = tv(&reference, &hist);
    assert!(
        d < (3.0 * floor).max(0.05),
        "batched TV {d:.4} vs noise floor {floor:.4} — BIASED OUTPUT UNDER BATCHING"
    );
}

/// ISSUE 2 satellite: multi-round end-to-end generation with the KV cache
/// on vs off produces IDENTICAL token streams, and the regime-priced
/// verify ledger with the cache enabled is <= the uncached ledger on every
/// dispatch (strictly cheaper once anything is resident). The priced cost
/// is reconstructed deterministically from the per-step bill — wall-time
/// components are excluded so the comparison cannot flake.
#[test]
fn cache_on_off_identical_streams_and_cheaper_ledger() {
    let regime = LatencyRegime::pair_7b();
    let block = CacheConfig::default().block_tokens;
    // Priced verify cost of one dispatch from its deterministic bill:
    // computed positions + written blocks + fetched resident blocks.
    let priced = |billed: usize, cached: usize| -> f64 {
        regime.target_pos_secs * billed as f64
            + regime.cache_write_secs * billed.div_ceil(block) as f64
            + regime.cache_fetch_secs * (cached / block) as f64
    };
    let run = |enabled: bool, policy: PolicyKind| {
        let spec = SimSpec::new(VOCAB, 2.0, 1.0, 99);
        let (draft, target) = SimModel::pair(spec);
        let cfg = EngineConfig {
            policy,
            tree_budget: 8,
            max_new_tokens: 32,
            target_temp: 0.6,
            seed: 13,
            ..EngineConfig::default()
        };
        let mut e =
            SpecEngine::new(Box::new(draft), Box::new(target), cfg, None)
                .with_cache(&CacheConfig {
                    enabled,
                    ..CacheConfig::default()
                });
        e.generate(&[3, 1, 4])
    };
    for policy in [
        PolicyKind::DySpec,
        PolicyKind::Sequoia,
        PolicyKind::SpecInfer,
        PolicyKind::Chain,
        PolicyKind::Baseline,
    ] {
        let warm = run(true, policy);
        let cold = run(false, policy);
        assert_eq!(
            warm.tokens, cold.tokens,
            "{policy}: cache changed the emitted stream"
        );
        assert_eq!(warm.steps.len(), cold.steps.len());
        for (k, (w, c)) in
            warm.steps.iter().zip(&cold.steps).enumerate()
        {
            let warm_cost = priced(w.billed_positions, w.cached_positions);
            let cold_cost = priced(c.billed_positions, c.cached_positions);
            assert!(
                warm_cost <= cold_cost + 1e-12,
                "{policy} dispatch {k}: cached ledger {warm_cost} above \
                 uncached {cold_cost}"
            );
            if k > 0 {
                assert!(
                    warm_cost < cold_cost,
                    "{policy} dispatch {k}: warm round not strictly cheaper"
                );
            }
        }
    }
}

/// Same satellite under forest batching: identical streams, and every
/// shared dispatch bills no more positions with the cache than without
/// (strictly fewer once sequences are past their first round).
#[test]
fn batched_cache_on_off_identical_streams_and_billed_positions_dominate() {
    let run = |enabled: bool| -> (Vec<Vec<u32>>, Vec<(usize, usize)>) {
        let spec = SimSpec::new(VOCAB, 2.0, 1.0, 99);
        let (draft, target) = SimModel::pair(spec);
        let mut cfg = Config::new();
        cfg.engine.tree_budget = 8;
        cfg.engine.seed = 21;
        cfg.sched.kind = SchedKind::Continuous;
        cfg.sched.max_active = 4;
        cfg.sched.global_budget = 24;
        cfg.cache.enabled = enabled;
        let mut b = Batcher::new(
            0,
            cfg,
            Box::new(draft),
            Box::new(target),
            Arc::new(Metrics::new()),
        );
        let rxs: Vec<mpsc::Receiver<GenEvent>> = (0..3u64)
            .map(|i| {
                let (tx, rx) = mpsc::channel();
                b.admit(Request {
                    id: i + 1,
                    prompt: vec![3, 1, 4],
                    params: GenParams::simple(16, 0.6),
                    submitted_at: Instant::now(),
                    cancel: CancelToken::new(),
                    events: Box::new(tx),
                    trace: 0,
                });
                rx
            })
            .collect();
        let mut bills = Vec::new();
        while b.active() > 0 {
            let rep = b.step();
            bills.push((rep.billed_positions, rep.cached_positions));
        }
        (rxs.iter().map(wait_tokens).collect(), bills)
    };
    let (warm_tokens, warm_bills) = run(true);
    let (cold_tokens, cold_bills) = run(false);
    assert_eq!(warm_tokens, cold_tokens, "cache changed batched streams");
    assert_eq!(warm_bills.len(), cold_bills.len());
    for (k, ((wb, wc), (cb, cc))) in
        warm_bills.iter().zip(&cold_bills).enumerate()
    {
        assert_eq!(*cc, 0, "uncached run reported hits");
        assert!(
            wb <= cb,
            "dispatch {k}: cache billed {wb} > uncached {cb}"
        );
        if k > 0 {
            assert!(wb < cb, "dispatch {k}: warm not strictly cheaper");
            assert!(*wc > 0, "dispatch {k}: no resident positions");
        }
    }
}

#[test]
fn second_token_distribution_unbiased_for_dyspec() {
    // Deeper check: the SECOND token's conditional distribution also
    // matches (guards against bias leaking through accepted prefixes).
    let hist = |policy: PolicyKind, salt: u64| -> Vec<f64> {
        let mut counts = vec![0usize; VOCAB];
        for seed in 0..RUNS as u64 {
            let spec = SimSpec::new(VOCAB, 2.0, 1.0, 55);
            let (draft, target) = SimModel::pair(spec);
            let cfg = EngineConfig {
                policy,
                tree_budget: 6,
                max_new_tokens: 2,
                target_temp: 0.6,
                seed: seed ^ salt,
                max_depth: 4,
                ..EngineConfig::default()
            };
            let mut e = SpecEngine::new(Box::new(draft), Box::new(target), cfg, None);
            let out = e.generate(&[9, 2]);
            counts[out.tokens[1] as usize] += 1;
        }
        counts.iter().map(|&c| c as f64 / RUNS as f64).collect()
    };
    let reference = hist(PolicyKind::Baseline, 31);
    let floor = tv(&reference, &hist(PolicyKind::Baseline, 77));
    let d = tv(&reference, &hist(PolicyKind::DySpec, 0));
    assert!(
        d < (3.0 * floor).max(0.06),
        "second-token TV {d:.4} vs floor {floor:.4}"
    );
}
