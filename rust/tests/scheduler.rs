//! Continuous-batching scheduler invariants (ISSUE 1 satellite):
//!   - budget conservation: Σ per-sequence allocations <= the global
//!     per-dispatch budget, and no sequence exceeds the single-request cap;
//!   - no starvation: every admitted sequence emits >= 1 token on every
//!     step it takes part in, so progress is guaranteed within one step;
//!   - shutdown drains in-flight sequences instead of dropping them;
//!   - the cross-request greedy allocator degenerates EXACTLY to the
//!     single-request DySpec tree when one sequence is active;
//!   - at temperature 0 the batched path emits the same greedy tokens as
//!     autoregressive target-only decoding (per-sequence correctness under
//!     batching).

use std::sync::{mpsc, Arc};
use std::time::Instant;

use dyspec::cache::CacheManager;
use dyspec::config::{CacheConfig, Config, EngineConfig, PolicyKind, SchedKind};
use dyspec::coordinator::{
    CancelToken, Coordinator, GenParams, Metrics, ModelFactory, Request,
    RequestHandle,
};
use dyspec::draft::dyspec::DySpecPolicy;
use dyspec::draft::TreePolicy;
use dyspec::engine::SpecEngine;
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::models::LogitModel;
use dyspec::sched::{build_forest, Batcher};
use dyspec::util::Rng;

const VOCAB: usize = 64;

fn sim_pair(seed: u64) -> (SimModel, SimModel) {
    SimModel::pair(SimSpec::new(VOCAB, 2.0, 0.8, seed))
}

fn mk_batcher(cfg: Config) -> Batcher {
    let (d, t) = sim_pair(17);
    Batcher::new(0, cfg, Box::new(d), Box::new(t), Arc::new(Metrics::new()))
}

fn mk_request(
    id: u64,
    prompt: Vec<u32>,
    max_new: usize,
    temperature: f32,
) -> (Request, RequestHandle) {
    let (tx, rx) = mpsc::channel();
    let cancel = CancelToken::new();
    (
        Request {
            id,
            prompt,
            params: GenParams::simple(max_new, temperature),
            submitted_at: Instant::now(),
            cancel: cancel.clone(),
            events: Box::new(tx),
            trace: 0,
        },
        RequestHandle {
            id,
            events: rx,
            cancel,
        },
    )
}

fn base_cfg() -> Config {
    let mut cfg = Config::new();
    cfg.engine.tree_budget = 12;
    cfg.engine.target_temp = 0.6;
    cfg.sched.kind = SchedKind::Continuous;
    cfg.sched.max_active = 16;
    cfg.sched.idle_tick_ms = 2;
    cfg
}

#[test]
fn budget_is_conserved_every_step() {
    let mut cfg = base_cfg();
    cfg.sched.global_budget = 20;
    let mut b = mk_batcher(cfg);
    let _rxs: Vec<_> = (0..6)
        .map(|i| {
            let (req, rx) = mk_request(i + 1, vec![i as u32 + 1, 2, 3], 24, 0.6);
            b.admit(req);
            rx
        })
        .collect();
    while b.active() > 0 {
        let report = b.step();
        let total: usize = report.allocated.iter().sum();
        assert!(
            total <= report.global_budget,
            "allocated {total} > global budget {}",
            report.global_budget
        );
        for &a in &report.allocated {
            assert!(a <= 12, "sequence exceeded single-request cap: {a}");
        }
    }
}

#[test]
fn no_sequence_starves() {
    // Budget smaller than the batch: the allocator must still hand every
    // speculating sequence at least its root token, and every sequence in
    // the dispatch must emit >= 1 token (progress within K = 1 steps).
    let mut cfg = base_cfg();
    cfg.sched.global_budget = 8; // 8 sequences, 8 tokens
    let mut b = mk_batcher(cfg);
    let rxs: Vec<_> = (0..8)
        .map(|i| {
            let (req, rx) = mk_request(i + 1, vec![40 + i as u32, 5], 16, 0.6);
            b.admit(req);
            rx
        })
        .collect();
    let mut steps = 0;
    while b.active() > 0 {
        let report = b.step();
        assert!(
            report.emitted.iter().all(|&e| e >= 1),
            "starved sequence in step {steps}: {:?}",
            report.emitted
        );
        let total: usize = report.allocated.iter().sum();
        assert!(total <= report.global_budget, "over budget");
        steps += 1;
        assert!(steps <= 16 * 8, "did not converge");
    }
    // progress bound: 16 tokens, >= 1 token/step -> <= 16 steps per seq
    for h in rxs {
        let resp = h.wait().unwrap();
        assert_eq!(resp.tokens.len(), 16);
        assert!(resp.steps <= 16, "seq took {} steps for 16 tokens", resp.steps);
    }
}

#[test]
fn single_sequence_reduces_to_dyspec_policy_tree() {
    let cfg = EngineConfig {
        tree_budget: 24,
        ..EngineConfig::default()
    };
    let prefix: Vec<u32> = vec![3, 1, 4, 1, 5];

    let (mut draft_a, _) = sim_pair(42);
    let mut rng_a = Rng::new(7);
    let want = DySpecPolicy.build(&mut draft_a, &prefix, &cfg, &mut rng_a);

    let (mut draft_b, _) = sim_pair(42);
    let mut rngs = vec![Rng::new(7)];
    let got = build_forest(
        &mut draft_b,
        &[prefix.as_slice()],
        &mut rngs,
        &cfg,
        cfg.tree_budget,
        &[cfg.tree_budget],
    );
    let got = &got.trees[0];

    assert_eq!(got.num_nodes(), want.num_nodes());
    for id in want.speculated() {
        assert_eq!(got.node(id).token, want.node(id).token, "node {id}");
        assert_eq!(got.node(id).parent, want.node(id).parent, "node {id}");
        assert!((got.node(id).est - want.node(id).est).abs() < 1e-12);
    }
}

#[test]
fn temp0_batched_output_matches_autoregressive() {
    // Deterministic greedy target: whatever the batch does to tree shapes,
    // each sequence must emit exactly the target-only continuation.
    let prompt = vec![9u32, 2, 6];
    let max_new = 20;

    let reference = {
        let (draft, target) = sim_pair(99);
        let cfg = EngineConfig {
            policy: PolicyKind::Baseline,
            max_new_tokens: max_new,
            target_temp: 0.0,
            seed: 1,
            ..EngineConfig::default()
        };
        let mut e = SpecEngine::new(Box::new(draft), Box::new(target), cfg, None);
        e.generate(&prompt).tokens
    };

    let mut cfg = base_cfg();
    cfg.engine.tree_budget = 8;
    let (d, t) = sim_pair(99);
    let mut b = Batcher::new(
        0,
        cfg,
        Box::new(d),
        Box::new(t),
        Arc::new(Metrics::new()),
    );
    let rxs: Vec<_> = (0..3)
        .map(|i| {
            let (req, rx) = mk_request(i + 1, prompt.clone(), max_new, 0.0);
            b.admit(req);
            rx
        })
        .collect();
    while b.active() > 0 {
        b.step();
    }
    for h in rxs {
        let resp = h.wait().unwrap();
        assert_eq!(
            resp.tokens, reference,
            "batched temp-0 output diverged from greedy decoding"
        );
    }
}

#[test]
fn coordinator_shutdown_drains_under_continuous_scheduler() {
    let factory: ModelFactory = Arc::new(|| {
        let (d, t) = sim_pair(5);
        (
            Box::new(d) as Box<dyn LogitModel>,
            Box::new(t) as Box<dyn LogitModel>,
        )
    });
    let mut cfg = base_cfg();
    cfg.server.workers = 1;
    cfg.server.queue_capacity = 32;
    let coord = Coordinator::start(cfg, factory);
    let rxs: Vec<_> = (0..10)
        .map(|i| {
            coord
                .try_submit(vec![i + 1, 2, 3], GenParams::simple(16, 0.6))
                .unwrap()
        })
        .collect();
    // Immediate shutdown: queued + in-flight work must still complete.
    coord.shutdown();
    for h in rxs {
        let resp = h.wait().expect("sequence dropped during shutdown");
        assert_eq!(resp.tokens.len(), 16);
    }
}

/// KV allocator invariant (ISSUE 2 satellite): across a full serve cycle
/// no block leaks once every sequence has walked Drain -> Done, and the
/// pool never exceeds its global budget mid-flight.
#[test]
fn cache_blocks_never_leak_after_drain_done() {
    let mut cfg = base_cfg();
    cfg.cache = CacheConfig {
        enabled: true,
        block_tokens: 4,
        max_blocks: 32,
        ..CacheConfig::default()
    };
    let mut b = mk_batcher(cfg);
    let lens = [1usize, 5, 12, 20];
    let rxs: Vec<_> = lens
        .iter()
        .enumerate()
        .map(|(i, &len)| {
            let (req, rx) =
                mk_request(i as u64 + 1, vec![50 + i as u32, 1, 2], len, 0.6);
            b.admit(req);
            rx
        })
        .collect();
    while b.active() > 0 {
        b.step();
        assert!(
            b.cache().used_blocks() <= b.cache().pool().capacity(),
            "block budget exceeded"
        );
    }
    for (h, &len) in rxs.into_iter().zip(&lens) {
        assert_eq!(h.wait().unwrap().tokens.len(), len);
    }
    assert_eq!(b.cache().used_blocks(), 0, "Drain->Done leaked blocks");
    let stats = b.cache().stats();
    assert_eq!(stats.allocated, stats.freed, "alloc/free imbalance");
    // The record_lookup feed saw both cold prefixes and warm hits.
    assert!(stats.miss_tokens > 0, "no cold positions recorded");
    assert!(stats.hit_tokens > 0, "no resident positions recorded");
}

/// Refcounts on REAL DySpec trees: leasing a built tree, rolling back the
/// rejected branches, and ending the round returns the pool exactly to
/// its pre-round state — and eviction pressure can never free a block the
/// in-flight lease still references.
#[test]
fn tree_rollback_and_eviction_respect_refcounts_on_real_trees() {
    let cfg = EngineConfig {
        tree_budget: 24,
        ..EngineConfig::default()
    };
    let mut manager = CacheManager::new(&CacheConfig {
        enabled: true,
        block_tokens: 2,
        max_blocks: 64,
        ..CacheConfig::default()
    });
    // A warm co-resident sequence that eviction may legally reclaim.
    let warm_prefix = vec![9u32; 10];
    manager.begin_round(7, &warm_prefix);
    manager.commit(7, 0, &warm_prefix, &[]);
    let baseline = manager.used_blocks();

    for seed in 0..10u64 {
        let (mut draft, _) = SimModel::pair(SimSpec::new(64, 2.0, 0.8, seed));
        let mut rng = Rng::new(seed);
        let prefix = vec![3, 1, 4, 1, 5];
        let tree = DySpecPolicy.build(&mut draft, &prefix, &cfg, &mut rng);
        let lease = manager.lease_tree(&tree);
        // Every tracked node's blocks are live while the lease is.
        let tracked: Vec<usize> =
            (1..tree.num_nodes()).filter_map(|id| lease.node_tail(id)).collect();
        for &blk in &tracked {
            assert!(manager.pool().refcount(blk) > 0);
        }
        // Budget pressure mid-lease: evicting the warm sequence must not
        // free any leased block.
        if seed == 0 {
            assert!(manager.evict_lru());
            for &blk in &tracked {
                assert!(
                    manager.pool().refcount(blk) > 0,
                    "eviction freed a leased block"
                );
            }
        }
        // Accept the heaviest first-layer path arbitrarily: first child
        // chain; everything else is a rejected branch.
        let mut accepted = Vec::new();
        let mut cur = dyspec::tree::ROOT;
        while let Some(&child) = tree.node(cur).children.first() {
            accepted.push(child);
            cur = child;
        }
        manager.end_lease(lease, &tree, &accepted);
        // Seed 0 evicted the only resident sequence mid-lease, so from
        // then on every round must return the pool to empty; before that
        // eviction the baseline was the warm sequence's blocks.
        assert_eq!(
            manager.used_blocks(),
            0,
            "seed {seed}: lease did not return the pool to baseline"
        );
    }
    assert!(baseline > 0, "warm sequence held no blocks");
    let stats = manager.stats();
    assert_eq!(stats.allocated, stats.freed, "alloc/free imbalance");
}

/// Chunked prefill bounds head-of-line blocking (ISSUE 10): with a long
/// cold prompt co-batched against a chatter, every step the chatter takes
/// part in bills at most its OWN round cost plus `prefill_chunk` prompt
/// positions — never the long prompt in one lump, which is exactly what
/// the one-shot path does on its first co-batched step.
#[test]
fn chunked_prefill_bounds_co_batched_billing() {
    const CHUNK: usize = 16;
    let long_prompt: Vec<u32> = (0..200u32).map(|k| k % 64).collect();

    let mk = |chunk: usize| {
        let mut cfg = base_cfg();
        cfg.cache.block_tokens = 4;
        cfg.engine.prefill_chunk = chunk;
        cfg.sched.prefill_budget = chunk;
        mk_batcher(cfg)
    };

    // One-shot reference: the cold long prompt lands entirely inside the
    // chatter's first co-batched step.
    let mut b = mk(0);
    let (long_req, _lh) = mk_request(1, long_prompt.clone(), 4, 0.6);
    let (short_req, _sh) = mk_request(2, vec![3, 1, 4], 4, 0.6);
    b.admit(long_req);
    b.admit(short_req);
    let rep = b.step();
    assert!(
        rep.billed_positions >= long_prompt.len(),
        "one-shot first step billed {} < the {}-token prompt",
        rep.billed_positions,
        long_prompt.len()
    );

    // Chunked: the long prompt enters as chunk rows, each bounded by the
    // grant, so the chatter's per-step bill is its own cost + <= CHUNK.
    let mut b = mk(CHUNK);
    let (long_req, lh) = mk_request(1, long_prompt.clone(), 4, 0.6);
    let (short_req, sh) = mk_request(2, vec![3, 1, 4], 4, 0.6);
    b.admit(long_req);
    b.admit(short_req);
    let mut saw_interleaved_chunk = false;
    while b.active() > 0 {
        let rep = b.step();
        assert!(rep.prefill_tokens <= CHUNK, "chunk grant exceeded");
        if rep.prefill_chunks > 0 && rep.billed.len() == 2 {
            saw_interleaved_chunk = true;
            // active-set order: long (id 1) first, then the chatter.
            let own = rep.billed[1];
            assert_eq!(
                rep.billed[0], rep.prefill_tokens,
                "chunk row billed beyond its grant"
            );
            assert!(
                rep.billed_positions <= own + CHUNK,
                "HOL bound broken: step billed {} > own {} + chunk {}",
                rep.billed_positions,
                own,
                CHUNK
            );
        }
    }
    assert!(saw_interleaved_chunk, "no co-batched chunk step observed");
    assert_eq!(lh.wait().unwrap().tokens.len(), 4);
    assert_eq!(sh.wait().unwrap().tokens.len(), 4);
}

/// A sequence cancelled mid-prefill releases everything it holds: cache
/// residency drains to zero and the prefill in-flight gauge does not
/// stick at the committed chunk positions.
#[test]
fn cancel_mid_prefill_releases_residency_and_gauges() {
    let mut cfg = base_cfg();
    cfg.cache.block_tokens = 4;
    cfg.engine.prefill_chunk = 8;
    cfg.sched.prefill_budget = 8;
    let metrics = Arc::new(Metrics::new());
    let (d, t) = sim_pair(17);
    let mut b =
        Batcher::new(0, cfg, Box::new(d), Box::new(t), metrics.clone());

    let long_prompt: Vec<u32> = (0..100u32).map(|k| k % 64).collect();
    let (req, h) = mk_request(1, long_prompt, 8, 0.6);
    b.admit(req);
    b.step();
    b.step();
    assert_eq!(
        metrics.prefill_tokens_in_flight(),
        16,
        "two 8-token chunks should be in flight"
    );
    assert!(b.cache().used_blocks() > 0, "chunks committed no residency");

    h.cancel.cancel();
    let rep = b.step();
    assert_eq!(rep.cancelled, 1);
    let resp = h.wait().unwrap();
    assert_eq!(resp.finish, dyspec::coordinator::FinishReason::Cancelled);
    assert!(resp.tokens.is_empty(), "mid-prefill seq emitted tokens");
    assert_eq!(b.cache().used_blocks(), 0, "cancel leaked blocks");
    assert_eq!(
        metrics.prefill_tokens_in_flight(),
        0,
        "prefill gauge stuck after cancel"
    );
    assert_eq!(metrics.prefill_chunks(), 2);
    assert_eq!(metrics.prefill_tokens(), 16);
}

#[test]
fn mixed_lengths_retire_incrementally() {
    // Different max_new_tokens finish at different steps; the batcher must
    // retire them individually while the rest keep going.
    let mut b = mk_batcher(base_cfg());
    let lens = [2usize, 6, 14];
    let rxs: Vec<_> = lens
        .iter()
        .enumerate()
        .map(|(i, &len)| {
            let (req, rx) = mk_request(i as u64 + 1, vec![7 + i as u32], len, 0.6);
            b.admit(req);
            rx
        })
        .collect();
    let mut max_active_seen = 0;
    while b.active() > 0 {
        max_active_seen = max_active_seen.max(b.active());
        b.step();
    }
    assert_eq!(max_active_seen, 3);
    for (h, &len) in rxs.into_iter().zip(&lens) {
        assert_eq!(h.wait().unwrap().tokens.len(), len);
    }
}
