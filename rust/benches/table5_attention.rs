//! Regenerates the paper's table5 (see DESIGN.md §5). Shares the runner with
//! `dyspec bench --experiment table5`.
use dyspec::bench::experiments::{run_experiment, ExpOpts};

fn main() {
    let opts = ExpOpts {
        prompts: std::env::var("DYSPEC_BENCH_PROMPTS").ok().and_then(|v| v.parse().ok()).unwrap_or(6),
        out: Some("results/table5.json".into()),
        ..ExpOpts::default()
    };
    for table in run_experiment("table5", &opts).expect("experiment") {
        println!("{}", table.render());
    }
}
