//! KV prefix-cache benchmark, two sweeps (see DESIGN.md §KV cache and
//! §Radix Prefix Cache): cached vs uncached verification cost as one
//! request's context grows, and radix-on vs radix-off cost for N clients
//! sharing a system prompt (the cross-request warm start). Shares the
//! runner with `dyspec bench --experiment cache` and records the result
//! as BENCH_cache.json at the repo root to seed the perf trajectory.
//! Env: DYSPEC_BENCH_PROMPTS (prompts per cell), DYSPEC_BENCH_TOKENS.
use dyspec::bench::experiments::{run_experiment, ExpOpts};

fn main() {
    let opts = ExpOpts {
        prompts: std::env::var("DYSPEC_BENCH_PROMPTS").ok().and_then(|v| v.parse().ok()).unwrap_or(4),
        max_new_tokens: std::env::var("DYSPEC_BENCH_TOKENS").ok().and_then(|v| v.parse().ok()).unwrap_or(64),
        out: Some("../BENCH_cache.json".into()),
        ..ExpOpts::default()
    };
    for table in run_experiment("cache", &opts).expect("experiment") {
        println!("{}", table.render());
    }
}
