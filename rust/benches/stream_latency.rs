//! Streaming-latency benchmark: client-observed TTFT + inter-chunk gaps,
//! protocol-v1 streaming vs one-shot replies, at 1/4/16 closed-loop
//! clients over real TCP (see DESIGN.md §Serving API v1). Shares the
//! runner with `dyspec bench --experiment stream` and records the result
//! as BENCH_stream.json at the repo root to seed the perf trajectory.
//! Env: DYSPEC_BENCH_PROMPTS (requests per client), DYSPEC_BENCH_TOKENS.
use dyspec::bench::experiments::{run_experiment, ExpOpts};

fn main() {
    let opts = ExpOpts {
        prompts: std::env::var("DYSPEC_BENCH_PROMPTS").ok().and_then(|v| v.parse().ok()).unwrap_or(4),
        max_new_tokens: std::env::var("DYSPEC_BENCH_TOKENS").ok().and_then(|v| v.parse().ok()).unwrap_or(64),
        out: Some("../BENCH_stream.json".into()),
        ..ExpOpts::default()
    };
    for table in run_experiment("stream", &opts).expect("experiment") {
        println!("{}", table.render());
    }
}
