//! Microbenchmarks of the L3 hot paths — the profile targets for the §Perf
//! pass: tree construction (heap), sibling sampling, mask build, DFS
//! reorder, block counting, verification walk, and the sim model dist.
//! Reports ns/op with warmup + repetition (criterion-style, hand-rolled).

use dyspec::bench::time_repeated;
use dyspec::config::EngineConfig;
use dyspec::draft::dyspec::DySpecPolicy;
use dyspec::draft::TreePolicy;
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::models::LogitModel;
use dyspec::sampling::SiblingSampler;
use dyspec::tree::{block_count, dfs_order, TreeMask};
use dyspec::util::Rng;
use dyspec::verify::{row_map, verify_tree};

fn report(name: &str, secs_per_op: f64, unit: &str) {
    let (scaled, suffix) = if secs_per_op < 1e-6 {
        (secs_per_op * 1e9, "ns")
    } else if secs_per_op < 1e-3 {
        (secs_per_op * 1e6, "us")
    } else {
        (secs_per_op * 1e3, "ms")
    };
    println!("{name:<38} {scaled:>10.2} {suffix}/{unit}");
}

fn main() {
    let spec = SimSpec::for_dataset("c4", 1.2, 42);
    let prefix: Vec<u32> = (0..128).map(|i| (i * 13 + 7) % 512).collect();
    let cfg = EngineConfig {
        tree_budget: 64,
        ..EngineConfig::default()
    };

    // Full Algorithm-1 build, including sim draft calls.
    {
        let (mut draft, _) = SimModel::pair(spec);
        let mut rng = Rng::new(1);
        let per = time_repeated(3, 30, || {
            let t = DySpecPolicy.build(&mut draft, &prefix, &cfg, &mut rng);
            std::hint::black_box(t.size());
        });
        report("dyspec_build (budget 64, sim draft)", per, "tree");
    }

    // Construction logic only: pre-drawn dists.
    {
        struct Canned {
            dists: Vec<Vec<f32>>,
            i: std::cell::Cell<usize>,
        }
        impl LogitModel for Canned {
            fn vocab(&self) -> usize {
                512
            }
            fn next_logits(&mut self, _ctx: &[u32]) -> Vec<f32> {
                let i = self.i.get();
                self.i.set((i + 1) % self.dists.len());
                self.dists[i].clone()
            }
        }
        let mut rng = Rng::new(2);
        let dists: Vec<Vec<f32>> = (0..128)
            .map(|_| (0..512).map(|_| rng.next_gaussian() as f32 * 3.0).collect())
            .collect();
        let mut model = Canned {
            dists,
            i: std::cell::Cell::new(0),
        };
        let mut rng = Rng::new(3);
        let per = time_repeated(3, 50, || {
            let t = DySpecPolicy.build(&mut model, &prefix, &cfg, &mut rng);
            std::hint::black_box(t.size());
        });
        report("dyspec_build (canned dists)", per, "tree");
    }

    // Sibling sampler draw.
    {
        let mut rng = Rng::new(4);
        let dist: Vec<f32> = {
            let mut d: Vec<f32> = (0..512).map(|_| rng.next_f32() + 1e-3).collect();
            dyspec::util::math::normalize(&mut d);
            d
        };
        let per = time_repeated(10, 2000, || {
            let mut s = SiblingSampler::new(dist.clone());
            for _ in 0..8 {
                std::hint::black_box(s.draw(&mut rng));
            }
        });
        report("sibling_sampler (8 draws, V=512)", per, "op");
    }

    // Tree -> mask -> dfs -> block count over a 64-node DySpec tree.
    let tree = {
        let (mut draft, _) = SimModel::pair(spec);
        let mut rng = Rng::new(5);
        DySpecPolicy.build(&mut draft, &prefix, &cfg, &mut rng)
    };
    {
        let per = time_repeated(10, 500, || {
            std::hint::black_box(dfs_order(&tree).len());
        });
        report("dfs_order (64 nodes)", per, "op");
        let order = dfs_order(&tree);
        let per = time_repeated(10, 500, || {
            std::hint::black_box(TreeMask::from_tree(&tree, &order).count_ones());
        });
        report("tree_mask_build (64 nodes)", per, "op");
        let mask = TreeMask::from_tree(&tree, &order);
        let per = time_repeated(10, 500, || {
            std::hint::black_box(block_count(&mask, 32));
        });
        report("block_count (64 nodes, b=32)", per, "op");
        let per = time_repeated(3, 100, || {
            std::hint::black_box(mask.to_full_f32(128, 320).len());
        });
        report("full_mask_f32 (S=320)", per, "op");
    }

    // Verification walk.
    {
        let order = dfs_order(&tree);
        let row_of = row_map(&tree, &order);
        let mut rng = Rng::new(6);
        let dists: Vec<Vec<f32>> = (0..order.len() + 1)
            .map(|_| {
                let mut d: Vec<f32> = (0..512).map(|_| rng.next_f32() + 1e-3).collect();
                dyspec::util::math::normalize(&mut d);
                d
            })
            .collect();
        let per = time_repeated(10, 500, || {
            std::hint::black_box(verify_tree(&tree, &dists, &row_of, &mut rng).emitted);
        });
        report("verify_tree (64 nodes)", per, "op");
    }

    // Sim model dist generation (the bench population driver).
    {
        let (mut draft, _) = SimModel::pair(spec);
        let mut i = 0u32;
        let per = time_repeated(10, 1000, || {
            i += 1;
            std::hint::black_box(draft.next_logits(&[i, 1, 2]).len());
        });
        report("sim_next_logits (V=512)", per, "op");
    }
}
