//! Adaptive-policy benchmark: accepted tokens/round on a mixed-temperature
//! workload, each static drafter vs online-adaptive selection over the same
//! set (see DESIGN.md §Adaptive Policy). Shares the runner with
//! `dyspec bench --experiment adaptive` and records the result as
//! BENCH_adaptive.json at the repo root to seed the perf trajectory.
//! Env: DYSPEC_BENCH_PROMPTS (requests per client), DYSPEC_BENCH_TOKENS.
use dyspec::bench::experiments::{run_experiment, ExpOpts};

fn main() {
    let opts = ExpOpts {
        prompts: std::env::var("DYSPEC_BENCH_PROMPTS").ok().and_then(|v| v.parse().ok()).unwrap_or(4),
        max_new_tokens: std::env::var("DYSPEC_BENCH_TOKENS").ok().and_then(|v| v.parse().ok()).unwrap_or(64),
        out: Some("../BENCH_adaptive.json".into()),
        ..ExpOpts::default()
    };
    for table in run_experiment("adaptive", &opts).expect("experiment") {
        println!("{}", table.render());
    }
}
