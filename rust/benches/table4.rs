//! Regenerates the paper's Table4 (see DESIGN.md §5). Shares the runner
//! with `dyspec bench --experiment table4`. Env: DYSPEC_BENCH_PROMPTS,
//! DYSPEC_BENCH_TOKENS scale the population (paper: 1000 x 128).
use dyspec::bench::experiments::{run_experiment, ExpOpts};

fn main() {
    let opts = ExpOpts {
        prompts: std::env::var("DYSPEC_BENCH_PROMPTS").ok().and_then(|v| v.parse().ok()).unwrap_or(6),
        max_new_tokens: std::env::var("DYSPEC_BENCH_TOKENS").ok().and_then(|v| v.parse().ok()).unwrap_or(128),
        out: Some("results/table4.json".into()),
        ..ExpOpts::default()
    };
    for table in run_experiment("table4", &opts).expect("experiment") {
        println!("{}", table.render());
    }
}
