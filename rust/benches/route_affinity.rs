//! Router benchmark: prefix-affinity vs round-robin routing at 1 and 4
//! workers on a shared-prefix workload (see DESIGN.md §Router Tier).
//! Shares the runner with `dyspec bench --experiment route` and records
//! the result as BENCH_route.json at the repo root to seed the perf
//! trajectory. Env: DYSPEC_BENCH_PROMPTS (requests per prefix group),
//! DYSPEC_BENCH_TOKENS.
use dyspec::bench::experiments::{run_experiment, ExpOpts};

fn main() {
    let opts = ExpOpts {
        prompts: std::env::var("DYSPEC_BENCH_PROMPTS").ok().and_then(|v| v.parse().ok()).unwrap_or(4),
        max_new_tokens: std::env::var("DYSPEC_BENCH_TOKENS").ok().and_then(|v| v.parse().ok()).unwrap_or(64),
        out: Some("../BENCH_route.json".into()),
        ..ExpOpts::default()
    };
    for table in run_experiment("route", &opts).expect("experiment") {
        println!("{}", table.render());
    }
}
