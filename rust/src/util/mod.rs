//! Dependency-free utilities shared across the stack: deterministic RNG,
//! numeric helpers, latency statistics, a minimal JSON writer/reader, and a
//! leveled logger. Everything here is deliberately boring; the substance of
//! the reproduction lives in `tree`, `draft`, `verify` and `engine`.

pub mod error;
pub mod json;
pub mod log;
pub mod math;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use stats::{AtomicF64, Histogram};
pub use timer::Timer;
