//! Deterministic RNG: SplitMix64 core (bit-identical with
//! `python/compile/corpus.py` — the cross-language corpus contract) plus a
//! xoshiro256** generator seeded from it for bulk sampling.

/// One SplitMix64 step; returns the output and advances `state`.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// SplitMix64 stream — matches `corpus.SplitMix64` in python exactly.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        splitmix64(&mut self.state)
    }

    /// Uniform in [0, 1) with 53-bit mantissa; same contract as python.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Simple modulo draw — bias is irrelevant at
    /// our vocab sizes and it is the easiest contract to keep identical
    /// across languages (python mirrors this exactly).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }
}

/// xoshiro256** 1.0 — the general-purpose generator for everything that does
/// NOT need to match python (verification draws, workload jitter, ...).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Seed the state from a SplitMix64 stream, per the xoshiro authors.
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Derive an independent child stream (for per-request / per-worker RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Standard normal via Box-Muller (used by the sim models).
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix64_golden_seed42() {
        // Same goldens as python/tests/test_corpus.py::test_splitmix64_golden.
        let mut rng = SplitMix64::new(42);
        assert_eq!(rng.next_u64(), 13679457532755275413);
        let rest: Vec<u64> = (0..3).map(|_| rng.next_u64()).collect();
        assert_eq!(
            rest,
            vec![2949826092126892291, 5139283748462763858, 6349198060258255764]
        );
    }

    #[test]
    fn splitmix_f64_unit_interval() {
        let mut rng = SplitMix64::new(7);
        let xs: Vec<f64> = (0..1000).map(|_| rng.next_f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((0.4..0.6).contains(&mean), "mean={mean}");
    }

    #[test]
    fn xoshiro_deterministic_and_forked_streams_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut fork = a.fork();
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| fork.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn next_below_bounds() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            assert!(rng.next_below(17) < 17);
        }
    }
}
