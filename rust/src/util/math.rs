//! Numeric helpers over probability vectors: softmax with temperature,
//! log-sum-exp, normalization, KL/TV distances. Distributions are plain
//! `Vec<f32>`/`&[f32]`; all helpers keep vectors finite and normalized.

/// Numerically-stable log-sum-exp.
pub fn log_sum_exp(xs: &[f32]) -> f32 {
    let m = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    let sum: f32 = xs.iter().map(|&x| (x - m).exp()).sum();
    m + sum.ln()
}

/// Softmax with temperature. `temp == 0` means greedy: a one-hot on the
/// argmax (ties broken toward the lowest index), which is how the paper's
/// temperature-0 rows are defined.
pub fn softmax_temp(logits: &[f32], temp: f32) -> Vec<f32> {
    if temp <= 0.0 {
        let mut out = vec![0.0; logits.len()];
        out[argmax(logits)] = 1.0;
        return out;
    }
    let scaled: Vec<f32> = logits.iter().map(|&x| x / temp).collect();
    let lse = log_sum_exp(&scaled);
    scaled.iter().map(|&x| (x - lse).exp()).collect()
}

/// Index of the maximum element (first on ties). Panics on empty input.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate().skip(1) {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Normalize in place to sum 1. Returns false (leaving the input zeroed) if
/// the total mass is not positive — the caller must handle exhaustion, which
/// is exactly DySpec's Algorithm-3 early-return condition.
pub fn normalize(xs: &mut [f32]) -> bool {
    let sum: f32 = xs.iter().sum();
    if sum <= 0.0 || !sum.is_finite() {
        xs.iter_mut().for_each(|x| *x = 0.0);
        return false;
    }
    let inv = 1.0 / sum;
    xs.iter_mut().for_each(|x| *x *= inv);
    true
}

/// The speculative-decoding residual `norm(relu(t - d))`, used after a
/// rejection to keep the output distribution unbiased. Returns false if the
/// residual has no mass (t <= d pointwise), in which case `out` is zeroed.
pub fn residual(t: &[f32], d: &[f32], out: &mut Vec<f32>) -> bool {
    out.clear();
    out.extend(t.iter().zip(d).map(|(&ti, &di)| (ti - di).max(0.0)));
    normalize(out)
}

/// KL(p || q) in nats, with the usual 0 log 0 = 0 convention.
pub fn kl_divergence(p: &[f32], q: &[f32]) -> f32 {
    p.iter()
        .zip(q)
        .filter(|(&pi, _)| pi > 0.0)
        .map(|(&pi, &qi)| pi * (pi / qi.max(1e-12)).ln())
        .sum()
}

/// Total-variation distance 0.5 * Σ|p - q|.
pub fn tv_distance(p: &[f32], q: &[f32]) -> f32 {
    0.5 * p
        .iter()
        .zip(q)
        .map(|(&pi, &qi)| (pi - qi).abs())
        .sum::<f32>()
}

/// Shannon entropy in nats.
pub fn entropy(p: &[f32]) -> f32 {
    -p.iter()
        .filter(|&&x| x > 0.0)
        .map(|&x| x * x.ln())
        .sum::<f32>()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f32, b: f32, tol: f32) {
        assert!((a - b).abs() < tol, "{a} vs {b}");
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax_temp(&[0.5, -1.0, 3.0, 0.0], 1.0);
        assert_close(p.iter().sum::<f32>(), 1.0, 1e-6);
        assert!(p.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn softmax_temp_zero_is_argmax_onehot() {
        let p = softmax_temp(&[0.5, 3.0, -1.0], 0.0);
        assert_eq!(p, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn softmax_low_temp_sharpens() {
        let hot = softmax_temp(&[1.0, 2.0, 3.0], 1.0);
        let cold = softmax_temp(&[1.0, 2.0, 3.0], 0.25);
        assert!(cold[2] > hot[2]);
    }

    #[test]
    fn softmax_handles_large_logits() {
        let p = softmax_temp(&[1e4, 1e4 - 1.0], 1.0);
        assert!(p.iter().all(|x| x.is_finite()));
        // f32 exp/ln at this magnitude costs a few ulps of mass
        assert_close(p.iter().sum::<f32>(), 1.0, 1e-3);
    }

    #[test]
    fn normalize_zero_mass_reports_false() {
        let mut xs = vec![0.0, 0.0];
        assert!(!normalize(&mut xs));
        assert_eq!(xs, vec![0.0, 0.0]);
    }

    #[test]
    fn residual_relu_norm() {
        let t = vec![0.5, 0.3, 0.2];
        let d = vec![0.7, 0.1, 0.2];
        let mut r = Vec::new();
        assert!(residual(&t, &d, &mut r));
        assert_close(r[0], 0.0, 1e-6);
        assert_close(r[1], 1.0, 1e-6);
        assert_close(r[2], 0.0, 1e-6);
    }

    #[test]
    fn residual_exhausted_when_t_le_d() {
        let t = vec![0.5, 0.5];
        let mut r = Vec::new();
        assert!(!residual(&t, &t.clone(), &mut r));
    }

    #[test]
    fn kl_zero_iff_equal() {
        let p = vec![0.25, 0.75];
        assert_close(kl_divergence(&p, &p), 0.0, 1e-6);
        let q = vec![0.75, 0.25];
        assert!(kl_divergence(&p, &q) > 0.0);
    }

    #[test]
    fn tv_bounds() {
        let p = vec![1.0, 0.0];
        let q = vec![0.0, 1.0];
        assert_close(tv_distance(&p, &q), 1.0, 1e-6);
        assert_close(tv_distance(&p, &p), 0.0, 1e-6);
    }

    #[test]
    fn entropy_uniform_is_log_n() {
        let p = vec![0.25; 4];
        assert_close(entropy(&p), (4.0f32).ln(), 1e-5);
    }
}
