//! Leveled stderr logger. Level comes from `DYSPEC_LOG` (error|warn|info|
//! debug|trace, default info). Deliberately tiny: no timestamps by default,
//! no global registry — serving output goes through `coordinator::metrics`.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn init_level() -> u8 {
    let lvl = match std::env::var("DYSPEC_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == 255 {
        cur = init_level();
    }
    (level as u8) <= cur
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[dyspec {tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_level_is_info() {
        // (Assumes DYSPEC_LOG unset in the test environment; if set, the
        // ordering property below still holds.)
        if std::env::var("DYSPEC_LOG").is_err() {
            assert!(enabled(Level::Info));
            assert!(enabled(Level::Warn));
            assert!(!enabled(Level::Trace));
        }
    }

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
    }
}
