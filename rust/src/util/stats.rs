//! Latency statistics: a log-bucketed quantile histogram (bounded memory,
//! ~9% worst-case relative quantile error), running mean/min/max, and a
//! lock-free f64 accumulator. Used by the bench harness, the coordinator
//! metrics, and the observability layer.

use std::sync::atomic::{AtomicU64, Ordering};

/// Buckets per power of two. Four sub-buckets per octave bounds the
/// relative quantile error at 2^(1/8) − 1 ≈ 9.1% (a reported quantile is
/// the geometric midpoint of its bucket).
const BUCKETS_PER_OCTAVE: usize = 4;
/// Smallest resolvable magnitude: 2^-30 ≈ 1 ns when recording seconds.
/// Anything at or below it (including 0) lands in bucket 0.
const MIN_EXP: i32 = -30;
/// Octaves covered: 2^-30 .. 2^34 ≈ 1.7e10 — nanoseconds to centuries.
const OCTAVES: usize = 64;
const NUM_BUCKETS: usize = OCTAVES * BUCKETS_PER_OCTAVE;

/// Log-bucketed histogram with exact count/sum/min/max. Replaces the old
/// exact-sample reservoir: serving-path histograms grow without bound on
/// samples, while buckets are O(1) per record and fixed-size forever.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

fn bucket_of(v: f64) -> usize {
    if !(v > 0.0) || v.log2() < MIN_EXP as f64 {
        return 0;
    }
    let idx = ((v.log2() - MIN_EXP as f64) * BUCKETS_PER_OCTAVE as f64) as usize;
    idx.min(NUM_BUCKETS - 1)
}

/// Geometric midpoint of a bucket.
fn representative(idx: usize) -> f64 {
    let exp = MIN_EXP as f64 + (idx as f64 + 0.5) / BUCKETS_PER_OCTAVE as f64;
    exp.exp2()
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; NUM_BUCKETS],
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn record(&mut self, v: f64) {
        self.n += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.counts[bucket_of(v)] += 1;
    }

    pub fn len(&self) -> usize {
        self.n as usize
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.sum / self.n as f64
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Nearest-rank percentile (q in [0, 1]) to within one bucket's
    /// resolution; q = 0 and q = 1 return the tracked exact min/max, and
    /// every answer is clamped into [min, max].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        if q <= 0.0 {
            return self.min;
        }
        if q >= 1.0 {
            return self.max;
        }
        let rank = ((q * self.n as f64).ceil() as u64).clamp(1, self.n);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return representative(idx).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p90(&self) -> f64 {
        self.percentile(0.90)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Running scalar aggregate without sample storage (hot-loop safe).
#[derive(Clone, Copy, Debug, Default)]
pub struct Running {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self {
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    #[inline]
    pub fn record(&mut self, v: f64) {
        self.n += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

/// Atomic f64 accumulator over `to_bits`/`from_bits` CAS — full f64
/// precision, unlike integer-microsecond stand-ins that drop
/// sub-microsecond remainders on every add.
#[derive(Debug, Default)]
pub struct AtomicF64(AtomicU64);

impl AtomicF64 {
    pub fn new(v: f64) -> Self {
        Self(AtomicU64::new(v.to_bits()))
    }

    pub fn load(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    pub fn store(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Atomically add `delta`; returns the new value.
    pub fn add(&self, delta: f64) -> f64 {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = f64::from_bits(cur) + delta;
            match self.0.compare_exchange_weak(
                cur,
                next.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return next,
                Err(got) => cur = got,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Quantiles are exact in rank and accurate in value to one log
    /// bucket (≤ ~9.1% relative); the extremes are exact.
    #[test]
    fn percentiles_are_log_bucket_accurate() {
        let mut h = Histogram::new();
        for v in 1..=100 {
            h.record(v as f64);
        }
        for (got, want) in [(h.p50(), 50.0), (h.p90(), 90.0), (h.p99(), 99.0)] {
            assert!(
                (got - want).abs() / want < 0.1,
                "got {got}, want {want} ± 10%"
            );
        }
        assert_eq!(h.percentile(1.0), 100.0);
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(h.len(), 100);
        assert_eq!(h.sum(), 5050.0);
        assert_eq!(h.mean(), 50.5);
    }

    #[test]
    fn quantiles_never_leave_the_observed_range() {
        let mut h = Histogram::new();
        h.record(0.003);
        h.record(0.004);
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
            let v = h.percentile(q);
            assert!((0.003..=0.004).contains(&v), "q={q} gave {v}");
        }
    }

    #[test]
    fn zero_and_negative_samples_land_in_the_bottom_bucket() {
        let mut h = Histogram::new();
        h.record(0.0);
        h.record(-1.0);
        h.record(1e-12);
        assert_eq!(h.len(), 3);
        assert_eq!(h.min(), -1.0);
        // Bucket-0 representative clamps to the tracked min.
        assert_eq!(h.p50(), -1.0);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    /// ISSUE 7 regression: every quantile of an empty histogram — the
    /// extremes included — is a finite 0.0, never the infinity min/max
    /// sentinels and never a panic.
    #[test]
    fn empty_histogram_quantiles_are_finite_at_every_q() {
        let h = Histogram::new();
        for q in [0.0, 0.25, 0.5, 0.99, 1.0, -3.0, 7.0] {
            assert_eq!(h.percentile(q), 0.0, "q={q}");
        }
        assert!(h.is_empty());
        assert_eq!(h.sum(), 0.0);
    }

    /// ISSUE 7 regression: zero, subnormal, and below-resolution (≪ the
    /// 2^-30 ≈ 1 ns floor) magnitudes all index bucket 0 — no negative
    /// index from `log2` of a denormal, no panic, and `log2(0) = -inf`
    /// stays out of the cast entirely. NaN is swallowed by the same
    /// `!(v > 0.0)` guard.
    #[test]
    fn zero_denormal_and_subnanosecond_values_index_bucket_zero() {
        for v in [
            0.0,
            -0.0,
            f64::MIN_POSITIVE, // smallest normal, 2^-1022
            5e-324,            // smallest subnormal
            (MIN_EXP as f64 - 1.0).exp2(), // one octave under the floor
            1e-12,                         // a real sub-ns duration
            f64::NAN,
            f64::NEG_INFINITY,
        ] {
            assert_eq!(bucket_of(v), 0, "v={v}");
        }
        // The floor itself and everything above it index normally…
        assert_eq!(bucket_of((MIN_EXP as f64).exp2()), 0);
        assert!(bucket_of(1e-6) > 0, "1 µs must clear bucket 0");
        // …and the top is clamped, `+inf` included.
        assert_eq!(bucket_of(f64::MAX), NUM_BUCKETS - 1);
        assert_eq!(bucket_of(f64::INFINITY), NUM_BUCKETS - 1);
    }

    /// The bucket index is monotone over positive magnitudes and always
    /// in range — recording any float can never index out of bounds.
    #[test]
    fn bucket_index_is_monotone_and_in_range() {
        let mut last = 0usize;
        let mut v = 1e-15f64;
        while v < 1e15 {
            let idx = bucket_of(v);
            assert!(idx < NUM_BUCKETS, "v={v} idx={idx}");
            assert!(idx >= last, "v={v}: bucket went backwards");
            last = idx;
            v *= 1.5;
        }
    }

    /// ≪1 µs samples quantize into bucket 0 but quantiles still clamp
    /// into the observed [min, max] instead of reporting the bucket-0
    /// representative (~1 ns).
    #[test]
    fn sub_microsecond_quantiles_stay_in_observed_range() {
        let mut h = Histogram::new();
        for v in [2e-10, 5e-10, 8e-10] {
            h.record(v);
        }
        for q in [0.0, 0.5, 0.9, 1.0] {
            let got = h.percentile(q);
            assert!(
                (2e-10..=8e-10).contains(&got),
                "q={q} escaped the range: {got}"
            );
        }
    }

    #[test]
    fn merge_combines_samples() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(1.0);
        b.record(3.0);
        a.merge(&b);
        assert_eq!(a.len(), 2);
        assert_eq!(a.mean(), 2.0);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 3.0);
        assert_eq!(a.percentile(1.0), 3.0);
    }

    #[test]
    fn running_aggregate() {
        let mut r = Running::new();
        for v in [2.0, 4.0, 6.0] {
            r.record(v);
        }
        assert_eq!(r.mean(), 4.0);
        assert_eq!(r.min, 2.0);
        assert_eq!(r.max, 6.0);
        assert_eq!(r.n, 3);
    }

    #[test]
    fn atomic_f64_accumulates_at_full_precision() {
        let a = AtomicF64::new(0.0);
        // Sub-microsecond deltas that a u64-microsecond accumulator
        // truncates to zero.
        for _ in 0..1000 {
            a.add(1e-7);
        }
        assert!((a.load() - 1e-4).abs() < 1e-12);
        a.store(2.5);
        assert_eq!(a.load(), 2.5);
        assert_eq!(a.add(0.5), 3.0);
        let d = AtomicF64::default();
        assert_eq!(d.load(), 0.0);
    }

    #[test]
    fn atomic_f64_is_consistent_across_threads() {
        use std::sync::Arc;
        let a = Arc::new(AtomicF64::new(0.0));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let a = a.clone();
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        a.add(0.125);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // 0.125 is exact in binary: no rounding, the total is exact iff
        // every CAS retried correctly.
        assert_eq!(a.load(), 4.0 * 10_000.0 * 0.125);
    }
}
