//! Latency statistics: an exact-percentile histogram (stores samples; our
//! bench populations are small) plus running mean/min/max. Used by the bench
//! harness and the coordinator metrics.

/// Sample reservoir with exact percentiles.
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    /// Exact percentile by nearest-rank (q in [0, 1]).
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
        let rank = ((q * self.samples.len() as f64).ceil() as usize)
            .clamp(1, self.samples.len());
        self.samples[rank - 1]
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p90(&mut self) -> f64 {
        self.percentile(0.90)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(0.99)
    }

    pub fn merge(&mut self, other: &Histogram) {
        self.samples.extend_from_slice(&other.samples);
        self.sorted = false;
    }
}

/// Running scalar aggregate without sample storage (hot-loop safe).
#[derive(Clone, Copy, Debug, Default)]
pub struct Running {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self {
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    #[inline]
    pub fn record(&mut self, v: f64) {
        self.n += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_exact() {
        let mut h = Histogram::new();
        for v in 1..=100 {
            h.record(v as f64);
        }
        assert_eq!(h.p50(), 50.0);
        assert_eq!(h.p90(), 90.0);
        assert_eq!(h.p99(), 99.0);
        assert_eq!(h.percentile(1.0), 100.0);
        assert_eq!(h.percentile(0.0), 1.0);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let mut h = Histogram::new();
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn merge_combines_samples() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(1.0);
        b.record(3.0);
        a.merge(&b);
        assert_eq!(a.len(), 2);
        assert_eq!(a.mean(), 2.0);
    }

    #[test]
    fn running_aggregate() {
        let mut r = Running::new();
        for v in [2.0, 4.0, 6.0] {
            r.record(v);
        }
        assert_eq!(r.mean(), 4.0);
        assert_eq!(r.min, 2.0);
        assert_eq!(r.max, 6.0);
        assert_eq!(r.n, 3);
    }
}
