//! Component timing: a scoped stopwatch plus a named-section accumulator
//! used by the engine to produce the paper's Fig-4 execution breakdown.

use std::collections::BTreeMap;
use std::time::Instant;

/// Simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_secs();
        self.start = Instant::now();
        e
    }
}

/// Accumulates wall time per named component (draft inference, target
/// inference, tree construction, mask generation, sampling, verification —
/// the exact bars of the paper's Fig. 4).
#[derive(Clone, Debug, Default)]
pub struct ComponentTimes {
    totals: BTreeMap<&'static str, f64>,
}

impl ComponentTimes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a component label.
    pub fn time<T>(&mut self, label: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(label, t.elapsed().as_secs_f64());
        out
    }

    pub fn add(&mut self, label: &'static str, secs: f64) {
        *self.totals.entry(label).or_insert(0.0) += secs;
    }

    pub fn get(&self, label: &str) -> f64 {
        self.totals.get(label).copied().unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.totals.values().sum()
    }

    pub fn merge(&mut self, other: &ComponentTimes) {
        for (k, v) in &other.totals {
            *self.totals.entry(k).or_insert(0.0) += v;
        }
    }

    /// (label, seconds, fraction-of-total), descending by time.
    pub fn breakdown(&self) -> Vec<(&'static str, f64, f64)> {
        let total = self.total().max(1e-12);
        let mut rows: Vec<_> = self
            .totals
            .iter()
            .map(|(&k, &v)| (k, v, v / total))
            .collect();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn component_times_accumulate() {
        let mut ct = ComponentTimes::new();
        ct.add("draft", 0.5);
        ct.add("draft", 0.5);
        ct.add("target", 3.0);
        assert_eq!(ct.get("draft"), 1.0);
        assert_eq!(ct.total(), 4.0);
        let rows = ct.breakdown();
        assert_eq!(rows[0].0, "target");
        assert!((rows[0].2 - 0.75).abs() < 1e-9);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut ct = ComponentTimes::new();
        let x = ct.time("x", || 41 + 1);
        assert_eq!(x, 42);
        assert!(ct.get("x") >= 0.0);
    }

    #[test]
    fn merge_sums() {
        let mut a = ComponentTimes::new();
        let mut b = ComponentTimes::new();
        a.add("k", 1.0);
        b.add("k", 2.0);
        a.merge(&b);
        assert_eq!(a.get("k"), 3.0);
    }
}
