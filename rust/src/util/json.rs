//! Minimal JSON: a writer for bench/metrics output and a reader sufficient
//! for `artifacts/meta.json` / `golden.json` and the line protocol of the
//! server. Not a general-purpose parser — but a strict-enough subset with
//! proper string escaping, nested containers, and numbers.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `meta.at(&["models", "target", "total_f32"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for key in path {
            cur = cur.get(key)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns Err(position, message) on malformed input.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut pos = 0;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing data at byte {pos}"));
    }
    Ok(value)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, val: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(val)
    } else {
        Err(format!("bad literal at byte {pos}", pos = *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err("truncated \\u escape".into());
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape")?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| "bad \\u escape")?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => return Err(format!("bad escape \\{}", c as char)),
                }
                *pos += 1;
            }
            _ => {
                // Consume one UTF-8 scalar.
                let s = std::str::from_utf8(&b[*pos..]).map_err(|e| e.to_string())?;
                let c = s.chars().next().ok_or("empty")?;
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // [
    let mut items = Vec::new();
    loop {
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == b']' {
            *pos += 1;
            return Ok(Json::Arr(items));
        }
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {}
            _ => return Err(format!("expected , or ] at byte {pos}", pos = *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // {
    let mut map = BTreeMap::new();
    loop {
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == b'}' {
            *pos += 1;
            return Ok(Json::Obj(map));
        }
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            return Err(format!("expected key at byte {pos}", pos = *pos));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected : at byte {pos}", pos = *pos));
        }
        *pos += 1;
        map.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {}
            _ => return Err(format!("expected , or }} at byte {pos}", pos = *pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_nested() {
        let doc = Json::obj(vec![
            ("a", Json::Num(1.0)),
            ("b", Json::Arr(vec![Json::Bool(true), Json::Null])),
            (
                "c",
                Json::obj(vec![("s", Json::Str("hi \"there\"\n".into()))]),
            ),
        ]);
        let text = doc.to_string();
        let back = parse(&text).unwrap();
        assert_eq!(doc, back);
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(parse("3.5").unwrap().as_f64(), Some(3.5));
        assert_eq!(parse("-2e3").unwrap().as_f64(), Some(-2000.0));
        assert_eq!(parse("42").unwrap().as_usize(), Some(42));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_ok()); // lenient trailing comma via loop shape
        assert!(parse("nope").is_err());
        assert!(parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn path_access() {
        let doc = parse(r#"{"models":{"target":{"total_f32":123}}}"#).unwrap();
        assert_eq!(
            doc.at(&["models", "target", "total_f32"]).unwrap().as_usize(),
            Some(123)
        );
        assert!(doc.at(&["nope"]).is_none());
    }

    #[test]
    fn unicode_escapes() {
        let doc = parse(r#""A\n""#).unwrap();
        assert_eq!(doc.as_str(), Some("A\n"));
    }

    #[test]
    fn integer_formatting_has_no_decimal_point() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }
}
