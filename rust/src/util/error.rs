//! Dependency-free error plumbing with an anyhow-compatible surface
//! (`Context`, `bail!`, `ensure!`). The crate builds offline with zero
//! external crates; the PJRT layer (`runtime/`) was the only anyhow user
//! and now goes through this shim so the hermetic build stays hermetic.

use std::fmt;

/// String-backed error: the artifact/runtime layer only ever needs to
/// bubble a human-readable message up to the CLI or a test.
#[derive(Clone, Debug)]
pub struct Error(String);

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Self(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Self(e.to_string())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Self(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Self(s.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result` or `Option`, anyhow-style.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        let none: Option<u32> = None;
        none.context("missing value")
    }

    #[test]
    fn context_on_option_and_result() {
        assert_eq!(fails().unwrap_err().to_string(), "missing value");
        let r: std::result::Result<u32, std::io::Error> = Err(
            std::io::Error::new(std::io::ErrorKind::NotFound, "nope"),
        );
        let e = r.with_context(|| format!("reading {}", "x")).unwrap_err();
        assert!(e.to_string().starts_with("reading x: "));
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(7).unwrap_err().to_string().contains("unlucky"));
    }
}
