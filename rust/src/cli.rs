//! Dependency-free CLI parsing: `dyspec <subcommand> [--key value]...
//! [key=value]...`. Subcommands dispatch in main.rs; this module only
//! tokenizes and validates the argument surface.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, PartialEq)]
pub struct Cli {
    pub command: String,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
    /// `--key value` and `key=value` pairs (the two spellings are merged).
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Cli {
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Cli, String> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut positional = Vec::new();
        let mut options = BTreeMap::new();
        let mut flags = Vec::new();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare -- is not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|next| !next.starts_with("--") && !next.contains('='))
                    .unwrap_or(false)
                {
                    options.insert(name.to_string(), it.next().unwrap());
                } else {
                    flags.push(name.to_string());
                }
            } else if let Some((k, v)) = arg.split_once('=') {
                options.insert(k.to_string(), v.to_string());
            } else {
                positional.push(arg);
            }
        }
        Ok(Cli {
            command,
            positional,
            options,
            flags,
        })
    }

    pub fn from_env() -> Result<Cli, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("invalid value for --{key}: {v}")),
        }
    }
}

pub const USAGE: &str = "\
dyspec — speculative decoding with dynamic token trees (paper reproduction)

USAGE:
  dyspec <command> [options] [key=value...]

COMMANDS:
  generate     run one generation (policy=dyspec|sequoia|specinfer|chain|baseline)
  bench        run a paper experiment (--experiment table1|table2|table3|table4|
               table5|fig2|fig4|fig5|fig9|serve|cache|stream|adaptive|route)
  serve        start the TCP serving coordinator (--addr host:port,
               scheduler=fcfs|continuous); wire protocol v1 over the
               reactor transport (reactor_threads=N event loops serve
               every connection — see DESIGN.md §Serving API v1 and
               §Transport; max_conns / outbox_frames bound admission
               and per-connection buffering)
  client       send a prompt to a running server (--addr host:port --dataset c4)
               --stream prints protocol-v1 chunk frames as rounds land;
               --cancel-after N cancels mid-stream and checks the
               finish=cancelled done frame; --drafter / --token_budget /
               --req_id set the per-request envelope fields;
               --conns N opens N concurrent streaming connections (one
               request each) to exercise the reactor pool;
               --stats prints the JSON metrics snapshot, --metrics the
               Prometheus text exposition, --trace the flight-recorder
               span dump as JSONL (trace=on server-side to record spans)
  selfcheck    verify artifacts + PJRT wiring against golden.json
  help         show this text

CONFIG KEYS (key=value, see config/mod.rs):
  policy, tree_budget, threshold, max_depth, temp, draft_temp,
  max_new_tokens, seed, stop_tokens (comma-separated),
  backend (sim|hlo|hlo-pallas), regime (7b|13b|70b),
  dataset (cnn|c4|owt), artifacts, prompt_len, num_prompts, addr, workers,
  scheduler (fcfs|continuous), global_budget, max_active, idle_tick_ms,
  prefill_chunk (tokens per chunked-prefill round, 0 = one-shot prefill),
  prefill_budget (per-step token pool for prefill chunks, 0 = prefill_chunk),
  cache (on|off), cache_block, cache_blocks,
  reactor_threads, max_conns, outbox_frames,
  trace (on|off — per-round span recording + trace-id echo on v1 frames),
  trace_ring (flight-recorder capacity per worker, spans),
  policy_mode (static|adaptive — online drafter/budget selection from the
  acceptance observatory; `policy=adaptive` is accepted as an alias),
  adapt_drafters (comma-separated competing drafters; empty = configured
  policy only), adapt_explore (UCB exploration weight),
  adapt_min_samples (cold-start proposals per drafter),
  adapt_cut (useful-bucket acceptance threshold),
  adapt_min_budget (retuned tree-budget floor),
  route (affinity|rr — prefix-affinity vs round-robin placement over the
  per-worker queues when workers > 1), route_prefix_len (tokens hashed
  for ownership), route_vnodes (ring virtual nodes per worker),
  route_max_depth (owner load before spilling), route_spill (on|off)

EXAMPLES:
  dyspec generate policy=dyspec backend=hlo dataset=cnn temp=0
  dyspec bench --experiment table1 --out results/table1.json
  dyspec bench --experiment stream --out BENCH_stream.json
  dyspec serve --addr 127.0.0.1:7341 backend=sim scheduler=continuous \\
      reactor_threads=4 max_conns=256
  dyspec client --addr 127.0.0.1:7341 --stream max_new_tokens=64
  dyspec client --addr 127.0.0.1:7341 --stream --cancel-after 2
  dyspec client --addr 127.0.0.1:7341 --conns 64 max_new_tokens=16
  dyspec serve --addr 127.0.0.1:7341 backend=sim trace=on
  dyspec client --addr 127.0.0.1:7341 --metrics
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Cli {
        Cli::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let cli = parse(&[
            "bench",
            "--experiment",
            "table1",
            "policy=dyspec",
            "--verbose",
            "--out=x.json",
        ]);
        assert_eq!(cli.command, "bench");
        assert_eq!(cli.opt("experiment"), Some("table1"));
        assert_eq!(cli.opt("policy"), Some("dyspec"));
        assert_eq!(cli.opt("out"), Some("x.json"));
        assert!(cli.has_flag("verbose"));
    }

    #[test]
    fn positional_args() {
        let cli = parse(&["generate", "hello"]);
        assert_eq!(cli.positional, vec!["hello"]);
    }

    #[test]
    fn opt_parse_with_default() {
        let cli = parse(&["bench", "--runs", "5"]);
        assert_eq!(cli.opt_parse("runs", 1usize).unwrap(), 5);
        assert_eq!(cli.opt_parse("missing", 3usize).unwrap(), 3);
        let bad = parse(&["bench", "--runs", "abc"]);
        // "abc" is consumed as the value of --runs
        assert!(bad.opt_parse::<usize>("runs", 1).is_err());
    }

    #[test]
    fn empty_args_default_to_help() {
        let cli = Cli::parse(std::iter::empty::<String>()).unwrap();
        assert_eq!(cli.command, "help");
    }

    #[test]
    fn flag_followed_by_option() {
        let cli = parse(&["serve", "--quiet", "--addr", "0.0.0.0:9"]);
        assert!(cli.has_flag("quiet"));
        assert_eq!(cli.opt("addr"), Some("0.0.0.0:9"));
    }
}
