//! Prefix-affinity router tier: N independent workers behind one
//! admission surface (DESIGN.md §Router Tier).
//!
//! The coordinator used to push every request into ONE shared queue that
//! N workers competed over — correct, but it scatters same-prefix
//! requests across workers, so KV residency (and, later, the
//! cross-request radix cache) dilutes as workers are added. This tier
//! gives every worker its own [`RequestQueue`] (its own engine/batcher,
//! block pool, and obs recorder behind it) and routes each admitted
//! request by consistent-hashing its prompt prefix ([`ring::HashRing`]),
//! so the worker that owns a prefix sees *all* of that prefix's traffic.
//!
//! The tier also owns worker health:
//!
//!   - per-shard `queued`/`inflight` gauges, maintained by wrapping each
//!     request's [`EventSink`] (settled exactly once, on `Done` or on
//!     sink drop — the same path that already guarantees clients an
//!     error when a worker drops a request);
//!   - a spill policy (`route_spill=on`): when the owner's load exceeds
//!     `route_max_depth`, the request goes to the least-loaded healthy
//!     worker instead, and is *counted* as a spill so affinity stats
//!     stay honest;
//!   - deterministic failover: a dead worker's prefixes re-own to the
//!     next live vnode clockwise on the ring, and [`Router::kill`]
//!     cancels everything queued or in flight on the dead shard via the
//!     existing [`CancelToken`] path (clients see a clean
//!     `finish=cancelled` / sink-drop error, never a hang);
//!   - graceful drain: [`Router::close_all`] closes every shard queue so
//!     workers finish what they hold and exit.
//!
//! Single-worker deployments are bit-identical to the pre-router
//! pipeline: the ring short-circuits to worker 0 before hashing, the
//! sink wrapper forwards events unchanged, and ids/traces are minted by
//! the same shared counter (pinned by `tests/router.rs`).

pub mod ring;

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::{RouteConfig, RouteMode};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::queue::{
    CancelToken, EventSink, GenEvent, GenParams, RequestQueue,
};
use crate::obs::WorkerStat;
use ring::HashRing;

/// Fixed ring seed (the default serve port, for grep-ability). Fixed —
/// not per-process random — so prefix ownership survives reconnects and
/// restarts, which is the whole point of affinity routing.
pub const RING_SEED: u64 = 0x7341_0000_0000_0001;

/// Lifecycle of one routed request, shared between the gauge-keeping
/// sink wrapper and the shard's cancellation registry.
const QUEUED: u8 = 0;
const ACTIVE: u8 = 1;
const SETTLED: u8 = 2;

fn gauge_dec(g: &AtomicU64) {
    let _ = g.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
        Some(cur.saturating_sub(1))
    });
}

/// Per-shard health + load accounting (lock-free; scraped by the
/// Prometheus exposition via [`Router::worker_stats`]).
#[derive(Debug, Default)]
pub struct ShardStats {
    /// Requests admitted to the shard queue, not yet picked up (first
    /// event not yet emitted).
    queued: AtomicU64,
    /// Requests the worker is actively generating (first chunk emitted,
    /// `Done` not yet).
    inflight: AtomicU64,
    /// Requests ever routed to this shard (includes spill-ins).
    routed: AtomicU64,
    /// Requests that landed here by spill rather than ring ownership.
    spilled: AtomicU64,
    alive: AtomicBool,
}

impl ShardStats {
    fn new() -> Self {
        Self {
            alive: AtomicBool::new(true),
            ..Self::default()
        }
    }

    pub fn alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    /// Load = queued + inflight; the spill policy and the least-loaded
    /// pick both read this.
    pub fn load(&self) -> u64 {
        self.queued.load(Ordering::Relaxed) + self.inflight.load(Ordering::Relaxed)
    }
}

/// One worker's admission side: its private queue, load gauges, and the
/// cancel registry used to abort its work on kill.
pub struct Shard {
    queue: RequestQueue,
    stats: Arc<ShardStats>,
    /// `(lifecycle, cancel)` for every request routed here that has not
    /// settled; pruned opportunistically on each admit.
    tracked: Mutex<Vec<(Arc<AtomicU8>, CancelToken)>>,
}

impl Shard {
    fn new(queue: RequestQueue) -> Self {
        Self {
            queue,
            stats: Arc::new(ShardStats::new()),
            tracked: Mutex::new(Vec::new()),
        }
    }

    fn track(&self, state: Arc<AtomicU8>, cancel: CancelToken) {
        let mut t = self.tracked.lock().unwrap();
        t.retain(|(s, _)| s.load(Ordering::SeqCst) != SETTLED);
        t.push((state, cancel));
    }
}

/// Event-sink wrapper that keeps the shard gauges honest. Forwards every
/// event byte-for-byte (wire streams are unchanged by routing); settles
/// the gauges exactly once — on `Done`, or on drop for requests the
/// worker never answered (rejected admissions, dropped queues).
struct RoutedSink {
    inner: Box<dyn EventSink>,
    stats: Arc<ShardStats>,
    state: Arc<AtomicU8>,
}

impl RoutedSink {
    fn new(inner: Box<dyn EventSink>, stats: Arc<ShardStats>) -> (Self, Arc<AtomicU8>) {
        let state = Arc::new(AtomicU8::new(QUEUED));
        stats.queued.fetch_add(1, Ordering::Relaxed);
        (
            Self {
                inner,
                stats: stats.clone(),
                state: state.clone(),
            },
            state,
        )
    }

    fn settle(&self) {
        match self.state.swap(SETTLED, Ordering::SeqCst) {
            QUEUED => gauge_dec(&self.stats.queued),
            ACTIVE => gauge_dec(&self.stats.inflight),
            _ => {}
        }
    }
}

impl EventSink for RoutedSink {
    fn send(&self, ev: GenEvent) -> bool {
        match &ev {
            GenEvent::Chunk { .. } => {
                if self
                    .state
                    .compare_exchange(QUEUED, ACTIVE, Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
                {
                    gauge_dec(&self.stats.queued);
                    self.stats.inflight.fetch_add(1, Ordering::Relaxed);
                }
            }
            GenEvent::Done(_) => self.settle(),
        }
        self.inner.send(ev)
    }

    fn attach_trace(&self, trace: u64) {
        self.inner.attach_trace(trace);
    }
}

impl Drop for RoutedSink {
    fn drop(&mut self) {
        self.settle();
    }
}

/// The routing decision for one request (what [`Router::submit`] chose
/// and why — surfaced for tests and the loadtest skew report).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RouteDecision {
    pub worker: usize,
    pub spilled: bool,
    pub failover: bool,
}

/// The router tier proper: the ring, the shards, and the counters.
pub struct Router {
    cfg: RouteConfig,
    ring: HashRing,
    shards: Vec<Shard>,
    metrics: Arc<Metrics>,
    /// Round-robin cursor (`route=rr`, the affinity-off baseline).
    rr_next: AtomicUsize,
}

impl Router {
    /// Build over per-worker queues (one per worker, already wired to
    /// their receivers). The ring is seeded with [`RING_SEED`].
    pub fn new(cfg: RouteConfig, queues: Vec<RequestQueue>, metrics: Arc<Metrics>) -> Self {
        let ring = HashRing::new(queues.len(), cfg.vnodes, RING_SEED);
        let shards = queues.into_iter().map(Shard::new).collect();
        Self {
            cfg,
            ring,
            shards,
            metrics,
            rr_next: AtomicUsize::new(0),
        }
    }

    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    pub fn stats(&self, wid: usize) -> &Arc<ShardStats> {
        &self.shards[wid].stats
    }

    /// Pick the destination worker for `prompt`. Affinity mode resolves
    /// ring ownership (with failover past dead workers), then applies
    /// the spill policy; rr mode cycles over live workers.
    pub fn route(&self, prompt: &[u32]) -> Result<RouteDecision, String> {
        let n = self.shards.len();
        // One worker: no hashing, no spill, no counters beyond routed —
        // the bit-identity contract with the unrouted pipeline.
        if n == 1 {
            if !self.shards[0].stats.alive() {
                return Err("no healthy workers".into());
            }
            return Ok(RouteDecision {
                worker: 0,
                spilled: false,
                failover: false,
            });
        }
        match self.cfg.mode {
            RouteMode::Rr => {
                let start = self.rr_next.fetch_add(1, Ordering::Relaxed);
                for i in 0..n {
                    let w = (start + i) % n;
                    if self.shards[w].stats.alive() {
                        return Ok(RouteDecision {
                            worker: w,
                            spilled: false,
                            failover: false,
                        });
                    }
                }
                Err("no healthy workers".into())
            }
            RouteMode::Affinity => {
                let owner = self
                    .ring
                    .owner(prompt, self.cfg.prefix_len, |w| {
                        self.shards[w].stats.alive()
                    })
                    .ok_or_else(|| String::from("no healthy workers"))?;
                let failover = owner != self.ring.primary(prompt, self.cfg.prefix_len);
                let mut worker = owner;
                let mut spilled = false;
                if self.cfg.spill
                    && self.shards[owner].stats.load() > self.cfg.max_depth as u64
                {
                    let least = (0..n)
                        .filter(|&w| self.shards[w].stats.alive())
                        .min_by_key(|&w| (self.shards[w].stats.load(), w))
                        .unwrap_or(owner);
                    if least != owner {
                        worker = least;
                        spilled = true;
                    }
                }
                Ok(RouteDecision {
                    worker,
                    spilled,
                    failover,
                })
            }
        }
    }

    /// Route + admit: the single submit path behind
    /// `Coordinator::try_submit_sink`. Validation, id/trace minting, and
    /// backpressure semantics are the shard queue's, unchanged; this
    /// tier only chooses the queue and keeps the gauges/registry.
    pub fn submit(
        &self,
        prompt: Vec<u32>,
        params: GenParams,
        events: Box<dyn EventSink>,
    ) -> Result<(u64, CancelToken), String> {
        let decision = self.route(&prompt)?;
        let shard = &self.shards[decision.worker];
        let (sink, state) = RoutedSink::new(events, shard.stats.clone());
        let (id, cancel) = shard.queue.try_submit_sink(prompt, params, Box::new(sink))?;
        shard.stats.routed.fetch_add(1, Ordering::Relaxed);
        self.metrics.on_routed();
        if decision.spilled {
            shard.stats.spilled.fetch_add(1, Ordering::Relaxed);
            self.metrics.on_route_spilled();
        }
        if decision.failover {
            self.metrics.on_route_failover();
        }
        shard.track(state, cancel.clone());
        Ok((id, cancel))
    }

    /// Kill a worker: mark it dead (its prefixes re-own on the next
    /// route), cancel everything queued or in flight on its shard, and
    /// close its queue so the worker thread drains and exits. Returns
    /// `false` if the worker was already dead (or out of range).
    pub fn kill(&self, wid: usize) -> bool {
        let Some(shard) = self.shards.get(wid) else {
            return false;
        };
        if !shard.stats.alive.swap(false, Ordering::SeqCst) {
            return false;
        }
        self.metrics.on_route_failover();
        let tracked = std::mem::take(&mut *shard.tracked.lock().unwrap());
        for (state, cancel) in tracked {
            if state.load(Ordering::SeqCst) != SETTLED {
                cancel.cancel();
            }
        }
        shard.queue.close();
        true
    }

    /// Graceful drain: close every shard queue. Workers finish what they
    /// hold (queued and in-flight requests complete normally) and exit.
    pub fn close_all(&self) {
        for shard in &self.shards {
            shard.queue.close();
        }
    }

    /// Per-worker rows for the Prometheus exposition.
    pub fn worker_stats(&self) -> Vec<WorkerStat> {
        self.shards
            .iter()
            .enumerate()
            .map(|(wid, s)| WorkerStat {
                worker: wid,
                alive: s.stats.alive(),
                queued: s.stats.queued.load(Ordering::Relaxed),
                inflight: s.stats.inflight.load(Ordering::Relaxed),
                routed: s.stats.routed.load(Ordering::Relaxed),
                spilled: s.stats.spilled.load(Ordering::Relaxed),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    /// A router over `n` live queues; receivers are kept so admissions
    /// don't see a disconnected channel.
    fn test_router(
        n: usize,
        cfg: RouteConfig,
    ) -> (Router, Vec<mpsc::Receiver<crate::coordinator::queue::Request>>, Arc<Metrics>) {
        let metrics = Arc::new(Metrics::new());
        let mut queues = Vec::new();
        let mut rxs = Vec::new();
        let ids = Arc::new(AtomicU64::new(1));
        for _ in 0..n {
            let (q, rx) = RequestQueue::new(256, metrics.clone());
            queues.push(q.with_ids(ids.clone()));
            rxs.push(rx);
        }
        (Router::new(cfg, queues, metrics.clone()), rxs, metrics)
    }

    fn sink() -> (Box<dyn EventSink>, mpsc::Receiver<GenEvent>) {
        let (tx, rx) = mpsc::channel();
        (Box::new(tx), rx)
    }

    fn done_event() -> GenEvent {
        use crate::coordinator::queue::{FinishReason, Response};
        GenEvent::Done(Box::new(Response {
            id: 0,
            worker: 0,
            tokens: Vec::new(),
            steps: 0,
            emitted_per_step: 0.0,
            queue_secs: 0.0,
            gen_secs: 0.0,
            ttft_secs: 0.0,
            virtual_secs: 0.0,
            cache_hits: 0,
            finish: FinishReason::Length,
        }))
    }

    fn prompt(group: u32, salt: u32) -> Vec<u32> {
        // 8-token shared prefix per group, then a unique suffix.
        let mut p: Vec<u32> = (0..8).map(|i| group * 1000 + i).collect();
        p.push(90_000 + salt);
        p
    }

    #[test]
    fn affinity_is_sticky_per_prefix_group() {
        let (router, _rxs, _m) = test_router(4, RouteConfig::default());
        for group in 0..6 {
            let owner = router.route(&prompt(group, 0)).unwrap().worker;
            for salt in 1..8 {
                let d = router.route(&prompt(group, salt)).unwrap();
                assert_eq!(d.worker, owner, "group {group} not sticky");
                assert!(!d.spilled && !d.failover);
            }
        }
    }

    #[test]
    fn rr_cycles_over_live_workers() {
        let cfg = RouteConfig {
            mode: RouteMode::Rr,
            ..RouteConfig::default()
        };
        let (router, _rxs, _m) = test_router(3, cfg);
        let hits: Vec<usize> = (0..6)
            .map(|_| router.route(&[1, 2, 3]).unwrap().worker)
            .collect();
        assert_eq!(hits, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn spill_moves_overflow_to_least_loaded_and_counts_it() {
        let cfg = RouteConfig {
            max_depth: 3,
            ..RouteConfig::default()
        };
        let (router, rxs, metrics) = test_router(4, cfg);
        // Hammer one prefix group; no worker drains, so the owner's
        // queued gauge climbs past max_depth and overflow spills.
        let owner = router.route(&prompt(7, 0)).unwrap().worker;
        let mut streams = Vec::new();
        let mut spills = 0;
        for salt in 0..12 {
            let (s, rx) = sink();
            router
                .submit(prompt(7, salt), GenParams::simple(8, 0.0), s)
                .unwrap();
            streams.push(rx);
            spills += router.worker_stats()[owner].spilled;
        }
        let stats = router.worker_stats();
        assert_eq!(stats[owner].queued, 4, "owner held to max_depth + 1");
        assert_eq!(metrics.router_spilled(), 12 - 4);
        assert_eq!(metrics.router_routed(), 12);
        // Spills are attributed to the shards that absorbed them, never
        // the owner.
        assert_eq!(stats[owner].spilled, 0);
        assert_eq!(spills, 0);
        let absorbed: u64 = stats.iter().map(|s| s.spilled).sum();
        assert_eq!(absorbed, 12 - 4);
        drop(rxs);
    }

    #[test]
    fn kill_cancels_tracked_requests_and_reroutes_the_prefix() {
        let (router, rxs, metrics) = test_router(4, RouteConfig::default());
        let owner = router.route(&prompt(3, 0)).unwrap().worker;
        let (s, _ev) = sink();
        let (_, cancel) = router
            .submit(prompt(3, 1), GenParams::simple(8, 0.0), s)
            .unwrap();
        assert!(!cancel.is_cancelled());
        assert!(router.kill(owner));
        assert!(!router.kill(owner), "second kill is a no-op");
        assert!(cancel.is_cancelled(), "kill must cancel tracked requests");
        // The group's traffic re-owns deterministically off the ring.
        let d = router.route(&prompt(3, 2)).unwrap();
        assert_ne!(d.worker, owner);
        assert!(d.failover);
        assert_eq!(d.worker, router.route(&prompt(3, 3)).unwrap().worker);
        assert!(metrics.router_failover() >= 1);
        // Dead shard's queue is closed: direct submissions now fail.
        let (s, _ev) = sink();
        let err = router.shards[owner]
            .queue
            .try_submit_sink(vec![1], GenParams::simple(8, 0.0), s)
            .unwrap_err();
        assert_eq!(err, "queue closed");
        drop(rxs);
    }

    #[test]
    fn gauges_settle_through_the_sink_lifecycle() {
        let (router, rxs, _m) = test_router(2, RouteConfig::default());
        let (s, _ev) = sink();
        let d = router.route(&prompt(1, 0)).unwrap();
        router
            .submit(prompt(1, 0), GenParams::simple(8, 0.0), s)
            .unwrap();
        assert_eq!(router.worker_stats()[d.worker].queued, 1);
        // Simulate the worker: pull the request, emit a chunk, then Done.
        let req = rxs[d.worker].try_recv().unwrap();
        req.events.send(GenEvent::Chunk {
            tokens: vec![1],
            stats: crate::coordinator::queue::RoundStats::default(),
        });
        let st = router.worker_stats();
        assert_eq!((st[d.worker].queued, st[d.worker].inflight), (0, 1));
        req.events.send(done_event());
        let st = router.worker_stats();
        assert_eq!((st[d.worker].queued, st[d.worker].inflight), (0, 0));
    }

    #[test]
    fn single_worker_routes_without_state() {
        let (router, _rxs, metrics) = test_router(1, RouteConfig::default());
        let d = router.route(&[1, 2, 3]).unwrap();
        assert_eq!(
            d,
            RouteDecision {
                worker: 0,
                spilled: false,
                failover: false
            }
        );
        assert_eq!(metrics.router_spilled(), 0);
    }
}
