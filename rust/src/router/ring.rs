//! Seeded consistent-hash ring over prompt prefixes.
//!
//! Each worker contributes `vnodes` virtual points to a sorted u64 ring;
//! a request hashes its first `prefix_len` prompt tokens and is owned by
//! the first point clockwise from the hash. Virtual nodes smooth the
//! per-worker arc length so removing one worker only re-owns that
//! worker's arcs (its keys scatter across the survivors) instead of
//! rotating every assignment the way modulo hashing would.
//!
//! Determinism contract: point placement and prefix hashing are seeded
//! splitmix64 scrambles (the same mixer as `obs::TraceId`), so the same
//! `(workers, vnodes, seed)` triple always builds the same ring and the
//! same prompt prefix always lands on the same worker — across requests,
//! reconnects, and process restarts. A ring with ONE worker never hashes
//! at all: `owner` short-circuits to worker 0 before touching the prompt,
//! which is what makes single-worker routing bit-identical to the
//! unrouted pipeline (pinned by `tests/router.rs`).

/// splitmix64 finalizer — the crate's standard cheap scramble.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Hash the routing prefix of a prompt: the first `prefix_len` tokens
/// folded through splitmix64. Prompts shorter than the prefix hash their
/// full length, so "same prefix" degrades gracefully to "same prompt".
pub fn hash_prefix(prompt: &[u32], prefix_len: usize, seed: u64) -> u64 {
    let take = prefix_len.max(1).min(prompt.len());
    let mut h = mix(seed);
    for &tok in &prompt[..take] {
        h = mix(h ^ u64::from(tok));
    }
    h
}

/// Sorted-vnode consistent-hash ring.
#[derive(Clone, Debug)]
pub struct HashRing {
    /// `(point, worker)` sorted by point; `workers * vnodes` entries.
    points: Vec<(u64, usize)>,
    workers: usize,
    seed: u64,
}

impl HashRing {
    pub fn new(workers: usize, vnodes: usize, seed: u64) -> Self {
        let workers = workers.max(1);
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(workers * vnodes);
        for wid in 0..workers {
            for v in 0..vnodes {
                let point =
                    mix(seed ^ mix(((wid as u64) << 32) | v as u64));
                points.push((point, wid));
            }
        }
        points.sort_unstable();
        Self {
            points,
            workers,
            seed,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The ring-primary owner of `prompt`, ignoring health. Single-worker
    /// rings short-circuit to 0 before any hashing.
    pub fn primary(&self, prompt: &[u32], prefix_len: usize) -> usize {
        if self.workers == 1 {
            return 0;
        }
        let h = hash_prefix(prompt, prefix_len, self.seed);
        self.owner_of_point(h, |_| true).unwrap_or(0)
    }

    /// The owner of `prompt` among workers for which `alive` holds:
    /// starting at the prefix hash, the first clockwise vnode belonging
    /// to a live worker. Deterministic failover falls out of the ring
    /// order — a dead worker's keys re-own to whichever live worker holds
    /// the next vnode, with no rendezvous or rebalancing step. Returns
    /// `None` when no worker is alive.
    pub fn owner(
        &self,
        prompt: &[u32],
        prefix_len: usize,
        alive: impl Fn(usize) -> bool,
    ) -> Option<usize> {
        if self.workers == 1 {
            return alive(0).then_some(0);
        }
        let h = hash_prefix(prompt, prefix_len, self.seed);
        self.owner_of_point(h, alive)
    }

    fn owner_of_point(
        &self,
        point: u64,
        alive: impl Fn(usize) -> bool,
    ) -> Option<usize> {
        let start = self
            .points
            .partition_point(|&(p, _)| p < point);
        let n = self.points.len();
        for i in 0..n {
            let (_, wid) = self.points[(start + i) % n];
            if alive(wid) {
                return Some(wid);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_prefix_same_owner() {
        let ring = HashRing::new(4, 64, 7);
        let a = ring.primary(&[1, 2, 3, 4, 90, 91], 4);
        let b = ring.primary(&[1, 2, 3, 4, 55, 56, 57], 4);
        assert_eq!(a, b, "shared 4-token prefix split across workers");
        // Rebuilding the ring with the same seed keeps the assignment.
        let again = HashRing::new(4, 64, 7);
        assert_eq!(again.primary(&[1, 2, 3, 4, 90, 91], 4), a);
    }

    #[test]
    fn different_prefixes_spread_across_workers() {
        let ring = HashRing::new(4, 64, 7);
        let mut seen = std::collections::BTreeSet::new();
        for p in 0..64u32 {
            seen.insert(ring.primary(&[p * 131, p * 17 + 1, 3, 4], 4));
        }
        assert!(
            seen.len() >= 3,
            "64 distinct prefixes hit only {} of 4 workers",
            seen.len()
        );
    }

    #[test]
    fn single_worker_ring_short_circuits() {
        let ring = HashRing::new(1, 64, 7);
        assert_eq!(ring.primary(&[9, 9, 9], 4), 0);
        assert_eq!(ring.owner(&[9, 9, 9], 4, |_| true), Some(0));
        assert_eq!(ring.owner(&[9, 9, 9], 4, |_| false), None);
    }

    #[test]
    fn dead_owner_fails_over_deterministically_and_minimally() {
        let ring = HashRing::new(4, 64, 7);
        let prompt = [5, 6, 7, 8, 1];
        let primary = ring.primary(&prompt, 4);
        let survivor = ring
            .owner(&prompt, 4, |w| w != primary)
            .expect("three workers still alive");
        assert_ne!(survivor, primary);
        // Deterministic: the same failover target every time.
        assert_eq!(ring.owner(&prompt, 4, |w| w != primary), Some(survivor));
        // Minimal disruption: keys NOT owned by the dead worker keep
        // their owner.
        for p in 0..128u32 {
            let key = [p * 7 + 3, p, 11, 12];
            let owner = ring.primary(&key, 4);
            if owner != primary {
                assert_eq!(ring.owner(&key, 4, |w| w != primary), Some(owner));
            }
        }
    }

    #[test]
    fn short_prompts_hash_their_full_length() {
        let ring = HashRing::new(4, 64, 7);
        // prefix_len 8 over a 2-token prompt must not panic and must be
        // deterministic.
        let a = ring.primary(&[1, 2], 8);
        assert_eq!(ring.primary(&[1, 2], 8), a);
    }
}
