//! `dyspec` — leader binary: generation, paper benchmarks, serving, and
//! artifact self-check. See `dyspec help` (cli::USAGE).

use std::sync::Arc;

use dyspec::bench::experiments::{run_experiment, ExpOpts};
use dyspec::cli::{Cli, USAGE};
use dyspec::config::{Config, ModelBackend};
use dyspec::coordinator::{Coordinator, ModelFactory};
use dyspec::data::prompts::PromptSet;
use dyspec::engine::SpecEngine;
use dyspec::models::hlo::HloModel;
use dyspec::models::sim::{SimModel, SimSpec};
use dyspec::models::LogitModel;
use dyspec::runtime::artifacts::{Artifacts, GraphKey, Role};
use dyspec::runtime::PjrtRuntime;
use dyspec::server::{Client, Server};
use dyspec::util::json::Json;

fn main() {
    let cli = match Cli::from_env() {
        Ok(cli) => cli,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match cli.command.as_str() {
        "generate" => cmd_generate(&cli),
        "bench" => cmd_bench(&cli),
        "serve" => cmd_serve(&cli),
        "client" => cmd_client(&cli),
        "selfcheck" => cmd_selfcheck(&cli),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command: {other}\n\n{USAGE}")),
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e}");
        1
    });
    std::process::exit(code);
}

/// Build a Config from the CLI's key=value options.
fn config_from(cli: &Cli) -> Result<Config, String> {
    let mut cfg = if let Some(preset) = cli.opt("preset") {
        Config::preset(preset)?
    } else {
        Config::new()
    };
    for (k, v) in &cli.options {
        if matches!(
            k.as_str(),
            "experiment"
                | "out"
                | "preset"
                | "runs"
                | "prompts"
                | "noise"
                // client-subcommand options, not config keys
                | "cancel-after"
                | "drafter"
                | "token_budget"
                | "req_id"
                | "conns"
        ) {
            continue; // harness-level options, not config keys
        }
        cfg.set(k, v)?;
    }
    Ok(cfg)
}

/// Construct the (draft, target) pair for the configured backend.
fn build_models(cfg: &Config) -> Result<(Box<dyn LogitModel>, Box<dyn LogitModel>), String> {
    match cfg.backend {
        ModelBackend::Sim => {
            let spec = SimSpec::for_dataset(&cfg.dataset, 1.0, cfg.engine.seed ^ 0xDA7A);
            let (d, t) = SimModel::pair(spec);
            Ok((Box::new(d), Box::new(t)))
        }
        ModelBackend::Hlo | ModelBackend::HloPallas => {
            let pallas = cfg.backend == ModelBackend::HloPallas;
            let arts = Artifacts::load(&cfg.artifacts_dir).map_err(|e| e.to_string())?;
            let mut rt = PjrtRuntime::cpu().map_err(|e| e.to_string())?;
            let seq = arts.seq_small();
            let target = HloModel::load(&mut rt, &arts, Role::Target, seq, pallas)
                .map_err(|e| e.to_string())?;
            let draft = HloModel::load(&mut rt, &arts, Role::Draft, seq, false)
                .map_err(|e| e.to_string())?;
            Ok((Box::new(draft), Box::new(target)))
        }
    }
}

fn cmd_generate(cli: &Cli) -> Result<(), String> {
    let cfg = config_from(cli)?;
    let prompts = PromptSet::by_name(&cfg.dataset, 1, cfg.prompt_len, cfg.engine.seed + 100)
        .ok_or("bad dataset")?;
    let (draft, target) = build_models(&cfg)?;
    let mut engine = SpecEngine::new(draft, target, cfg.engine.clone(), cfg.regime)
        .with_cache(&cfg.cache);

    let t = std::time::Instant::now();
    let stats = engine.generate(prompts.get(0));
    let wall = t.elapsed().as_secs_f64();

    println!(
        "policy={} backend={} dataset={} temp={} budget={}",
        cfg.engine.policy,
        cfg.backend.name(),
        cfg.dataset,
        cfg.engine.target_temp,
        cfg.engine.tree_budget
    );
    println!(
        "generated {} tokens in {} steps ({:.2} tokens/step), wall {:.3}s",
        stats.tokens.len(),
        stats.steps.len(),
        stats.mean_emitted_per_step(),
        wall
    );
    if cfg.regime.is_some() {
        println!(
            "virtual latency/token ({} regime): {:.5}s",
            cfg.regime.unwrap().name,
            stats.virtual_latency_per_token()
        );
    }
    println!(
        "kv cache: {} | hit rate {:.1}% | {:.1} billed positions/step",
        if cfg.cache.enabled { "on" } else { "off" },
        stats.cache_hit_rate() * 100.0,
        stats.billed_positions_per_step(),
    );
    println!("component breakdown:");
    for (label, secs, frac) in stats.aggregate_times().breakdown() {
        println!("  {label:<16} {secs:>9.4}s  {:.1}%", frac * 100.0);
    }
    let shown: Vec<String> = stats.tokens.iter().take(32).map(|t| t.to_string()).collect();
    println!("tokens[..32]: {}", shown.join(" "));
    Ok(())
}

fn cmd_bench(cli: &Cli) -> Result<(), String> {
    let experiment = cli.opt("experiment").ok_or("missing --experiment")?;
    let opts = ExpOpts {
        prompts: cli.opt_parse("prompts", 6usize)?,
        max_new_tokens: cli.opt_parse("max_new_tokens", 128usize)?,
        noise: cli.opt_parse("noise", 1.0f32)?,
        seed: cli.opt_parse("seed", 1u64)?,
        out: cli.opt("out").map(String::from),
    };
    for table in run_experiment(experiment, &opts)? {
        println!("{}", table.render());
    }
    Ok(())
}

fn cmd_serve(cli: &Cli) -> Result<(), String> {
    let cfg = config_from(cli)?;
    let factory: ModelFactory = {
        let cfg = cfg.clone();
        Arc::new(move || build_models(&cfg).expect("worker model construction"))
    };
    let coord = Arc::new(Coordinator::start(cfg.clone(), factory));
    let server = Server::bind(&cfg.server.addr, coord).map_err(|e| e.to_string())?;
    println!("dyspec serving on {} (backend={}, policy={}, workers={})",
        server.local_addr().map_err(|e| e.to_string())?,
        cfg.backend.name(),
        cfg.engine.policy,
        cfg.server.workers
    );
    server.run().map_err(|e| e.to_string())
}

fn cmd_client(cli: &Cli) -> Result<(), String> {
    let cfg = config_from(cli)?;
    let addr = cli.opt("addr").unwrap_or(&cfg.server.addr);
    if let Some(conns) = cli.opt("conns") {
        // Before opening the control connection: the fan-out drive
        // should own every one of the server's admission slots it asks
        // for.
        let conns: usize = conns.parse().map_err(|_| "bad --conns")?;
        return cmd_client_conns(&cfg, addr, conns);
    }
    let mut client = Client::connect(addr).map_err(|e| e.to_string())?;
    if cli.has_flag("stats") {
        println!("{}", client.stats()?.to_string());
        return Ok(());
    }
    if cli.has_flag("metrics") {
        // Raw Prometheus text exposition — pipe straight into a scraper.
        print!("{}", client.metrics()?);
        return Ok(());
    }
    if cli.has_flag("trace") {
        // Flight-recorder dump as JSONL: one span object per line, with
        // a stderr header carrying the recorder state.
        let dump = client.trace()?;
        let tracing = matches!(dump.get("tracing"), Some(Json::Bool(true)));
        let dropped = dump.get("dropped").and_then(Json::as_f64).unwrap_or(0.0);
        let spans = dump.get("spans").and_then(Json::as_arr);
        eprintln!(
            "tracing={} dropped={dropped} spans={}",
            if tracing { "on" } else { "off" },
            spans.map(|s| s.len()).unwrap_or(0)
        );
        for span in spans.into_iter().flatten() {
            println!("{}", span.to_string());
        }
        return Ok(());
    }
    if cli.has_flag("shutdown") {
        client.shutdown()?;
        println!("server shut down");
        return Ok(());
    }
    let prompts = PromptSet::by_name(&cfg.dataset, 1, cfg.prompt_len, cfg.engine.seed + 100)
        .ok_or("bad dataset")?;
    let cancel_after: Option<usize> = match cli.opt("cancel-after") {
        Some(v) => Some(v.parse().map_err(|_| "bad --cancel-after")?),
        None => None,
    };
    if cli.has_flag("stream") || cancel_after.is_some() {
        return cmd_client_stream(cli, &cfg, &mut client, prompts.get(0), cancel_after);
    }
    let reply = client.generate_detailed(
        prompts.get(0),
        cfg.engine.max_new_tokens,
        cfg.engine.target_temp,
    )?;
    println!("{}", reply.to_string());
    Ok(())
}

/// Reactor fan-out drive: open `conns` concurrent connections, stream
/// one request on each, and report completion + the server's transport
/// gauges — the quick way to see a fixed reactor pool serving many
/// sockets (`dyspec client --conns 64`).
fn cmd_client_conns(
    cfg: &Config,
    addr: &str,
    conns: usize,
) -> Result<(), String> {
    if conns == 0 {
        return Err("--conns must be >= 1".into());
    }
    let prompts = PromptSet::by_name(
        &cfg.dataset,
        conns,
        cfg.prompt_len,
        cfg.engine.seed + 100,
    )
    .ok_or("bad dataset")?;
    let max_new = cfg.engine.max_new_tokens;
    let temp = cfg.engine.target_temp;
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..conns)
        .map(|k| {
            let addr = addr.to_string();
            let prompt: Vec<u32> = prompts.get(k).to_vec();
            std::thread::spawn(move || -> Result<usize, String> {
                let mut client =
                    Client::connect(&addr).map_err(|e| e.to_string())?;
                let params = dyspec::coordinator::GenParams::simple(max_new, temp);
                let (tokens, _done) =
                    client.generate_stream(1, &prompt, &params, |_| {})?;
                Ok(tokens.len())
            })
        })
        .collect();
    let mut ok = 0usize;
    let mut failed = 0usize;
    let mut tokens = 0usize;
    for h in handles {
        match h.join().map_err(|_| "client thread panicked")? {
            Ok(n) => {
                ok += 1;
                tokens += n;
            }
            Err(e) => {
                failed += 1;
                eprintln!("conn failed: {e}");
            }
        }
    }
    println!(
        "{ok}/{conns} connections completed ({failed} failed), {tokens} tokens in {:.2}s",
        t0.elapsed().as_secs_f64()
    );
    let mut client = Client::connect(addr).map_err(|e| e.to_string())?;
    let stats = client.stats()?;
    for key in ["transport_threads", "open_conns", "outbox_frames", "backpressure_closed"] {
        let v = stats.get(key).and_then(Json::as_f64).unwrap_or(-1.0);
        println!("  {key}: {v}");
    }
    if failed > 0 {
        return Err(format!("{failed} of {conns} connections failed"));
    }
    Ok(())
}

/// Protocol-v1 streaming drive: print every frame as it lands; with
/// `--cancel-after N`, send a cancel after the Nth chunk and require the
/// stream to end with `finish:"cancelled"` (the CI conformance check).
fn cmd_client_stream(
    cli: &Cli,
    cfg: &Config,
    client: &mut Client,
    prompt: &[u32],
    cancel_after: Option<usize>,
) -> Result<(), String> {
    let req_id: u64 = cli.opt_parse("req_id", 1u64)?;
    let params = dyspec::coordinator::GenParams {
        max_new_tokens: cfg.engine.max_new_tokens,
        temperature: cfg.engine.target_temp,
        seed: cli.opt("seed").map(|_| cfg.engine.seed),
        stop_tokens: cfg.engine.stop_tokens.clone(),
        drafter: match cli.opt("drafter") {
            Some(name) => Some(
                dyspec::config::PolicyKind::parse(name)
                    .ok_or_else(|| format!("bad --drafter: {name}"))?,
            ),
            None => None,
        },
        token_budget: match cli.opt("token_budget") {
            Some(v) => Some(v.parse().map_err(|_| "bad --token_budget")?),
            None => None,
        },
    };
    client.submit(req_id, prompt, &params, true)?;
    let mut chunks = 0usize;
    loop {
        let frame = client.read_frame()?;
        println!("{}", frame.body.to_string());
        if frame.req_id != Some(req_id) {
            return Err(format!("frame for unexpected req {:?}", frame.req_id));
        }
        match frame.event.as_str() {
            "chunk" => {
                chunks += 1;
                if cancel_after == Some(chunks) {
                    client.cancel(req_id)?;
                }
            }
            "done" => {
                let finish = frame.finish().map(|f| f.name()).unwrap_or("?");
                eprintln!("stream done: {chunks} chunks, finish={finish}");
                if cancel_after.is_some() && finish != "cancelled" {
                    return Err(format!(
                        "expected finish=cancelled after cancel, got {finish}"
                    ));
                }
                if cancel_after.is_none() && finish == "cancelled" {
                    return Err("stream cancelled unexpectedly".into());
                }
                return Ok(());
            }
            "error" => {
                return Err(frame
                    .error()
                    .unwrap_or("unknown server error")
                    .to_string())
            }
            other => return Err(format!("unexpected event: {other}")),
        }
    }
}

/// Verify artifacts + the PJRT wiring: load the target model and compare a
/// pinned forward pass against golden.json from the python side.
fn cmd_selfcheck(cli: &Cli) -> Result<(), String> {
    let cfg = config_from(cli)?;
    let arts = Artifacts::load(&cfg.artifacts_dir).map_err(|e| e.to_string())?;
    let golden = arts.golden().map_err(|e| e.to_string())?;
    let seq = golden
        .get("seq_len")
        .and_then(Json::as_usize)
        .ok_or("golden.json missing seq_len")?;
    let vocab = arts.vocab_size();
    println!("artifacts: vocab={vocab} seq_small={} seq_large={}", arts.seq_small(), arts.seq_large());

    let mut rt = PjrtRuntime::cpu().map_err(|e| e.to_string())?;
    println!("PJRT platform: {}", rt.platform());

    let tokens: Vec<i32> = (0..seq as i32).map(|i| (7 * i + 3) % vocab as i32).collect();
    let positions: Vec<i32> = (0..seq as i32).collect();
    let mask = dyspec::tree::mask::causal_f32(seq, seq);

    for role in [Role::Target, Role::Draft] {
        let model = rt
            .load(&arts, GraphKey { role, seq_len: seq, pallas: false })
            .map_err(|e| e.to_string())?;
        let logits = model
            .forward(&tokens, &positions, &mask)
            .map_err(|e| e.to_string())?;
        let last = &logits[(seq - 1) * vocab..seq * vocab];
        let want = golden
            .at(&[role.name(), "last_row_first8"])
            .and_then(Json::as_arr)
            .ok_or("golden missing role data")?;
        let mut max_err = 0f64;
        for (i, w) in want.iter().enumerate() {
            let w = w.as_f64().unwrap_or(f64::NAN);
            max_err = max_err.max((last[i] as f64 - w).abs());
        }
        let argmax = dyspec::util::math::argmax(last);
        let want_argmax = golden
            .at(&[role.name(), "last_row_argmax"])
            .and_then(Json::as_usize)
            .ok_or("golden missing argmax")?;
        let ok = max_err < 2e-3 && argmax == want_argmax;
        println!(
            "{}: max|Δlogit| = {max_err:.2e}, argmax {} (want {}) -> {}",
            role.name(),
            argmax,
            want_argmax,
            if ok { "OK" } else { "MISMATCH" }
        );
        if !ok {
            return Err(format!("{} golden check failed", role.name()));
        }
    }
    println!("selfcheck OK: python-jax and rust-PJRT agree");
    Ok(())
}
