//! Typed configuration for every subsystem, with key=value overrides and
//! named presets mirroring the paper's experimental setups (Tables 1-4).
//!
//! Precedence: preset defaults < file (key=value lines) < CLI overrides.

use std::collections::BTreeMap;
use std::fmt;

/// Which draft-tree construction policy to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// DySpec Algorithm 1: greedy max-heap expansion.
    DySpec,
    /// DySpec Algorithm 2: layer-by-layer with threshold.
    DySpecThreshold,
    /// Sequoia-style positional DP tree (fixed shape per acceptance profile).
    Sequoia,
    /// SpecInfer-style fixed k-ary expansion.
    SpecInfer,
    /// Single chain (classic speculative decoding).
    Chain,
    /// No speculation: plain autoregressive decoding.
    Baseline,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "dyspec" => Self::DySpec,
            "dyspec-threshold" | "threshold" => Self::DySpecThreshold,
            "sequoia" => Self::Sequoia,
            "specinfer" => Self::SpecInfer,
            "chain" => Self::Chain,
            "baseline" | "autoregressive" => Self::Baseline,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::DySpec => "dyspec",
            Self::DySpecThreshold => "dyspec-threshold",
            Self::Sequoia => "sequoia",
            Self::SpecInfer => "specinfer",
            Self::Chain => "chain",
            Self::Baseline => "baseline",
        }
    }

    pub fn all() -> [PolicyKind; 6] {
        [
            Self::DySpec,
            Self::DySpecThreshold,
            Self::Sequoia,
            Self::SpecInfer,
            Self::Chain,
            Self::Baseline,
        ]
    }
}

impl fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Whether the per-round drafter/budget choice is fixed by config
/// (`static`) or driven online by the acceptance observatory
/// (`adaptive`) — the closed loop over the PR-6 telemetry
/// (DESIGN.md §Adaptive Policy).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PolicyMode {
    #[default]
    Static,
    Adaptive,
}

impl PolicyMode {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "static" => Self::Static,
            "adaptive" | "adapt" => Self::Adaptive,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Static => "static",
            Self::Adaptive => "adaptive",
        }
    }
}

impl fmt::Display for PolicyMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Online-adaptive policy knobs (`round::adapt`, DESIGN.md §Adaptive
/// Policy). All estimator state lives per worker; this struct only
/// carries the registered drafter set and the UCB/retune dials.
#[derive(Clone, Debug, PartialEq)]
pub struct AdaptConfig {
    pub mode: PolicyMode,
    /// Drafters the controller may select among, in registration order.
    /// Empty = the singleton set `[engine.policy]`, which degenerates to
    /// static selection by construction (the equivalence the differential
    /// suite pins).
    pub drafters: Vec<PolicyKind>,
    /// UCB exploration coefficient `c` in
    /// `rate + c * sqrt(ln(N+1) / (n+1))`.
    pub explore: f64,
    /// Proposed-node samples below which a drafter counts as cold and is
    /// explored ahead of any exploitation.
    pub min_samples: u64,
    /// Probability-bucket smoothed acceptance rate below which a bucket's
    /// proposed mass counts as wasted when retuning the tree budget.
    pub cut: f64,
    /// Retuned tree budgets never shrink below this floor.
    pub min_budget: usize,
}

impl Default for AdaptConfig {
    fn default() -> Self {
        Self {
            mode: PolicyMode::Static,
            drafters: Vec::new(),
            explore: 0.5,
            min_samples: 128,
            cut: 0.25,
            min_budget: 4,
        }
    }
}

/// Which serving scheduler multiplexes requests onto a worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedKind {
    /// One request at a time per worker, FCFS from the shared queue.
    Fcfs,
    /// Step-level continuous batching: every target dispatch packs all
    /// active sequences' trees under one cross-request token budget.
    Continuous,
}

impl SchedKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "fcfs" => Self::Fcfs,
            "continuous" | "cb" => Self::Continuous,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Fcfs => "fcfs",
            Self::Continuous => "continuous",
        }
    }
}

impl fmt::Display for SchedKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Scheduler-layer knobs (`sched/`, DESIGN.md §Scheduler).
#[derive(Clone, Debug, PartialEq)]
pub struct SchedConfig {
    pub kind: SchedKind,
    /// Global speculated-token budget per verification dispatch, shared by
    /// every sequence in the batch (0 = inherit `engine.tree_budget`). The
    /// batcher clamps it up to the active-sequence count so each sequence
    /// is guaranteed at least one frontier token per step.
    pub global_budget: usize,
    /// Max sequences simultaneously interleaved by one batcher.
    pub max_active: usize,
    /// Queue poll interval while idle, in ms — also the FCFS worker's
    /// shutdown-poll tick (previously hardcoded at 50 ms).
    pub idle_tick_ms: u64,
    /// Per-step token budget reserved for prefill chunks when chunked
    /// prefill is on (`engine.prefill_chunk > 0`); positions left over
    /// after chunk scheduling go to the cross-request speculation
    /// allocator. 0 = inherit `engine.prefill_chunk` (one chunk's worth
    /// per step). Ignored while chunking is off.
    pub prefill_budget: usize,
}

impl Default for SchedConfig {
    fn default() -> Self {
        Self {
            kind: SchedKind::Fcfs,
            global_budget: 0,
            max_active: 8,
            idle_tick_ms: 50,
            prefill_budget: 0,
        }
    }
}

/// KV prefix-cache knobs (`cache/`, DESIGN.md §KV cache). The block budget
/// is per worker: each worker's `CacheManager` owns its own pool.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CacheConfig {
    /// Retain accepted prefixes across speculation rounds (default on;
    /// `cache=off` re-scores every dispatch from position zero).
    pub enabled: bool,
    /// KV positions per block (paged-allocator granularity).
    pub block_tokens: usize,
    /// Global per-worker block budget; LRU sequences are evicted when a
    /// commit cannot allocate within it.
    pub max_blocks: usize,
    /// Cross-request radix prefix tree (`radix=on`): committed prefixes
    /// stay resident in a shared token-keyed tree after their sequence
    /// retires, so the next request starts warm at its longest shared
    /// prefix (DESIGN.md §Radix Prefix Cache). Default off: per-sequence
    /// residency only, bit-identical billing to the pre-radix pipeline.
    pub radix: bool,
    /// Minimum matched tokens for a radix admission to count (and pin):
    /// shorter matches start cold instead of pinning near-root nodes.
    pub radix_min_tokens: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            block_tokens: 16,
            max_blocks: 4096,
            radix: false,
            radix_min_tokens: 16,
        }
    }
}

/// Which model backend drives draft/target scoring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelBackend {
    /// Correlated-distribution simulator (algorithm-level benches; no PJRT).
    Sim,
    /// AOT HLO transformer via PJRT CPU, ref attention.
    Hlo,
    /// AOT HLO transformer with the Pallas tree-attention kernel inlined.
    HloPallas,
}

impl ModelBackend {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "sim" => Self::Sim,
            "hlo" => Self::Hlo,
            "hlo-pallas" | "pallas" => Self::HloPallas,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Sim => "sim",
            Self::Hlo => "hlo",
            Self::HloPallas => "hlo-pallas",
        }
    }
}

/// Hardware regime being emulated — sets the injected T_t/T_d latency ratio
/// (paper §4.3/§5.3: the regime, not the silicon, determines the shape).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LatencyRegime {
    pub name: &'static str,
    /// Draft per-step seconds (paper: JF68M ~ sub-ms; 7B ~ 25 ms).
    pub draft_step_secs: f64,
    /// Target per-verification seconds (paper: 7B ~ 22 ms at bs 1+64; 13B ~
    /// 30 ms; offloaded 70B ~ 5 s).
    pub target_step_secs: f64,
    /// SPECULATED tokens one target dispatch absorbs at
    /// `target_step_secs` — the batch width the step time was calibrated
    /// at (paper §5.1: bs 1+64, i.e. 64 speculated tokens; root rows ride
    /// free). The shared round pipeline (`round::conclude_round`) bills
    /// every dispatch — both schedulers — in ceil(speculated / width)
    /// units, so packing beyond the calibrated width is not free: a
    /// batch-of-1 at `tree_budget <= verify_width` bills exactly one
    /// step, a bigger single tree proportionally more. `usize::MAX` for
    /// the offload regime, whose step is weight-streaming-bound (flat per
    /// dispatch).
    pub verify_width: usize,
    /// Marginal seconds per COMPUTED position in a verification dispatch
    /// (the context-length term the KV cache removes: uncached scoring
    /// bills the whole prefix here, cached scoring only the non-resident
    /// part plus the tree rows — `cache::verify_bill`).
    pub target_pos_secs: f64,
    /// Seconds per KV block (re)written by a dispatch.
    pub cache_write_secs: f64,
    /// Seconds per resident KV block fetched by a dispatch. Kept below
    /// both `cache_write_secs` and `target_pos_secs * block_tokens` so a
    /// cached dispatch is never priced above the same dispatch uncached
    /// (pinned by `regime_cache_terms_keep_cached_cheaper`).
    pub cache_fetch_secs: f64,
}

impl LatencyRegime {
    /// JF68M -> Llama2-7B on A100 (Table 1). The paper captures the draft
    /// in CUDA graphs (§5.3), putting a JF68M step at ~0.25 ms against a
    /// ~22 ms tree-verification step: T_t/T_d ≈ 90.
    pub fn pair_7b() -> Self {
        Self {
            name: "7b",
            draft_step_secs: 0.00025,
            target_step_secs: 0.0225,
            verify_width: 64,
            target_pos_secs: 2.0e-5,
            cache_write_secs: 4.0e-6,
            cache_fetch_secs: 1.0e-6,
        }
    }

    /// JF68M -> Llama2-13B (Table 2): T_t/T_d ≈ 120.
    pub fn pair_13b() -> Self {
        Self {
            name: "13b",
            draft_step_secs: 0.00025,
            target_step_secs: 0.0303,
            verify_width: 64,
            target_pos_secs: 2.6e-5,
            cache_write_secs: 5.0e-6,
            cache_fetch_secs: 1.2e-6,
        }
    }

    /// Llama2-7B -> CPU-offloaded Llama2-70B (Tables 3/4): the paper's
    /// stated T_t/T_d ≈ 2×10³ regime (§5.3; ~2.5 ms effective draft step vs
    /// ~5 s offloaded target step, no CUDA graphs for the 7B draft).
    pub fn pair_70b_offload() -> Self {
        Self {
            name: "70b-offload",
            draft_step_secs: 0.0025,
            target_step_secs: 5.0,
            verify_width: usize::MAX,
            // Weight streaming dominates the offload step; marginal
            // per-position compute and cache traffic are second-order.
            target_pos_secs: 5.0e-5,
            cache_write_secs: 8.0e-6,
            cache_fetch_secs: 2.0e-6,
        }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        Some(match s {
            "7b" => Self::pair_7b(),
            "13b" => Self::pair_13b(),
            "70b" | "70b-offload" => Self::pair_70b_offload(),
            _ => return None,
        })
    }

    pub fn ratio(&self) -> f64 {
        self.target_step_secs / self.draft_step_secs
    }
}

/// Engine-level knobs for one generation run.
#[derive(Clone, Debug, PartialEq)]
pub struct EngineConfig {
    pub policy: PolicyKind,
    /// Speculative budget: max speculated tokens per verification step.
    pub tree_budget: usize,
    /// Threshold for Algorithm 2 (est-acceptance cutoff; paper uses ~1/n).
    pub threshold: f64,
    /// Max tree depth guard (paper: D << N; protects the layer loop).
    pub max_depth: usize,
    pub target_temp: f32,
    /// Paper §5.1: draft temperature fixed at 0.6.
    pub draft_temp: f32,
    pub max_new_tokens: usize,
    /// SpecInfer per-layer branch widths.
    pub specinfer_widths: Vec<usize>,
    /// Sequoia positional acceptance estimate used by its DP.
    pub sequoia_accept_rate: f64,
    pub seed: u64,
    /// Default stop tokens: emitting any of them finishes the generation
    /// (reason `stop`, the token included). Protocol-v1 requests override
    /// this per request.
    pub stop_tokens: Vec<u32>,
    /// Chunked prefill (DESIGN.md §Chunked Prefill): split a cold
    /// prompt's first computation into chunks of at most this many
    /// tokens, one bare forest row per step, so a long arrival bounds
    /// each co-batched step's extra cost to `prefill_chunk` positions
    /// instead of the whole prompt. 0 (default) = off: the entire
    /// non-resident prompt is computed in the first dispatch, exactly
    /// the pre-chunking pipeline. Token streams are bit-identical on vs
    /// off (pinned by `tests/prefill_equivalence.rs`).
    pub prefill_chunk: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            policy: PolicyKind::DySpec,
            tree_budget: 64,
            threshold: 1.0 / 64.0,
            max_depth: 24,
            target_temp: 0.0,
            draft_temp: 0.6,
            max_new_tokens: 128,
            specinfer_widths: vec![4, 2, 2, 1, 1, 1],
            sequoia_accept_rate: 0.75,
            seed: 0,
            stop_tokens: Vec::new(),
            prefill_chunk: 0,
        }
    }
}

/// Serving-layer knobs.
#[derive(Clone, Debug, PartialEq)]
pub struct ServerConfig {
    pub addr: String,
    pub workers: usize,
    pub queue_capacity: usize,
    /// Max batched requests admitted per scheduling round.
    pub max_batch: usize,
    /// Event-loop threads in the reactor transport (DESIGN.md
    /// §Transport). Connections are multiplexed over this fixed pool —
    /// server thread count is O(reactor_threads + workers), never
    /// O(connections).
    pub reactor_threads: usize,
    /// Admission control: connections beyond this are refused at accept
    /// with `{"error":"server at capacity"}`.
    pub max_conns: usize,
    /// Per-connection outbox ceiling, in frames. A client that stops
    /// draining its socket until this many frames pile up is treated as
    /// gone (connection closed, in-flight requests cancelled) instead of
    /// buffered without bound.
    pub outbox_frames: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7341".into(),
            workers: 2,
            queue_capacity: 256,
            max_batch: 8,
            reactor_threads: 2,
            max_conns: 1024,
            outbox_frames: 1024,
        }
    }
}

/// Observability knobs (`obs/`, DESIGN.md §Observability). Acceptance
/// counters and stage latency histograms are always on (they are plain
/// arithmetic on data the round pipeline already computed); only span
/// *recording* is gated, because spans allocate and take a per-worker
/// lock.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ObsConfig {
    /// Record per-round pipeline-stage spans into the flight-recorder
    /// ring and echo trace ids on protocol-v1 frames (`trace=on`).
    /// Default off; pinned bit-identical when off by
    /// `tests/obs_differential.rs`.
    pub trace: bool,
    /// Flight-recorder capacity per worker, in spans (5 per round).
    pub trace_ring: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self {
            trace: false,
            trace_ring: 4096,
        }
    }
}

/// How the router tier picks a worker for an admitted request
/// (`router/`, DESIGN.md §Router Tier).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RouteMode {
    /// Consistent-hash the prompt prefix so each worker's cache
    /// concentrates residency for the prefixes it owns.
    #[default]
    Affinity,
    /// Round-robin over live workers — the affinity-off baseline the
    /// route bench compares against.
    Rr,
}

impl RouteMode {
    pub fn name(&self) -> &'static str {
        match self {
            Self::Affinity => "affinity",
            Self::Rr => "rr",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "affinity" => Self::Affinity,
            "rr" | "round-robin" | "roundrobin" => Self::Rr,
            _ => return None,
        })
    }
}

/// Router-tier knobs (`route*` keys).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RouteConfig {
    pub mode: RouteMode,
    /// Prompt tokens hashed for ring placement: requests sharing their
    /// first `prefix_len` tokens land on the same worker.
    pub prefix_len: usize,
    /// Virtual nodes per worker on the consistent-hash ring (more vnodes
    /// → smoother per-worker arc length → less skew).
    pub vnodes: usize,
    /// Spill threshold: when the owner's load (queued + in flight)
    /// exceeds this, the request goes to the least-loaded healthy worker
    /// instead (counted as a spill).
    pub max_depth: usize,
    /// Enable the spill policy (`route_spill=on`, the default). Off
    /// means strict affinity: the owner takes all its traffic no matter
    /// how deep its queue (backpressure still applies per shard).
    pub spill: bool,
}

impl Default for RouteConfig {
    fn default() -> Self {
        Self {
            mode: RouteMode::Affinity,
            prefix_len: 32,
            vnodes: 64,
            max_depth: 64,
            spill: true,
        }
    }
}

/// Top-level configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub engine: EngineConfig,
    pub server: ServerConfig,
    pub sched: SchedConfig,
    pub cache: CacheConfig,
    pub obs: ObsConfig,
    pub adapt: AdaptConfig,
    pub route: RouteConfig,
    pub backend: ModelBackend,
    pub regime: Option<LatencyRegime>,
    pub dataset: String,
    pub artifacts_dir: String,
    pub prompt_len: usize,
    pub num_prompts: usize,
}

impl Default for ModelBackend {
    fn default() -> Self {
        Self::Sim
    }
}

impl Default for Config {
    fn default() -> Self {
        Self::new()
    }
}

impl Config {
    pub fn new() -> Self {
        Self {
            engine: EngineConfig::default(),
            server: ServerConfig::default(),
            sched: SchedConfig::default(),
            cache: CacheConfig::default(),
            obs: ObsConfig::default(),
            adapt: AdaptConfig::default(),
            route: RouteConfig::default(),
            backend: ModelBackend::Sim,
            regime: None,
            dataset: "c4".into(),
            artifacts_dir: "artifacts".into(),
            prompt_len: 128,
            num_prompts: 16,
        }
    }

    /// Apply one `key=value` override. Unknown keys are an error (typos must
    /// not pass silently in bench configs).
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        let bad = |what: &str| Err(format!("invalid {what}: {value}"));
        match key {
            // `policy` names the (base) drafter; as a convenience the
            // ISSUE-spelled `policy=adaptive|static` toggles the mode
            // instead, leaving the drafter untouched (canonical mode key:
            // `policy_mode`).
            "policy" => match PolicyKind::parse(value) {
                Some(p) => self.engine.policy = p,
                None => match PolicyMode::parse(value) {
                    Some(m) => self.adapt.mode = m,
                    None => return bad("policy"),
                },
            },
            "policy_mode" => match PolicyMode::parse(value) {
                Some(m) => self.adapt.mode = m,
                None => return bad("policy_mode"),
            },
            "adapt_drafters" => {
                let mut kinds = Vec::new();
                for part in value.split(',').filter(|p| !p.trim().is_empty())
                {
                    match PolicyKind::parse(part.trim()) {
                        Some(k) if !kinds.contains(&k) => kinds.push(k),
                        Some(_) => {} // duplicate registration is a no-op
                        None => return bad("adapt_drafters"),
                    }
                }
                self.adapt.drafters = kinds;
            }
            "adapt_explore" => match value.parse::<f64>() {
                Ok(v) if v >= 0.0 && v.is_finite() => {
                    self.adapt.explore = v
                }
                _ => return bad("adapt_explore"),
            },
            "adapt_min_samples" => match value.parse() {
                Ok(v) => self.adapt.min_samples = v,
                Err(_) => return bad("adapt_min_samples"),
            },
            "adapt_cut" => match value.parse::<f64>() {
                Ok(v) if (0.0..=1.0).contains(&v) => self.adapt.cut = v,
                _ => return bad("adapt_cut"),
            },
            "adapt_min_budget" => match value.parse() {
                Ok(v) if v >= 1 => self.adapt.min_budget = v,
                _ => return bad("adapt_min_budget"),
            },
            "tree_budget" | "budget" => match value.parse() {
                Ok(v) => self.engine.tree_budget = v,
                Err(_) => return bad("tree_budget"),
            },
            "threshold" => match value.parse() {
                Ok(v) => self.engine.threshold = v,
                Err(_) => return bad("threshold"),
            },
            "max_depth" => match value.parse() {
                Ok(v) => self.engine.max_depth = v,
                Err(_) => return bad("max_depth"),
            },
            "target_temp" | "temp" => match value.parse() {
                Ok(v) => self.engine.target_temp = v,
                Err(_) => return bad("target_temp"),
            },
            "draft_temp" => match value.parse() {
                Ok(v) => self.engine.draft_temp = v,
                Err(_) => return bad("draft_temp"),
            },
            "max_new_tokens" => match value.parse() {
                Ok(v) => self.engine.max_new_tokens = v,
                Err(_) => return bad("max_new_tokens"),
            },
            "seed" => match value.parse() {
                Ok(v) => self.engine.seed = v,
                Err(_) => return bad("seed"),
            },
            "stop_tokens" => {
                let mut toks = Vec::new();
                for part in value.split(',').filter(|p| !p.trim().is_empty()) {
                    match part.trim().parse() {
                        Ok(v) => toks.push(v),
                        Err(_) => return bad("stop_tokens"),
                    }
                }
                self.engine.stop_tokens = toks;
            }
            "backend" => match ModelBackend::parse(value) {
                Some(b) => self.backend = b,
                None => return bad("backend"),
            },
            "regime" => match LatencyRegime::by_name(value) {
                Some(r) => self.regime = Some(r),
                None => return bad("regime"),
            },
            "dataset" => {
                if crate::data::markov::Profile::by_name(value).is_none() {
                    return bad("dataset");
                }
                self.dataset = value.into();
            }
            "artifacts" | "artifacts_dir" => self.artifacts_dir = value.into(),
            "prompt_len" => match value.parse() {
                Ok(v) => self.prompt_len = v,
                Err(_) => return bad("prompt_len"),
            },
            "num_prompts" => match value.parse() {
                Ok(v) => self.num_prompts = v,
                Err(_) => return bad("num_prompts"),
            },
            "addr" => self.server.addr = value.into(),
            "workers" => match value.parse() {
                Ok(v) => self.server.workers = v,
                Err(_) => return bad("workers"),
            },
            "queue_capacity" => match value.parse() {
                Ok(v) => self.server.queue_capacity = v,
                Err(_) => return bad("queue_capacity"),
            },
            "max_batch" => match value.parse() {
                Ok(v) => self.server.max_batch = v,
                Err(_) => return bad("max_batch"),
            },
            "reactor_threads" => match value.parse() {
                Ok(v) if v >= 1 => self.server.reactor_threads = v,
                _ => return bad("reactor_threads"),
            },
            "max_conns" => match value.parse() {
                Ok(v) if v >= 1 => self.server.max_conns = v,
                _ => return bad("max_conns"),
            },
            "outbox_frames" => match value.parse() {
                Ok(v) if v >= 1 => self.server.outbox_frames = v,
                _ => return bad("outbox_frames"),
            },
            "scheduler" => match SchedKind::parse(value) {
                Some(k) => self.sched.kind = k,
                None => return bad("scheduler"),
            },
            "global_budget" => match value.parse() {
                Ok(v) => self.sched.global_budget = v,
                Err(_) => return bad("global_budget"),
            },
            "max_active" => match value.parse() {
                Ok(v) => self.sched.max_active = v,
                Err(_) => return bad("max_active"),
            },
            "idle_tick_ms" => match value.parse() {
                Ok(v) => self.sched.idle_tick_ms = v,
                Err(_) => return bad("idle_tick_ms"),
            },
            "prefill_chunk" => match value.parse() {
                Ok(v) => self.engine.prefill_chunk = v,
                Err(_) => return bad("prefill_chunk"),
            },
            "prefill_budget" => match value.parse() {
                Ok(v) => self.sched.prefill_budget = v,
                Err(_) => return bad("prefill_budget"),
            },
            "cache" => match value {
                "on" | "true" | "1" => self.cache.enabled = true,
                "off" | "false" | "0" => self.cache.enabled = false,
                _ => return bad("cache"),
            },
            "cache_block" | "cache_block_tokens" => match value.parse() {
                Ok(v) if v > 0 => self.cache.block_tokens = v,
                _ => return bad("cache_block"),
            },
            "cache_blocks" | "cache_max_blocks" => match value.parse() {
                Ok(v) if v > 0 => self.cache.max_blocks = v,
                _ => return bad("cache_blocks"),
            },
            "radix" => match value {
                "on" | "true" | "1" => self.cache.radix = true,
                "off" | "false" | "0" => self.cache.radix = false,
                _ => return bad("radix"),
            },
            "radix_min_tokens" => match value.parse() {
                Ok(v) if v >= 1 => self.cache.radix_min_tokens = v,
                _ => return bad("radix_min_tokens"),
            },
            "trace" => match value {
                "on" | "true" | "1" => self.obs.trace = true,
                "off" | "false" | "0" => self.obs.trace = false,
                _ => return bad("trace"),
            },
            "trace_ring" => match value.parse() {
                Ok(v) if v >= 1 => self.obs.trace_ring = v,
                _ => return bad("trace_ring"),
            },
            "route" => match RouteMode::parse(value) {
                Some(m) => self.route.mode = m,
                None => return bad("route"),
            },
            "route_prefix_len" => match value.parse() {
                Ok(v) if v >= 1 => self.route.prefix_len = v,
                _ => return bad("route_prefix_len"),
            },
            "route_vnodes" => match value.parse() {
                Ok(v) if v >= 1 => self.route.vnodes = v,
                _ => return bad("route_vnodes"),
            },
            "route_max_depth" => match value.parse() {
                Ok(v) if v >= 1 => self.route.max_depth = v,
                _ => return bad("route_max_depth"),
            },
            "route_spill" => match value {
                "on" | "true" | "1" => self.route.spill = true,
                "off" | "false" | "0" => self.route.spill = false,
                _ => return bad("route_spill"),
            },
            _ => return Err(format!("unknown config key: {key}")),
        }
        Ok(())
    }

    /// Parse `key=value` lines (comments with '#', blanks skipped).
    pub fn apply_lines(&mut self, text: &str) -> Result<(), String> {
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key=value", lineno + 1))?;
            self.set(k.trim(), v.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        }
        Ok(())
    }

    /// Named presets: `table1`..`table4` mirror the paper's setups.
    pub fn preset(name: &str) -> Result<Self, String> {
        let mut cfg = Config::new();
        match name {
            "table1" => {
                cfg.regime = Some(LatencyRegime::pair_7b());
                cfg.engine.tree_budget = 64;
            }
            "table2" => {
                cfg.regime = Some(LatencyRegime::pair_13b());
                cfg.engine.tree_budget = 64;
            }
            "table3" => {
                cfg.regime = Some(LatencyRegime::pair_70b_offload());
                cfg.engine.tree_budget = 64;
            }
            "table4" => {
                cfg.regime = Some(LatencyRegime::pair_70b_offload());
                cfg.engine.tree_budget = 768;
                cfg.engine.policy = PolicyKind::DySpecThreshold;
                cfg.engine.threshold = 0.001;
                cfg.engine.max_depth = 48;
            }
            _ => return Err(format!("unknown preset: {name}")),
        }
        Ok(cfg)
    }

    /// Flatten to key=value map (round-trips through `set`).
    pub fn to_map(&self) -> BTreeMap<String, String> {
        let mut m = BTreeMap::new();
        m.insert("policy".into(), self.engine.policy.name().into());
        m.insert("tree_budget".into(), self.engine.tree_budget.to_string());
        m.insert("threshold".into(), self.engine.threshold.to_string());
        m.insert("max_depth".into(), self.engine.max_depth.to_string());
        m.insert("target_temp".into(), self.engine.target_temp.to_string());
        m.insert("draft_temp".into(), self.engine.draft_temp.to_string());
        m.insert(
            "max_new_tokens".into(),
            self.engine.max_new_tokens.to_string(),
        );
        m.insert("seed".into(), self.engine.seed.to_string());
        m.insert(
            "stop_tokens".into(),
            self.engine
                .stop_tokens
                .iter()
                .map(|t| t.to_string())
                .collect::<Vec<_>>()
                .join(","),
        );
        m.insert("backend".into(), self.backend.name().into());
        if let Some(r) = &self.regime {
            m.insert("regime".into(), r.name.into());
        }
        m.insert("dataset".into(), self.dataset.clone());
        m.insert("prompt_len".into(), self.prompt_len.to_string());
        m.insert("scheduler".into(), self.sched.kind.name().into());
        m.insert(
            "global_budget".into(),
            self.sched.global_budget.to_string(),
        );
        m.insert("max_active".into(), self.sched.max_active.to_string());
        m.insert(
            "idle_tick_ms".into(),
            self.sched.idle_tick_ms.to_string(),
        );
        m.insert(
            "prefill_chunk".into(),
            self.engine.prefill_chunk.to_string(),
        );
        m.insert(
            "prefill_budget".into(),
            self.sched.prefill_budget.to_string(),
        );
        m.insert(
            "cache".into(),
            if self.cache.enabled { "on" } else { "off" }.into(),
        );
        m.insert(
            "cache_block".into(),
            self.cache.block_tokens.to_string(),
        );
        m.insert("cache_blocks".into(), self.cache.max_blocks.to_string());
        m.insert(
            "radix".into(),
            if self.cache.radix { "on" } else { "off" }.into(),
        );
        m.insert(
            "radix_min_tokens".into(),
            self.cache.radix_min_tokens.to_string(),
        );
        m.insert(
            "trace".into(),
            if self.obs.trace { "on" } else { "off" }.into(),
        );
        m.insert("trace_ring".into(), self.obs.trace_ring.to_string());
        m.insert("policy_mode".into(), self.adapt.mode.name().into());
        m.insert(
            "adapt_drafters".into(),
            self.adapt
                .drafters
                .iter()
                .map(|k| k.name().to_string())
                .collect::<Vec<_>>()
                .join(","),
        );
        m.insert("adapt_explore".into(), self.adapt.explore.to_string());
        m.insert(
            "adapt_min_samples".into(),
            self.adapt.min_samples.to_string(),
        );
        m.insert("adapt_cut".into(), self.adapt.cut.to_string());
        m.insert(
            "adapt_min_budget".into(),
            self.adapt.min_budget.to_string(),
        );
        m.insert(
            "reactor_threads".into(),
            self.server.reactor_threads.to_string(),
        );
        m.insert("max_conns".into(), self.server.max_conns.to_string());
        m.insert(
            "outbox_frames".into(),
            self.server.outbox_frames.to_string(),
        );
        m.insert("route".into(), self.route.mode.name().into());
        m.insert(
            "route_prefix_len".into(),
            self.route.prefix_len.to_string(),
        );
        m.insert("route_vnodes".into(), self.route.vnodes.to_string());
        m.insert(
            "route_max_depth".into(),
            self.route.max_depth.to_string(),
        );
        m.insert(
            "route_spill".into(),
            if self.route.spill { "on" } else { "off" }.into(),
        );
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_round_trip() {
        for p in PolicyKind::all() {
            assert_eq!(PolicyKind::parse(p.name()), Some(p));
        }
        assert_eq!(PolicyKind::parse("nope"), None);
    }

    #[test]
    fn set_and_reject() {
        let mut cfg = Config::new();
        cfg.set("policy", "sequoia").unwrap();
        assert_eq!(cfg.engine.policy, PolicyKind::Sequoia);
        cfg.set("tree_budget", "768").unwrap();
        assert_eq!(cfg.engine.tree_budget, 768);
        assert!(cfg.set("tree_budget", "many").is_err());
        assert!(cfg.set("no_such_key", "1").is_err());
        assert!(cfg.set("dataset", "wikipedia").is_err());
    }

    #[test]
    fn scheduler_keys_round_trip() {
        let mut cfg = Config::new();
        assert_eq!(cfg.sched.kind, SchedKind::Fcfs);
        cfg.set("scheduler", "continuous").unwrap();
        assert_eq!(cfg.sched.kind, SchedKind::Continuous);
        cfg.set("global_budget", "96").unwrap();
        cfg.set("max_active", "16").unwrap();
        cfg.set("idle_tick_ms", "5").unwrap();
        assert_eq!(cfg.sched.global_budget, 96);
        assert_eq!(cfg.sched.max_active, 16);
        assert_eq!(cfg.sched.idle_tick_ms, 5);
        assert!(cfg.set("scheduler", "round-robin").is_err());
        for k in [SchedKind::Fcfs, SchedKind::Continuous] {
            assert_eq!(SchedKind::parse(k.name()), Some(k));
        }
    }

    #[test]
    fn prefill_keys_round_trip_and_default_off() {
        let mut cfg = Config::new();
        assert_eq!(cfg.engine.prefill_chunk, 0, "chunking must default off");
        assert_eq!(cfg.sched.prefill_budget, 0);
        cfg.set("prefill_chunk", "128").unwrap();
        cfg.set("prefill_budget", "256").unwrap();
        assert_eq!(cfg.engine.prefill_chunk, 128);
        assert_eq!(cfg.sched.prefill_budget, 256);
        assert!(cfg.set("prefill_chunk", "lots").is_err());
        assert!(cfg.set("prefill_budget", "-1").is_err());
        let map = cfg.to_map();
        assert_eq!(map.get("prefill_chunk").unwrap(), "128");
        assert_eq!(map.get("prefill_budget").unwrap(), "256");
    }

    #[test]
    fn apply_lines_with_comments() {
        let mut cfg = Config::new();
        cfg.apply_lines("# comment\n policy = chain \n\ntemp=0.6 # inline\n")
            .unwrap();
        assert_eq!(cfg.engine.policy, PolicyKind::Chain);
        assert!((cfg.engine.target_temp - 0.6).abs() < 1e-6);
        assert!(cfg.apply_lines("garbage").is_err());
    }

    #[test]
    fn presets_match_paper_setups() {
        let t3 = Config::preset("table3").unwrap();
        assert_eq!(t3.engine.tree_budget, 64);
        assert!(t3.regime.unwrap().ratio() > 1000.0);
        let t4 = Config::preset("table4").unwrap();
        assert_eq!(t4.engine.tree_budget, 768);
        assert_eq!(t4.engine.policy, PolicyKind::DySpecThreshold);
        assert!(Config::preset("table9").is_err());
    }

    #[test]
    fn stop_tokens_key_round_trips() {
        let mut cfg = Config::new();
        cfg.set("stop_tokens", "5, 9,12").unwrap();
        assert_eq!(cfg.engine.stop_tokens, vec![5, 9, 12]);
        cfg.set("stop_tokens", "").unwrap();
        assert!(cfg.engine.stop_tokens.is_empty());
        assert!(cfg.set("stop_tokens", "a,b").is_err());
        cfg.set("stop_tokens", "3").unwrap();
        let map = cfg.to_map();
        assert_eq!(map.get("stop_tokens").unwrap(), "3");
    }

    #[test]
    fn transport_keys_round_trip_and_validate() {
        let mut cfg = Config::new();
        assert_eq!(cfg.server.reactor_threads, 2);
        assert_eq!(cfg.server.max_conns, 1024);
        assert_eq!(cfg.server.outbox_frames, 1024);
        cfg.set("reactor_threads", "4").unwrap();
        cfg.set("max_conns", "64").unwrap();
        cfg.set("outbox_frames", "256").unwrap();
        assert_eq!(cfg.server.reactor_threads, 4);
        assert_eq!(cfg.server.max_conns, 64);
        assert_eq!(cfg.server.outbox_frames, 256);
        // Zero or garbage never passes validation (a zero-thread reactor
        // or zero-slot outbox cannot serve anything).
        assert!(cfg.set("reactor_threads", "0").is_err());
        assert!(cfg.set("max_conns", "0").is_err());
        assert!(cfg.set("outbox_frames", "0").is_err());
        assert!(cfg.set("reactor_threads", "many").is_err());
        let map = cfg.to_map();
        assert_eq!(map.get("reactor_threads").unwrap(), "4");
        assert_eq!(map.get("max_conns").unwrap(), "64");
        assert_eq!(map.get("outbox_frames").unwrap(), "256");
    }

    #[test]
    fn cache_keys_round_trip() {
        let mut cfg = Config::new();
        assert!(cfg.cache.enabled);
        cfg.set("cache", "off").unwrap();
        assert!(!cfg.cache.enabled);
        cfg.set("cache", "on").unwrap();
        cfg.set("cache_block", "8").unwrap();
        cfg.set("cache_blocks", "128").unwrap();
        assert_eq!(cfg.cache.block_tokens, 8);
        assert_eq!(cfg.cache.max_blocks, 128);
        assert!(cfg.set("cache", "maybe").is_err());
        assert!(cfg.set("cache_block", "0").is_err());
        assert!(cfg.set("cache_blocks", "zero").is_err());
        // Radix keys: default off, on/off syntax, floor validation.
        assert!(!cfg.cache.radix);
        assert_eq!(cfg.cache.radix_min_tokens, 16);
        cfg.set("radix", "on").unwrap();
        cfg.set("radix_min_tokens", "64").unwrap();
        assert!(cfg.cache.radix);
        assert_eq!(cfg.cache.radix_min_tokens, 64);
        assert!(cfg.set("radix", "maybe").is_err());
        assert!(cfg.set("radix_min_tokens", "0").is_err());
        let map = cfg.to_map();
        assert_eq!(map.get("radix").unwrap(), "on");
        assert_eq!(map.get("radix_min_tokens").unwrap(), "64");
        cfg.set("radix", "off").unwrap();
        assert!(!cfg.cache.radix);
    }

    #[test]
    fn trace_keys_round_trip_and_validate() {
        let mut cfg = Config::new();
        assert!(!cfg.obs.trace);
        assert_eq!(cfg.obs.trace_ring, 4096);
        cfg.set("trace", "on").unwrap();
        cfg.set("trace_ring", "64").unwrap();
        assert!(cfg.obs.trace);
        assert_eq!(cfg.obs.trace_ring, 64);
        assert!(cfg.set("trace", "maybe").is_err());
        assert!(cfg.set("trace_ring", "0").is_err());
        let map = cfg.to_map();
        assert_eq!(map.get("trace").unwrap(), "on");
        assert_eq!(map.get("trace_ring").unwrap(), "64");
        cfg.set("trace", "off").unwrap();
        assert!(!cfg.obs.trace);
    }

    #[test]
    fn route_keys_round_trip_and_validate() {
        let mut cfg = Config::new();
        assert_eq!(cfg.route, RouteConfig::default());
        assert_eq!(cfg.route.mode, RouteMode::Affinity);
        cfg.set("route", "rr").unwrap();
        cfg.set("route_prefix_len", "16").unwrap();
        cfg.set("route_vnodes", "128").unwrap();
        cfg.set("route_max_depth", "8").unwrap();
        cfg.set("route_spill", "off").unwrap();
        assert_eq!(cfg.route.mode, RouteMode::Rr);
        assert_eq!(cfg.route.prefix_len, 16);
        assert_eq!(cfg.route.vnodes, 128);
        assert_eq!(cfg.route.max_depth, 8);
        assert!(!cfg.route.spill);
        assert!(cfg.set("route", "random").is_err());
        assert!(cfg.set("route_prefix_len", "0").is_err());
        assert!(cfg.set("route_vnodes", "0").is_err());
        assert!(cfg.set("route_max_depth", "0").is_err());
        assert!(cfg.set("route_spill", "maybe").is_err());
        let map = cfg.to_map();
        assert_eq!(map.get("route").unwrap(), "rr");
        assert_eq!(map.get("route_prefix_len").unwrap(), "16");
        assert_eq!(map.get("route_vnodes").unwrap(), "128");
        assert_eq!(map.get("route_max_depth").unwrap(), "8");
        assert_eq!(map.get("route_spill").unwrap(), "off");
        cfg.set("route", "affinity").unwrap();
        assert_eq!(cfg.route.mode, RouteMode::Affinity);
        for m in [RouteMode::Affinity, RouteMode::Rr] {
            assert_eq!(RouteMode::parse(m.name()), Some(m));
        }
    }

    /// The invariant `cache::verify_bill` prices against: fetching a
    /// resident block must be cheaper than re-computing it (and than
    /// re-writing it), in every built-in regime at the default block size.
    #[test]
    fn regime_cache_terms_keep_cached_cheaper() {
        let block = CacheConfig::default().block_tokens as f64;
        for r in [
            LatencyRegime::pair_7b(),
            LatencyRegime::pair_13b(),
            LatencyRegime::pair_70b_offload(),
        ] {
            assert!(r.target_pos_secs > 0.0, "{}", r.name);
            assert!(
                r.cache_fetch_secs <= r.cache_write_secs,
                "{}: fetch > write",
                r.name
            );
            assert!(
                r.cache_fetch_secs <= r.target_pos_secs * block,
                "{}: fetching a block dearer than recomputing it",
                r.name
            );
        }
    }

    #[test]
    fn regime_ratios() {
        // 7B pair: CUDA-graphed JF68M (paper §5.3) — T_t/T_d ≈ 90.
        assert!((LatencyRegime::pair_7b().ratio() - 90.0).abs() < 5.0);
        // 70B offload: the paper's stated ≈2×10³ regime.
        assert!(LatencyRegime::pair_70b_offload().ratio() >= 2000.0);
    }

    #[test]
    fn to_map_round_trips() {
        let mut cfg = Config::preset("table4").unwrap();
        cfg.set("dataset", "owt").unwrap();
        cfg.set("policy_mode", "adaptive").unwrap();
        cfg.set("adapt_drafters", "dyspec,chain").unwrap();
        let map = cfg.to_map();
        let mut cfg2 = Config::new();
        for (k, v) in &map {
            cfg2.set(k, v).unwrap();
        }
        assert_eq!(cfg2.engine, cfg.engine);
        assert_eq!(cfg2.dataset, cfg.dataset);
        assert_eq!(cfg2.adapt, cfg.adapt);
    }

    #[test]
    fn adapt_keys_round_trip_and_validate() {
        let mut cfg = Config::new();
        assert_eq!(cfg.adapt, AdaptConfig::default());
        assert_eq!(cfg.adapt.mode, PolicyMode::Static);
        cfg.set("policy_mode", "adaptive").unwrap();
        assert_eq!(cfg.adapt.mode, PolicyMode::Adaptive);
        cfg.set("adapt_drafters", "dyspec, chain,specinfer").unwrap();
        assert_eq!(
            cfg.adapt.drafters,
            vec![
                PolicyKind::DySpec,
                PolicyKind::Chain,
                PolicyKind::SpecInfer
            ]
        );
        // Duplicate registration collapses; empty clears.
        cfg.set("adapt_drafters", "chain,chain").unwrap();
        assert_eq!(cfg.adapt.drafters, vec![PolicyKind::Chain]);
        cfg.set("adapt_drafters", "").unwrap();
        assert!(cfg.adapt.drafters.is_empty());
        cfg.set("adapt_explore", "1.25").unwrap();
        cfg.set("adapt_min_samples", "32").unwrap();
        cfg.set("adapt_cut", "0.4").unwrap();
        cfg.set("adapt_min_budget", "2").unwrap();
        assert!((cfg.adapt.explore - 1.25).abs() < 1e-12);
        assert_eq!(cfg.adapt.min_samples, 32);
        assert!((cfg.adapt.cut - 0.4).abs() < 1e-12);
        assert_eq!(cfg.adapt.min_budget, 2);
        assert!(cfg.set("policy_mode", "magic").is_err());
        assert!(cfg.set("adapt_drafters", "dyspec,nope").is_err());
        assert!(cfg.set("adapt_explore", "-1").is_err());
        assert!(cfg.set("adapt_cut", "1.5").is_err());
        assert!(cfg.set("adapt_min_budget", "0").is_err());
        let map = cfg.to_map();
        assert_eq!(map.get("policy_mode").unwrap(), "adaptive");
        assert_eq!(map.get("adapt_min_samples").unwrap(), "32");
    }

    /// The ISSUE's literal spelling: `policy=adaptive|static` toggles the
    /// mode without clobbering the configured drafter.
    #[test]
    fn policy_key_accepts_mode_aliases() {
        let mut cfg = Config::new();
        cfg.set("policy", "sequoia").unwrap();
        cfg.set("policy", "adaptive").unwrap();
        assert_eq!(cfg.engine.policy, PolicyKind::Sequoia);
        assert_eq!(cfg.adapt.mode, PolicyMode::Adaptive);
        cfg.set("policy", "static").unwrap();
        assert_eq!(cfg.adapt.mode, PolicyMode::Static);
        assert_eq!(cfg.engine.policy, PolicyKind::Sequoia);
        assert!(cfg.set("policy", "nope").is_err());
        for m in [PolicyMode::Static, PolicyMode::Adaptive] {
            assert_eq!(PolicyMode::parse(m.name()), Some(m));
        }
    }
}
