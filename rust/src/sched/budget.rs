//! Cross-request greedy budget allocation — the scheduler's core rule.
//!
//! DySpec's Algorithm 1 greedily expands the single candidate sampling with
//! the highest estimated acceptance; the exchange argument behind its
//! optimality (paper Appendix D) never uses the fact that candidates come
//! from one sequence, so the same rule extends verbatim across sequences:
//! one max-heap holds candidate samplings from EVERY active sequence, and
//! each pop spends one token of the shared per-dispatch budget on the
//! globally best frontier node. With a single sequence this reduces exactly
//! to `draft::dyspec::DySpecPolicy::build` (same heap algebra, same rng
//! stream) — pinned by `rust/tests/scheduler.rs`.
//!
//! Fairness comes for free: every sequence's first sampling enters the heap
//! with estimate 1.0 and ties break FIFO, so the first `n` pops hand one
//! token to each of the `n` sequences before any sequence receives its
//! second. With `global_budget >= n` no sequence is starved of speculation,
//! and every sequence in the dispatch emits >= 1 token regardless (the
//! verification bonus).
//!
//! Policies without per-candidate estimates (chain, SpecInfer, Sequoia,
//! the layered threshold variant) get a deterministic near-equal split of
//! the budget instead (`build_forest_fair`).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::config::EngineConfig;
use crate::draft::TreePolicy;
use crate::models::LogitModel;
use crate::sampling::{dist_from_logits, SiblingSampler};
use crate::tree::{NodeId, TokenTree, ROOT};
use crate::util::Rng;

/// A pending sampling: "draw the next child of `node` in sequence `seq`".
///
/// KEEP IN SYNC with `draft::dyspec` — this is deliberately the same heap
/// algebra plus a sequence tag, and `rust/tests/scheduler.rs::
/// single_sequence_reduces_to_dyspec_policy_tree` pins bit-exact
/// equivalence; any fix to the pop/draw/push logic there must land here
/// too (and vice versa) or that test starts guarding divergence.
struct Candidate {
    est: f64,
    seq: usize,
    node: NodeId,
    /// None = lazily scored on first expansion, exactly like DySpec.
    sampler: Option<SiblingSampler>,
    /// Global FIFO tie-breaker (also what round-robins est-1.0 roots).
    push_no: u64,
}

impl PartialEq for Candidate {
    fn eq(&self, other: &Self) -> bool {
        self.est == other.est && self.push_no == other.push_no
    }
}

impl Eq for Candidate {}

impl PartialOrd for Candidate {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Candidate {
    fn cmp(&self, other: &Self) -> Ordering {
        self.est
            .partial_cmp(&other.est)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.push_no.cmp(&self.push_no))
    }
}

/// Per-step result of one allocation round.
pub struct ForestAlloc {
    /// One speculated tree per input prefix (same order).
    pub trees: Vec<TokenTree>,
    /// Speculated tokens each sequence received (== trees[i].size()).
    pub allocated: Vec<usize>,
}

impl ForestAlloc {
    fn from_trees(trees: Vec<TokenTree>) -> Self {
        let allocated = trees.iter().map(|t| t.size()).collect();
        Self { trees, allocated }
    }

    pub fn total_allocated(&self) -> usize {
        self.allocated.iter().sum()
    }
}

/// Build one speculated tree per prefix under a SHARED `global_budget`,
/// spending each token on the globally highest-estimate candidate. Each
/// sequence's tree is additionally capped at `caps[i]` — normally
/// `cfg.tree_budget`, clamped further by the request's own `token_budget`
/// (a sequence never grows a bigger tree than the single-request engine
/// would give it, nor than its client asked to pay for).
pub fn build_forest(
    draft: &mut dyn LogitModel,
    prefixes: &[&[u32]],
    rngs: &mut [Rng],
    cfg: &EngineConfig,
    global_budget: usize,
    caps: &[usize],
) -> ForestAlloc {
    assert_eq!(prefixes.len(), rngs.len(), "one rng per sequence");
    assert_eq!(prefixes.len(), caps.len(), "one cap per sequence");
    let mut trees: Vec<TokenTree> = prefixes
        .iter()
        .map(|p| {
            let root_dist =
                dist_from_logits(&draft.next_logits(p), cfg.draft_temp);
            TokenTree::new(*p.last().expect("empty prefix"), root_dist)
        })
        .collect();

    let mut heap = BinaryHeap::new();
    let mut push_no = 0u64;
    for (i, tree) in trees.iter().enumerate() {
        heap.push(Candidate {
            est: 1.0,
            seq: i,
            node: ROOT,
            sampler: Some(SiblingSampler::new(
                tree.node(ROOT).draft_dist.clone(),
            )),
            push_no,
        });
        push_no += 1;
    }

    let mut spent = 0usize;
    let mut ctx: Vec<u32> = Vec::new();
    while spent < global_budget {
        let Some(mut cand) = heap.pop() else { break };
        if cand.est <= 0.0 {
            break; // everything left is worthless, for every sequence
        }
        if trees[cand.seq].size() >= caps[cand.seq] {
            continue; // this sequence's tree is full; drop the candidate
        }
        // Lazy scoring on first expansion (same as DySpec §Perf L3.1; same
        // is_none/as_mut shape — the match form trips NLL).
        if cand.sampler.is_none() {
            ctx.clear();
            ctx.extend_from_slice(prefixes[cand.seq]);
            ctx.extend(trees[cand.seq].path_tokens(cand.node));
            let dist =
                dist_from_logits(&draft.next_logits(&ctx), cfg.draft_temp);
            trees[cand.seq].node_mut(cand.node).draft_dist = dist.clone();
            cand.sampler = Some(SiblingSampler::new(dist));
        }
        let sampler = cand.sampler.as_mut().expect("sampler just installed");
        let Some((token, r_y)) = sampler.draw(&mut rngs[cand.seq]) else {
            continue; // draft mass at this position exhausted
        };
        let v0 = cand.est * r_y as f64;
        let v1 = cand.est * (1.0 - r_y as f64);
        let child = trees[cand.seq].add_child(cand.node, token as u32, v0);
        spent += 1;

        if v1 > 0.0 && !sampler.exhausted() {
            heap.push(Candidate {
                est: v1,
                seq: cand.seq,
                node: cand.node,
                sampler: cand.sampler,
                push_no,
            });
            push_no += 1;
        }
        if v0 > 0.0 && trees[cand.seq].node(child).depth < cfg.max_depth {
            heap.push(Candidate {
                est: v0,
                seq: cand.seq,
                node: child,
                sampler: None,
                push_no,
            });
            push_no += 1;
        }
    }
    ForestAlloc::from_trees(trees)
}

/// Deterministic near-equal budget shares: `global_budget / n` each, the
/// remainder going to the earliest sequences.
pub fn fair_shares(n: usize, global_budget: usize) -> Vec<usize> {
    if n == 0 {
        return Vec::new();
    }
    let base = global_budget / n;
    let rem = global_budget % n;
    (0..n).map(|i| base + usize::from(i < rem)).collect()
}

/// Forest construction for policies without cross-sequence estimates: each
/// sequence builds its own tree with the configured policy at its fair
/// share of the global budget.
pub fn build_forest_fair(
    policy: &dyn TreePolicy,
    draft: &mut dyn LogitModel,
    prefixes: &[&[u32]],
    rngs: &mut [Rng],
    cfg: &EngineConfig,
    global_budget: usize,
    caps: &[usize],
) -> ForestAlloc {
    assert_eq!(prefixes.len(), rngs.len(), "one rng per sequence");
    assert_eq!(prefixes.len(), caps.len(), "one cap per sequence");
    let shares = fair_shares(prefixes.len(), global_budget);
    let trees = prefixes
        .iter()
        .zip(rngs.iter_mut())
        .zip(shares.into_iter().zip(caps))
        .map(|((prefix, rng), (share, &cap))| {
            let share = share.min(cap);
            if share == 0 {
                // Bare verification row: root only, no draft dispatch.
                return TokenTree::new(
                    *prefix.last().expect("empty prefix"),
                    Vec::new(),
                );
            }
            let mut c = cfg.clone();
            c.tree_budget = share;
            policy.build(draft, prefix, &c, rng)
        })
        .collect();
    ForestAlloc::from_trees(trees)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::sim::{SimModel, SimSpec};

    fn sim_draft(seed: u64) -> SimModel {
        SimModel::pair(SimSpec::new(64, 2.0, 0.8, seed)).0
    }

    fn prefixes() -> Vec<Vec<u32>> {
        vec![vec![3, 1, 4], vec![2, 7, 1, 8], vec![9, 9, 9]]
    }

    #[test]
    fn conserves_global_budget() {
        let ps = prefixes();
        let refs: Vec<&[u32]> = ps.iter().map(|p| p.as_slice()).collect();
        let mut rngs: Vec<Rng> = (0..3).map(Rng::new).collect();
        let cfg = EngineConfig::default();
        let mut draft = sim_draft(5);
        for budget in [3usize, 8, 24, 64] {
            let alloc = build_forest(
                &mut draft,
                &refs,
                &mut rngs,
                &cfg,
                budget,
                &[cfg.tree_budget; 3],
            );
            assert_eq!(alloc.trees.len(), 3);
            assert!(alloc.total_allocated() <= budget);
            for (t, &n) in alloc.trees.iter().zip(&alloc.allocated) {
                assert_eq!(t.size(), n);
                t.check_invariants().unwrap();
            }
        }
    }

    #[test]
    fn every_sequence_gets_a_token_when_budget_covers_roots() {
        let ps = prefixes();
        let refs: Vec<&[u32]> = ps.iter().map(|p| p.as_slice()).collect();
        let mut rngs: Vec<Rng> = (0..3).map(|i| Rng::new(100 + i)).collect();
        let cfg = EngineConfig::default();
        let mut draft = sim_draft(6);
        let alloc = build_forest(
            &mut draft,
            &refs,
            &mut rngs,
            &cfg,
            3,
            &[cfg.tree_budget; 3],
        );
        assert!(
            alloc.allocated.iter().all(|&n| n == 1),
            "roots not round-robined: {:?}",
            alloc.allocated
        );
    }

    #[test]
    fn per_sequence_cap_respected() {
        let ps = prefixes();
        let refs: Vec<&[u32]> = ps.iter().map(|p| p.as_slice()).collect();
        let mut rngs: Vec<Rng> = (0..3).map(|i| Rng::new(7 + i)).collect();
        let cfg = EngineConfig {
            tree_budget: 4,
            ..EngineConfig::default()
        };
        let mut draft = sim_draft(7);
        let alloc = build_forest(
            &mut draft,
            &refs,
            &mut rngs,
            &cfg,
            100,
            &[cfg.tree_budget; 3],
        );
        for &n in &alloc.allocated {
            assert!(n <= 4, "per-seq cap exceeded: {n}");
        }
    }

    #[test]
    fn per_request_token_budget_caps_one_sequence() {
        let ps = prefixes();
        let refs: Vec<&[u32]> = ps.iter().map(|p| p.as_slice()).collect();
        let mut rngs: Vec<Rng> = (0..3).map(|i| Rng::new(11 + i)).collect();
        let cfg = EngineConfig {
            tree_budget: 16,
            ..EngineConfig::default()
        };
        let mut draft = sim_draft(9);
        // Middle sequence carries a tight per-request cap.
        let alloc =
            build_forest(&mut draft, &refs, &mut rngs, &cfg, 48, &[16, 2, 16]);
        assert!(alloc.allocated[1] <= 2, "request cap exceeded");
        assert!(alloc.total_allocated() <= 48);
    }

    #[test]
    fn fair_shares_sum_and_spread() {
        assert_eq!(fair_shares(3, 8), vec![3, 3, 2]);
        assert_eq!(fair_shares(4, 2), vec![1, 1, 0, 0]);
        assert_eq!(fair_shares(0, 10), Vec::<usize>::new());
        assert_eq!(fair_shares(2, 0), vec![0, 0]);
    }

    #[test]
    fn fair_builder_handles_zero_shares() {
        let policy = crate::draft::make_policy(crate::config::PolicyKind::Chain);
        let ps = prefixes();
        let refs: Vec<&[u32]> = ps.iter().map(|p| p.as_slice()).collect();
        let mut rngs: Vec<Rng> = (0..3).map(|i| Rng::new(i)).collect();
        let cfg = EngineConfig::default();
        let mut draft = sim_draft(8);
        let alloc = build_forest_fair(
            policy.as_ref(),
            &mut draft,
            &refs,
            &mut rngs,
            &cfg,
            2,
            &[cfg.tree_budget; 3],
        );
        assert_eq!(alloc.allocated[2], 0);
        assert!(alloc.total_allocated() <= 2);
    }
}
