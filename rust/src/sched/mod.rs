//! Continuous-batching scheduler: step-level multi-sequence serving with a
//! cross-request dynamic token budget.
//!
//! The FCFS worker loop runs one request to completion per engine, so every
//! target dispatch carries a single request's tree and throughput collapses
//! under concurrency. This subsystem replaces that loop with step-level
//! multiplexing:
//!
//!   - [`sequence`] — the per-request state machine
//!     (`Prefill -> Speculate -> Drain -> Done`);
//!   - [`budget`] — the cross-request greedy budget rule: one max-heap of
//!     candidate samplings from every active sequence, spending the shared
//!     per-dispatch token budget on the globally highest estimated
//!     acceptance (DySpec's Algorithm 1 lifted across sequences);
//!   - [`batcher`] — the step loop that admits, sweeps cancellations,
//!     runs the shared round pipeline (`crate::round`) over the active
//!     set, and distributes results.
//!
//! The round itself (tree growth, batched verification, acceptance, KV
//! commit/rollback) lives in `crate::round` and is shared with the FCFS
//! engine — the scheduler switch selects an admission policy, not an
//! implementation (DESIGN.md §Round Pipeline). Select this one with
//! `scheduler = continuous` (see `config::SchedConfig`); DESIGN.md
//! §Scheduler has the full design rationale.

pub mod batcher;
pub mod budget;
pub mod sequence;

pub use batcher::{Batcher, StepReport};
pub use budget::{build_forest, build_forest_fair, fair_shares, ForestAlloc};
pub use sequence::{SeqState, Sequence};
