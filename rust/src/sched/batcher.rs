//! Step-level continuous batcher — the batch-of-n admission wrapper around
//! the shared round pipeline (`crate::round`, DESIGN.md §Round Pipeline).
//!
//! Each iteration of [`Batcher::run`]:
//!   1. retires cancelled sequences (slot + KV residency released before
//!      any further work is spent on them);
//!   2. admits new requests from the shared queue up to `sched.max_active`;
//!   3. resolves the step's effective draft policy and global budget, then
//!      hands the whole active set to `round::run_round` — budget
//!      allocation, tree growth, the ONE batched verification dispatch
//!      (`models::LogitModel::score_forest`), acceptance, and KV
//!      commit/rollback all happen inside the pipeline;
//!   4. streams each sequence's accepted chunk through its event channel
//!      (`GenEvent::Chunk`) and advances its state machine
//!      (`sched::sequence`), retiring finished sequences.
//!
//! One target dispatch therefore serves the whole active set — under the
//! paper's hardware-regime accounting that is the continuous-batching
//! throughput win, measured by `bench --experiment serve`.
//!
//! Per-request `drafter` overrides are honored when the step's speculating
//! set agrees on one policy (`draft::round_policy`); a mixed batch falls
//! back to the worker's configured policy — the cross-request greedy
//! allocator is policy-global by construction (DESIGN.md §Serving API v1).
//!
//! Shutdown drains: the loop only exits once the queue is disconnected AND
//! every in-flight sequence reached `Done`, so closing the coordinator
//! never drops accepted work. Cancellation is the one exception — a
//! cancelled sequence finishes immediately with its partial output.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::cache::CacheManager;
use crate::config::{Config, PolicyKind};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::queue::{EventSink, FinishReason, GenEvent, Request};
use crate::draft::{make_policy, round_policy, TreePolicy};
use crate::log_debug;
use crate::models::LogitModel;
use crate::obs::{Observatory, TraceId};
use crate::round::adapt::AdaptiveController;
use crate::round::{self, RoundCtx, SeqRound};
use crate::sched::sequence::Sequence;

/// What one scheduler step did — consumed by metrics and the invariant
/// tests in `rust/tests/scheduler.rs`.
#[derive(Clone, Debug, Default)]
pub struct StepReport {
    /// Sequences in the dispatch.
    pub active: usize,
    /// Global speculation budget offered this step.
    pub global_budget: usize,
    /// Per-sequence speculated tokens allocated (aligned with the active
    /// set at the start of the step).
    pub allocated: Vec<usize>,
    /// Per-sequence tokens emitted this step (same alignment).
    pub emitted: Vec<usize>,
    pub draft_dispatches: u64,
    /// Verification positions computed across the dispatch (non-resident
    /// prefixes + every tree row; `cache::verify_bill`).
    pub billed_positions: usize,
    /// Prefix positions served from the KV cache across the dispatch.
    pub cached_positions: usize,
    /// Virtual regime cost of the step (one shared target dispatch).
    pub virtual_secs: f64,
    /// Per-sequence verification positions computed (same alignment as
    /// `allocated`/`emitted`: the dispatched subset, in active-set
    /// order) — the head-of-line-blocking bound tests read this.
    pub billed: Vec<usize>,
    /// Prefill chunk rows in this step's dispatch (0 with chunking off).
    pub prefill_chunks: usize,
    /// Prompt positions computed by those chunk rows.
    pub prefill_tokens: usize,
    /// Sequences that finished (responses sent) this step.
    pub completed: usize,
    /// Sequences retired by cancellation before this step's dispatch.
    pub cancelled: usize,
}

/// A continuous batcher bound to one worker's model pair.
pub struct Batcher {
    wid: usize,
    pub cfg: Config,
    draft: Box<dyn LogitModel>,
    target: Box<dyn LogitModel>,
    /// Fair-split construction policy, cached for the step loop and
    /// rebuilt only when the effective kind changes (per-request drafter
    /// overrides on homogeneous batches).
    fair_policy: Box<dyn TreePolicy>,
    fair_policy_kind: PolicyKind,
    metrics: Arc<Metrics>,
    seqs: Vec<Sequence>,
    seed_salt: u64,
    /// KV residency across rounds for every multiplexed sequence, under
    /// this worker's global block budget (`cfg.cache`).
    cache: CacheManager,
    /// Observatory for per-round span/acceptance recording (`None` for
    /// standalone batchers — tests, benches).
    obs: Option<Arc<Observatory>>,
    /// Online drafter/budget selection (`policy_mode=adaptive`,
    /// DESIGN.md §Adaptive Policy); `None` keeps the static path. The
    /// controller supplies the *default* kind each step — homogeneous
    /// per-request overrides still win via `draft::round_policy`.
    adapt: Option<AdaptiveController>,
}

impl Batcher {
    pub fn new(
        wid: usize,
        cfg: Config,
        draft: Box<dyn LogitModel>,
        target: Box<dyn LogitModel>,
        metrics: Arc<Metrics>,
    ) -> Self {
        let seed_salt = cfg.engine.seed ^ 0x5EED_BA7C_0000_0001;
        let cache = CacheManager::new(&cfg.cache);
        let fair_policy_kind = cfg.engine.policy;
        let adapt = AdaptiveController::new(&cfg.adapt, cfg.engine.policy);
        Self {
            wid,
            cfg,
            draft,
            target,
            fair_policy: make_policy(fair_policy_kind),
            fair_policy_kind,
            metrics,
            seqs: Vec::new(),
            seed_salt,
            cache,
            obs: None,
            adapt,
        }
    }

    /// Attach the worker's observatory (builder style): each step then
    /// lands its stage latencies and acceptance counters there, plus
    /// spans when tracing is enabled. Purely observational.
    pub fn with_obs(mut self, obs: Arc<Observatory>) -> Self {
        self.obs = Some(obs);
        self
    }

    pub fn active(&self) -> usize {
        self.seqs.len()
    }

    /// This worker's KV cache state (tests and metrics).
    pub fn cache(&self) -> &CacheManager {
        &self.cache
    }

    fn capacity_left(&self) -> usize {
        self.cfg.sched.max_active.max(1).saturating_sub(self.seqs.len())
    }

    /// Admit one request into the active set (a request cancelled while
    /// queued is retired immediately without taking a slot).
    pub fn admit(&mut self, req: Request) {
        let seq = Sequence::new(req, self.seed_salt);
        self.metrics.on_started(seq.queue_secs);
        if seq.is_cancelled() {
            self.retire(seq, true);
            return;
        }
        self.seqs.push(seq);
    }

    /// Send the sequence's final `Done` event and release everything it
    /// holds. `cancelled` selects the metrics bucket.
    fn retire(&mut self, mut seq: Sequence, cancelled: bool) {
        if cancelled {
            seq.finish = FinishReason::Cancelled;
        }
        // Residency dies with the sequence (leak-freedom is pinned by
        // rust/tests/scheduler.rs and rust/tests/protocol_v1.rs).
        self.cache.drop_seq(seq.id);
        self.metrics
            .on_resident_blocks(self.cache.used_blocks() as u64);
        let (tx, resp) = seq.into_response(self.wid);
        self.metrics.tokens_in_flight_sub(resp.tokens.len() as u64);
        if cancelled {
            self.metrics.on_cancelled();
        } else {
            self.metrics.on_completed(resp.tokens.len(), resp.gen_secs);
        }
        // Receiver may have given up; that's fine.
        let _ = tx.send(GenEvent::Done(Box::new(resp)));
        // A sequence retired mid-prefill (cancel, disconnect) takes its
        // in-flight prompt positions with it — drain the gauge now rather
        // than waiting for a step that may never come.
        self.refresh_prefill_gauge();
    }

    /// Publish the chunked-prefill in-flight gauge: prompt positions
    /// already computed for sequences still mid-prefill. Zero with
    /// chunking off or no mid-prefill sequence in the active set.
    fn refresh_prefill_gauge(&self) {
        let chunk = self.cfg.engine.prefill_chunk;
        let in_flight: usize = self
            .seqs
            .iter()
            .filter(|s| s.mid_prefill(chunk))
            .map(|s| s.prefill_pos)
            .sum();
        self.metrics.set_prefill_in_flight(in_flight as u64);
    }

    /// Retire every cancelled sequence now, before budget or model time is
    /// spent on it. Returns how many were retired.
    fn sweep_cancelled(&mut self) -> usize {
        let cancelled: Vec<usize> = (0..self.seqs.len())
            .filter(|&i| self.seqs[i].is_cancelled())
            .collect();
        // Largest index first keeps the remaining swap_remove indices valid.
        for &i in cancelled.iter().rev() {
            let seq = self.seqs.swap_remove(i);
            self.retire(seq, true);
        }
        cancelled.len()
    }

    /// The shared per-dispatch speculation budget when `n_spec` sequences
    /// want speculation: the configured global budget (default: the
    /// single-request tree budget), never below one token per sequence.
    fn global_budget(&self, n_spec: usize) -> usize {
        let base = if self.cfg.sched.global_budget > 0 {
            self.cfg.sched.global_budget
        } else {
            self.cfg.engine.tree_budget
        };
        base.max(n_spec)
    }

    /// One scheduler iteration over the current active set: resolve the
    /// step's policy + budget, run the shared round pipeline
    /// (`round::run_round`) over every sequence, then stream chunks and
    /// advance state machines. No-op when the active set is empty.
    pub fn step(&mut self) -> StepReport {
        let mut report = StepReport {
            cancelled: self.sweep_cancelled(),
            ..StepReport::default()
        };
        let n = self.seqs.len();
        if n == 0 {
            self.refresh_prefill_gauge();
            return report;
        }
        let metrics = self.metrics.clone();

        // --- chunked-prefill scheduling (DESIGN.md §Chunked Prefill) ---
        // A mid-prefill sequence takes a bare prefill chunk row this step
        // instead of a speculation round: up to `prefill_chunk` prompt
        // tokens, chunk ends rounded down to a cache-block boundary,
        // granted oldest-admission-first (request ids are minted
        // monotonically) under the per-step `prefill_budget` token pool.
        // Mid-prefill sequences the pool cannot cover sit the step out
        // entirely — they are omitted from the dispatch, never given an
        // empty prefix. With chunking off every active sequence is
        // dispatched, exactly the historical step.
        let chunk = self.cfg.engine.prefill_chunk;
        let mut chunk_ends: Vec<Option<usize>> = vec![None; n];
        let mut in_step: Vec<bool> = vec![true; n];
        let mut prefill_used = 0usize;
        if chunk > 0 {
            let b = self.cache.block_tokens().max(1);
            let pool = if self.cfg.sched.prefill_budget > 0 {
                self.cfg.sched.prefill_budget
            } else {
                chunk
            };
            let mut mid: Vec<usize> = (0..n)
                .filter(|&i| self.seqs[i].mid_prefill(chunk))
                .collect();
            mid.sort_by_key(|&i| self.seqs[i].id);
            let mut left = pool;
            for &i in &mid {
                if left == 0 {
                    // Budget spent: sits this step out. At least one
                    // chunk is always granted (pool >= 1), so prefill
                    // makes progress every step.
                    in_step[i] = false;
                    continue;
                }
                let pos = self.seqs[i].prefill_pos;
                let size = chunk.min(left);
                let mut end = ((pos + size) / b) * b;
                if end <= pos {
                    end = pos + size; // >= 1 token of progress
                }
                debug_assert!(end < self.seqs[i].ctx.len());
                left -= end - pos;
                prefill_used += end - pos;
                chunk_ends[i] = Some(end);
            }
        }
        // Dispatched subset, in active-set order.
        let scheduled: Vec<usize> =
            (0..n).filter(|&i| in_step[i]).collect();
        report.active = scheduled.len();

        // --- admission-policy side of the round: who speculates, under
        // which policy, at what shared budget ---
        let spec_count = scheduled
            .iter()
            .filter(|&&i| {
                chunk_ends[i].is_none() && self.seqs[i].wants_speculation()
            })
            .count();
        // Adaptive default: the controller picks the step's fallback
        // drafter and shrinks budgets by observed useful mass; static
        // mode keeps the configured policy and budgets untouched. The
        // `.max(spec_count)` floor (one token per speculating sequence)
        // survives both the retune and the prefill-token carve-out.
        let default_kind = match &self.adapt {
            Some(a) => a.pick(),
            None => self.cfg.engine.policy,
        };
        let budget = if spec_count == 0 {
            0
        } else {
            let base = self.global_budget(spec_count);
            let scaled = match &self.adapt {
                Some(a) => a.scale(base).max(spec_count),
                None => base,
            };
            // The step's token budget is shared: chunk tokens come out
            // of the speculation allocator's pool so the dispatch stays
            // bounded, but never below one token per speculator.
            scaled.saturating_sub(prefill_used).max(spec_count)
        };
        let policy_kind = round_policy(
            scheduled
                .iter()
                .filter(|&&i| {
                    chunk_ends[i].is_none()
                        && self.seqs[i].wants_speculation()
                })
                .map(|&i| self.seqs[i].drafter),
            default_kind,
        );
        if policy_kind != self.fair_policy_kind {
            self.fair_policy = make_policy(policy_kind);
            self.fair_policy_kind = policy_kind;
        }

        // --- the shared round pipeline over the whole active set ---
        let engine_budget = match &self.adapt {
            Some(a) => a.scale(self.cfg.engine.tree_budget),
            None => self.cfg.engine.tree_budget,
        };
        let outcome = {
            let rc = RoundCtx {
                cfg: &self.cfg.engine,
                policy: self.fair_policy.as_ref(),
                policy_kind,
                global_budget: budget,
                regime: self.cfg.regime,
            };
            let mut views: Vec<SeqRound<'_>> = self
                .seqs
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| in_step[*i])
                .map(|(i, s)| {
                    let cap = s.tree_cap(engine_budget);
                    let wants =
                        chunk_ends[i].is_none() && s.wants_speculation();
                    SeqRound {
                        id: s.id,
                        // A chunk row scores only the granted prompt
                        // slice; everything else sees its full context.
                        prefix: match chunk_ends[i] {
                            Some(end) => &s.ctx[..end],
                            None => s.ctx.as_slice(),
                        },
                        rng: &mut s.rng,
                        temperature: s.temperature,
                        cap,
                        wants_spec: wants,
                        prefill: chunk_ends[i].is_some(),
                    }
                })
                .collect();
            round::run_round(
                &rc,
                self.draft.as_mut(),
                self.target.as_mut(),
                &mut self.cache,
                &mut views,
            )
        };
        report.global_budget = outcome.global_budget;
        report.allocated = outcome.seqs.iter().map(|s| s.allocated).collect();
        report.billed =
            outcome.seqs.iter().map(|s| s.bill.billed_positions).collect();
        report.draft_dispatches = outcome.draft_dispatches;
        report.billed_positions = outcome.billed_positions;
        report.cached_positions = outcome.cached_positions;
        report.prefill_chunks = outcome.prefill_rows;
        report.prefill_tokens = outcome.prefill_tokens;
        let virt = outcome.virtual_secs_or_zero();
        report.virtual_secs = virt;
        let used = outcome.spec_tokens;
        let (radix_lookups, radix_hits, warm_tokens) = (
            outcome.radix_lookups,
            outcome.radix_hits,
            outcome.warm_start_tokens,
        );

        if let Some(a) = &mut self.adapt {
            a.observe(policy_kind, &outcome.accept);
        }
        if let Some(obs) = &self.obs {
            // A batched round's spans belong to every co-batched request;
            // only a batch of one is attributed to a single trace id.
            let trace = if n == 1 { self.seqs[0].trace } else { 0 };
            obs.record_round(
                self.wid,
                TraceId(trace),
                report.active,
                policy_kind,
                &outcome.times,
                &outcome.accept,
            );
        }

        // --- stream chunks + advance state machines (after the round so
        // every chunk's RoundStats carries the shared virtual cost) ---
        let mut finished: Vec<usize> = Vec::new();
        for (k, so) in outcome.seqs.into_iter().enumerate() {
            let i = scheduled[k];
            let seq = &mut self.seqs[i];
            seq.cache_hits += so.bill.cached_positions as u64;
            seq.virtual_secs += virt;
            if so.prefill {
                // A chunk row emits nothing and is not a generation step:
                // no on_step, no stream chunk, no TTFT — the clock keeps
                // running until the first real token.
                let end = chunk_ends[i]
                    .expect("prefill outcome for a non-chunk sequence");
                seq.on_prefill_chunk(end);
                report.emitted.push(0);
                continue;
            }
            let stats = so.stats(virt); // round stamped by on_step
            let allocated = so.allocated;
            let before = seq.emitted.len();
            let done = seq.on_step(so.tokens, allocated, stats);
            report.emitted.push(seq.emitted.len() - before);
            metrics.on_chunk();
            if seq.steps == 1 {
                if let Some(t) = seq.ttft_secs {
                    metrics.on_first_token(t);
                }
            }
            if done {
                finished.push(i);
            }
        }

        let emitted_total: usize = report.emitted.iter().sum();
        metrics.on_dispatches(
            1,
            report.active as u64,
            used as u64,
            report.global_budget as u64,
            virt,
        );
        if report.prefill_chunks > 0 {
            metrics.on_prefill(
                report.prefill_chunks as u64,
                report.prefill_tokens as u64,
            );
        }
        metrics.tokens_in_flight_add(emitted_total as u64);
        metrics.on_cache(
            report.cached_positions as u64,
            report.billed_positions as u64,
            self.cache.used_blocks() as u64,
        );
        if self.cache.radix_enabled() {
            let g = self.cache.radix_gauges();
            metrics.on_radix(
                radix_lookups as u64,
                radix_hits as u64,
                warm_tokens as u64,
                g.nodes as u64,
                g.depth_tokens as u64,
                g.shared_blocks as u64,
            );
        }

        // Retire finished sequences (largest index first keeps the
        // remaining swap_remove indices valid).
        for &i in finished.iter().rev() {
            let seq = self.seqs.swap_remove(i);
            self.retire(seq, false);
            report.completed += 1;
        }
        self.refresh_prefill_gauge();
        report
    }

    /// Serve the shared queue until shutdown is requested AND every
    /// in-flight sequence has drained.
    pub fn run(
        &mut self,
        rx: &Mutex<mpsc::Receiver<Request>>,
        shutdown: &AtomicBool,
    ) {
        let idle = Duration::from_millis(self.cfg.sched.idle_tick_ms.max(1));
        log_debug!(
            "worker {} batcher up (policy={}, max_active={})",
            self.wid,
            self.cfg.engine.policy,
            self.cfg.sched.max_active
        );
        loop {
            // Admit up to capacity without blocking the active set.
            let mut disconnected = false;
            while self.capacity_left() > 0 {
                let pulled = {
                    let guard = rx.lock().expect("queue receiver poisoned");
                    guard.try_recv()
                };
                match pulled {
                    Ok(req) => self.admit(req),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }
            if self.seqs.is_empty() {
                if disconnected {
                    break;
                }
                // Idle: block for one request or a shutdown-poll tick.
                let pulled = {
                    let guard = rx.lock().expect("queue receiver poisoned");
                    guard.recv_timeout(idle)
                };
                match pulled {
                    Ok(req) => self.admit(req),
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if shutdown.load(Ordering::SeqCst) {
                            break;
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
                continue;
            }
            // In-flight sequences always progress — shutdown drains,
            // never drops (cancellation is the explicit early exit).
            self.step();
        }
        log_debug!("worker {} batcher down", self.wid);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queue::{CancelToken, GenParams, RequestHandle};
    use crate::models::sim::{SimModel, SimSpec};
    use std::time::Instant;

    fn mk_batcher(max_active: usize, budget: usize) -> Batcher {
        let mut cfg = Config::new();
        cfg.engine.tree_budget = 8;
        cfg.engine.target_temp = 0.6;
        cfg.sched.max_active = max_active;
        cfg.sched.global_budget = budget;
        let (d, t) = SimModel::pair(SimSpec::new(64, 2.0, 0.8, 11));
        Batcher::new(
            0,
            cfg,
            Box::new(d),
            Box::new(t),
            Arc::new(Metrics::new()),
        )
    }

    /// Deterministic per-request prompt of `len` in-vocab tokens — the
    /// fixtures exercise mixed prompt lengths, not just 3-token stubs.
    /// (`len=3` reproduces the historical `[id+1, 2, 3]` fixture exactly,
    /// so the seeded-stream tests keep their pinned expectations.)
    fn mk_prompt(id: u64, len: usize) -> Vec<u32> {
        (0..len as u32)
            .map(|k| if k == 0 { (id as u32 + 1) % 64 } else { (k + 1) % 64 })
            .collect()
    }

    fn mk_seq_with(
        id: u64,
        prompt_len: usize,
        params: GenParams,
    ) -> (Request, RequestHandle) {
        let (tx, rx) = mpsc::channel();
        let cancel = CancelToken::new();
        (
            Request {
                id,
                prompt: mk_prompt(id, prompt_len),
                params,
                submitted_at: Instant::now(),
                cancel: cancel.clone(),
                events: Box::new(tx),
                trace: 0,
            },
            RequestHandle {
                id,
                events: rx,
                cancel,
            },
        )
    }

    fn mk_seq(id: u64, prompt_len: usize) -> (Request, RequestHandle) {
        mk_seq_with(id, prompt_len, GenParams::simple(12, 0.6))
    }

    fn mk_request_with(
        id: u64,
        params: GenParams,
    ) -> (Request, RequestHandle) {
        mk_seq_with(id, 3, params)
    }

    fn mk_request(id: u64, max_new: usize) -> (Request, RequestHandle) {
        mk_request_with(id, GenParams::simple(max_new, 0.6))
    }

    #[test]
    fn steps_multiple_sequences_to_completion() {
        let mut b = mk_batcher(8, 16);
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let (req, h) = mk_request(i + 1, 12);
                b.admit(req);
                h
            })
            .collect();
        assert_eq!(b.active(), 4);
        let mut guard = 0;
        while b.active() > 0 {
            let report = b.step();
            assert_eq!(report.emitted.len(), report.active);
            // every sequence in the dispatch makes progress
            assert!(report.emitted.iter().all(|&e| e >= 1));
            guard += 1;
            assert!(guard <= 4 * 12, "batcher failed to converge");
        }
        for h in handles {
            let resp = h.wait().unwrap();
            assert_eq!(resp.tokens.len(), 12);
            assert!(resp.steps >= 1);
            assert!(resp.ttft_secs >= 0.0);
        }
    }

    #[test]
    fn empty_step_is_noop() {
        let mut b = mk_batcher(4, 8);
        let report = b.step();
        assert_eq!(report.active, 0);
        assert_eq!(report.completed, 0);
    }

    #[test]
    fn drain_state_takes_no_budget() {
        let mut b = mk_batcher(4, 16);
        let (req, h) = mk_request(1, 1); // one token: Drain from the start
        b.admit(req);
        let report = b.step();
        assert_eq!(report.global_budget, 0);
        assert_eq!(report.allocated, vec![0]);
        assert_eq!(report.emitted, vec![1]);
        assert_eq!(h.wait().unwrap().tokens.len(), 1);
        assert_eq!(b.active(), 0);
    }

    #[test]
    fn cancelled_sequence_is_retired_before_the_dispatch() {
        let mut b = mk_batcher(4, 16);
        let (req1, h1) = mk_request(1, 64);
        let (req2, h2) = mk_request(2, 8);
        b.admit(req1);
        b.admit(req2);
        b.step();
        assert!(b.cache().used_blocks() > 0);
        h1.cancel.cancel();
        let report = b.step();
        assert_eq!(report.cancelled, 1);
        assert_eq!(report.active, 1, "cancelled seq still dispatched");
        let resp = h1.wait().unwrap();
        assert_eq!(resp.finish, FinishReason::Cancelled);
        assert!(resp.tokens.len() < 64);
        while b.active() > 0 {
            b.step();
        }
        assert_eq!(h2.wait().unwrap().tokens.len(), 8);
        assert_eq!(b.cache().used_blocks(), 0, "cancel leaked blocks");
    }

    #[test]
    fn pre_cancelled_request_never_takes_a_slot() {
        let mut b = mk_batcher(4, 16);
        let (req, h) = mk_request(1, 16);
        h.cancel.cancel();
        b.admit(req);
        assert_eq!(b.active(), 0);
        let resp = h.wait().unwrap();
        assert_eq!(resp.finish, FinishReason::Cancelled);
        assert!(resp.tokens.is_empty());
    }

    #[test]
    fn per_request_token_budget_caps_allocation() {
        let mut b = mk_batcher(4, 32);
        let (req, _h) = mk_request_with(
            1,
            GenParams {
                token_budget: Some(2),
                ..GenParams::simple(24, 0.6)
            },
        );
        b.admit(req);
        while b.active() > 0 {
            let report = b.step();
            assert!(
                report.allocated.iter().all(|&a| a <= 2),
                "token_budget cap exceeded: {:?}",
                report.allocated
            );
        }
    }

    #[test]
    fn stop_token_retires_sequence_early() {
        let mut b = mk_batcher(4, 16);
        // First run uncapped to learn the stream, then stop at its 3rd token.
        let (req, h) = mk_request_with(
            1,
            GenParams {
                seed: Some(5),
                ..GenParams::simple(24, 0.6)
            },
        );
        b.admit(req);
        while b.active() > 0 {
            b.step();
        }
        let tokens = h.wait().unwrap().tokens;
        let stop = tokens[2];
        let first_hit = tokens.iter().position(|&t| t == stop).unwrap();

        let (req, h) = mk_request_with(
            2,
            GenParams {
                seed: Some(5),
                stop_tokens: vec![stop],
                ..GenParams::simple(24, 0.6)
            },
        );
        b.admit(req);
        while b.active() > 0 {
            b.step();
        }
        let resp = h.wait().unwrap();
        assert_eq!(resp.finish, FinishReason::Stop);
        assert_eq!(resp.tokens, tokens[..first_hit + 1].to_vec());
    }

    #[test]
    fn cache_residency_kicks_in_after_first_step_and_drains_clean() {
        let mut b = mk_batcher(8, 16);
        let handles: Vec<_> = (0..3)
            .map(|i| {
                let (req, h) = mk_request(i + 1, 10);
                b.admit(req);
                h
            })
            .collect();
        let first = b.step();
        assert_eq!(first.cached_positions, 0, "cold start cannot hit");
        assert!(first.billed_positions > 0);
        assert!(b.cache().used_blocks() > 0, "no residency committed");
        while b.active() > 0 {
            let rep = b.step();
            assert!(
                rep.cached_positions > 0,
                "warm step served nothing from cache"
            );
        }
        for h in handles {
            let resp = h.wait().unwrap();
            assert_eq!(resp.tokens.len(), 10);
            assert!(
                resp.cache_hits > 0,
                "multi-step request reported no cache hits"
            );
        }
        assert_eq!(
            b.cache().used_blocks(),
            0,
            "retired sequences leaked blocks"
        );
    }

    #[test]
    fn cache_off_bills_everything() {
        let mut cfg = Config::new();
        cfg.engine.tree_budget = 8;
        cfg.sched.max_active = 4;
        cfg.sched.global_budget = 8;
        cfg.cache.enabled = false;
        let (d, t) = SimModel::pair(SimSpec::new(64, 2.0, 0.8, 11));
        let mut b = Batcher::new(
            0,
            cfg,
            Box::new(d),
            Box::new(t),
            Arc::new(Metrics::new()),
        );
        let (req, _h) = mk_request(1, 6);
        b.admit(req);
        while b.active() > 0 {
            let rep = b.step();
            assert_eq!(rep.cached_positions, 0);
            assert_eq!(b.cache().used_blocks(), 0);
        }
    }

    /// Batched steps land in the observatory: one record per step with
    /// the batch's sequence count, trace attributed only at batch-of-1.
    #[test]
    fn observatory_sees_batched_steps() {
        let obs = Arc::new(crate::obs::Observatory::new(1, true, 256));
        let mut b = mk_batcher(8, 16).with_obs(obs.clone());
        let _handles: Vec<_> = (0..3)
            .map(|i| {
                let (req, h) = mk_request(i + 1, 6);
                b.admit(req);
                h
            })
            .collect();
        let mut steps = 0u64;
        while b.active() > 0 {
            b.step();
            steps += 1;
        }
        let q = obs.stage_quantiles();
        assert!(q.iter().all(|(_, n, ..)| *n == steps));
        let (spans, _) = obs.dump_spans();
        assert_eq!(spans.len(), steps as usize * 5);
        // Batch of 3: spans carry the batch width and no single trace.
        assert!(spans.iter().take(5).all(|s| s.seqs == 3 && s.trace == 0));
        let table = obs.acceptance();
        assert_eq!(table.len(), 1);
        assert!(table[0].1.proposed() > 0);
    }

    fn mk_adaptive_batcher(drafters: &str) -> Batcher {
        let mut cfg = Config::new();
        cfg.engine.tree_budget = 8;
        cfg.engine.target_temp = 0.6;
        cfg.sched.max_active = 8;
        cfg.sched.global_budget = 16;
        cfg.set("policy_mode", "adaptive").unwrap();
        if !drafters.is_empty() {
            cfg.set("adapt_drafters", drafters).unwrap();
        }
        cfg.set("adapt_min_samples", "8").unwrap();
        let (d, t) = SimModel::pair(SimSpec::new(64, 2.0, 0.8, 11));
        Batcher::new(
            0,
            cfg,
            Box::new(d),
            Box::new(t),
            Arc::new(Metrics::new()),
        )
    }

    /// The tentpole equivalence at batcher level: adaptive mode with one
    /// registered drafter (here: the implicit fallback of an empty list)
    /// streams bit-identically to static mode. The full matrix lives in
    /// `rust/tests/adaptive_differential.rs`.
    #[test]
    fn adaptive_singleton_batch_matches_static() {
        let run = |mut b: Batcher| {
            let handles: Vec<_> = (0..3)
                .map(|i| {
                    let (req, h) = mk_request(i + 1, 10);
                    b.admit(req);
                    h
                })
                .collect();
            while b.active() > 0 {
                b.step();
            }
            handles
                .into_iter()
                .map(|h| h.wait().unwrap().tokens)
                .collect::<Vec<_>>()
        };
        let static_streams = run(mk_batcher(8, 16));
        let adaptive_streams = run(mk_adaptive_batcher(""));
        assert_eq!(adaptive_streams, static_streams);
    }

    /// With competing drafters every cold arm gets explored, the shared
    /// budget never loses its one-token-per-sequence floor, and every
    /// request still completes exactly.
    #[test]
    fn adaptive_multi_drafter_batch_completes_and_explores() {
        let obs = Arc::new(crate::obs::Observatory::new(1, false, 8));
        let mut b = mk_adaptive_batcher("dyspec,chain").with_obs(obs.clone());
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let (req, h) = mk_request(i + 1, 12);
                b.admit(req);
                h
            })
            .collect();
        while b.active() > 0 {
            let rep = b.step();
            if rep.global_budget > 0 {
                assert!(rep.global_budget >= rep.active.min(4));
            }
        }
        for h in handles {
            assert_eq!(h.wait().unwrap().tokens.len(), 12);
        }
        let table = obs.acceptance();
        assert_eq!(table.len(), 2, "a cold drafter was never explored");
    }

    #[test]
    fn metrics_see_batched_dispatches_and_chunks() {
        let mut b = mk_batcher(8, 12);
        let _handles: Vec<_> = (0..3)
            .map(|i| {
                let (req, h) = mk_request(i + 1, 6);
                b.admit(req);
                h
            })
            .collect();
        b.step();
        let m = b.metrics.clone();
        assert_eq!(m.dispatches(), 1);
        assert!(m.batch_occupancy() >= 3.0 - 1e-9);
        assert_eq!(m.chunks(), 3, "one chunk per sequence per step");
    }

    fn mk_chunked_batcher(chunk: usize, budget: usize) -> Batcher {
        let mut cfg = Config::new();
        cfg.engine.tree_budget = 8;
        cfg.engine.target_temp = 0.6;
        cfg.engine.prefill_chunk = chunk;
        cfg.sched.max_active = 8;
        cfg.sched.global_budget = 16;
        cfg.sched.prefill_budget = budget;
        cfg.cache.block_tokens = 4;
        let (d, t) = SimModel::pair(SimSpec::new(64, 2.0, 0.8, 11));
        Batcher::new(
            0,
            cfg,
            Box::new(d),
            Box::new(t),
            Arc::new(Metrics::new()),
        )
    }

    /// A long prompt is admitted as chunk rows co-batched with a chatter:
    /// the chunk emits nothing while the chatter keeps streaming, the
    /// in-flight gauge tracks committed chunk positions, and everything
    /// drains clean.
    #[test]
    fn chunked_prefill_interleaves_long_prompt_with_chatter() {
        let mut b = mk_chunked_batcher(8, 8);
        let m = b.metrics.clone();
        let (long_req, long_h) = mk_seq(1, 40);
        let (short_req, short_h) = mk_seq(2, 3);
        b.admit(long_req);
        b.admit(short_req);

        // 40-token prompt, chunk 8, block 4: chunk rounds end at
        // 8/16/24/32, then the final 8 prompt positions ride the long
        // sequence's first speculation round.
        let rep = b.step();
        assert_eq!(rep.active, 2);
        assert_eq!(rep.prefill_chunks, 1);
        assert_eq!(rep.prefill_tokens, 8);
        assert_eq!(rep.emitted.len(), 2);
        assert_eq!(rep.emitted[0], 0, "chunk row emitted tokens");
        assert!(rep.emitted[1] >= 1, "chatter starved by the chunk");
        assert_eq!(rep.billed[0], 8, "chunk billed more than its grant");
        assert_eq!(m.prefill_tokens_in_flight(), 8);

        let mut chunk_steps = 1usize;
        while b.active() > 0 {
            let rep = b.step();
            chunk_steps += rep.prefill_chunks;
        }
        assert_eq!(chunk_steps, 4, "40-token prompt needs 4 chunk rounds");
        assert_eq!(m.prefill_chunks(), 4);
        assert_eq!(m.prefill_tokens(), 32);
        assert_eq!(m.prefill_tokens_in_flight(), 0, "gauge stuck after drain");
        assert_eq!(long_h.wait().unwrap().tokens.len(), 12);
        assert_eq!(short_h.wait().unwrap().tokens.len(), 12);
        assert_eq!(b.cache().used_blocks(), 0, "chunked prefill leaked");
    }

    /// The prefill pool admits chunks oldest-first: with a one-chunk pool
    /// and two long prompts, exactly one chunk row runs per step and the
    /// younger sequence sits steps out rather than being dispatched with
    /// an empty slice.
    #[test]
    fn prefill_pool_grants_oldest_first() {
        let mut b = mk_chunked_batcher(8, 8);
        let (a_req, a_h) = mk_seq(1, 24);
        let (b_req, b_h) = mk_seq(2, 24);
        b.admit(a_req);
        b.admit(b_req);
        let rep = b.step();
        // Pool of 8 covers one 8-token chunk: the older long gets it, the
        // younger is omitted from the dispatch entirely.
        assert_eq!(rep.prefill_chunks, 1);
        assert_eq!(rep.active, 1);
        while b.active() > 0 {
            b.step();
        }
        assert_eq!(a_h.wait().unwrap().tokens.len(), 12);
        assert_eq!(b_h.wait().unwrap().tokens.len(), 12);
        assert_eq!(b.cache().used_blocks(), 0);
    }
}
