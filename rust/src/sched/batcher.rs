//! Step-level continuous batcher.
//!
//! Each iteration of [`Batcher::run`]:
//!   1. retires cancelled sequences (slot + KV residency released before
//!      any further work is spent on them);
//!   2. admits new requests from the shared queue up to `sched.max_active`;
//!   3. asks the budget allocator for one speculated tree per sequence,
//!      spending the GLOBAL per-dispatch token budget greedily across
//!      sequences by estimated acceptance (`sched::budget`), each sequence
//!      further capped by its request's own `token_budget`;
//!   4. packs every sequence's tree (plus bare root rows for draining
//!      sequences) into ONE batched target verification
//!      (`models::LogitModel::score_forest`);
//!   5. walks each sequence's accept/reject outcome, streams the accepted
//!      chunk through the request's event channel (`GenEvent::Chunk`), and
//!      advances its state machine (`sched::sequence`).
//!
//! One target dispatch therefore serves the whole active set — under the
//! paper's hardware-regime accounting that is the continuous-batching
//! throughput win, measured by `bench --experiment serve`.
//!
//! Per-request `drafter` overrides are honored when the step's speculating
//! set agrees on one policy (a homogeneous batch); a mixed batch falls
//! back to the worker's configured policy — the cross-request greedy
//! allocator is policy-global by construction (DESIGN.md §Serving API v1).
//!
//! Shutdown drains: the loop only exits once the queue is disconnected AND
//! every in-flight sequence reached `Done`, so closing the coordinator
//! never drops accepted work. Cancellation is the one exception — a
//! cancelled sequence finishes immediately with its partial output.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::cache::{verify_bill, CacheManager, TreeLease, VerifyBill};
use crate::config::{Config, PolicyKind};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::queue::{
    EventSink, FinishReason, GenEvent, Request, RoundStats,
};
use crate::draft::{make_policy, TreePolicy};
use crate::log_debug;
use crate::models::{ForestItem, LogitModel, TimedModel};
use crate::sampling::dist_from_logits;
use crate::sched::budget::{build_forest, build_forest_fair, ForestAlloc};
use crate::sched::sequence::Sequence;
use crate::tree::{dfs_order, NodeId, TokenTree};
use crate::util::timer::Timer;
use crate::util::Rng;
use crate::verify::{row_map, verify_tree};

/// What one scheduler step did — consumed by metrics and the invariant
/// tests in `rust/tests/scheduler.rs`.
#[derive(Clone, Debug, Default)]
pub struct StepReport {
    /// Sequences in the dispatch.
    pub active: usize,
    /// Global speculation budget offered this step.
    pub global_budget: usize,
    /// Per-sequence speculated tokens allocated (aligned with the active
    /// set at the start of the step).
    pub allocated: Vec<usize>,
    /// Per-sequence tokens emitted this step (same alignment).
    pub emitted: Vec<usize>,
    pub draft_dispatches: u64,
    /// Verification positions computed across the dispatch (non-resident
    /// prefixes + every tree row; `cache::verify_bill`).
    pub billed_positions: usize,
    /// Prefix positions served from the KV cache across the dispatch.
    pub cached_positions: usize,
    /// Virtual regime cost of the step (one shared target dispatch).
    pub virtual_secs: f64,
    /// Sequences that finished (responses sent) this step.
    pub completed: usize,
    /// Sequences retired by cancellation before this step's dispatch.
    pub cancelled: usize,
}

/// A continuous batcher bound to one worker's model pair.
pub struct Batcher {
    wid: usize,
    pub cfg: Config,
    draft: Box<dyn LogitModel>,
    target: Box<dyn LogitModel>,
    /// Fair-split construction policy, cached for the step loop and
    /// rebuilt only when the effective kind changes (per-request drafter
    /// overrides on homogeneous batches).
    fair_policy: Box<dyn TreePolicy>,
    fair_policy_kind: PolicyKind,
    metrics: Arc<Metrics>,
    seqs: Vec<Sequence>,
    seed_salt: u64,
    /// KV residency across rounds for every multiplexed sequence, under
    /// this worker's global block budget (`cfg.cache`).
    cache: CacheManager,
}

impl Batcher {
    pub fn new(
        wid: usize,
        cfg: Config,
        draft: Box<dyn LogitModel>,
        target: Box<dyn LogitModel>,
        metrics: Arc<Metrics>,
    ) -> Self {
        let seed_salt = cfg.engine.seed ^ 0x5EED_BA7C_0000_0001;
        let cache = CacheManager::new(&cfg.cache);
        let fair_policy_kind = cfg.engine.policy;
        Self {
            wid,
            cfg,
            draft,
            target,
            fair_policy: make_policy(fair_policy_kind),
            fair_policy_kind,
            metrics,
            seqs: Vec::new(),
            seed_salt,
            cache,
        }
    }

    pub fn active(&self) -> usize {
        self.seqs.len()
    }

    /// This worker's KV cache state (tests and metrics).
    pub fn cache(&self) -> &CacheManager {
        &self.cache
    }

    fn capacity_left(&self) -> usize {
        self.cfg.sched.max_active.max(1).saturating_sub(self.seqs.len())
    }

    /// Admit one request into the active set (a request cancelled while
    /// queued is retired immediately without taking a slot).
    pub fn admit(&mut self, req: Request) {
        let seq = Sequence::new(req, self.seed_salt);
        self.metrics.on_started(seq.queue_secs);
        if seq.is_cancelled() {
            self.retire(seq, true);
            return;
        }
        self.seqs.push(seq);
    }

    /// Send the sequence's final `Done` event and release everything it
    /// holds. `cancelled` selects the metrics bucket.
    fn retire(&mut self, mut seq: Sequence, cancelled: bool) {
        if cancelled {
            seq.finish = FinishReason::Cancelled;
        }
        // Residency dies with the sequence (leak-freedom is pinned by
        // rust/tests/scheduler.rs and rust/tests/protocol_v1.rs).
        self.cache.drop_seq(seq.id);
        self.metrics
            .on_resident_blocks(self.cache.used_blocks() as u64);
        let (tx, resp) = seq.into_response(self.wid);
        self.metrics.tokens_in_flight_sub(resp.tokens.len() as u64);
        if cancelled {
            self.metrics.on_cancelled();
        } else {
            self.metrics.on_completed(resp.tokens.len(), resp.gen_secs);
        }
        // Receiver may have given up; that's fine.
        let _ = tx.send(GenEvent::Done(Box::new(resp)));
    }

    /// Retire every cancelled sequence now, before budget or model time is
    /// spent on it. Returns how many were retired.
    fn sweep_cancelled(&mut self) -> usize {
        let cancelled: Vec<usize> = (0..self.seqs.len())
            .filter(|&i| self.seqs[i].is_cancelled())
            .collect();
        // Largest index first keeps the remaining swap_remove indices valid.
        for &i in cancelled.iter().rev() {
            let seq = self.seqs.swap_remove(i);
            self.retire(seq, true);
        }
        cancelled.len()
    }

    /// The shared per-dispatch speculation budget when `n_spec` sequences
    /// want speculation: the configured global budget (default: the
    /// single-request tree budget), never below one token per sequence.
    fn global_budget(&self, n_spec: usize) -> usize {
        let base = if self.cfg.sched.global_budget > 0 {
            self.cfg.sched.global_budget
        } else {
            self.cfg.engine.tree_budget
        };
        base.max(n_spec)
    }

    /// The draft policy this step runs: the per-request override when the
    /// speculating set is homogeneous, the worker default otherwise.
    fn step_policy(&self, spec_idx: &[usize]) -> PolicyKind {
        let mut kinds = spec_idx.iter().map(|&i| {
            self.seqs[i]
                .drafter
                .unwrap_or(self.cfg.engine.policy)
        });
        let Some(first) = kinds.next() else {
            return self.cfg.engine.policy;
        };
        if kinds.all(|k| k == first) {
            first
        } else {
            self.cfg.engine.policy
        }
    }

    /// One scheduler iteration over the current active set. No-op when the
    /// active set is empty.
    pub fn step(&mut self) -> StepReport {
        let mut report = StepReport {
            cancelled: self.sweep_cancelled(),
            ..StepReport::default()
        };
        let n = self.seqs.len();
        if n == 0 {
            return report;
        }
        report.active = n;
        let metrics = self.metrics.clone();
        let draft_before = self.draft.call_counts().dispatches;

        // --- cross-request budget allocation + tree construction ---
        let spec_idx: Vec<usize> = (0..n)
            .filter(|&i| self.seqs[i].wants_speculation())
            .collect();
        let budget = if spec_idx.is_empty() {
            0
        } else {
            self.global_budget(spec_idx.len())
        };
        report.global_budget = budget;
        let policy_kind = self.step_policy(&spec_idx);
        if policy_kind != self.fair_policy_kind {
            self.fair_policy = make_policy(policy_kind);
            self.fair_policy_kind = policy_kind;
        }

        let t_build = Timer::start();
        let (alloc, draft_wall_secs): (ForestAlloc, f64) = {
            // Rngs are cloned out and written back: the allocator needs
            // them mutably while the prefixes borrow the sequences.
            let mut rngs: Vec<Rng> = spec_idx
                .iter()
                .map(|&i| self.seqs[i].rng.clone())
                .collect();
            let prefixes: Vec<&[u32]> = spec_idx
                .iter()
                .map(|&i| self.seqs[i].ctx.as_slice())
                .collect();
            let caps: Vec<usize> = spec_idx
                .iter()
                .map(|&i| self.seqs[i].tree_cap(self.cfg.engine.tree_budget))
                .collect();
            // Split inference wall time out of construction logic, exactly
            // like the engine's FCFS ledger — model time is billed at
            // regime rates below, never wall time.
            let mut timed = TimedModel::new(self.draft.as_mut());
            let alloc = if policy_kind == PolicyKind::DySpec {
                build_forest(
                    &mut timed,
                    &prefixes,
                    &mut rngs,
                    &self.cfg.engine,
                    budget,
                    &caps,
                )
            } else {
                build_forest_fair(
                    self.fair_policy.as_ref(),
                    &mut timed,
                    &prefixes,
                    &mut rngs,
                    &self.cfg.engine,
                    budget,
                    &caps,
                )
            };
            let draft_wall_secs = timed.secs;
            drop(prefixes);
            for (k, &i) in spec_idx.iter().enumerate() {
                self.seqs[i].rng = rngs[k].clone();
            }
            (alloc, draft_wall_secs)
        };
        let build_secs = t_build.elapsed_secs();
        report.draft_dispatches =
            self.draft.call_counts().dispatches - draft_before;

        // Align trees with the full active set; draining sequences get a
        // bare root row (no speculation, still >= 1 emitted token).
        let mut trees: Vec<TokenTree> = Vec::with_capacity(n);
        let mut alloc_by_seq = vec![0usize; n];
        {
            let mut built = alloc.trees.into_iter();
            let mut spec_pos = 0usize;
            for (i, row) in alloc_by_seq.iter_mut().enumerate() {
                if spec_pos < spec_idx.len() && spec_idx[spec_pos] == i {
                    let tree = built.next().expect("allocator arity");
                    *row = tree.size();
                    trees.push(tree);
                    spec_pos += 1;
                } else {
                    let last = *self.seqs[i].ctx.last().expect("empty ctx");
                    trees.push(TokenTree::new(last, Vec::new()));
                }
            }
        }
        report.allocated = alloc_by_seq.clone();
        let orders: Vec<Vec<NodeId>> =
            trees.iter().map(dfs_order).collect();

        // --- KV residency: resident prefix marks + transient COW leases
        // for the speculated branches (DESIGN.md §KV cache) ---
        let cached_lens: Vec<usize> = (0..n)
            .map(|i| {
                self.cache
                    .begin_round(self.seqs[i].id)
                    .min(self.seqs[i].ctx.len())
            })
            .collect();
        let mut leases: Vec<TreeLease> =
            trees.iter().map(|t| self.cache.lease_tree(t)).collect();

        // --- ONE batched target dispatch for the whole active set ---
        let all_rows = {
            let items: Vec<ForestItem<'_>> = (0..n)
                .map(|i| ForestItem {
                    prefix: &self.seqs[i].ctx,
                    cached_len: cached_lens[i],
                    tree: &trees[i],
                    order: &orders[i],
                })
                .collect();
            self.target.score_forest(&items)
        };

        // --- phase A: per-sequence verification + cache round end ---
        // (chunk emission waits for phase B so every chunk's RoundStats
        // can carry the step's shared virtual cost)
        let t_verify = Timer::start();
        let block_tokens = self.cache.block_tokens();
        let mut outcomes: Vec<(Vec<u32>, usize, VerifyBill)> =
            Vec::with_capacity(n);
        let mut billed_total = 0usize;
        let mut cached_total = 0usize;
        let mut fetched_total = 0usize;
        let mut written_total = 0usize;
        for i in 0..n {
            let seq = &mut self.seqs[i];
            let seq_id = seq.id;
            let prefix_len = seq.ctx.len();
            let dists: Vec<Vec<f32>> = all_rows[i]
                .iter()
                .map(|r| dist_from_logits(r, seq.temperature))
                .collect();
            let row_of = row_map(&trees[i], &orders[i]);
            let out = verify_tree(&trees[i], &dists, &row_of, &mut seq.rng);

            // Rollback rejected branches, retain miss region + accepted
            // path as the new resident prefix, price the dispatch slice.
            let lease = std::mem::take(&mut leases[i]);
            self.cache.end_lease(lease, &trees[i], &out.accepted_nodes);
            self.cache.commit(
                seq_id,
                cached_lens[i],
                prefix_len,
                out.accepted.len(),
            );
            let bill = verify_bill(
                prefix_len,
                cached_lens[i],
                orders[i].len(),
                block_tokens,
            );
            self.cache.record_lookup(
                bill.cached_positions as u64,
                (prefix_len - bill.cached_positions) as u64,
            );
            billed_total += bill.billed_positions;
            cached_total += bill.cached_positions;
            fetched_total += bill.fetched_blocks;
            written_total += bill.written_blocks;

            let accepted = out.accepted.len();
            let mut tokens = out.accepted;
            tokens.push(out.bonus);
            outcomes.push((tokens, accepted, bill));
        }
        let verify_secs = t_verify.elapsed_secs();
        report.billed_positions = billed_total;
        report.cached_positions = cached_total;

        let used: usize = alloc_by_seq.iter().sum();

        // Virtual regime accounting, mirroring the engine's FCFS ledger
        // (engine/mod.rs): model inference is billed at regime rates ONLY
        // (wall time excluded via TimedModel; target wall never billed),
        // pure scheduling/verification logic at measured wall time. The
        // shared target dispatch is billed in ceil(spec_tokens /
        // verify_width) units: per-sequence root rows ride free exactly as
        // the single root row does in the engine's one-unit step, so a
        // single-sequence continuous step bills identically to FCFS, and
        // packing more SPECULATED tokens than the width the regime's step
        // time was calibrated at costs proportionally more.
        let construct_secs = (build_secs - draft_wall_secs).max(0.0);
        let virt = self
            .cfg
            .regime
            .map(|r| {
                let units = if r.verify_width == usize::MAX || used == 0 {
                    1
                } else {
                    ((used + r.verify_width - 1) / r.verify_width.max(1)).max(1)
                };
                r.draft_step_secs * report.draft_dispatches as f64
                    + r.target_step_secs * units as f64
                    + r.target_pos_secs * billed_total as f64
                    + r.cache_fetch_secs * fetched_total as f64
                    + r.cache_write_secs * written_total as f64
                    + construct_secs
                    + verify_secs
            })
            .unwrap_or(0.0);
        report.virtual_secs = virt;

        // --- phase B: stream chunks + advance state machines ---
        let mut finished: Vec<usize> = Vec::new();
        for (i, (tokens, accepted, bill)) in
            outcomes.into_iter().enumerate()
        {
            let seq = &mut self.seqs[i];
            seq.cache_hits += bill.cached_positions as u64;
            seq.virtual_secs += virt;
            let stats = RoundStats {
                round: 0, // set by on_step to the sequence's step count
                tree_size: alloc_by_seq[i],
                accepted,
                billed_positions: bill.billed_positions,
                cached_positions: bill.cached_positions,
                virtual_secs: virt,
            };
            let before = seq.emitted.len();
            let done = seq.on_step(tokens, alloc_by_seq[i], stats);
            report.emitted.push(seq.emitted.len() - before);
            metrics.on_chunk();
            if seq.steps == 1 {
                if let Some(t) = seq.ttft_secs {
                    metrics.on_first_token(t);
                }
            }
            if done {
                finished.push(i);
            }
        }

        let emitted_total: usize = report.emitted.iter().sum();
        metrics.on_dispatches(1, n as u64, used as u64, budget as u64, virt);
        metrics.tokens_in_flight_add(emitted_total as u64);
        metrics.on_cache(
            cached_total as u64,
            billed_total as u64,
            self.cache.used_blocks() as u64,
        );

        // Retire finished sequences (largest index first keeps the
        // remaining swap_remove indices valid).
        for &i in finished.iter().rev() {
            let seq = self.seqs.swap_remove(i);
            self.retire(seq, false);
            report.completed += 1;
        }
        report
    }

    /// Serve the shared queue until shutdown is requested AND every
    /// in-flight sequence has drained.
    pub fn run(
        &mut self,
        rx: &Mutex<mpsc::Receiver<Request>>,
        shutdown: &AtomicBool,
    ) {
        let idle = Duration::from_millis(self.cfg.sched.idle_tick_ms.max(1));
        log_debug!(
            "worker {} batcher up (policy={}, max_active={})",
            self.wid,
            self.cfg.engine.policy,
            self.cfg.sched.max_active
        );
        loop {
            // Admit up to capacity without blocking the active set.
            let mut disconnected = false;
            while self.capacity_left() > 0 {
                let pulled = {
                    let guard = rx.lock().expect("queue receiver poisoned");
                    guard.try_recv()
                };
                match pulled {
                    Ok(req) => self.admit(req),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }
            if self.seqs.is_empty() {
                if disconnected {
                    break;
                }
                // Idle: block for one request or a shutdown-poll tick.
                let pulled = {
                    let guard = rx.lock().expect("queue receiver poisoned");
                    guard.recv_timeout(idle)
                };
                match pulled {
                    Ok(req) => self.admit(req),
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if shutdown.load(Ordering::SeqCst) {
                            break;
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
                continue;
            }
            // In-flight sequences always progress — shutdown drains,
            // never drops (cancellation is the explicit early exit).
            self.step();
        }
        log_debug!("worker {} batcher down", self.wid);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queue::{CancelToken, GenParams, RequestHandle};
    use crate::models::sim::{SimModel, SimSpec};
    use std::time::Instant;

    fn mk_batcher(max_active: usize, budget: usize) -> Batcher {
        let mut cfg = Config::new();
        cfg.engine.tree_budget = 8;
        cfg.engine.target_temp = 0.6;
        cfg.sched.max_active = max_active;
        cfg.sched.global_budget = budget;
        let (d, t) = SimModel::pair(SimSpec::new(64, 2.0, 0.8, 11));
        Batcher::new(
            0,
            cfg,
            Box::new(d),
            Box::new(t),
            Arc::new(Metrics::new()),
        )
    }

    fn mk_request_with(
        id: u64,
        params: GenParams,
    ) -> (Request, RequestHandle) {
        let (tx, rx) = mpsc::channel();
        let cancel = CancelToken::new();
        (
            Request {
                id,
                prompt: vec![id as u32 + 1, 2, 3],
                params,
                submitted_at: Instant::now(),
                cancel: cancel.clone(),
                events: Box::new(tx),
            },
            RequestHandle {
                id,
                events: rx,
                cancel,
            },
        )
    }

    fn mk_request(id: u64, max_new: usize) -> (Request, RequestHandle) {
        mk_request_with(id, GenParams::simple(max_new, 0.6))
    }

    #[test]
    fn steps_multiple_sequences_to_completion() {
        let mut b = mk_batcher(8, 16);
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let (req, h) = mk_request(i + 1, 12);
                b.admit(req);
                h
            })
            .collect();
        assert_eq!(b.active(), 4);
        let mut guard = 0;
        while b.active() > 0 {
            let report = b.step();
            assert_eq!(report.emitted.len(), report.active);
            // every sequence in the dispatch makes progress
            assert!(report.emitted.iter().all(|&e| e >= 1));
            guard += 1;
            assert!(guard <= 4 * 12, "batcher failed to converge");
        }
        for h in handles {
            let resp = h.wait().unwrap();
            assert_eq!(resp.tokens.len(), 12);
            assert!(resp.steps >= 1);
            assert!(resp.ttft_secs >= 0.0);
        }
    }

    #[test]
    fn empty_step_is_noop() {
        let mut b = mk_batcher(4, 8);
        let report = b.step();
        assert_eq!(report.active, 0);
        assert_eq!(report.completed, 0);
    }

    #[test]
    fn drain_state_takes_no_budget() {
        let mut b = mk_batcher(4, 16);
        let (req, h) = mk_request(1, 1); // one token: Drain from the start
        b.admit(req);
        let report = b.step();
        assert_eq!(report.global_budget, 0);
        assert_eq!(report.allocated, vec![0]);
        assert_eq!(report.emitted, vec![1]);
        assert_eq!(h.wait().unwrap().tokens.len(), 1);
        assert_eq!(b.active(), 0);
    }

    #[test]
    fn cancelled_sequence_is_retired_before_the_dispatch() {
        let mut b = mk_batcher(4, 16);
        let (req1, h1) = mk_request(1, 64);
        let (req2, h2) = mk_request(2, 8);
        b.admit(req1);
        b.admit(req2);
        b.step();
        assert!(b.cache().used_blocks() > 0);
        h1.cancel.cancel();
        let report = b.step();
        assert_eq!(report.cancelled, 1);
        assert_eq!(report.active, 1, "cancelled seq still dispatched");
        let resp = h1.wait().unwrap();
        assert_eq!(resp.finish, FinishReason::Cancelled);
        assert!(resp.tokens.len() < 64);
        while b.active() > 0 {
            b.step();
        }
        assert_eq!(h2.wait().unwrap().tokens.len(), 8);
        assert_eq!(b.cache().used_blocks(), 0, "cancel leaked blocks");
    }

    #[test]
    fn pre_cancelled_request_never_takes_a_slot() {
        let mut b = mk_batcher(4, 16);
        let (req, h) = mk_request(1, 16);
        h.cancel.cancel();
        b.admit(req);
        assert_eq!(b.active(), 0);
        let resp = h.wait().unwrap();
        assert_eq!(resp.finish, FinishReason::Cancelled);
        assert!(resp.tokens.is_empty());
    }

    #[test]
    fn per_request_token_budget_caps_allocation() {
        let mut b = mk_batcher(4, 32);
        let (req, _h) = mk_request_with(
            1,
            GenParams {
                token_budget: Some(2),
                ..GenParams::simple(24, 0.6)
            },
        );
        b.admit(req);
        while b.active() > 0 {
            let report = b.step();
            assert!(
                report.allocated.iter().all(|&a| a <= 2),
                "token_budget cap exceeded: {:?}",
                report.allocated
            );
        }
    }

    #[test]
    fn stop_token_retires_sequence_early() {
        let mut b = mk_batcher(4, 16);
        // First run uncapped to learn the stream, then stop at its 3rd token.
        let (req, h) = mk_request_with(
            1,
            GenParams {
                seed: Some(5),
                ..GenParams::simple(24, 0.6)
            },
        );
        b.admit(req);
        while b.active() > 0 {
            b.step();
        }
        let tokens = h.wait().unwrap().tokens;
        let stop = tokens[2];
        let first_hit = tokens.iter().position(|&t| t == stop).unwrap();

        let (req, h) = mk_request_with(
            2,
            GenParams {
                seed: Some(5),
                stop_tokens: vec![stop],
                ..GenParams::simple(24, 0.6)
            },
        );
        b.admit(req);
        while b.active() > 0 {
            b.step();
        }
        let resp = h.wait().unwrap();
        assert_eq!(resp.finish, FinishReason::Stop);
        assert_eq!(resp.tokens, tokens[..first_hit + 1].to_vec());
    }

    #[test]
    fn cache_residency_kicks_in_after_first_step_and_drains_clean() {
        let mut b = mk_batcher(8, 16);
        let handles: Vec<_> = (0..3)
            .map(|i| {
                let (req, h) = mk_request(i + 1, 10);
                b.admit(req);
                h
            })
            .collect();
        let first = b.step();
        assert_eq!(first.cached_positions, 0, "cold start cannot hit");
        assert!(first.billed_positions > 0);
        assert!(b.cache().used_blocks() > 0, "no residency committed");
        while b.active() > 0 {
            let rep = b.step();
            assert!(
                rep.cached_positions > 0,
                "warm step served nothing from cache"
            );
        }
        for h in handles {
            let resp = h.wait().unwrap();
            assert_eq!(resp.tokens.len(), 10);
            assert!(
                resp.cache_hits > 0,
                "multi-step request reported no cache hits"
            );
        }
        assert_eq!(
            b.cache().used_blocks(),
            0,
            "retired sequences leaked blocks"
        );
    }

    #[test]
    fn cache_off_bills_everything() {
        let mut cfg = Config::new();
        cfg.engine.tree_budget = 8;
        cfg.sched.max_active = 4;
        cfg.sched.global_budget = 8;
        cfg.cache.enabled = false;
        let (d, t) = SimModel::pair(SimSpec::new(64, 2.0, 0.8, 11));
        let mut b = Batcher::new(
            0,
            cfg,
            Box::new(d),
            Box::new(t),
            Arc::new(Metrics::new()),
        );
        let (req, _h) = mk_request(1, 6);
        b.admit(req);
        while b.active() > 0 {
            let rep = b.step();
            assert_eq!(rep.cached_positions, 0);
            assert_eq!(b.cache().used_blocks(), 0);
        }
    }

    #[test]
    fn metrics_see_batched_dispatches_and_chunks() {
        let mut b = mk_batcher(8, 12);
        let _handles: Vec<_> = (0..3)
            .map(|i| {
                let (req, h) = mk_request(i + 1, 6);
                b.admit(req);
                h
            })
            .collect();
        b.step();
        let m = b.metrics.clone();
        assert_eq!(m.dispatches(), 1);
        assert!(m.batch_occupancy() >= 3.0 - 1e-9);
        assert_eq!(m.chunks(), 3, "one chunk per sequence per step");
    }
}
