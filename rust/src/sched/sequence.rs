//! Per-sequence state machine for the continuous batcher.
//!
//! A request admitted into a batcher becomes a `Sequence` and moves through
//! `Prefill -> Speculate -> Drain -> Done`:
//!
//!   - **Prefill**: admitted, not yet part of any dispatch (TTFT pending).
//!   - **Speculate**: competes for shares of the global speculation budget.
//!   - **Drain**: exactly one token left — takes a bare verification row
//!     (the bonus token needs no speculated tree), so its budget share
//!     flows to sequences that can still convert budget into acceptance.
//!   - **Done**: every token emitted (or a stop token / cancellation cut
//!     the generation short); the `Done` event has been handed back.
//!
//! Every dispatch emits at least one token per participating sequence (the
//! verification bonus), so a sequence in any live state makes progress on
//! every scheduler step — the no-starvation invariant the scheduler tests
//! pin down. Each step's accepted chunk is streamed through the request's
//! event channel as the step lands (`GenEvent::Chunk`).

use std::time::Instant;

use crate::coordinator::queue::{
    CancelToken, EventSink, FinishReason, GenEvent, Request, Response,
    RoundStats,
};
use crate::util::Rng;

/// Lifecycle of one admitted sequence (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqState {
    Prefill,
    Speculate,
    Drain,
    Done,
}

/// One in-flight generation multiplexed by a batcher.
pub struct Sequence {
    pub id: u64,
    pub state: SeqState,
    /// prompt ++ emitted tokens — the context of the next dispatch.
    pub ctx: Vec<u32>,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub temperature: f32,
    /// Emitting any of these finishes the sequence (reason `stop`).
    pub stop_tokens: Vec<u32>,
    /// Per-request speculation cap (protocol-v1 `token_budget`).
    pub token_budget: Option<usize>,
    /// Per-request draft-policy override (honored when the step's
    /// speculating set is homogeneous; see `draft::round_policy`).
    pub drafter: Option<crate::config::PolicyKind>,
    pub emitted: Vec<u32>,
    /// Scheduler steps this sequence took part in.
    pub steps: usize,
    /// Speculated-tree tokens allocated to this sequence, summed over its
    /// steps — the budget-share metric.
    pub budget_tokens: u64,
    /// Prefix positions this sequence served from the KV cache, summed
    /// over its dispatches (the per-sequence half of the worker's
    /// hit-rate metric; residency itself lives in `cache::CacheManager`,
    /// keyed by `id`).
    pub cache_hits: u64,
    /// Chunked-prefill progress: prompt positions already computed by
    /// prefill chunk rounds (DESIGN.md §Chunked Prefill). Tracked
    /// independently of cache residency so the schedule is identical
    /// with the cache off (chunks are then wasted compute, but the token
    /// stream never observes them). 0 when chunking is off.
    pub prefill_pos: usize,
    /// Per-sequence sampling stream. With an explicit request `seed` the
    /// stream is derived from it alone (same seed -> same stream on any
    /// worker); otherwise it is seeded from (scheduler seed, request id)
    /// so streams never collide across co-batched sequences. NOTE: the
    /// *position* in the stream still depends on batch composition — the
    /// shared-budget allocator draws a data-dependent number of samples
    /// per step — so, unlike FCFS, continuous mode does not promise
    /// identical tokens for the same request under different concurrent
    /// load (it promises the same output *distribution*; see
    /// rust/tests/unbiasedness.rs).
    pub rng: Rng,
    pub submitted_at: Instant,
    pub admitted_at: Instant,
    pub queue_secs: f64,
    /// Submission-to-first-token seconds, set by the first step.
    pub ttft_secs: Option<f64>,
    /// Virtual regime seconds across the dispatches this sequence shared.
    pub virtual_secs: f64,
    /// Why the sequence reached `Done` (valid once it did).
    pub finish: FinishReason,
    /// Cooperative cancellation, shared with the submitter.
    pub cancel: CancelToken,
    /// Admission-minted trace id (0 = untraced); round spans carry it
    /// when the sequence is dispatched alone.
    pub trace: u64,
    events: Box<dyn EventSink>,
}

impl Sequence {
    pub fn new(req: Request, seed_salt: u64) -> Self {
        let queue_secs = req.submitted_at.elapsed().as_secs_f64();
        let rng = match req.params.seed {
            Some(s) => Rng::new(seed_salt ^ s.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            None => Rng::new(
                seed_salt ^ req.id.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            ),
        };
        Self {
            id: req.id,
            state: SeqState::Prefill,
            prompt_len: req.prompt.len(),
            ctx: req.prompt,
            max_new_tokens: req.params.max_new_tokens.max(1),
            temperature: req.params.temperature,
            stop_tokens: req.params.stop_tokens,
            token_budget: req.params.token_budget,
            drafter: req.params.drafter,
            emitted: Vec::new(),
            steps: 0,
            budget_tokens: 0,
            cache_hits: 0,
            prefill_pos: 0,
            rng,
            submitted_at: req.submitted_at,
            admitted_at: Instant::now(),
            queue_secs,
            ttft_secs: None,
            virtual_secs: 0.0,
            finish: FinishReason::Length,
            cancel: req.cancel,
            trace: req.trace,
            events: req.events,
        }
    }

    pub fn remaining(&self) -> usize {
        self.max_new_tokens - self.emitted.len()
    }

    pub fn is_done(&self) -> bool {
        self.state == SeqState::Done
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }

    /// This sequence's per-round speculation cap: the engine tree budget,
    /// further clamped by the request's own `token_budget`.
    pub fn tree_cap(&self, engine_budget: usize) -> usize {
        match self.token_budget {
            Some(cap) if cap > 0 => engine_budget.min(cap),
            _ => engine_budget,
        }
    }

    /// Eligible for speculation-budget shares this step? Draining
    /// sequences (one token left) and finished ones are not. A sequence
    /// still mid-chunked-prefill also is not — the batcher filters those
    /// with [`Sequence::mid_prefill`] before consulting this.
    pub fn wants_speculation(&self) -> bool {
        matches!(self.state, SeqState::Prefill | SeqState::Speculate)
            && self.remaining() > 1
    }

    /// Still inside chunked prefill at chunk size `chunk`? True while
    /// more than one chunk's worth of prompt remains uncomputed: the
    /// sequence then takes a prefill chunk row this step (or sits out if
    /// the per-step prefill budget is spent) instead of a speculation
    /// round. Once the tail fits in one chunk, the ordinary first
    /// speculation round computes it together with its tree — exactly
    /// the rows a one-shot prefill would have computed, so the sampled
    /// stream is bit-identical. Always false with chunking off.
    pub fn mid_prefill(&self, chunk: usize) -> bool {
        chunk > 0
            && self.state == SeqState::Prefill
            && self.ctx.len() - self.prefill_pos > chunk
    }

    /// Record one prefill chunk round: prompt positions up to `end` are
    /// now computed (and, cache on, resident). No token was sampled, no
    /// event is streamed, and `steps` counts decode rounds only.
    pub fn on_prefill_chunk(&mut self, end: usize) {
        debug_assert!(self.state == SeqState::Prefill);
        debug_assert!(
            end > self.prefill_pos && end < self.ctx.len(),
            "chunk must make progress and leave a tail for the first round"
        );
        self.prefill_pos = end;
    }

    /// Record one step's emitted tokens (overshoot truncated, stop tokens
    /// honored), stream the chunk event, charge the allocated budget
    /// share, advance the state machine. Returns true when the sequence
    /// just reached `Done`.
    pub fn on_step(
        &mut self,
        mut tokens: Vec<u32>,
        allocated: usize,
        mut stats: RoundStats,
    ) -> bool {
        debug_assert!(!self.is_done(), "stepping a finished sequence");
        self.steps += 1;
        self.budget_tokens += allocated as u64;
        // Same chunk rule as the FCFS engine, one definition
        // (`engine::events::truncate_chunk`): stop-token truncation before
        // the length cap, Stop only if the stop token survived it.
        let stopped = crate::engine::truncate_chunk(
            &mut tokens,
            &self.stop_tokens,
            self.remaining(),
        );
        if self.ttft_secs.is_none() && !tokens.is_empty() {
            self.ttft_secs = Some(self.submitted_at.elapsed().as_secs_f64());
        }
        self.ctx.extend_from_slice(&tokens);
        self.emitted.extend_from_slice(&tokens);
        stats.round = self.steps;
        // Receiver may have given up; cancellation is explicit, never
        // inferred from a closed channel.
        let _ = self.events.send(GenEvent::Chunk {
            tokens,
            stats,
        });
        self.state = if stopped {
            self.finish = FinishReason::Stop;
            SeqState::Done
        } else {
            match self.remaining() {
                0 => SeqState::Done,
                1 => SeqState::Drain,
                _ => SeqState::Speculate,
            }
        };
        self.is_done()
    }

    /// Consume the finished sequence into its response + event sink.
    /// Call exactly once, after `on_step` returned true or the batcher
    /// retired the sequence on cancellation (set `finish` first).
    pub fn into_response(
        self,
        worker: usize,
    ) -> (Box<dyn EventSink>, Response) {
        let steps = self.steps.max(1);
        let resp = Response {
            id: self.id,
            worker,
            steps: self.steps,
            emitted_per_step: self.emitted.len() as f64 / steps as f64,
            tokens: self.emitted,
            queue_secs: self.queue_secs,
            gen_secs: self.admitted_at.elapsed().as_secs_f64(),
            ttft_secs: self.ttft_secs.unwrap_or(0.0),
            virtual_secs: self.virtual_secs,
            cache_hits: self.cache_hits,
            finish: self.finish,
        };
        (self.events, resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queue::GenParams;
    use std::sync::mpsc;

    fn mk_req(
        id: u64,
        prompt: Vec<u32>,
        params: GenParams,
    ) -> (Request, mpsc::Receiver<GenEvent>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                id,
                prompt,
                params,
                submitted_at: Instant::now(),
                cancel: CancelToken::new(),
                events: Box::new(tx),
                trace: 0,
            },
            rx,
        )
    }

    fn mk_seq(max_new: usize) -> (Sequence, mpsc::Receiver<GenEvent>) {
        let (req, rx) =
            mk_req(7, vec![1, 2, 3], GenParams::simple(max_new, 0.6));
        (Sequence::new(req, 42), rx)
    }

    fn drain_chunks(rx: &mpsc::Receiver<GenEvent>) -> Vec<u32> {
        let mut out = Vec::new();
        while let Ok(ev) = rx.try_recv() {
            if let GenEvent::Chunk { tokens, .. } = ev {
                out.extend_from_slice(&tokens);
            }
        }
        out
    }

    #[test]
    fn state_machine_walk() {
        let (mut s, rx) = mk_seq(4);
        assert_eq!(s.state, SeqState::Prefill);
        assert!(s.wants_speculation());

        assert!(!s.on_step(vec![9, 8], 5, RoundStats::default())); // 2 of 4
        assert_eq!(s.state, SeqState::Speculate);
        assert!(s.ttft_secs.is_some());
        assert_eq!(s.ctx, vec![1, 2, 3, 9, 8]);

        assert!(!s.on_step(vec![7], 5, RoundStats::default())); // one left
        assert_eq!(s.state, SeqState::Drain);
        assert!(!s.wants_speculation());

        assert!(s.on_step(vec![6], 0, RoundStats::default())); // final token
        assert_eq!(s.state, SeqState::Done);
        assert_eq!(s.budget_tokens, 10);
        assert_eq!(drain_chunks(&rx), vec![9, 8, 7, 6]);

        let (tx, resp) = s.into_response(3);
        assert_eq!(resp.tokens, vec![9, 8, 7, 6]);
        assert_eq!(resp.worker, 3);
        assert_eq!(resp.steps, 3);
        assert_eq!(resp.finish, FinishReason::Length);
        assert!(resp.ttft_secs >= 0.0);
        assert!(tx.send(GenEvent::Done(Box::new(resp))));
        match rx.recv().unwrap() {
            GenEvent::Done(resp) => assert_eq!(resp.tokens.len(), 4),
            _ => panic!("expected done"),
        }
    }

    #[test]
    fn overshoot_is_truncated() {
        let (mut s, rx) = mk_seq(2);
        assert!(s.on_step(vec![4, 5, 6, 7], 8, RoundStats::default()));
        assert_eq!(s.emitted, vec![4, 5]);
        assert_eq!(s.remaining(), 0);
        assert_eq!(drain_chunks(&rx), vec![4, 5]);
    }

    #[test]
    fn stop_token_finishes_mid_chunk() {
        let (req, rx) = mk_req(
            1,
            vec![1],
            GenParams {
                stop_tokens: vec![50],
                ..GenParams::simple(16, 0.6)
            },
        );
        let mut s = Sequence::new(req, 9);
        assert!(s.on_step(vec![4, 50, 6], 3, RoundStats::default()));
        assert_eq!(s.finish, FinishReason::Stop);
        assert_eq!(s.emitted, vec![4, 50]);
        assert_eq!(drain_chunks(&rx), vec![4, 50]);
    }

    #[test]
    fn single_token_request_drains_immediately() {
        let (s, _rx) = mk_seq(1);
        // remaining() == 1 from the start: never asks for tree budget.
        assert!(!s.wants_speculation());
        assert_eq!(s.state, SeqState::Prefill);
    }

    #[test]
    fn chunked_prefill_progress_walk() {
        let (req, _rx) =
            mk_req(7, (1..=10).collect(), GenParams::simple(8, 0.6));
        let mut s = Sequence::new(req, 42);
        assert!(!s.mid_prefill(0), "chunking off is never mid-prefill");
        assert!(s.mid_prefill(4));
        s.on_prefill_chunk(4);
        assert!(s.mid_prefill(4), "6 uncomputed tokens > chunk 4");
        s.on_prefill_chunk(8);
        assert!(
            !s.mid_prefill(4),
            "2-token tail rides the first speculation round"
        );
        assert_eq!(s.state, SeqState::Prefill);
        assert!(s.wants_speculation());
        assert!(!s.on_step(vec![11], 3, RoundStats::default()));
        assert_eq!(s.state, SeqState::Speculate);
    }

    #[test]
    fn tree_cap_respects_request_budget() {
        let (req, _rx) = mk_req(
            1,
            vec![1],
            GenParams {
                token_budget: Some(4),
                ..GenParams::simple(16, 0.6)
            },
        );
        let s = Sequence::new(req, 9);
        assert_eq!(s.tree_cap(12), 4);
        assert_eq!(s.tree_cap(2), 2);
        let (s2, _rx2) = mk_seq(4);
        assert_eq!(s2.tree_cap(12), 12);
    }

    #[test]
    fn rng_streams_differ_by_request_id_but_pin_to_explicit_seed() {
        let mk = |id, seed| {
            let (req, _rx) = mk_req(
                id,
                vec![1],
                GenParams {
                    seed,
                    ..GenParams::simple(4, 0.0)
                },
            );
            Sequence::new(req, 9)
        };
        let mut a = mk(1, None);
        let mut b = mk(2, None);
        assert_ne!(a.rng.next_u64(), b.rng.next_u64());
        // Explicit seed: stream independent of the server-assigned id.
        let mut c = mk(3, Some(42));
        let mut d = mk(4, Some(42));
        assert_eq!(c.rng.next_u64(), d.rng.next_u64());
    }
}
