//! Per-sequence state machine for the continuous batcher.
//!
//! A request admitted into a batcher becomes a `Sequence` and moves through
//! `Prefill -> Speculate -> Drain -> Done`:
//!
//!   - **Prefill**: admitted, not yet part of any dispatch (TTFT pending).
//!   - **Speculate**: competes for shares of the global speculation budget.
//!   - **Drain**: exactly one token left — takes a bare verification row
//!     (the bonus token needs no speculated tree), so its budget share
//!     flows to sequences that can still convert budget into acceptance.
//!   - **Done**: every token emitted; the response has been handed back.
//!
//! Every dispatch emits at least one token per participating sequence (the
//! verification bonus), so a sequence in any live state makes progress on
//! every scheduler step — the no-starvation invariant the scheduler tests
//! pin down.

use std::sync::mpsc;
use std::time::Instant;

use crate::coordinator::queue::{Request, Response};
use crate::util::Rng;

/// Lifecycle of one admitted sequence (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqState {
    Prefill,
    Speculate,
    Drain,
    Done,
}

/// One in-flight generation multiplexed by a batcher.
pub struct Sequence {
    pub id: u64,
    pub state: SeqState,
    /// prompt ++ emitted tokens — the context of the next dispatch.
    pub ctx: Vec<u32>,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub emitted: Vec<u32>,
    /// Scheduler steps this sequence took part in.
    pub steps: usize,
    /// Speculated-tree tokens allocated to this sequence, summed over its
    /// steps — the budget-share metric.
    pub budget_tokens: u64,
    /// Prefix positions this sequence served from the KV cache, summed
    /// over its dispatches (the per-sequence half of the worker's
    /// hit-rate metric; residency itself lives in `cache::CacheManager`,
    /// keyed by `id`).
    pub cache_hits: u64,
    /// Per-sequence sampling stream, seeded from (scheduler seed, request
    /// id) so streams never collide across co-batched sequences. NOTE:
    /// the *position* in the stream still depends on batch composition —
    /// the shared-budget allocator draws a data-dependent number of
    /// samples per step — so, unlike FCFS, continuous mode does not
    /// promise identical tokens for the same request under different
    /// concurrent load (it promises the same output *distribution*; see
    /// rust/tests/unbiasedness.rs).
    pub rng: Rng,
    pub submitted_at: Instant,
    pub admitted_at: Instant,
    pub queue_secs: f64,
    /// Submission-to-first-token seconds, set by the first step.
    pub ttft_secs: Option<f64>,
    /// Virtual regime seconds across the dispatches this sequence shared.
    pub virtual_secs: f64,
    respond: mpsc::Sender<Response>,
}

impl Sequence {
    pub fn new(req: Request, seed_salt: u64) -> Self {
        let queue_secs = req.submitted_at.elapsed().as_secs_f64();
        Self {
            id: req.id,
            state: SeqState::Prefill,
            prompt_len: req.prompt.len(),
            ctx: req.prompt,
            max_new_tokens: req.max_new_tokens.max(1),
            temperature: req.temperature,
            emitted: Vec::new(),
            steps: 0,
            budget_tokens: 0,
            cache_hits: 0,
            rng: Rng::new(
                seed_salt ^ req.id.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            ),
            submitted_at: req.submitted_at,
            admitted_at: Instant::now(),
            queue_secs,
            ttft_secs: None,
            virtual_secs: 0.0,
            respond: req.respond,
        }
    }

    pub fn remaining(&self) -> usize {
        self.max_new_tokens - self.emitted.len()
    }

    pub fn is_done(&self) -> bool {
        self.state == SeqState::Done
    }

    /// Eligible for speculation-budget shares this step? Draining
    /// sequences (one token left) and finished ones are not.
    pub fn wants_speculation(&self) -> bool {
        matches!(self.state, SeqState::Prefill | SeqState::Speculate)
            && self.remaining() > 1
    }

    /// Record one step's emitted tokens (overshoot truncated), charge the
    /// allocated budget share, advance the state machine. Returns true when
    /// the sequence just reached `Done`.
    pub fn on_step(&mut self, mut tokens: Vec<u32>, allocated: usize) -> bool {
        debug_assert!(!self.is_done(), "stepping a finished sequence");
        self.steps += 1;
        self.budget_tokens += allocated as u64;
        tokens.truncate(self.remaining());
        if self.ttft_secs.is_none() && !tokens.is_empty() {
            self.ttft_secs = Some(self.submitted_at.elapsed().as_secs_f64());
        }
        self.ctx.extend_from_slice(&tokens);
        self.emitted.extend_from_slice(&tokens);
        self.state = match self.remaining() {
            0 => SeqState::Done,
            1 => SeqState::Drain,
            _ => SeqState::Speculate,
        };
        self.is_done()
    }

    /// Consume the finished sequence into its response. Call exactly once,
    /// after `on_step` returned true.
    pub fn into_response(self, worker: usize) -> (mpsc::Sender<Response>, Response) {
        debug_assert!(self.state == SeqState::Done);
        let steps = self.steps.max(1);
        let resp = Response {
            id: self.id,
            worker,
            steps: self.steps,
            emitted_per_step: self.emitted.len() as f64 / steps as f64,
            tokens: self.emitted,
            queue_secs: self.queue_secs,
            gen_secs: self.admitted_at.elapsed().as_secs_f64(),
            ttft_secs: self.ttft_secs.unwrap_or(0.0),
            virtual_secs: self.virtual_secs,
            cache_hits: self.cache_hits,
        };
        (self.respond, resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_seq(max_new: usize) -> (Sequence, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: 7,
            prompt: vec![1, 2, 3],
            max_new_tokens: max_new,
            temperature: 0.6,
            submitted_at: Instant::now(),
            respond: tx,
        };
        (Sequence::new(req, 42), rx)
    }

    #[test]
    fn state_machine_walk() {
        let (mut s, rx) = mk_seq(4);
        assert_eq!(s.state, SeqState::Prefill);
        assert!(s.wants_speculation());

        assert!(!s.on_step(vec![9, 8], 5)); // 2 of 4 emitted
        assert_eq!(s.state, SeqState::Speculate);
        assert!(s.ttft_secs.is_some());
        assert_eq!(s.ctx, vec![1, 2, 3, 9, 8]);

        assert!(!s.on_step(vec![7], 5)); // 3 of 4 -> one left
        assert_eq!(s.state, SeqState::Drain);
        assert!(!s.wants_speculation());

        assert!(s.on_step(vec![6], 0)); // final token
        assert_eq!(s.state, SeqState::Done);
        assert_eq!(s.budget_tokens, 10);

        let (tx, resp) = s.into_response(3);
        assert_eq!(resp.tokens, vec![9, 8, 7, 6]);
        assert_eq!(resp.worker, 3);
        assert_eq!(resp.steps, 3);
        assert!(resp.ttft_secs >= 0.0);
        tx.send(resp).unwrap();
        assert_eq!(rx.recv().unwrap().tokens.len(), 4);
    }

    #[test]
    fn overshoot_is_truncated() {
        let (mut s, _rx) = mk_seq(2);
        assert!(s.on_step(vec![4, 5, 6, 7], 8));
        assert_eq!(s.emitted, vec![4, 5]);
        assert_eq!(s.remaining(), 0);
    }

    #[test]
    fn single_token_request_drains_immediately() {
        let (s, _rx) = mk_seq(1);
        // remaining() == 1 from the start: never asks for tree budget.
        assert!(!s.wants_speculation());
        assert_eq!(s.state, SeqState::Prefill);
    }

    #[test]
    fn rng_streams_differ_by_request_id() {
        let (tx, _rx) = mpsc::channel();
        let (tx2, _rx2) = mpsc::channel();
        let mk = |id, tx| Request {
            id,
            prompt: vec![1],
            max_new_tokens: 4,
            temperature: 0.0,
            submitted_at: Instant::now(),
            respond: tx,
        };
        let mut a = Sequence::new(mk(1, tx), 9);
        let mut b = Sequence::new(mk(2, tx2), 9);
        assert_ne!(a.rng.next_u64(), b.rng.next_u64());
    }
}
