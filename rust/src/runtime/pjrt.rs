//! Real PJRT execution via the `xla` (xla-rs) crate. Compiled only with
//! `--features pjrt`; add the `xla` dependency to Cargo.toml when enabling
//! (kept out of the manifest so the default build resolves offline).

use std::collections::HashMap;
use std::rc::Rc;

use super::artifacts::{Artifacts, GraphKey};
use crate::ensure;
use crate::util::error::{Context, Result};

/// Shared PJRT CPU client + compiled-executable cache.
///
/// NOT `Send`: PJRT handles are raw pointers. Each serving worker thread
/// builds its own runtime (the client is cheap; compilation is the cost and
/// happens once per worker at startup).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    compiled: HashMap<GraphKey, Rc<CompiledModel>>,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            compiled: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) a model graph and pre-upload its weights.
    pub fn load(&mut self, arts: &Artifacts, key: GraphKey) -> Result<Rc<CompiledModel>> {
        if !self.compiled.contains_key(&key) {
            let model = CompiledModel::compile(&self.client, arts, key)?;
            self.compiled.insert(key, Rc::new(model));
        }
        Ok(self.compiled[&key].clone())
    }
}

/// One compiled forward graph with resident weight buffers.
///
/// Signature (fixed by python/compile/model.py::make_forward_fn):
///   (*weights, tokens i32[S], positions i32[S], mask f32[S,S])
///     -> (logits f32[S, V],)
pub struct CompiledModel {
    exe: xla::PjRtLoadedExecutable,
    /// Weight buffers, uploaded once at load time (never per call).
    param_bufs: Vec<xla::PjRtBuffer>,
    pub seq_len: usize,
    pub vocab: usize,
}

impl CompiledModel {
    fn compile(client: &xla::PjRtClient, arts: &Artifacts, key: GraphKey) -> Result<Self> {
        let path = arts.graph_path(key)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;

        // Upload weights once.
        let table = arts.param_table(key.role)?;
        let flat = arts.load_params(key.role)?;
        let mut param_bufs = Vec::with_capacity(table.len());
        for entry in &table {
            let data = &flat[entry.offset..entry.offset + entry.size];
            let buf = client
                .buffer_from_host_buffer::<f32>(data, &entry.shape, None)
                .with_context(|| format!("uploading weight {}", entry.name))?;
            param_bufs.push(buf);
        }
        Ok(Self {
            exe,
            param_bufs,
            seq_len: key.seq_len,
            vocab: arts.vocab_size(),
        })
    }

    /// Run the forward pass; returns row-major [seq_len * vocab] logits.
    pub fn forward(&self, tokens: &[i32], positions: &[i32], mask: &[f32]) -> Result<Vec<f32>> {
        let s = self.seq_len;
        ensure!(tokens.len() == s, "tokens len {} != {s}", tokens.len());
        ensure!(positions.len() == s, "positions len {}", positions.len());
        ensure!(mask.len() == s * s, "mask len {}", mask.len());
        let client = self.exe.client();
        let tok = client
            .buffer_from_host_buffer::<i32>(tokens, &[s], None)
            .context("uploading tokens")?;
        let pos = client
            .buffer_from_host_buffer::<i32>(positions, &[s], None)
            .context("uploading positions")?;
        let msk = client
            .buffer_from_host_buffer::<f32>(mask, &[s, s], None)
            .context("uploading mask")?;
        let mut args: Vec<&xla::PjRtBuffer> = self.param_bufs.iter().collect();
        args.push(&tok);
        args.push(&pos);
        args.push(&msk);
        let result = self.exe.execute_b(&args).context("executing graph")?;
        let lit = result[0][0].to_literal_sync().context("fetching result")?;
        let out = lit.to_tuple1().context("unpacking tuple")?;
        let logits = out.to_vec::<f32>().context("reading logits")?;
        ensure!(
            logits.len() == s * self.vocab,
            "unexpected logits len {}",
            logits.len()
        );
        Ok(logits)
    }
}
