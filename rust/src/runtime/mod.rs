//! PJRT runtime: loads `artifacts/` (HLO text + weights + metadata) and
//! executes the AOT-compiled model graphs from the rust hot path. Python is
//! never imported here — the binary is self-contained once `make artifacts`
//! has run.
//!
//! The actual PJRT execution needs the `xla` (xla-rs) crate plus an
//! XLA/PJRT CPU plugin, which the hermetic offline build cannot fetch, so
//! it is gated behind the `pjrt` cargo feature (see Cargo.toml). Without
//! the feature, [`PjrtRuntime`]/[`CompiledModel`] are API-compatible stubs
//! whose constructors return a descriptive error — every caller (CLI
//! selfcheck, HLO backends, integration tests) already treats "runtime
//! unavailable" as a skip/error path, so nothing else changes shape.

pub mod artifacts;

pub use artifacts::{Artifacts, GraphKey};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{CompiledModel, PjrtRuntime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{CompiledModel, PjrtRuntime};
