//! Hermetic stand-in for the PJRT runtime (built when the `pjrt` feature
//! is off). Same API surface as `runtime::pjrt`; every entry point that
//! would touch an accelerator returns a descriptive error instead, so the
//! sim-backend serving stack, benches and tests build offline with zero
//! external crates.

use std::rc::Rc;

use super::artifacts::{Artifacts, GraphKey};
use crate::bail;
use crate::util::error::Result;

const UNAVAILABLE: &str = "PJRT backend not compiled in: rebuild with \
`--features pjrt` (requires the xla-rs crate and an XLA/PJRT CPU plugin; \
see runtime/mod.rs). The `sim` backend needs no artifacts or PJRT.";

/// Stub PJRT client/executable cache — construction always fails.
pub struct PjrtRuntime {
    _private: (),
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        bail!("{UNAVAILABLE}")
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn load(
        &mut self,
        _arts: &Artifacts,
        _key: GraphKey,
    ) -> Result<Rc<CompiledModel>> {
        bail!("{UNAVAILABLE}")
    }
}

/// Stub compiled graph; `forward` always fails.
pub struct CompiledModel {
    pub seq_len: usize,
    pub vocab: usize,
}

impl CompiledModel {
    pub fn forward(
        &self,
        _tokens: &[i32],
        _positions: &[i32],
        _mask: &[f32],
    ) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = PjrtRuntime::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("pjrt"));
    }
}
