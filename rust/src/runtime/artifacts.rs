//! Artifact registry: parses `artifacts/meta.json` (written by
//! `python/compile/aot.py`), exposes graph/weight paths, loads weight blobs,
//! and verifies the build is complete before the runtime touches PJRT.

use std::path::{Path, PathBuf};

use crate::bail;
use crate::util::error::{Context, Error, Result};
use crate::util::json::{parse, Json};

/// Which compiled graph to load.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GraphKey {
    pub role: Role,
    pub seq_len: usize,
    pub pallas: bool,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Role {
    Target,
    Draft,
}

impl Role {
    pub fn name(&self) -> &'static str {
        match self {
            Role::Target => "target",
            Role::Draft => "draft",
        }
    }
}

/// One weight-table entry (mirrors meta.json "params").
#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Loaded artifact metadata.
pub struct Artifacts {
    dir: PathBuf,
    meta: Json,
}

impl Artifacts {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                meta_path.display()
            )
        })?;
        let meta = parse(&text)
            .map_err(|e| Error::msg(format!("parsing meta.json: {e}")))?;
        Ok(Self { dir, meta })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn vocab_size(&self) -> usize {
        self.meta
            .get("vocab_size")
            .and_then(Json::as_usize)
            .unwrap_or(512)
    }

    pub fn max_positions(&self) -> usize {
        self.meta
            .get("max_positions")
            .and_then(Json::as_usize)
            .unwrap_or(1024)
    }

    pub fn seq_small(&self) -> usize {
        self.meta
            .get("seq_small")
            .and_then(Json::as_usize)
            .unwrap_or(320)
    }

    pub fn seq_large(&self) -> usize {
        self.meta
            .get("seq_large")
            .and_then(Json::as_usize)
            .unwrap_or(1024)
    }

    /// Resolve a graph file, verifying it is in the meta index.
    pub fn graph_path(&self, key: GraphKey) -> Result<PathBuf> {
        let want_impl = if key.pallas { "pallas" } else { "ref" };
        let graphs = self
            .meta
            .get("graphs")
            .and_then(Json::as_arr)
            .context("meta.json missing graphs")?;
        for g in graphs {
            let role = g.get("role").and_then(Json::as_str).unwrap_or("");
            let seq = g.get("seq_len").and_then(Json::as_usize).unwrap_or(0);
            let impl_ = g.get("attn_impl").and_then(Json::as_str).unwrap_or("");
            if role == key.role.name() && seq == key.seq_len && impl_ == want_impl {
                let file = g
                    .get("file")
                    .and_then(Json::as_str)
                    .context("graph entry missing file")?;
                let path = self.dir.join(file);
                if !path.exists() {
                    bail!("graph file missing: {}", path.display());
                }
                return Ok(path);
            }
        }
        bail!(
            "no graph for role={} seq={} impl={want_impl} in meta.json",
            key.role.name(),
            key.seq_len
        )
    }

    /// Weight table for a role, in feed order.
    pub fn param_table(&self, role: Role) -> Result<Vec<ParamEntry>> {
        let list = self
            .meta
            .at(&["models", role.name(), "params"])
            .and_then(Json::as_arr)
            .context("meta.json missing param table")?;
        list.iter()
            .map(|e| {
                Ok(ParamEntry {
                    name: e
                        .get("name")
                        .and_then(Json::as_str)
                        .context("param name")?
                        .to_string(),
                    shape: e
                        .get("shape")
                        .and_then(Json::as_arr)
                        .context("param shape")?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    offset: e.get("offset").and_then(Json::as_usize).context("offset")?,
                    size: e.get("size").and_then(Json::as_usize).context("size")?,
                })
            })
            .collect()
    }

    /// Load and validate a role's weight blob (f32 little-endian).
    pub fn load_params(&self, role: Role) -> Result<Vec<f32>> {
        let path = self.dir.join(format!("{}_params.bin", role.name()));
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let expect = self
            .meta
            .at(&["models", role.name(), "total_f32"])
            .and_then(Json::as_usize)
            .context("total_f32")?;
        if bytes.len() != expect * 4 {
            bail!(
                "{}: expected {} f32 ({} bytes), found {} bytes",
                path.display(),
                expect,
                expect * 4,
                bytes.len()
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    /// Golden logits (artifacts/golden.json) for the wiring smoke test.
    pub fn golden(&self) -> Result<Json> {
        let path = self.dir.join("golden.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        parse(&text).map_err(|e| Error::msg(format!("parsing golden.json: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_or_skip() -> Option<Artifacts> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Artifacts::load(dir).ok()
    }

    #[test]
    fn meta_parses_when_built() {
        let Some(arts) = artifacts_or_skip() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert_eq!(arts.vocab_size(), 512);
        assert!(arts.seq_small() >= 64);
        assert!(arts.seq_large() > arts.seq_small());
        let table = arts.param_table(Role::Target).unwrap();
        assert_eq!(table[0].name, "tok_emb");
        // offsets contiguous
        let mut offset = 0;
        for e in &table {
            assert_eq!(e.offset, offset);
            assert_eq!(e.size, e.shape.iter().product::<usize>());
            offset += e.size;
        }
    }

    #[test]
    fn graph_paths_resolve_when_built() {
        let Some(arts) = artifacts_or_skip() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        for role in [Role::Target, Role::Draft] {
            let key = GraphKey {
                role,
                seq_len: arts.seq_small(),
                pallas: false,
            };
            assert!(arts.graph_path(key).unwrap().exists());
        }
        // pallas variant exists for target at seq_small
        assert!(arts
            .graph_path(GraphKey {
                role: Role::Target,
                seq_len: arts.seq_small(),
                pallas: true
            })
            .is_ok());
        // and not for bogus sizes
        assert!(arts
            .graph_path(GraphKey {
                role: Role::Target,
                seq_len: 12345,
                pallas: false
            })
            .is_err());
    }

    #[test]
    fn params_load_when_built() {
        let Some(arts) = artifacts_or_skip() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let flat = arts.load_params(Role::Draft).unwrap();
        assert!(!flat.is_empty());
        assert!(flat.iter().all(|x| x.is_finite()));
    }
}
