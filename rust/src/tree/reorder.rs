//! Block-sparsity-friendly token orders (paper Appendix C).
//!
//! The number of non-zero blocks in the tree attention mask depends on the
//! token permutation. Heavy-path decomposition (HPD) is near-optimal because
//! it packs long root-to-leaf paths into contiguous index ranges (a path of
//! length L contributes O(L^2 / b^2) blocks when contiguous). DySpec's trees
//! give earlier siblings larger subtrees, so plain DFS in child order closely
//! approximates HPD — the paper uses DFS; we implement all three orders and
//! benchmark them against each other (Table 5, Fig 6/7/9).

use super::arena::{NodeId, TokenTree, ROOT};

/// Insertion (construction) order — the paper's "original order" baseline.
/// For Algorithm 1 this is the heap-pop order.
pub fn insertion_order(tree: &TokenTree) -> Vec<NodeId> {
    tree.speculated().collect()
}

/// Depth-first order, children visited in sampling order. The paper's
/// reorder: "DYSPEC leverages DFS to rearrange node indices".
pub fn dfs_order(tree: &TokenTree) -> Vec<NodeId> {
    let mut out = Vec::with_capacity(tree.size());
    // Explicit stack; push children reversed so the FIRST child is popped
    // first (sampling order preserved).
    let mut stack: Vec<NodeId> = tree.node(ROOT).children.iter().rev().copied().collect();
    while let Some(id) = stack.pop() {
        out.push(id);
        for &c in tree.node(id).children.iter().rev() {
            stack.push(c);
        }
    }
    out
}

/// Heavy-path-decomposition order (Sleator & Tarjan 1981): DFS visiting
/// children in DESCENDING subtree size, so the heaviest path stays
/// contiguous. The near-optimal reference order.
pub fn hpd_order(tree: &TokenTree) -> Vec<NodeId> {
    let sizes = tree.subtree_sizes();
    let mut out = Vec::with_capacity(tree.size());
    let mut stack: Vec<NodeId> = sorted_children(tree, ROOT, &sizes);
    while let Some(id) = stack.pop() {
        out.push(id);
        stack.extend(sorted_children(tree, id, &sizes));
    }
    out
}

/// Children of `id` sorted so that, after pushing to a LIFO stack, they pop
/// in descending subtree size (heaviest first).
fn sorted_children(tree: &TokenTree, id: NodeId, sizes: &[usize]) -> Vec<NodeId> {
    let mut kids: Vec<NodeId> = tree.node(id).children.clone();
    // ascending, so the heaviest is on top of the stack
    kids.sort_by_key(|&c| sizes[c]);
    kids
}

/// Check that `order` is a permutation of the speculated nodes.
pub fn is_permutation(tree: &TokenTree, order: &[NodeId]) -> bool {
    if order.len() != tree.size() {
        return false;
    }
    let mut seen = vec![false; tree.num_nodes()];
    for &id in order {
        if id == ROOT || id >= tree.num_nodes() || seen[id] {
            return false;
        }
        seen[id] = true;
    }
    true
}

/// Check the DFS-contiguity property: every node's subtree occupies a
/// contiguous range (true for dfs/hpd orders, generally false for insertion).
pub fn subtrees_contiguous(tree: &TokenTree, order: &[NodeId]) -> bool {
    let mut pos = vec![usize::MAX; tree.num_nodes()];
    for (i, &id) in order.iter().enumerate() {
        pos[id] = i;
    }
    let sizes = tree.subtree_sizes();
    for &id in order {
        let lo = pos[id];
        let hi = lo + sizes[id];
        // all descendants must be in [lo, hi)
        for &other in order {
            if tree.is_ancestor(id, other) {
                let p = pos[other];
                if p < lo || p >= hi {
                    return false;
                }
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_tree(n: usize, seed: u64) -> TokenTree {
        let mut rng = Rng::new(seed);
        let mut t = TokenTree::new(0, vec![]);
        for i in 0..n {
            let parent = if i == 0 {
                ROOT
            } else {
                rng.next_below(t.num_nodes())
            };
            t.add_child(parent, i as u32, 0.5);
        }
        t
    }

    #[test]
    fn orders_are_permutations() {
        let t = random_tree(40, 1);
        for order in [insertion_order(&t), dfs_order(&t), hpd_order(&t)] {
            assert!(is_permutation(&t, &order));
        }
    }

    #[test]
    fn dfs_and_hpd_are_subtree_contiguous() {
        for seed in 0..5 {
            let t = random_tree(30, seed);
            assert!(subtrees_contiguous(&t, &dfs_order(&t)));
            assert!(subtrees_contiguous(&t, &hpd_order(&t)));
        }
    }

    #[test]
    fn dfs_respects_child_sampling_order() {
        let mut t = TokenTree::new(0, vec![]);
        let a = t.add_child(ROOT, 1, 0.9);
        let b = t.add_child(ROOT, 2, 0.5);
        let a1 = t.add_child(a, 3, 0.4);
        let order = dfs_order(&t);
        assert_eq!(order, vec![a, a1, b]);
    }

    #[test]
    fn hpd_visits_heavy_child_first() {
        let mut t = TokenTree::new(0, vec![]);
        let light = t.add_child(ROOT, 1, 0.9); // subtree size 1
        let heavy = t.add_child(ROOT, 2, 0.5); // subtree size 3
        let h1 = t.add_child(heavy, 3, 0.4);
        let h2 = t.add_child(h1, 4, 0.3);
        assert_eq!(hpd_order(&t), vec![heavy, h1, h2, light]);
    }

    #[test]
    fn chain_orders_agree() {
        let mut t = TokenTree::new(0, vec![]);
        let mut p = ROOT;
        for i in 0..10 {
            p = t.add_child(p, i, 0.5);
        }
        assert_eq!(dfs_order(&t), insertion_order(&t));
        assert_eq!(hpd_order(&t), insertion_order(&t));
    }
}
