//! Multi-root ("forest") attention layout for batched verification.
//!
//! The continuous batcher packs every active sequence's speculated tree
//! into ONE target dispatch. Each sequence owns a contiguous row segment
//! (its causal prefix followed by its tree tokens); rows never attend
//! across segments, so the packed mask is block-diagonal over sequences
//! with the usual prefix-causal + tree-ancestor structure inside each
//! block. The layout is what a batched backend needs to translate
//! `models::ForestItem` groups into token/position/mask buffers.

use super::arena::{NodeId, TokenTree};
use super::mask::TreeMask;

/// Row span of one sequence inside a packed forest dispatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ForestSegment {
    /// First row of this sequence's prefix block.
    pub prefix_start: usize,
    pub prefix_len: usize,
    /// First row of this sequence's speculated-tree block.
    pub tree_start: usize,
    pub tree_len: usize,
}

impl ForestSegment {
    pub fn rows(&self) -> usize {
        self.prefix_len + self.tree_len
    }

    /// One-past-the-last row of this segment.
    pub fn end(&self) -> usize {
        self.tree_start + self.tree_len
    }
}

/// Contiguous row assignment for several (prefix, tree) groups.
#[derive(Clone, Debug)]
pub struct ForestLayout {
    pub segments: Vec<ForestSegment>,
    /// Total live rows (pad rows of a fixed-shape dispatch come after).
    pub rows: usize,
}

impl ForestLayout {
    /// Lay out `groups` = (prefix_len, tree_size) pairs back to back.
    pub fn pack(groups: &[(usize, usize)]) -> Self {
        let mut segments = Vec::with_capacity(groups.len());
        let mut at = 0usize;
        for &(prefix_len, tree_len) in groups {
            segments.push(ForestSegment {
                prefix_start: at,
                prefix_len,
                tree_start: at + prefix_len,
                tree_len,
            });
            at += prefix_len + tree_len;
        }
        Self { segments, rows: at }
    }

    /// Global row of tree-local row `i` in group `g`.
    pub fn tree_row(&self, g: usize, i: usize) -> usize {
        let seg = &self.segments[g];
        debug_assert!(i < seg.tree_len);
        seg.tree_start + i
    }

    /// Build the full [s, s] f32 mask: per-segment causal prefix, tree rows
    /// seeing their whole prefix plus tree ancestors, zero attention across
    /// segments, pad rows (>= `rows`) attending only to themselves.
    pub fn to_full_f32(&self, masks: &[&TreeMask], s: usize) -> Vec<f32> {
        assert_eq!(masks.len(), self.segments.len(), "mask/segment arity");
        assert!(self.rows <= s, "forest rows {} > seq {s}", self.rows);
        let mut out = vec![0.0f32; s * s];
        for (seg, mask) in self.segments.iter().zip(masks) {
            assert_eq!(mask.n, seg.tree_len, "tree mask size mismatch");
            for i in 0..seg.prefix_len {
                let row = (seg.prefix_start + i) * s;
                for j in 0..=i {
                    out[row + seg.prefix_start + j] = 1.0;
                }
            }
            for i in 0..seg.tree_len {
                let row = (seg.tree_start + i) * s;
                for j in 0..seg.prefix_len {
                    out[row + seg.prefix_start + j] = 1.0;
                }
                for j in 0..seg.tree_len {
                    if mask.get(i, j) {
                        out[row + seg.tree_start + j] = 1.0;
                    }
                }
            }
        }
        for i in self.rows..s {
            out[i * s + i] = 1.0;
        }
        out
    }
}

/// Convenience over (prefix_len, tree, order) triples: builds the per-tree
/// masks, packs the layout, and renders the combined [s, s] mask.
pub fn forest_mask_f32(
    items: &[(usize, &TokenTree, &[NodeId])],
    s: usize,
) -> (ForestLayout, Vec<f32>) {
    let masks: Vec<TreeMask> = items
        .iter()
        .map(|&(_, tree, order)| TreeMask::from_tree(tree, order))
        .collect();
    let groups: Vec<(usize, usize)> = items
        .iter()
        .map(|&(prefix_len, _, order)| (prefix_len, order.len()))
        .collect();
    let layout = ForestLayout::pack(&groups);
    let refs: Vec<&TreeMask> = masks.iter().collect();
    let full = layout.to_full_f32(&refs, s);
    (layout, full)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::arena::ROOT;

    fn sample_tree() -> (TokenTree, Vec<NodeId>) {
        let mut t = TokenTree::new(0, vec![]);
        let a = t.add_child(ROOT, 1, 0.9);
        let b = t.add_child(a, 2, 0.8);
        let c = t.add_child(ROOT, 3, 0.5);
        (t, vec![a, b, c])
    }

    #[test]
    fn pack_assigns_contiguous_disjoint_segments() {
        let layout = ForestLayout::pack(&[(3, 2), (4, 0), (1, 3)]);
        assert_eq!(layout.rows, 13);
        assert_eq!(layout.segments[0].prefix_start, 0);
        assert_eq!(layout.segments[0].tree_start, 3);
        assert_eq!(layout.segments[0].end(), 5);
        assert_eq!(layout.segments[1].prefix_start, 5);
        assert_eq!(layout.segments[1].end(), 9);
        assert_eq!(layout.segments[2].tree_start, 10);
        assert_eq!(layout.tree_row(2, 1), 11);
    }

    #[test]
    fn single_group_matches_tree_mask_embedding() {
        let (t, order) = sample_tree();
        let m = TreeMask::from_tree(&t, &order);
        let s = 8;
        let want = m.to_full_f32(3, s);
        let (layout, got) = forest_mask_f32(&[(3, &t, &order)], s);
        assert_eq!(layout.rows, 6);
        assert_eq!(got, want);
    }

    #[test]
    fn no_attention_across_segments() {
        let (t1, o1) = sample_tree();
        let (t2, o2) = sample_tree();
        let s = 16;
        let (layout, full) =
            forest_mask_f32(&[(2, &t1, &o1), (3, &t2, &o2)], s);
        let boundary = layout.segments[0].end();
        assert_eq!(boundary, 5);
        for i in 0..layout.rows {
            for j in 0..layout.rows {
                let same_side = (i < boundary) == (j < boundary);
                if !same_side {
                    assert_eq!(
                        full[i * s + j],
                        0.0,
                        "cross-segment attention at ({i},{j})"
                    );
                }
            }
        }
        // Second segment's tree row for node b sees its own prefix + a.
        let seg = layout.segments[1];
        let row_b = (seg.tree_start + 1) * s;
        assert_eq!(full[row_b + seg.prefix_start], 1.0); // own prefix
        assert_eq!(full[row_b + seg.tree_start], 1.0); // ancestor a
        assert_eq!(full[row_b + seg.tree_start + 1], 1.0); // self
        assert_eq!(full[row_b + seg.tree_start + 2], 0.0); // sibling c
    }

    #[test]
    fn pad_rows_self_attend() {
        let (t, order) = sample_tree();
        let s = 10;
        let (layout, full) = forest_mask_f32(&[(2, &t, &order)], s);
        for i in layout.rows..s {
            assert_eq!(full[i * s + i], 1.0);
            assert_eq!(full[i * s], 0.0);
        }
    }

    #[test]
    fn empty_tree_group_is_prefix_only() {
        let t = TokenTree::new(7, vec![]);
        let order: Vec<NodeId> = Vec::new();
        let (layout, full) = forest_mask_f32(&[(3, &t, &order)], 4);
        assert_eq!(layout.rows, 3);
        assert_eq!(layout.segments[0].tree_len, 0);
        // plain causal block
        assert_eq!(full[2 * 4], 1.0);
        assert_eq!(full[2 * 4 + 3], 0.0);
    }
}
