//! Tree attention masks (paper §2 "Tree Attention"): mask[i][j] = 1 iff
//! query i may attend to key j — j is an ancestor of i (or i itself), plus
//! the full causal prefix. Produces both the compact tree-only mask (for
//! block-count metrics) and the full [S,S] f32 buffer the AOT model expects.

use super::arena::{NodeId, TokenTree, ROOT};

/// Boolean mask over an ordered set of tree nodes.
#[derive(Clone, Debug)]
pub struct TreeMask {
    pub n: usize,
    bits: Vec<bool>, // row-major n x n
}

impl TreeMask {
    /// Build the tree-only mask for `order` (a permutation of speculated
    /// node ids): entry (i, j) set iff order[j] is an ancestor-or-self of
    /// order[i].
    pub fn from_tree(tree: &TokenTree, order: &[NodeId]) -> Self {
        let n = order.len();
        // node id -> row index
        let max_id = order.iter().copied().max().unwrap_or(0);
        let mut row_of = vec![usize::MAX; max_id + 1];
        for (i, &id) in order.iter().enumerate() {
            row_of[id] = i;
        }
        let mut bits = vec![false; n * n];
        for (i, &id) in order.iter().enumerate() {
            bits[i * n + i] = true;
            // Walk ancestors up to (but excluding) ROOT.
            let mut cur = tree.node(id).parent;
            while let Some(p) = cur {
                if p == ROOT {
                    break;
                }
                let j = row_of[p];
                debug_assert_ne!(j, usize::MAX, "ancestor not in order");
                bits[i * n + j] = true;
                cur = tree.node(p).parent;
            }
        }
        Self { n, bits }
    }

    /// Plain causal (lower-triangular) mask — the prefix block.
    pub fn causal(n: usize) -> Self {
        let mut bits = vec![false; n * n];
        for i in 0..n {
            for j in 0..=i {
                bits[i * n + j] = true;
            }
        }
        Self { n, bits }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> bool {
        self.bits[i * self.n + j]
    }

    pub fn set(&mut self, i: usize, j: usize, v: bool) {
        self.bits[i * self.n + j] = v;
    }

    pub fn count_ones(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }

    /// Embed this tree mask into a full [s, s] f32 buffer at `prefix_len`:
    /// rows/cols [0, prefix_len) are causal prefix, rows [prefix_len,
    /// prefix_len + n) are tree tokens that see the whole prefix plus their
    /// tree ancestors. Rows beyond prefix_len + n are PAD: they attend only
    /// to themselves (keeps softmax finite; outputs unused).
    pub fn to_full_f32(&self, prefix_len: usize, s: usize) -> Vec<f32> {
        let n = self.n;
        assert!(prefix_len + n <= s, "prefix {prefix_len} + tree {n} > seq {s}");
        let mut out = vec![0.0f32; s * s];
        for i in 0..prefix_len {
            for j in 0..=i {
                out[i * s + j] = 1.0;
            }
        }
        for i in 0..n {
            let row = (prefix_len + i) * s;
            for j in 0..prefix_len {
                out[row + j] = 1.0;
            }
            for j in 0..n {
                if self.get(i, j) {
                    out[row + prefix_len + j] = 1.0;
                }
            }
        }
        for i in (prefix_len + n)..s {
            out[i * s + i] = 1.0;
        }
        out
    }
}

/// Full causal [s, s] f32 mask with pad-self rows beyond `live`.
pub fn causal_f32(live: usize, s: usize) -> Vec<f32> {
    assert!(live <= s);
    let mut out = vec![0.0f32; s * s];
    for i in 0..live {
        for j in 0..=i {
            out[i * s + j] = 1.0;
        }
    }
    for i in live..s {
        out[i * s + i] = 1.0;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::arena::ROOT;

    fn sample_tree() -> (TokenTree, Vec<NodeId>) {
        // root -> a -> b
        //      \-> c
        let mut t = TokenTree::new(0, vec![]);
        let a = t.add_child(ROOT, 1, 0.9);
        let b = t.add_child(a, 2, 0.8);
        let c = t.add_child(ROOT, 3, 0.5);
        (t, vec![a, b, c])
    }

    #[test]
    fn ancestor_bits() {
        let (t, order) = sample_tree();
        let m = TreeMask::from_tree(&t, &order);
        // rows: a=0, b=1, c=2
        assert!(m.get(0, 0) && m.get(1, 1) && m.get(2, 2)); // self
        assert!(m.get(1, 0)); // b sees a
        assert!(!m.get(0, 1)); // a does not see b
        assert!(!m.get(1, 2) && !m.get(2, 1)); // b, c unrelated
        assert!(!m.get(2, 0)); // c does not see a
    }

    #[test]
    fn permuted_order_permutes_mask() {
        let (t, order) = sample_tree();
        let m = TreeMask::from_tree(&t, &[order[2], order[0], order[1]]);
        // rows: c=0, a=1, b=2
        assert!(m.get(2, 1)); // b sees a
        assert!(!m.get(1, 0)); // a does not see c
    }

    #[test]
    fn full_mask_layout() {
        let (t, order) = sample_tree();
        let m = TreeMask::from_tree(&t, &order);
        let s = 8;
        let p = 3;
        let full = m.to_full_f32(p, s);
        // prefix causal:
        assert_eq!(full[0 * s + 0], 1.0);
        assert_eq!(full[0 * s + 1], 0.0);
        assert_eq!(full[2 * s + 0], 1.0);
        // tree row b (= row p+1) sees prefix + a + itself:
        assert_eq!(full[(p + 1) * s + 0], 1.0);
        assert_eq!(full[(p + 1) * s + p], 1.0); // a
        assert_eq!(full[(p + 1) * s + p + 1], 1.0); // self
        assert_eq!(full[(p + 1) * s + p + 2], 0.0); // not c
        // pad rows self-attend only:
        assert_eq!(full[7 * s + 7], 1.0);
        assert_eq!(full[7 * s + 0], 0.0);
    }

    #[test]
    fn causal_matches_treemask_causal() {
        let m = TreeMask::causal(4);
        assert!(m.get(3, 0) && m.get(3, 3) && !m.get(0, 3));
        assert_eq!(m.count_ones(), 10);
        let f = causal_f32(2, 4);
        assert_eq!(f[1 * 4 + 0], 1.0);
        assert_eq!(f[2 * 4 + 2], 1.0); // pad self
        assert_eq!(f[2 * 4 + 0], 0.0);
    }

    #[test]
    #[should_panic]
    fn full_mask_overflow_panics() {
        let (t, order) = sample_tree();
        let m = TreeMask::from_tree(&t, &order);
        let _ = m.to_full_f32(6, 8); // 6 + 3 > 8
    }
}
