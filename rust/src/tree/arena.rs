//! The speculated-token tree (Figure 3 of the paper), as a flat arena.
//!
//! Node 0 is always the ROOT and represents the last accepted context token:
//! it carries the draft distribution conditioned on the full prefix, from
//! which first-layer speculations are sampled. All other nodes are
//! *speculated tokens*; `tree.size()` counts only those (the paper's "tree
//! size"/guess budget counts speculated tokens, not the root).

use crate::util::math::entropy;

pub type NodeId = usize;
pub const ROOT: NodeId = 0;

/// One tree node.
#[derive(Clone, Debug)]
pub struct Node {
    /// The speculated token (undefined semantic for ROOT; stored as the last
    /// prefix token for debugging).
    pub token: u32,
    pub parent: Option<NodeId>,
    /// Children in SAMPLING order — verification walks them in this order
    /// and the order determines the sibling-rejection products (paper §4.1).
    pub children: Vec<NodeId>,
    /// Depth below root (root = 0; first speculated layer = 1).
    pub depth: usize,
    /// Estimated acceptance value `v` at the time this node was created
    /// (the heap key in Algorithm 1). 1.0 for ROOT.
    pub est: f64,
    /// Draft distribution D(· | path up to and including this node) — the
    /// distribution this node's children are sampled from, stored
    /// pre-sibling-zeroing (Algorithm 3 re-derives the residual walk).
    /// Empty until the draft model has scored this node.
    pub draft_dist: Vec<f32>,
}

/// Flat-arena token tree.
#[derive(Clone, Debug)]
pub struct TokenTree {
    nodes: Vec<Node>,
}

impl TokenTree {
    /// New tree whose root holds the draft distribution after the prefix.
    pub fn new(last_prefix_token: u32, root_dist: Vec<f32>) -> Self {
        Self {
            nodes: vec![Node {
                token: last_prefix_token,
                parent: None,
                children: Vec::new(),
                depth: 0,
                est: 1.0,
                draft_dist: root_dist,
            }],
        }
    }

    /// Number of speculated tokens (excludes ROOT).
    pub fn size(&self) -> usize {
        self.nodes.len() - 1
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id]
    }

    /// Append a speculated token under `parent`; returns its id.
    pub fn add_child(&mut self, parent: NodeId, token: u32, est: f64) -> NodeId {
        let id = self.nodes.len();
        let depth = self.nodes[parent].depth + 1;
        self.nodes.push(Node {
            token,
            parent: Some(parent),
            children: Vec::new(),
            depth,
            est,
            draft_dist: Vec::new(),
        });
        self.nodes[parent].children.push(id);
        id
    }

    /// Maximum depth over speculated nodes (0 for an empty tree).
    pub fn depth(&self) -> usize {
        self.nodes.iter().map(|n| n.depth).max().unwrap_or(0)
    }

    /// Ids of speculated nodes in insertion order (excludes ROOT).
    pub fn speculated(&self) -> impl Iterator<Item = NodeId> + '_ {
        1..self.nodes.len()
    }

    /// Path from ROOT (exclusive) down to `id` (inclusive).
    pub fn path_from_root(&self, id: NodeId) -> Vec<NodeId> {
        let mut path = Vec::new();
        let mut cur = Some(id);
        while let Some(n) = cur {
            if n == ROOT {
                break;
            }
            path.push(n);
            cur = self.nodes[n].parent;
        }
        path.reverse();
        path
    }

    /// Token sequence along the path root→id (speculated tokens only).
    pub fn path_tokens(&self, id: NodeId) -> Vec<u32> {
        self.path_from_root(id)
            .into_iter()
            .map(|n| self.nodes[n].token)
            .collect()
    }

    /// True iff `anc` is a strict ancestor of `id` (ROOT is ancestor of all).
    pub fn is_ancestor(&self, anc: NodeId, id: NodeId) -> bool {
        let mut cur = self.nodes[id].parent;
        while let Some(n) = cur {
            if n == anc {
                return true;
            }
            cur = self.nodes[n].parent;
        }
        false
    }

    /// Subtree sizes (node + descendants) for every node, O(n) since
    /// children always have larger arena indices than parents.
    pub fn subtree_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![1usize; self.nodes.len()];
        for id in (1..self.nodes.len()).rev() {
            let parent = self.nodes[id].parent.unwrap();
            sizes[parent] += sizes[id];
        }
        sizes
    }

    /// Per-layer widths (index 0 = first speculated layer).
    pub fn layer_widths(&self) -> Vec<usize> {
        let mut widths = Vec::new();
        for node in self.nodes.iter().skip(1) {
            let layer = node.depth - 1;
            if widths.len() <= layer {
                widths.resize(layer + 1, 0);
            }
            widths[layer] += 1;
        }
        widths
    }

    /// Σ over speculated nodes of their estimated acceptance value — the
    /// greedy objective of Algorithm 1 / Appendix D.
    pub fn total_estimate(&self) -> f64 {
        self.nodes.iter().skip(1).map(|n| n.est).sum()
    }

    /// Mean entropy of the stored draft distributions (diagnostics).
    pub fn mean_dist_entropy(&self) -> f32 {
        let dists: Vec<&Node> = self
            .nodes
            .iter()
            .filter(|n| !n.draft_dist.is_empty())
            .collect();
        if dists.is_empty() {
            return 0.0;
        }
        dists.iter().map(|n| entropy(&n.draft_dist)).sum::<f32>() / dists.len() as f32
    }

    /// Structural sanity — used by property tests and debug assertions.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (id, node) in self.nodes.iter().enumerate() {
            match node.parent {
                None if id != ROOT => return Err(format!("non-root {id} has no parent")),
                Some(p) if p >= id => {
                    return Err(format!("parent {p} not before child {id}"))
                }
                Some(p) if self.nodes[p].depth + 1 != node.depth => {
                    return Err(format!("depth mismatch at {id}"))
                }
                _ => {}
            }
            for &c in &node.children {
                if self.nodes[c].parent != Some(id) {
                    return Err(format!("child link broken {id}->{c}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain3() -> TokenTree {
        let mut t = TokenTree::new(9, vec![0.5, 0.5]);
        let a = t.add_child(ROOT, 1, 0.9);
        let b = t.add_child(a, 2, 0.8);
        t.add_child(b, 3, 0.7);
        t
    }

    #[test]
    fn sizes_and_depth() {
        let t = chain3();
        assert_eq!(t.size(), 3);
        assert_eq!(t.num_nodes(), 4);
        assert_eq!(t.depth(), 3);
        t.check_invariants().unwrap();
    }

    #[test]
    fn path_and_ancestry() {
        let mut t = TokenTree::new(0, vec![]);
        let a = t.add_child(ROOT, 10, 0.9);
        let b = t.add_child(a, 11, 0.5);
        let c = t.add_child(ROOT, 12, 0.4);
        assert_eq!(t.path_tokens(b), vec![10, 11]);
        assert!(t.is_ancestor(ROOT, b));
        assert!(t.is_ancestor(a, b));
        assert!(!t.is_ancestor(c, b));
        assert!(!t.is_ancestor(b, a));
    }

    #[test]
    fn subtree_sizes_and_layers() {
        let mut t = TokenTree::new(0, vec![]);
        let a = t.add_child(ROOT, 1, 0.9); // layer 1
        let _b = t.add_child(ROOT, 2, 0.5); // layer 1
        t.add_child(a, 3, 0.4); // layer 2
        let sizes = t.subtree_sizes();
        assert_eq!(sizes[ROOT], 4);
        assert_eq!(sizes[a], 2);
        assert_eq!(t.layer_widths(), vec![2, 1]);
    }

    #[test]
    fn total_estimate_sums_speculated_only() {
        let t = chain3();
        assert!((t.total_estimate() - 2.4).abs() < 1e-9);
    }

    #[test]
    fn children_keep_sampling_order() {
        let mut t = TokenTree::new(0, vec![]);
        let ids: Vec<_> = (0..4).map(|i| t.add_child(ROOT, i as u32, 0.5)).collect();
        assert_eq!(t.node(ROOT).children, ids);
    }
}
