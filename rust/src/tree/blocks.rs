//! Block-occupancy metric (paper Definition 1, Table 5, Fig 8/9): the number
//! of (b x b) tiles of the attention mask containing at least one attendable
//! position. This is what a block-sparse kernel must compute, so it is the
//! hardware-independent efficiency measure the paper itself reports; the
//! python L1 kernel computes the identical table (`block_occupancy`).

use super::mask::TreeMask;

/// Occupancy table: out[qb][kb] = true iff tile has any set bit. The mask is
/// zero-padded up to a block multiple (same convention as the kernel).
pub fn occupancy(mask: &TreeMask, block: usize) -> Vec<Vec<bool>> {
    assert!(block > 0);
    let n = mask.n;
    let nb = n.div_ceil(block);
    let mut occ = vec![vec![false; nb]; nb];
    for i in 0..n {
        for j in 0..n {
            if mask.get(i, j) {
                occ[i / block][j / block] = true;
            }
        }
    }
    occ
}

/// Number of occupied tiles.
pub fn block_count(mask: &TreeMask, block: usize) -> usize {
    occupancy(mask, block)
        .iter()
        .map(|row| row.iter().filter(|&&b| b).count())
        .sum()
}

/// Block count of a full (prefix + tree) mask where the prefix is causal and
/// every tree row attends to the entire prefix (the Fig-9 object). Computed
/// analytically for the prefix part + exactly for the tree part:
///   - prefix x prefix: lower-triangular tiles = nb*(nb+1)/2
///   - tree rows x prefix cols: all occupied
///   - prefix rows x tree cols: none
///   - tree x tree: `block_count` of the tree mask, offset by prefix%block.
/// For exactness with unaligned prefixes we just materialize the composite
/// occupancy directly.
pub fn block_count_with_prefix(mask: &TreeMask, prefix_len: usize, block: usize) -> usize {
    let n = mask.n + prefix_len;
    let nb = n.div_ceil(block);
    let mut occ = vec![false; nb * nb];
    // causal prefix
    for i in 0..prefix_len {
        let bi = i / block;
        // row i occupies tiles 0..=i/block
        for bj in 0..=(i / block) {
            occ[bi * nb + bj] = true;
        }
    }
    // tree rows see full prefix
    for i in 0..mask.n {
        let bi = (prefix_len + i) / block;
        for bj in 0..prefix_len.div_ceil(block) {
            occ[bi * nb + bj] = true;
        }
        // tree-tree bits
        for j in 0..mask.n {
            if mask.get(i, j) {
                occ[bi * nb + (prefix_len + j) / block] = true;
            }
        }
    }
    occ.iter().filter(|&&b| b).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::arena::{TokenTree, ROOT};
    use crate::tree::reorder::{dfs_order, insertion_order};
    use crate::util::Rng;

    #[test]
    fn causal_block_count_is_triangle() {
        let m = TreeMask::causal(64);
        // 64/16 = 4 tiles per side; lower triangle = 4*5/2 = 10
        assert_eq!(block_count(&m, 16), 10);
    }

    #[test]
    fn diagonal_only() {
        let mut m = TreeMask::causal(32);
        // strip to diagonal
        for i in 0..32 {
            for j in 0..32 {
                m.set(i, j, i == j);
            }
        }
        assert_eq!(block_count(&m, 16), 2);
    }

    #[test]
    fn unaligned_sizes_pad() {
        let m = TreeMask::causal(20); // 20 with block 16 -> 2x2 tiles, lower tri = 3
        assert_eq!(block_count(&m, 16), 3);
    }

    #[test]
    fn dfs_never_worse_than_insertion_on_random_trees() {
        // The paper's core Appendix-C claim, checked on BFS-ish random trees
        // where insertion order interleaves branches.
        let mut rng = Rng::new(7);
        let mut wins = 0;
        for seed in 0..20 {
            let mut t = TokenTree::new(0, vec![]);
            let mut rng2 = Rng::new(seed);
            for i in 0..64 {
                let parent = if i == 0 { ROOT } else { rng2.next_below(t.num_nodes()) };
                t.add_child(parent, rng.next_below(512) as u32, 0.5);
            }
            let ins = block_count(&TreeMask::from_tree(&t, &insertion_order(&t)), 16);
            let dfs = block_count(&TreeMask::from_tree(&t, &dfs_order(&t)), 16);
            assert!(dfs <= ins, "seed {seed}: dfs {dfs} > insertion {ins}");
            if dfs < ins {
                wins += 1;
            }
        }
        assert!(wins >= 10, "reorder should strictly help usually: {wins}/20");
    }

    #[test]
    fn with_prefix_composition() {
        // empty tree: just the causal prefix triangle
        let t = TokenTree::new(0, vec![]);
        let m = TreeMask::from_tree(&t, &[]);
        assert_eq!(block_count_with_prefix(&m, 64, 16), 10);
        // one-node tree adds one row: prefix tiles (4) + self tile (1)
        let mut t2 = TokenTree::new(0, vec![]);
        let a = t2.add_child(ROOT, 1, 0.5);
        let m2 = TreeMask::from_tree(&t2, &[a]);
        assert_eq!(block_count_with_prefix(&m2, 64, 16), 10 + 4 + 1);
    }
}
