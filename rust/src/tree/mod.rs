//! Token-tree substrate: the speculated-token tree arena, tree attention
//! masks, block-sparsity-friendly reorders (paper Appendix C), and the
//! block-occupancy metric (Table 5, Fig 8/9).

pub mod arena;
pub mod blocks;
pub mod forest;
pub mod mask;
pub mod reorder;

pub use arena::{NodeId, TokenTree, ROOT};
pub use blocks::{block_count, block_count_with_prefix, occupancy};
pub use forest::{forest_mask_f32, ForestLayout, ForestSegment};
pub use mask::TreeMask;
pub use reorder::{dfs_order, hpd_order, insertion_order};
