//! Serving prompts: fixed-length windows drawn from a profile corpus,
//! following the paper's evaluation protocol (first 128 tokens as the fixed
//! prompt, 128 generated as completion; §5.1).

use super::markov::Corpus;

/// A pool of prompts for one dataset profile.
pub struct PromptSet {
    pub profile: &'static str,
    pub prompt_len: usize,
    prompts: Vec<Vec<u32>>,
}

impl PromptSet {
    /// Draw `count` prompts of `prompt_len` tokens. Each prompt comes from
    /// its own stream seed so prompts are independent draws from the
    /// profile's distribution (the paper samples 1000 pieces per dataset).
    pub fn generate(corpus: &Corpus, count: usize, prompt_len: usize, base_seed: u64) -> Self {
        let prompts = (0..count)
            .map(|i| corpus.generate(prompt_len, base_seed.wrapping_add(i as u64 + 1)))
            .collect();
        Self {
            profile: corpus.profile.name,
            prompt_len,
            prompts,
        }
    }

    pub fn by_name(name: &str, count: usize, prompt_len: usize, base_seed: u64) -> Option<Self> {
        Corpus::by_name(name).map(|c| Self::generate(&c, count, prompt_len, base_seed))
    }

    pub fn len(&self) -> usize {
        self.prompts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prompts.is_empty()
    }

    pub fn get(&self, i: usize) -> &[u32] {
        &self.prompts[i % self.prompts.len()]
    }

    pub fn iter(&self) -> impl Iterator<Item = &[u32]> {
        self.prompts.iter().map(|p| p.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_shapes() {
        let ps = PromptSet::by_name("cnn", 5, 128, 100).unwrap();
        assert_eq!(ps.len(), 5);
        assert!(ps.iter().all(|p| p.len() == 128));
    }

    #[test]
    fn prompts_are_distinct_and_deterministic() {
        let a = PromptSet::by_name("c4", 3, 32, 7).unwrap();
        let b = PromptSet::by_name("c4", 3, 32, 7).unwrap();
        for i in 0..3 {
            assert_eq!(a.get(i), b.get(i));
        }
        assert_ne!(a.get(0), a.get(1));
    }

    #[test]
    fn get_wraps_around() {
        let ps = PromptSet::by_name("owt", 2, 16, 1).unwrap();
        assert_eq!(ps.get(0), ps.get(2));
    }
}
