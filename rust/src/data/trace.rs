//! Request traces for the serving coordinator: Poisson arrivals over the
//! prompt pool, with per-request generation budgets. This is the synthetic
//! stand-in for a production request log (DESIGN.md §3) — the coordinator
//! benches replay these traces.

use crate::util::Rng;

/// One request arrival.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Arrival time offset from trace start, seconds.
    pub at_secs: f64,
    /// Index into the prompt pool.
    pub prompt_idx: usize,
    /// Tokens to generate.
    pub max_new_tokens: usize,
    /// Sampling temperature for the target.
    pub temperature: f32,
}

/// A replayable arrival trace.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    pub events: Vec<TraceEvent>,
}

impl RequestTrace {
    /// Poisson arrivals at `rate_rps` for `n_requests`, cycling over
    /// `pool_size` prompts. Deterministic in `seed`.
    pub fn poisson(
        n_requests: usize,
        rate_rps: f64,
        pool_size: usize,
        max_new_tokens: usize,
        temperature: f32,
        seed: u64,
    ) -> Self {
        assert!(rate_rps > 0.0 && pool_size > 0);
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        let events = (0..n_requests)
            .map(|i| {
                // Exponential inter-arrival via inverse CDF.
                let u = rng.next_f64().max(1e-12);
                t += -u.ln() / rate_rps;
                TraceEvent {
                    at_secs: t,
                    prompt_idx: i % pool_size,
                    max_new_tokens,
                    temperature,
                }
            })
            .collect();
        Self { events }
    }

    /// All requests at t=0 (closed-loop batch replay).
    pub fn burst(n_requests: usize, pool_size: usize, max_new_tokens: usize, temperature: f32) -> Self {
        let events = (0..n_requests)
            .map(|i| TraceEvent {
                at_secs: 0.0,
                prompt_idx: i % pool_size,
                max_new_tokens,
                temperature,
            })
            .collect();
        Self { events }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn duration_secs(&self) -> f64 {
        self.events.last().map(|e| e.at_secs).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_monotone_and_deterministic() {
        let a = RequestTrace::poisson(50, 10.0, 8, 128, 0.6, 1);
        let b = RequestTrace::poisson(50, 10.0, 8, 128, 0.6, 1);
        assert_eq!(a.events, b.events);
        assert!(a.events.windows(2).all(|w| w[0].at_secs <= w[1].at_secs));
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let tr = RequestTrace::poisson(2000, 50.0, 4, 16, 0.0, 2);
        let rate = tr.len() as f64 / tr.duration_secs();
        assert!((rate - 50.0).abs() < 5.0, "rate={rate}");
    }

    #[test]
    fn burst_all_at_zero() {
        let tr = RequestTrace::burst(5, 2, 64, 0.0);
        assert!(tr.events.iter().all(|e| e.at_secs == 0.0));
        assert_eq!(tr.events[4].prompt_idx, 0); // cycles pool
    }
}
