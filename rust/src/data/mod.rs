//! Synthetic data substrate: the three dataset-profile corpora (shared,
//! bit-identical, with the python training side), serving prompts sampled
//! from them, and request traces for the coordinator load tests.

pub mod markov;
pub mod prompts;
pub mod trace;

pub use markov::{Corpus, Profile, PROFILE_NAMES};
pub use prompts::PromptSet;
pub use trace::{RequestTrace, TraceEvent};
