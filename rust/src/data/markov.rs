//! Seeded Markov-chain corpora with dataset-like entropy profiles.
//!
//! EXACT port of `python/compile/corpus.py`. The python side trains the
//! models on these streams; this side samples serving prompts from them.
//! For the same (profile, stream seed) both languages produce byte-identical
//! token sequences — pinned by the golden tests below AND by
//! `python/tests/test_corpus.py::test_golden_token_prefix`. If you touch the
//! sampling logic, update both.

use crate::util::rng::SplitMix64;

pub const VOCAB_SIZE: usize = 512;
const NUM_SUCC: usize = 8;

/// A dataset profile = Markov-chain shape parameters. Entropy ordering:
/// cnn < c4 < owt (repetitive news < web crawl < open web).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Profile {
    pub name: &'static str,
    pub seed: u64,
    /// Probability mass concentrated on the NUM_SUCC preferred successors.
    pub sticky_mass: f64,
    /// Skew among the preferred successors (1.0 = uniform).
    pub skew: f64,
}

pub const PROFILE_NAMES: [&str; 3] = ["cnn", "c4", "owt"];

impl Profile {
    pub fn by_name(name: &str) -> Option<Profile> {
        // Seeds match python: 0xC44_0001..3 (underscore = visual only).
        match name {
            "cnn" => Some(Profile {
                name: "cnn",
                seed: 0xC44_0001,
                sticky_mass: 0.92,
                skew: 2.0,
            }),
            "c4" => Some(Profile {
                name: "c4",
                seed: 0xC44_0002,
                sticky_mass: 0.80,
                skew: 1.3,
            }),
            "owt" => Some(Profile {
                name: "owt",
                seed: 0xC44_0003,
                sticky_mass: 0.62,
                skew: 1.0,
            }),
            _ => None,
        }
    }
}

/// A generated token stream plus its profile tables (reusable across draws).
pub struct Corpus {
    pub profile: Profile,
    succ: Vec<[u32; NUM_SUCC]>,
    rank_mass: [f64; NUM_SUCC],
}

impl Corpus {
    pub fn new(profile: Profile) -> Self {
        let mut rng = SplitMix64::new(profile.seed);
        let mut succ = Vec::with_capacity(VOCAB_SIZE);
        for _ in 0..VOCAB_SIZE {
            let mut row = [0u32; NUM_SUCC];
            for slot in &mut row {
                *slot = rng.next_below(VOCAB_SIZE as u64) as u32;
            }
            succ.push(row);
        }
        // rank weights: w_j ∝ skew^{-j}, scaled to sticky_mass in total.
        let mut w = [0f64; NUM_SUCC];
        let mut total = 0.0;
        for (j, slot) in w.iter_mut().enumerate() {
            *slot = profile.skew.powi(-(j as i32));
            total += *slot;
        }
        for slot in &mut w {
            *slot = *slot / total * profile.sticky_mass;
        }
        Self {
            profile,
            succ,
            rank_mass: w,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        Profile::by_name(name).map(Self::new)
    }

    /// Sample the next token. Mirrors python `corpus.next_token`.
    fn next_token(&self, state: u32, rng: &mut SplitMix64) -> u32 {
        let u = rng.next_f64();
        if u < self.profile.sticky_mass {
            let mut acc = 0.0;
            for j in 0..NUM_SUCC {
                acc += self.rank_mass[j];
                if u < acc {
                    return self.succ[state as usize][j];
                }
            }
            return self.succ[state as usize][NUM_SUCC - 1];
        }
        rng.next_below(VOCAB_SIZE as u64) as u32
    }

    /// Generate `n` tokens for a stream seed. Identical to python
    /// `corpus.generate(profile, n, stream_seed)`.
    pub fn generate(&self, n: usize, stream_seed: u64) -> Vec<u32> {
        let seed = self.profile.seed ^ stream_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = SplitMix64::new(seed);
        let mut state = rng.next_below(VOCAB_SIZE as u64) as u32;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            state = self.next_token(state, &mut rng);
            out.push(state);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_token_prefixes_match_python() {
        // Same values as python/tests/test_corpus.py::test_golden_token_prefix.
        let cases: [(&str, [u32; 8]); 3] = [
            ("cnn", [347, 288, 427, 355, 419, 295, 425, 461]),
            ("c4", [347, 382, 0, 393, 42, 50, 163, 75]),
            ("owt", [501, 164, 89, 167, 247, 181, 509, 456]),
        ];
        for (name, want) in cases {
            let corpus = Corpus::by_name(name).unwrap();
            let got = corpus.generate(8, 1);
            assert_eq!(got, want, "profile {name}");
        }
    }

    #[test]
    fn deterministic_per_stream_seed() {
        let corpus = Corpus::by_name("c4").unwrap();
        assert_eq!(corpus.generate(64, 3), corpus.generate(64, 3));
        assert_ne!(corpus.generate(64, 3), corpus.generate(64, 4));
    }

    #[test]
    fn tokens_in_vocab() {
        let corpus = Corpus::by_name("owt").unwrap();
        let toks = corpus.generate(2048, 9);
        assert!(toks.iter().all(|&t| (t as usize) < VOCAB_SIZE));
    }

    fn bigram_entropy(tokens: &[u32]) -> f64 {
        use std::collections::HashMap;
        let mut counts: HashMap<u32, HashMap<u32, u64>> = HashMap::new();
        for w in tokens.windows(2) {
            *counts.entry(w[0]).or_default().entry(w[1]).or_insert(0) += 1;
        }
        let total: u64 = counts.values().map(|s| s.values().sum::<u64>()).sum();
        let mut h = 0.0;
        for succs in counts.values() {
            let n: u64 = succs.values().sum();
            let hs: f64 = succs
                .values()
                .map(|&c| {
                    let p = c as f64 / n as f64;
                    -p * p.log2()
                })
                .sum();
            h += n as f64 / total as f64 * hs;
        }
        h
    }

    #[test]
    fn entropy_ordering_cnn_lt_c4_lt_owt() {
        let h: Vec<f64> = PROFILE_NAMES
            .iter()
            .map(|name| bigram_entropy(&Corpus::by_name(name).unwrap().generate(40_000, 2)))
        .collect();
        assert!(h[0] < h[1] && h[1] < h[2], "{h:?}");
    }

    #[test]
    fn unknown_profile_is_none() {
        assert!(Corpus::by_name("wikipedia").is_none());
    }
}
