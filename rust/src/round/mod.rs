//! The unified speculation-round pipeline (DESIGN.md §Round Pipeline).
//!
//! One speculation round — draft-tree growth under a budget allocation,
//! forest/mask construction, the batched incremental verification
//! dispatch, stochastic acceptance + bonus-token sampling, KV lease
//! commit/rollback, and the `RoundStats`/virtual-latency accounting — used
//! to be implemented twice: once in `engine/mod.rs` (the FCFS path) and
//! once in `sched/batcher.rs` (the continuous path). This module is the
//! single implementation both now call, parameterized over one-or-many
//! sequences:
//!
//!   - [`plan_round`] — snapshot KV residency, allocate the shared
//!     speculation budget across the participating sequences, grow one
//!     draft tree per sequence (bare verification rows for the rest), and
//!     lay out verification orders + row maps ([`RoundPlan`]);
//!   - [`dispatch_round`] — take transient copy-on-write KV leases for the
//!     speculated branches and run ONE batched
//!     `LogitModel::score_forest` dispatch over the whole set
//!     ([`RoundDispatch`]);
//!   - [`conclude_round`] — per sequence: temperature the rows, walk the
//!     stochastic accept/reject verification, roll back rejected branches,
//!     commit the accepted path as the new resident prefix, and price the
//!     dispatch slice ([`RoundOutcome`]).
//!
//! [`run_round`] chains the three phases. The FCFS engine is a batch-of-1
//! instance (`SpecEngine::generate_streamed` builds one [`SeqRound`] per
//! round); the continuous batcher is the batch-of-n instance plus
//! admission/sweep/retire. `scheduler=fcfs|continuous` therefore selects an
//! admission policy, not an implementation — bit-identity of the two
//! callers is pinned by `rust/tests/round_equivalence.rs` on top of the
//! pre-existing `unbiasedness.rs` / `cache_equivalence.rs` /
//! `scheduler.rs` / `protocol_v1.rs` contracts.
//!
//! Policy semantics inside the pipeline:
//!
//!   - `PolicyKind::DySpec` grows the whole forest with the cross-sequence
//!     greedy heap (`sched::budget::build_forest`) — bit-identical to
//!     `DySpecPolicy::build` when one sequence participates (pinned by
//!     `scheduler.rs::single_sequence_reduces_to_dyspec_policy_tree`);
//!   - other speculative policies build per-sequence trees at a fair split
//!     of the budget (`build_forest_fair`), which for one sequence is
//!     exactly the policy's single-request tree;
//!   - `PolicyKind::Baseline` takes a bare verification row and NO draft
//!     dispatch: the bonus sample from the target row 0 IS autoregressive
//!     decoding, with the same single rng draw per round.

pub mod adapt;

use crate::cache::{verify_bill, CacheManager, TreeLease, VerifyBill};
use crate::config::{EngineConfig, LatencyRegime, PolicyKind};
use crate::draft::TreePolicy;
use crate::engine::RoundStats;
use crate::models::{ForestItem, LogitModel, TimedModel};
use crate::obs::AcceptanceRecord;
use crate::sampling::dist_from_logits;
use crate::sched::budget::{build_forest, build_forest_fair};
use crate::tree::{dfs_order, NodeId, TokenTree};
use crate::util::timer::{ComponentTimes, Timer};
use crate::util::Rng;
use crate::verify::{row_map, verify_tree};

/// Round-wide parameters, fixed for one `run_round` call.
pub struct RoundCtx<'a> {
    pub cfg: &'a EngineConfig,
    /// Tree builder matching `policy_kind` (used by the fair-split path;
    /// the DySpec heap and the Baseline bare row never consult it).
    pub policy: &'a dyn TreePolicy,
    /// Effective draft policy this round (the caller resolves per-request
    /// overrides — see `draft::round_policy`).
    pub policy_kind: PolicyKind,
    /// Shared speculated-token budget offered to the round. Zeroed by the
    /// pipeline when no sequence speculates.
    pub global_budget: usize,
    pub regime: Option<LatencyRegime>,
}

/// One sequence's view into its caller-owned state for one round.
pub struct SeqRound<'a> {
    /// KV-residency key (`cache::CacheManager` sequence id).
    pub id: u64,
    /// prompt ++ emitted tokens — the context this round verifies against.
    pub prefix: &'a [u32],
    /// The sequence's sampling stream (draft draws + verification walk).
    pub rng: &'a mut Rng,
    pub temperature: f32,
    /// Per-round speculation cap (engine tree budget, clamped further by
    /// the request's own `token_budget`).
    pub cap: usize,
    /// False = bare verification row (draining / no speculation wanted).
    pub wants_spec: bool,
    /// Prefill chunk row (DESIGN.md §Chunked Prefill): `prefix` is a
    /// PARTIAL prompt — the round computes and commits its positions into
    /// residency but samples NOTHING. The sequence's rng is untouched, no
    /// token is emitted, and the bill is exactly the chunk's non-resident
    /// positions (bare tree, zero verification rows). Implies
    /// `wants_spec == false`.
    pub prefill: bool,
}

/// Phase 1 output: residency snapshots + the allocated draft forest.
pub struct RoundPlan {
    pub trees: Vec<TokenTree>,
    pub orders: Vec<Vec<NodeId>>,
    pub row_maps: Vec<Vec<usize>>,
    /// Resident prefix positions per sequence, snapshotted before the
    /// dispatch (the bill is computed against this mark). Includes any
    /// radix warm start granted at admission.
    pub cached_lens: Vec<usize>,
    /// Radix admission outcome per sequence: `Some(w)` when this round
    /// admitted the sequence and the radix lookup matched `w` tokens
    /// (0 = cold admission), `None` when no lookup ran (already-admitted
    /// sequence, or radix off).
    pub warm_starts: Vec<Option<usize>>,
    /// Speculated tokens allocated per sequence (== trees[i].size()).
    pub allocated: Vec<usize>,
    /// Effective budget: the caller's `global_budget`, or 0 when no
    /// sequence speculated this round.
    pub global_budget: usize,
    pub draft_dispatches: u64,
    times: ComponentTimes,
}

/// Phase 2 output: the batched verification rows + live KV leases.
pub struct RoundDispatch {
    pub plan: RoundPlan,
    /// Per sequence, the target logits rows (row 0 = root).
    pub rows: Vec<Vec<Vec<f32>>>,
    leases: Vec<TreeLease>,
}

/// Per-sequence result of one concluded round.
pub struct SeqRoundOutcome {
    pub id: u64,
    /// Accepted speculated tokens + the bonus token, untruncated (the
    /// caller applies stop-token/length truncation via
    /// `engine::truncate_chunk`).
    pub tokens: Vec<u32>,
    /// Speculated tokens accepted (excludes the bonus).
    pub accepted: usize,
    /// Speculated tokens allocated to this sequence (its tree size).
    pub allocated: usize,
    pub tree_depth: usize,
    /// Radix warm-start tokens granted when this round admitted the
    /// sequence (0 for already-admitted sequences or radix off).
    pub warm_start: usize,
    /// True for a prefill chunk row: `tokens` is empty by construction
    /// and the bill covers only the chunk's computed positions.
    pub prefill: bool,
    pub bill: VerifyBill,
}

impl SeqRoundOutcome {
    /// Round statistics for this sequence's chunk. `round` is 0 — the
    /// caller stamps its own 1-based round index; `virtual_secs` is the
    /// round's shared dispatch cost.
    pub fn stats(&self, virtual_secs: f64) -> RoundStats {
        RoundStats {
            round: 0,
            tree_size: self.allocated,
            accepted: self.accepted,
            billed_positions: self.bill.billed_positions,
            cached_positions: self.bill.cached_positions,
            virtual_secs,
        }
    }
}

/// Phase 3 output: everything one round did.
pub struct RoundOutcome {
    /// Aligned with the `SeqRound` input order.
    pub seqs: Vec<SeqRoundOutcome>,
    pub global_budget: usize,
    pub draft_dispatches: u64,
    /// Always 1: the round is one (forest-)batched target dispatch.
    pub target_dispatches: u64,
    /// Totals across the dispatch (`cache::verify_bill` split).
    pub billed_positions: usize,
    pub cached_positions: usize,
    pub fetched_blocks: usize,
    pub written_blocks: usize,
    /// Σ radix warm-start tokens granted at this round's admissions.
    pub warm_start_tokens: usize,
    /// Radix admission lookups this round (fresh sequences only).
    pub radix_lookups: usize,
    /// Lookups that matched a usable shared prefix (warm start > 0).
    pub radix_hits: usize,
    /// Σ allocated — the speculated tokens the dispatch carried.
    pub spec_tokens: usize,
    /// Σ prompt positions computed by prefill chunk rows this round
    /// (their `bill.billed_positions`; zero when chunking is off).
    pub prefill_tokens: usize,
    /// Prefill chunk rows in the dispatch.
    pub prefill_rows: usize,
    /// Measured wall time per component (Fig 4 buckets: draft_infer,
    /// tree_construct, mask, target_infer, sample, verify — plus the KV
    /// commit/rollback under "commit").
    pub times: ComponentTimes,
    /// What verification said about every speculated node, bucketed by
    /// tree depth and construction-time acceptance estimate — the
    /// observability layer's per-round acceptance sample
    /// (`obs::Observatory`). Purely observational: computed from the
    /// verified tree without touching any sampling stream.
    pub accept: AcceptanceRecord,
    /// Shared virtual regime cost of the round's dispatch (None without a
    /// regime). Model inference is billed at regime rates only; the
    /// pure-logic components at measured wall time.
    pub virtual_secs: Option<f64>,
}

impl RoundOutcome {
    pub fn virtual_secs_or_zero(&self) -> f64 {
        self.virtual_secs.unwrap_or(0.0)
    }
}

/// Phase 1: snapshot residency, allocate the budget, grow the forest.
pub fn plan_round(
    rc: &RoundCtx<'_>,
    draft: &mut dyn LogitModel,
    cache: &mut CacheManager,
    seqs: &mut [SeqRound<'_>],
) -> RoundPlan {
    let n = seqs.len();
    let mut times = ComponentTimes::new();

    // Residency snapshots (also touches the LRU clock). Tree construction
    // never consults the cache, so snapshotting before the build is
    // equivalent to after it — and matches the FCFS engine's historical
    // begin-round-first ordering. For a sequence's FIRST round the
    // admission may resolve against the cross-request radix tree
    // (DESIGN.md §Radix Prefix Cache): `begin_round` then returns the
    // longest shared resident prefix, so the warm positions flow into
    // `cached_lens` and `verify_bill` prices them as cached fetches with
    // no further caller logic.
    let cached_lens: Vec<usize> = seqs
        .iter()
        .map(|v| cache.begin_round(v.id, v.prefix).min(v.prefix.len()))
        .collect();
    // Warm-start observability: Some(w) exactly when `begin_round` above
    // ran a radix admission lookup for a fresh sequence (w = matched
    // tokens, possibly 0); None for known sequences or radix off.
    let warm_starts: Vec<Option<usize>> =
        seqs.iter().map(|v| cache.take_warm_start(v.id)).collect();

    // Who speculates this round. Baseline takes the bare-row path for
    // every sequence: autoregressive decoding pays no draft dispatch.
    let spec: Vec<usize> = if rc.policy_kind == PolicyKind::Baseline {
        Vec::new()
    } else {
        (0..n)
            .filter(|&i| seqs[i].wants_spec && !seqs[i].prefill)
            .collect()
    };
    let global_budget = if spec.is_empty() { 0 } else { rc.global_budget };

    // --- draft-tree construction (Fig 4: "tree construction" + "draft") ---
    let t_build = Timer::start();
    let (spec_trees, draft_secs, draft_dispatches) = if spec.is_empty() {
        (Vec::new(), 0.0, 0)
    } else {
        let prefixes: Vec<&[u32]> =
            spec.iter().map(|&i| seqs[i].prefix).collect();
        let caps: Vec<usize> = spec.iter().map(|&i| seqs[i].cap).collect();
        // Rngs are cloned out and written back: the allocator needs them
        // mutably while the prefixes borrow the sequences.
        let mut rngs: Vec<Rng> =
            spec.iter().map(|&i| seqs[i].rng.clone()).collect();
        let mut timed = TimedModel::new(draft);
        let alloc = if rc.policy_kind == PolicyKind::DySpec {
            build_forest(
                &mut timed,
                &prefixes,
                &mut rngs,
                rc.cfg,
                global_budget,
                &caps,
            )
        } else {
            build_forest_fair(
                rc.policy,
                &mut timed,
                &prefixes,
                &mut rngs,
                rc.cfg,
                global_budget,
                &caps,
            )
        };
        let secs = timed.secs;
        let dispatches = timed.dispatches();
        for (k, &i) in spec.iter().enumerate() {
            *seqs[i].rng = rngs[k].clone();
        }
        (alloc.trees, secs, dispatches)
    };
    let build_total = t_build.elapsed_secs();
    times.add("draft_infer", draft_secs);
    times.add("tree_construct", (build_total - draft_secs).max(0.0));

    // Align trees with the full set; non-speculating sequences get a bare
    // root row (no speculation, still >= 1 emitted token).
    let mut trees: Vec<TokenTree> = Vec::with_capacity(n);
    {
        let mut built = spec_trees.into_iter();
        let mut sp = 0usize;
        for (i, v) in seqs.iter().enumerate() {
            if sp < spec.len() && spec[sp] == i {
                trees.push(built.next().expect("allocator arity"));
                sp += 1;
            } else {
                let last = *v.prefix.last().expect("empty prefix");
                trees.push(TokenTree::new(last, Vec::new()));
            }
        }
    }
    let allocated: Vec<usize> = trees.iter().map(TokenTree::size).collect();

    // --- verification order + row maps (Fig 4: "generate masks") ---
    let t_mask = Timer::start();
    let orders: Vec<Vec<NodeId>> = trees.iter().map(dfs_order).collect();
    let row_maps: Vec<Vec<usize>> = trees
        .iter()
        .zip(&orders)
        .map(|(t, o)| row_map(t, o))
        .collect();
    times.add("mask", t_mask.elapsed_secs());

    RoundPlan {
        trees,
        orders,
        row_maps,
        cached_lens,
        warm_starts,
        allocated,
        global_budget,
        draft_dispatches,
        times,
    }
}

/// Phase 2: lease the speculated branches and run the one batched target
/// verification dispatch (incremental: only non-resident prefixes + tree
/// rows are computed/billed).
pub fn dispatch_round(
    mut plan: RoundPlan,
    target: &mut dyn LogitModel,
    cache: &mut CacheManager,
    seqs: &[SeqRound<'_>],
) -> RoundDispatch {
    let leases: Vec<TreeLease> =
        plan.trees.iter().map(|t| cache.lease_tree(t)).collect();
    let t = Timer::start();
    let rows = {
        let items: Vec<ForestItem<'_>> = seqs
            .iter()
            .enumerate()
            .map(|(i, v)| ForestItem {
                prefix: v.prefix,
                cached_len: plan.cached_lens[i],
                tree: &plan.trees[i],
                order: &plan.orders[i],
            })
            .collect();
        target.score_forest(&items)
    };
    plan.times.add("target_infer", t.elapsed_secs());
    RoundDispatch { plan, rows, leases }
}

/// Phase 3: per-sequence acceptance walk, lease rollback, residency
/// commit, and the round's cost accounting.
pub fn conclude_round(
    rc: &RoundCtx<'_>,
    dispatch: RoundDispatch,
    cache: &mut CacheManager,
    seqs: &mut [SeqRound<'_>],
) -> RoundOutcome {
    let RoundDispatch {
        plan,
        rows,
        mut leases,
    } = dispatch;
    let mut times = plan.times;
    let block_tokens = cache.block_tokens();

    let mut out = Vec::with_capacity(seqs.len());
    let mut accept = AcceptanceRecord::default();
    let (mut billed, mut cached) = (0usize, 0usize);
    let (mut fetched, mut written) = (0usize, 0usize);
    let (mut sample_secs, mut verify_secs, mut commit_secs) =
        (0.0f64, 0.0f64, 0.0f64);
    let mut prefill_tokens = 0usize;
    let mut prefill_rows = 0usize;
    for (i, v) in seqs.iter_mut().enumerate() {
        let prefix_len = v.prefix.len();

        // Prefill chunk rows sample NOTHING: no dists, no verification
        // walk, no bonus draw — the sequence's rng stream is untouched, so
        // the eventual first speculation round (over the full prompt)
        // draws exactly what a one-shot prefill would have. The chunk's
        // computed positions commit into residency (and, radix on,
        // publish), and the bill is the chunk's miss region alone (bare
        // tree, zero verification rows).
        if v.prefill {
            let t = Timer::start();
            let lease = std::mem::take(&mut leases[i]);
            cache.end_lease(lease, &plan.trees[i], &[]);
            cache.commit(v.id, plan.cached_lens[i], v.prefix, &[]);
            let bill = verify_bill(
                prefix_len,
                plan.cached_lens[i],
                plan.orders[i].len(),
                block_tokens,
            );
            cache.record_lookup(
                bill.cached_positions as u64,
                (prefix_len - bill.cached_positions) as u64,
            );
            commit_secs += t.elapsed_secs();
            billed += bill.billed_positions;
            cached += bill.cached_positions;
            fetched += bill.fetched_blocks;
            written += bill.written_blocks;
            prefill_tokens += bill.billed_positions;
            prefill_rows += 1;
            out.push(SeqRoundOutcome {
                id: v.id,
                tokens: Vec::new(),
                accepted: 0,
                allocated: 0,
                tree_depth: 0,
                warm_start: plan.warm_starts[i].unwrap_or(0),
                prefill: true,
                bill,
            });
            continue;
        }

        // --- temperature + sampling dists (Fig 4: "sampling") ---
        let t = Timer::start();
        let dists: Vec<Vec<f32>> = rows[i]
            .iter()
            .map(|r| dist_from_logits(r, v.temperature))
            .collect();
        sample_secs += t.elapsed_secs();

        // --- stochastic accept/reject walk (Fig 4: "verification") ---
        let t = Timer::start();
        let walked =
            verify_tree(&plan.trees[i], &dists, &plan.row_maps[i], v.rng);
        verify_secs += t.elapsed_secs();

        // Acceptance observatory sample: every speculated node's verdict,
        // keyed by depth and the construction-time estimate (`Node::est`,
        // the paper's Fig-2 x-axis). `accepted_nodes` is a root path, so
        // the membership scan is O(depth) per node.
        for id in plan.trees[i].speculated() {
            let node = plan.trees[i].node(id);
            accept.note(
                node.depth,
                node.est,
                walked.accepted_nodes.contains(&id),
            );
        }

        // Cache round end (the "commit" stage): rejected branches roll
        // back (refcounts to zero), the accepted path + the scored miss
        // region become the new resident prefix — and, radix on, the
        // block-aligned accepted prefix is published into the shared
        // radix tree — and the dispatch slice is priced.
        let t = Timer::start();
        let lease = std::mem::take(&mut leases[i]);
        cache.end_lease(lease, &plan.trees[i], &walked.accepted_nodes);
        cache.commit(v.id, plan.cached_lens[i], v.prefix, &walked.accepted);
        let bill = verify_bill(
            prefix_len,
            plan.cached_lens[i],
            plan.orders[i].len(),
            block_tokens,
        );
        cache.record_lookup(
            bill.cached_positions as u64,
            (prefix_len - bill.cached_positions) as u64,
        );
        commit_secs += t.elapsed_secs();
        billed += bill.billed_positions;
        cached += bill.cached_positions;
        fetched += bill.fetched_blocks;
        written += bill.written_blocks;

        let accepted = walked.accepted.len();
        let mut tokens = walked.accepted;
        tokens.push(walked.bonus);
        out.push(SeqRoundOutcome {
            id: v.id,
            tokens,
            accepted,
            allocated: plan.allocated[i],
            tree_depth: plan.trees[i].depth(),
            warm_start: plan.warm_starts[i].unwrap_or(0),
            prefill: false,
            bill,
        });
    }
    times.add("sample", sample_secs);
    times.add("verify", verify_secs);
    // A separate label: virtual_secs below sums its explicit pure-logic
    // labels, so commit wall time never perturbs regime accounting.
    times.add("commit", commit_secs);

    // Virtual hardware-regime cost of the round (paper Eq. 3): draft and
    // target dispatches at the regime's step times — the shared target
    // dispatch in ceil(spec_tokens / verify_width) units, so root rows
    // ride free and a batch-of-1 bills exactly one step — computed
    // positions and cache traffic at the regime's marginal rates, and the
    // pure-logic components at measured wall time (model wall time is
    // excluded via TimedModel / the target timer; never billed).
    let spec_tokens: usize = plan.allocated.iter().sum();
    let virtual_secs = rc.regime.map(|r| {
        let units = if r.verify_width == usize::MAX || spec_tokens == 0 {
            1
        } else {
            spec_tokens.div_ceil(r.verify_width.max(1)).max(1)
        };
        r.draft_step_secs * plan.draft_dispatches as f64
            + r.target_step_secs * units as f64
            + r.target_pos_secs * billed as f64
            + r.cache_fetch_secs * fetched as f64
            + r.cache_write_secs * written as f64
            + times.get("tree_construct")
            + times.get("mask")
            + times.get("sample")
            + times.get("verify")
    });

    RoundOutcome {
        seqs: out,
        global_budget: plan.global_budget,
        draft_dispatches: plan.draft_dispatches,
        target_dispatches: 1,
        billed_positions: billed,
        cached_positions: cached,
        fetched_blocks: fetched,
        written_blocks: written,
        warm_start_tokens: plan
            .warm_starts
            .iter()
            .map(|w| w.unwrap_or(0))
            .sum(),
        radix_lookups: plan.warm_starts.iter().flatten().count(),
        radix_hits: plan
            .warm_starts
            .iter()
            .filter(|w| w.unwrap_or(0) > 0)
            .count(),
        spec_tokens,
        prefill_tokens,
        prefill_rows,
        times,
        virtual_secs,
        accept,
    }
}

/// The full round: plan → dispatch → conclude.
pub fn run_round(
    rc: &RoundCtx<'_>,
    draft: &mut dyn LogitModel,
    target: &mut dyn LogitModel,
    cache: &mut CacheManager,
    seqs: &mut [SeqRound<'_>],
) -> RoundOutcome {
    let plan = plan_round(rc, draft, cache, seqs);
    let dispatch = dispatch_round(plan, target, cache, seqs);
    conclude_round(rc, dispatch, cache, seqs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CacheConfig;
    use crate::draft::make_policy;
    use crate::models::sim::{SimModel, SimSpec};

    fn ctx_cfg(policy: PolicyKind, budget: usize) -> EngineConfig {
        EngineConfig {
            policy,
            tree_budget: budget,
            target_temp: 0.6,
            ..EngineConfig::default()
        }
    }

    fn run_one(
        policy: PolicyKind,
        budget: usize,
        wants_spec: bool,
        regime: Option<LatencyRegime>,
    ) -> RoundOutcome {
        let (mut draft, mut target) =
            SimModel::pair(SimSpec::new(64, 2.0, 0.8, 11));
        let cfg = ctx_cfg(policy, budget);
        let pol = make_policy(policy);
        let rc = RoundCtx {
            cfg: &cfg,
            policy: pol.as_ref(),
            policy_kind: policy,
            global_budget: budget,
            regime,
        };
        let mut cache = CacheManager::new(&CacheConfig::default());
        let mut rng = Rng::new(3);
        let prefix = [5u32, 6, 7];
        let mut seqs = [SeqRound {
            id: 0,
            prefix: &prefix[..],
            rng: &mut rng,
            temperature: 0.6,
            cap: budget,
            wants_spec,
            prefill: false,
        }];
        run_round(&rc, &mut draft, &mut target, &mut cache, &mut seqs)
    }

    #[test]
    fn speculative_round_emits_accepted_plus_bonus() {
        let out = run_one(PolicyKind::DySpec, 12, true, None);
        assert_eq!(out.seqs.len(), 1);
        let s = &out.seqs[0];
        assert_eq!(s.tokens.len(), s.accepted + 1);
        assert_eq!(s.allocated, 12);
        assert_eq!(out.spec_tokens, 12);
        assert_eq!(out.global_budget, 12);
        assert!(out.draft_dispatches >= 1);
        assert_eq!(out.target_dispatches, 1);
        // Cold round bills the whole prefix plus every tree row.
        assert_eq!(s.bill.billed_positions, 3 + 12);
        assert!(out.virtual_secs.is_none());
    }

    #[test]
    fn baseline_round_is_autoregressive_with_no_draft_cost() {
        let out = run_one(PolicyKind::Baseline, 12, true, None);
        let s = &out.seqs[0];
        assert_eq!(s.tokens.len(), 1, "baseline emits exactly the bonus");
        assert_eq!(s.accepted, 0);
        assert_eq!(s.allocated, 0);
        assert_eq!(out.draft_dispatches, 0, "baseline paid a draft dispatch");
        assert_eq!(out.global_budget, 0);
        assert_eq!(s.bill.billed_positions, 3);
    }

    #[test]
    fn draining_sequence_takes_a_bare_row() {
        let out = run_one(PolicyKind::DySpec, 12, false, None);
        let s = &out.seqs[0];
        assert_eq!(s.allocated, 0);
        assert_eq!(s.tokens.len(), 1);
        assert_eq!(out.draft_dispatches, 0);
        assert_eq!(out.global_budget, 0, "no speculator, no budget");
    }

    #[test]
    fn prefill_chunk_row_commits_without_sampling() {
        let (mut draft, mut target) =
            SimModel::pair(SimSpec::new(64, 2.0, 0.8, 11));
        let cfg = ctx_cfg(PolicyKind::DySpec, 12);
        let pol = make_policy(PolicyKind::DySpec);
        let rc = RoundCtx {
            cfg: &cfg,
            policy: pol.as_ref(),
            policy_kind: PolicyKind::DySpec,
            global_budget: 12,
            regime: None,
        };
        let mut cache = CacheManager::new(&CacheConfig {
            enabled: true,
            block_tokens: 4,
            ..CacheConfig::default()
        });
        let mut rng = Rng::new(3);
        let before = rng.clone();
        let prompt = [5u32, 6, 7, 8, 9, 10, 11, 12];
        let mut seqs = [SeqRound {
            id: 1,
            prefix: &prompt[..4],
            rng: &mut rng,
            temperature: 0.6,
            cap: 12,
            wants_spec: false,
            prefill: true,
        }];
        let out =
            run_round(&rc, &mut draft, &mut target, &mut cache, &mut seqs);
        let s = &out.seqs[0];
        assert!(s.prefill);
        assert!(s.tokens.is_empty(), "prefill chunk sampled a token");
        assert_eq!(s.accepted, 0);
        assert_eq!(s.allocated, 0);
        // Bare tree, zero verification rows: the bill is the chunk alone.
        assert_eq!(s.bill.billed_positions, 4);
        assert_eq!(out.prefill_tokens, 4);
        assert_eq!(out.prefill_rows, 1);
        assert_eq!(out.draft_dispatches, 0, "prefill paid a draft dispatch");
        assert!(out.accept.is_empty());
        // The sampling stream is untouched: the eventual first speculation
        // round draws exactly what a one-shot prefill would have.
        assert_eq!(rng.clone().next_u64(), before.clone().next_u64());
        // The chunk's positions are now resident; the next chunk's round
        // bills only its own fresh positions.
        assert_eq!(cache.resident(1), 4);
        let mut seqs = [SeqRound {
            id: 1,
            prefix: &prompt[..],
            rng: &mut rng,
            temperature: 0.6,
            cap: 12,
            wants_spec: false,
            prefill: true,
        }];
        let out =
            run_round(&rc, &mut draft, &mut target, &mut cache, &mut seqs);
        assert_eq!(out.seqs[0].bill.billed_positions, 4);
        assert_eq!(out.seqs[0].bill.cached_positions, 4);
        cache.drop_seq(1);
        assert_eq!(cache.used_blocks(), 0);
    }

    #[test]
    fn regime_bills_one_unit_for_batch_of_one() {
        let regime = LatencyRegime::pair_7b();
        let out = run_one(PolicyKind::DySpec, 12, true, Some(regime));
        let v = out.virtual_secs.expect("regime configured");
        assert!(v >= regime.target_step_secs);
        assert!(
            v >= regime.target_step_secs
                + regime.draft_step_secs * out.draft_dispatches as f64
                + regime.target_pos_secs * out.billed_positions as f64
        );
        // 12 speculated tokens <= verify_width 64: exactly one step unit.
        assert!(
            v < 2.0 * regime.target_step_secs,
            "batch-of-1 billed more than one dispatch unit"
        );
    }

    #[test]
    fn acceptance_record_counts_every_speculated_node() {
        let out = run_one(PolicyKind::DySpec, 12, true, None);
        let s = &out.seqs[0];
        assert_eq!(
            out.accept.proposed(),
            12,
            "every speculated node must be counted"
        );
        assert_eq!(out.accept.accepted(), s.accepted as u64);
        // Accepted nodes form a root path: at most one acceptance per
        // depth level.
        for d in 0..crate::obs::MAX_DEPTH {
            assert!(out.accept.depth_accepted[d] <= 1);
            assert!(
                out.accept.depth_accepted[d] <= out.accept.depth_proposed[d]
            );
        }
        // Baseline and bare-row rounds record nothing.
        assert!(run_one(PolicyKind::Baseline, 12, true, None)
            .accept
            .is_empty());
        assert!(run_one(PolicyKind::DySpec, 12, false, None)
            .accept
            .is_empty());
    }

    #[test]
    fn commit_stage_is_timed() {
        let out = run_one(PolicyKind::DySpec, 12, true, None);
        assert!(out.times.get("commit") >= 0.0);
        // The regime's virtual cost sums explicit labels only, so the new
        // label must not leak into regime accounting.
        let regime = LatencyRegime::pair_7b();
        let with = run_one(PolicyKind::DySpec, 12, true, Some(regime));
        let v = with.virtual_secs.expect("regime configured");
        let floor = regime.target_step_secs
            + regime.draft_step_secs * with.draft_dispatches as f64
            + regime.target_pos_secs * with.billed_positions as f64
            + with.times.get("tree_construct")
            + with.times.get("mask")
            + with.times.get("sample")
            + with.times.get("verify");
        assert!(v >= floor - 1e-12);
        assert!(
            v <= floor
                + regime.cache_fetch_secs * with.fetched_blocks as f64
                + regime.cache_write_secs * with.written_blocks as f64
                + 1e-12,
            "commit wall time leaked into virtual cost"
        );
    }

    #[test]
    fn multi_sequence_round_serves_every_sequence() {
        let (mut draft, mut target) =
            SimModel::pair(SimSpec::new(64, 2.0, 0.8, 11));
        let cfg = ctx_cfg(PolicyKind::DySpec, 8);
        let pol = make_policy(PolicyKind::DySpec);
        let rc = RoundCtx {
            cfg: &cfg,
            policy: pol.as_ref(),
            policy_kind: PolicyKind::DySpec,
            global_budget: 12,
            regime: None,
        };
        let mut cache = CacheManager::new(&CacheConfig::default());
        let mut rngs: Vec<Rng> = (0..3).map(Rng::new).collect();
        let prefixes: Vec<Vec<u32>> =
            vec![vec![1, 2], vec![3, 4, 5], vec![6]];
        let mut it = rngs.iter_mut();
        let mut seqs: Vec<SeqRound> = prefixes
            .iter()
            .enumerate()
            .map(|(i, p)| SeqRound {
                id: i as u64 + 1,
                prefix: p.as_slice(),
                rng: it.next().expect("rng arity"),
                temperature: 0.6,
                cap: 8,
                // middle sequence drains: bare row
                wants_spec: i != 1,
                prefill: false,
            })
            .collect();
        let out =
            run_round(&rc, &mut draft, &mut target, &mut cache, &mut seqs);
        assert_eq!(out.seqs.len(), 3);
        assert_eq!(out.seqs[1].allocated, 0, "draining seq got budget");
        assert!(out.seqs[0].allocated >= 1, "speculator starved");
        assert!(out.seqs[2].allocated >= 1, "speculator starved");
        assert!(out.spec_tokens <= 12, "over budget");
        for s in &out.seqs {
            assert!(!s.tokens.is_empty(), "no progress for a sequence");
        }
        assert_eq!(out.target_dispatches, 1);
        // Residency committed for every sequence; drop cleans the pool.
        assert!(cache.used_blocks() > 0);
        for i in 1..=3u64 {
            cache.drop_seq(i);
        }
        assert_eq!(cache.used_blocks(), 0);
    }
}
