//! Online-adaptive drafter + budget selection (DESIGN.md §Adaptive
//! Policy).
//!
//! DySpec's Figure 2 observation — draft probability predicts acceptance
//! — is measured online by the PR 6 acceptance observatory. This module
//! closes the loop: an [`AdaptiveController`] keeps one smoothed
//! [`AcceptanceRecord`] per *registered* drafter and, each round, (a)
//! picks the drafter by a deterministic UCB score and (b) retunes the
//! token-tree budget by the useful-probability-mass fraction of the
//! chosen drafter's observed proposals.
//!
//! Determinism is load-bearing. The exploration term is UCB-style, not
//! epsilon-greedy, precisely so no RNG draw is consumed: the token
//! stream's bit-identity depends on the per-sequence rng sequence, and
//! an adaptive controller that burned draws would perturb every stream.
//! Selection depends only on the observation history, which in a
//! deterministic simulation is itself reproducible.
//!
//! Equivalence argument (pinned by `rust/tests/adaptive_differential.rs`):
//! with exactly one registered drafter both [`AdaptiveController::pick`]
//! and [`AdaptiveController::scale`] short-circuit *before* reading the
//! estimator — `pick` returns the singleton, `scale` returns the base
//! budget unchanged — so `policy_mode=adaptive` with one drafter is
//! `policy_mode=static` by construction, not by numerical coincidence.
//! Adaptivity (selection *and* budget retune) engages only when two or
//! more drafters compete.

use crate::config::{AdaptConfig, PolicyKind, PolicyMode};
use crate::obs::AcceptanceRecord;

/// Per-worker estimator closing the observatory→planner loop.
///
/// Owned by whichever component drives `run_round` for a worker (the
/// FCFS `SpecEngine` or the continuous `Batcher`); never shared across
/// workers, so no locking — the observatory remains the cross-worker
/// aggregate while this is the per-worker working estimate.
#[derive(Debug, Clone)]
pub struct AdaptiveController {
    /// Drafters competing for selection, in registration order.
    /// Registration order is the deterministic tie-break everywhere.
    registered: Vec<PolicyKind>,
    /// Per-drafter observation totals, index-aligned with `registered`.
    seen: Vec<AcceptanceRecord>,
    /// UCB exploration weight (`adapt_explore`).
    explore: f64,
    /// Proposals below which a drafter is "cold" and explored
    /// round-robin before any exploitation (`adapt_min_samples`).
    min_samples: u64,
    /// Per-bucket smoothed acceptance threshold under which a
    /// probability bucket's proposals count as wasted (`adapt_cut`).
    cut: f64,
    /// Floor for the retuned budget (`adapt_min_budget`).
    min_budget: usize,
}

impl AdaptiveController {
    /// Build the controller from config, or `None` when
    /// `policy_mode=static` (callers then keep the static path
    /// untouched). An empty `adapt_drafters` list registers just the
    /// engine's configured drafter, which by the singleton
    /// short-circuit degenerates to static behaviour.
    pub fn new(cfg: &AdaptConfig, fallback: PolicyKind) -> Option<Self> {
        if cfg.mode == PolicyMode::Static {
            return None;
        }
        let registered = if cfg.drafters.is_empty() {
            vec![fallback]
        } else {
            cfg.drafters.clone()
        };
        let seen = vec![AcceptanceRecord::default(); registered.len()];
        Some(AdaptiveController {
            registered,
            seen,
            explore: cfg.explore,
            min_samples: cfg.min_samples,
            cut: cfg.cut,
            min_budget: cfg.min_budget.max(1),
        })
    }

    /// The registered drafter set, in registration order.
    pub fn registered(&self) -> &[PolicyKind] {
        &self.registered
    }

    /// Pick the drafter for the next round.
    ///
    /// Cold-start: any drafter with fewer than `min_samples` proposals
    /// is explored first (fewest proposals wins, registration order
    /// breaks ties), guaranteeing every drafter keeps getting sampled.
    /// Warm: argmax of the UCB score
    /// `smoothed_rate + explore * sqrt(ln(N + 1) / (n_d + 1))`
    /// where `N` is total proposals across drafters and `n_d` this
    /// drafter's — the exploration floor decays but never vanishes.
    pub fn pick(&self) -> PolicyKind {
        if self.registered.len() == 1 {
            return self.registered[0];
        }
        if let Some(cold) = self
            .seen
            .iter()
            .enumerate()
            .filter(|(_, r)| r.proposed() < self.min_samples)
            .min_by_key(|(_, r)| r.proposed())
        {
            return self.registered[cold.0];
        }
        let total: u64 = self.seen.iter().map(|r| r.proposed()).sum();
        let ln_n = ((total + 1) as f64).ln();
        let mut best = 0;
        let mut best_score = f64::NEG_INFINITY;
        for (i, rec) in self.seen.iter().enumerate() {
            let bonus =
                self.explore * (ln_n / (rec.proposed() + 1) as f64).sqrt();
            let score = rec.smoothed_rate() + bonus;
            if score > best_score {
                best_score = score;
                best = i;
            }
        }
        self.registered[best]
    }

    /// Retune a base token-tree budget from the chosen drafter's
    /// observed per-probability-bucket acceptance: shrink toward the
    /// useful fraction of proposed mass (buckets whose smoothed
    /// acceptance clears `cut`), grow back toward `base` as acceptance
    /// recovers. Never exceeds `base`, never drops below `min_budget`,
    /// and returns `base` untouched for a singleton registration.
    pub fn scale(&self, base: usize) -> usize {
        if self.registered.len() == 1 {
            return base;
        }
        let idx = self
            .registered
            .iter()
            .position(|&k| k == self.pick())
            .unwrap_or(0);
        let u = self.seen[idx].useful_fraction(self.cut);
        let scaled = (base as f64 * u).ceil() as usize;
        scaled.clamp(self.min_budget.min(base), base)
    }

    /// One-call resolution for round planning: the drafter for this
    /// round and the budget it should run under.
    pub fn resolve(&self, base: usize) -> (PolicyKind, usize) {
        (self.pick(), self.scale(base))
    }

    /// Fold a concluded round's acceptance record into the estimate for
    /// the drafter that ran it. Unregistered drafters (e.g. a per-request
    /// override outside the adaptive set) are ignored — they carry no
    /// information about the competing set.
    pub fn observe(&mut self, kind: PolicyKind, rec: &AcceptanceRecord) {
        if let Some(i) = self.registered.iter().position(|&k| k == kind) {
            self.seen[i].merge(rec);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn adaptive_cfg(drafters: &str) -> AdaptConfig {
        let mut cfg = Config::new();
        cfg.set("policy_mode", "adaptive").unwrap();
        if !drafters.is_empty() {
            cfg.set("adapt_drafters", drafters).unwrap();
        }
        cfg.adapt
    }

    fn accepted_rec(proposed: u64, accepted: u64) -> AcceptanceRecord {
        let mut rec = AcceptanceRecord::default();
        for i in 0..proposed {
            rec.note(1, 0.9, i < accepted);
        }
        rec
    }

    #[test]
    fn static_mode_builds_no_controller() {
        let cfg = AdaptConfig::default();
        assert!(AdaptiveController::new(&cfg, PolicyKind::DySpec).is_none());
    }

    #[test]
    fn empty_drafter_list_registers_the_fallback() {
        let cfg = adaptive_cfg("");
        let a = AdaptiveController::new(&cfg, PolicyKind::Chain).unwrap();
        assert_eq!(a.registered(), &[PolicyKind::Chain]);
    }

    #[test]
    fn singleton_short_circuits_before_the_estimator() {
        let cfg = adaptive_cfg("chain");
        let mut a =
            AdaptiveController::new(&cfg, PolicyKind::DySpec).unwrap();
        // Pour in an arbitrarily hostile history: selection and budget
        // must not move, because a singleton never consults the data.
        a.observe(PolicyKind::Chain, &accepted_rec(10_000, 0));
        assert_eq!(a.pick(), PolicyKind::Chain);
        for base in [1usize, 4, 64, 512] {
            assert_eq!(a.scale(base), base);
        }
        assert_eq!(a.resolve(64), (PolicyKind::Chain, 64));
    }

    #[test]
    fn cold_drafters_are_explored_in_registration_order() {
        let cfg = adaptive_cfg("dyspec,chain,specinfer");
        let mut a =
            AdaptiveController::new(&cfg, PolicyKind::DySpec).unwrap();
        // All cold with zero samples: registration order breaks the tie.
        assert_eq!(a.pick(), PolicyKind::DySpec);
        a.observe(PolicyKind::DySpec, &accepted_rec(1, 1));
        // DySpec now has 1 proposal, others 0: fewest-first.
        assert_eq!(a.pick(), PolicyKind::Chain);
        a.observe(PolicyKind::Chain, &accepted_rec(2, 2));
        assert_eq!(a.pick(), PolicyKind::SpecInfer);
    }

    #[test]
    fn warm_selection_exploits_the_best_observed_rate() {
        let mut cfg = adaptive_cfg("dyspec,chain");
        cfg.min_samples = 4;
        cfg.explore = 0.1;
        let mut a =
            AdaptiveController::new(&cfg, PolicyKind::DySpec).unwrap();
        a.observe(PolicyKind::DySpec, &accepted_rec(100, 20));
        a.observe(PolicyKind::Chain, &accepted_rec(100, 90));
        assert_eq!(a.pick(), PolicyKind::Chain);
        // ...and flips when the evidence flips.
        a.observe(PolicyKind::DySpec, &accepted_rec(4_000, 4_000));
        assert_eq!(a.pick(), PolicyKind::DySpec);
    }

    #[test]
    fn exploration_floor_revisits_a_starved_drafter() {
        let mut cfg = adaptive_cfg("dyspec,chain");
        cfg.min_samples = 1;
        cfg.explore = 2.0;
        let mut a =
            AdaptiveController::new(&cfg, PolicyKind::DySpec).unwrap();
        // Chain is slightly better but dyspec is barely sampled: a large
        // exploration weight must pull the pick back to the starved arm.
        a.observe(PolicyKind::DySpec, &accepted_rec(1, 0));
        a.observe(PolicyKind::Chain, &accepted_rec(10_000, 6_000));
        assert_eq!(a.pick(), PolicyKind::DySpec);
    }

    #[test]
    fn budget_shrinks_with_wasted_mass_and_respects_floors() {
        let mut cfg = adaptive_cfg("dyspec,chain");
        cfg.min_samples = 1;
        cfg.explore = 0.0;
        cfg.min_budget = 4;
        let mut a =
            AdaptiveController::new(&cfg, PolicyKind::DySpec).unwrap();
        // dyspec: half its proposed mass in a bucket that never lands.
        let mut rec = AcceptanceRecord::default();
        for _ in 0..50 {
            rec.note(1, 0.9, true);
        }
        for _ in 0..50 {
            rec.note(2, 1e-4, false);
        }
        a.observe(PolicyKind::DySpec, &rec);
        a.observe(PolicyKind::Chain, &accepted_rec(100, 10));
        assert_eq!(a.pick(), PolicyKind::DySpec);
        assert_eq!(a.scale(64), 32);
        // Floor: never below min_budget...
        assert_eq!(a.scale(6), 4);
        // ...unless base itself is smaller, which is never exceeded.
        assert_eq!(a.scale(2), 2);
    }

    #[test]
    fn observe_ignores_unregistered_drafters() {
        let cfg = adaptive_cfg("dyspec,chain");
        let mut a =
            AdaptiveController::new(&cfg, PolicyKind::DySpec).unwrap();
        a.observe(PolicyKind::Sequoia, &accepted_rec(500, 500));
        assert_eq!(
            a.seen.iter().map(|r| r.proposed()).sum::<u64>(),
            0,
            "foreign drafter leaked into the estimator"
        );
    }
}
