//! Sequoia baseline (Chen et al. 2024): a *fixed* tree shape optimized
//! offline from positional acceptance-rate estimates, then filled with
//! sampled tokens at run time.
//!
//! Sequoia's dynamic program maximizes the expected accepted length given
//! per-sibling-rank acceptance probabilities a(1) >= a(2) >= ... — the
//! probability the k-th candidate at a position survives verification. With
//! static weights w(node) = ∏ over the path of a(rank), the optimal
//! budget-n subtree is the top-n nodes by weight (same exchange argument as
//! DySpec's Appendix D, but over the FIXED weight table rather than
//! run-time draft probabilities — that fixedness is exactly what the paper
//! shows loses to DySpec on diverse inputs). We materialize the shape with
//! a weight-ordered heap, which is equivalent to the DP for this objective.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::TreePolicy;
use crate::config::{EngineConfig, PolicyKind};
use crate::models::LogitModel;
use crate::sampling::SiblingSampler;
use crate::tree::{TokenTree, ROOT};
use crate::util::Rng;

/// Tree-shape node used during offline shape construction.
#[derive(Clone, Debug, PartialEq)]
pub struct ShapeNode {
    pub parent: usize, // index into shape vec; usize::MAX for virtual root
    pub rank: usize,   // sibling rank (0-based)
    pub weight: f64,
}

struct ShapeCand {
    weight: f64,
    parent: usize,
    rank: usize,
    seq: u64,
}

impl PartialEq for ShapeCand {
    fn eq(&self, other: &Self) -> bool {
        self.weight == other.weight && self.seq == other.seq
    }
}
impl Eq for ShapeCand {}
impl PartialOrd for ShapeCand {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for ShapeCand {
    fn cmp(&self, other: &Self) -> Ordering {
        self.weight
            .partial_cmp(&other.weight)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Per-rank acceptance model: a(r) = alpha * beta^r (geometric decay over
/// sibling rank, Sequoia's positional-acceptance fit), capped at `max_rank`.
pub fn rank_accept(alpha: f64, beta: f64, rank: usize, max_rank: usize) -> f64 {
    if rank >= max_rank {
        0.0
    } else {
        alpha * beta.powi(rank as i32)
    }
}

/// Offline shape optimization: top-`budget` nodes by weight.
pub fn optimal_shape(budget: usize, alpha: f64, beta: f64, max_rank: usize, max_depth: usize) -> Vec<ShapeNode> {
    let mut shape: Vec<ShapeNode> = Vec::with_capacity(budget);
    let mut heap = BinaryHeap::new();
    let mut seq = 0u64;
    heap.push(ShapeCand {
        weight: rank_accept(alpha, beta, 0, max_rank),
        parent: usize::MAX,
        rank: 0,
        seq,
    });
    let mut depth_of = Vec::with_capacity(budget);
    while shape.len() < budget {
        let Some(cand) = heap.pop() else { break };
        if cand.weight <= 0.0 {
            break;
        }
        let idx = shape.len();
        let depth = if cand.parent == usize::MAX {
            1
        } else {
            depth_of[cand.parent] + 1
        };
        shape.push(ShapeNode {
            parent: cand.parent,
            rank: cand.rank,
            weight: cand.weight,
        });
        depth_of.push(depth);
        // sibling candidate at the same position
        let sib = rank_accept(alpha, beta, cand.rank + 1, max_rank);
        if sib > 0.0 {
            let parent_w = if cand.parent == usize::MAX {
                1.0
            } else {
                shape[cand.parent].weight
            };
            seq += 1;
            heap.push(ShapeCand {
                weight: parent_w * sib / 1.0,
                parent: cand.parent,
                rank: cand.rank + 1,
                seq,
            });
        }
        // first child of the new node
        if depth < max_depth {
            let child = rank_accept(alpha, beta, 0, max_rank);
            seq += 1;
            heap.push(ShapeCand {
                weight: cand.weight * child,
                parent: idx,
                rank: 0,
                seq,
            });
        }
    }
    shape
}

pub struct SequoiaPolicy {
    /// Sibling-rank decay for the positional acceptance fit.
    pub beta: f64,
    pub max_rank: usize,
}

impl Default for SequoiaPolicy {
    fn default() -> Self {
        Self {
            beta: 0.55,
            max_rank: 8,
        }
    }
}

impl TreePolicy for SequoiaPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Sequoia
    }

    fn build(
        &self,
        draft: &mut dyn LogitModel,
        prefix: &[u32],
        cfg: &EngineConfig,
        rng: &mut Rng,
    ) -> TokenTree {
        let shape = optimal_shape(
            cfg.tree_budget,
            cfg.sequoia_accept_rate,
            self.beta,
            self.max_rank,
            cfg.max_depth,
        );
        let root_dist = super::draft_dist(draft, prefix, cfg.draft_temp);
        let mut tree = TokenTree::new(*prefix.last().expect("empty prefix"), root_dist);

        // Fill the fixed shape with sampled tokens. Children of one shape
        // node must be drawn rank-order from one residual sampler.
        let mut ctx = prefix.to_vec();
        let mut node_of_shape = vec![usize::MAX; shape.len()];
        let mut sampler_of: Vec<Option<SiblingSampler>> = vec![None; shape.len() + 1];
        sampler_of[0] = Some(SiblingSampler::new(tree.node(ROOT).draft_dist.clone()));

        for (i, s) in shape.iter().enumerate() {
            let (parent_tree, slot) = if s.parent == usize::MAX {
                (ROOT, 0)
            } else {
                (node_of_shape[s.parent], s.parent + 1)
            };
            if parent_tree == usize::MAX {
                continue; // ancestor dropped (draft mass exhausted)
            }
            // Lazily score the parent with the draft model.
            if sampler_of[slot].is_none() {
                if tree.node(parent_tree).draft_dist.is_empty() {
                    ctx.truncate(prefix.len());
                    ctx.extend(tree.path_tokens(parent_tree));
                    let dist = super::draft_dist(draft, &ctx, cfg.draft_temp);
                    tree.node_mut(parent_tree).draft_dist = dist;
                }
                sampler_of[slot] =
                    Some(SiblingSampler::new(tree.node(parent_tree).draft_dist.clone()));
            }
            let Some((token, _p)) = sampler_of[slot].as_mut().unwrap().draw(rng) else {
                continue;
            };
            let id = tree.add_child(parent_tree, token as u32, s.weight);
            node_of_shape[i] = id;
        }
        tree
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::draft::testutil::{prefix, sim_draft};

    #[test]
    fn shape_is_budget_sized_and_weight_sorted() {
        let shape = optimal_shape(64, 0.75, 0.5, 8, 24);
        assert_eq!(shape.len(), 64);
        for w in shape.windows(2) {
            assert!(w[0].weight >= w[1].weight - 1e-12);
        }
    }

    #[test]
    fn high_alpha_prefers_depth_low_alpha_prefers_width() {
        let deep = optimal_shape(16, 0.95, 0.3, 8, 32);
        let wide = optimal_shape(16, 0.3, 0.9, 8, 32);
        let depth = |shape: &[ShapeNode]| {
            let mut d = vec![0usize; shape.len()];
            let mut maxd = 0;
            for (i, s) in shape.iter().enumerate() {
                d[i] = if s.parent == usize::MAX { 1 } else { d[s.parent] + 1 };
                maxd = maxd.max(d[i]);
            }
            maxd
        };
        assert!(depth(&deep) > depth(&wide), "{} vs {}", depth(&deep), depth(&wide));
    }

    #[test]
    fn shape_is_static_across_inputs() {
        // The defining limitation vs DySpec: same shape regardless of query.
        let a = optimal_shape(32, 0.75, 0.55, 8, 24);
        let b = optimal_shape(32, 0.75, 0.55, 8, 24);
        assert_eq!(a, b);
    }

    #[test]
    fn builds_valid_tree() {
        let cfg = EngineConfig {
            tree_budget: 32,
            ..EngineConfig::default()
        };
        let mut draft = sim_draft(0.8, 42);
        let mut rng = Rng::new(1);
        let tree = SequoiaPolicy::default().build(&mut draft, &prefix(), &cfg, &mut rng);
        tree.check_invariants().unwrap();
        assert!(tree.size() > 0 && tree.size() <= 32);
    }

    #[test]
    fn rank_accept_decays() {
        assert!(rank_accept(0.8, 0.5, 0, 8) > rank_accept(0.8, 0.5, 1, 8));
        assert_eq!(rank_accept(0.8, 0.5, 8, 8), 0.0);
    }
}
