//! Draft-tree construction policies. `TreePolicy` is the pluggable strategy
//! interface; DySpec's dynamic trees (Algorithms 1 and 2) sit next to the
//! baselines the paper compares against (Sequoia, SpecInfer, chain).
//!
//! Contract shared by all policies (required for unbiased verification):
//!   - every node's `draft_dist` holds the temperature-applied draft
//!     distribution conditioned on (prefix ++ path-to-node);
//!   - children are stored in SAMPLING order, and sibling k was drawn from
//!     the residual with siblings < k zeroed-and-renormalized;
//!   - whether a sampled token is KEPT never depends on the token identity
//!     (the paper's problem-2 constraint — anything else biases the output).

pub mod chain;
pub mod dyspec;
pub mod sequoia;
pub mod specinfer;
pub mod threshold;

use crate::config::{EngineConfig, PolicyKind};
use crate::models::LogitModel;
use crate::tree::TokenTree;
use crate::util::Rng;

/// A draft-tree construction strategy.
pub trait TreePolicy {
    fn kind(&self) -> PolicyKind;

    /// Build the speculated tree for `prefix`.
    fn build(
        &self,
        draft: &mut dyn LogitModel,
        prefix: &[u32],
        cfg: &EngineConfig,
        rng: &mut Rng,
    ) -> TokenTree;
}

/// Resolve the draft policy one speculation round runs, from the
/// participating sequences' per-request overrides: the override when the
/// set is homogeneous (every sequence names the same policy, explicitly or
/// by defaulting), the worker `default` otherwise — the cross-request
/// greedy allocator is policy-global by construction, so a mixed batch
/// cannot honor per-sequence policies (DESIGN.md §Round Pipeline). An
/// empty set (nothing speculating) resolves to `default`.
pub fn round_policy<I>(overrides: I, default: PolicyKind) -> PolicyKind
where
    I: IntoIterator<Item = Option<PolicyKind>>,
{
    let mut kinds = overrides.into_iter().map(|o| o.unwrap_or(default));
    let Some(first) = kinds.next() else {
        return default;
    };
    if kinds.all(|k| k == first) {
        first
    } else {
        default
    }
}

/// Instantiate the policy selected by the config.
pub fn make_policy(kind: PolicyKind) -> Box<dyn TreePolicy> {
    match kind {
        PolicyKind::DySpec => Box::new(dyspec::DySpecPolicy),
        PolicyKind::DySpecThreshold => Box::new(threshold::ThresholdPolicy),
        PolicyKind::Sequoia => Box::new(sequoia::SequoiaPolicy::default()),
        PolicyKind::SpecInfer => Box::new(specinfer::SpecInferPolicy),
        PolicyKind::Chain => Box::new(chain::ChainPolicy),
        PolicyKind::Baseline => Box::new(chain::NoSpeculation),
    }
}

/// Shared helper: temperature-applied draft distribution for a context.
pub(crate) fn draft_dist(
    draft: &mut dyn LogitModel,
    ctx: &[u32],
    temp: f32,
) -> Vec<f32> {
    crate::sampling::dist_from_logits(&draft.next_logits(ctx), temp)
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::models::sim::{SimModel, SimSpec};

    pub fn sim_draft(noise: f32, seed: u64) -> SimModel {
        let spec = SimSpec::new(64, 2.0, noise, seed);
        SimModel::pair(spec).0
    }

    pub fn prefix() -> Vec<u32> {
        vec![3, 1, 4, 1, 5, 9, 2, 6]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::ROOT;

    /// Every policy must satisfy the shared structural contract.
    #[test]
    fn all_policies_respect_budget_and_invariants() {
        let cfg = EngineConfig {
            tree_budget: 24,
            ..EngineConfig::default()
        };
        for kind in [
            PolicyKind::DySpec,
            PolicyKind::DySpecThreshold,
            PolicyKind::Sequoia,
            PolicyKind::SpecInfer,
            PolicyKind::Chain,
        ] {
            let policy = make_policy(kind);
            let mut draft = testutil::sim_draft(0.8, 42);
            let mut rng = Rng::new(7);
            let tree = policy.build(&mut draft, &testutil::prefix(), &cfg, &mut rng);
            assert!(tree.size() <= cfg.tree_budget, "{kind}: over budget");
            assert!(tree.size() >= 1, "{kind}: empty tree");
            tree.check_invariants().unwrap();
            // every non-leaf node must carry its draft distribution
            for id in tree.speculated() {
                if !tree.node(id).children.is_empty() {
                    assert!(
                        !tree.node(id).draft_dist.is_empty(),
                        "{kind}: inner node missing dist"
                    );
                }
            }
            assert!(!tree.node(ROOT).draft_dist.is_empty(), "{kind}: root dist");
        }
    }

    #[test]
    fn round_policy_honors_homogeneous_overrides_only() {
        use PolicyKind::{Chain, DySpec, Sequoia};
        assert_eq!(round_policy(std::iter::empty(), DySpec), DySpec);
        assert_eq!(round_policy([Some(Chain)], DySpec), Chain);
        assert_eq!(round_policy([None::<PolicyKind>, None], DySpec), DySpec);
        // Explicit override agreeing with defaulted sequences: homogeneous.
        assert_eq!(round_policy([Some(DySpec), None], DySpec), DySpec);
        // Mixed batch falls back to the worker default.
        assert_eq!(round_policy([Some(Chain), Some(Sequoia)], DySpec), DySpec);
        assert_eq!(round_policy([Some(Chain), None], DySpec), DySpec);
    }

    #[test]
    fn baseline_builds_empty_tree() {
        let policy = make_policy(PolicyKind::Baseline);
        let mut draft = testutil::sim_draft(0.8, 1);
        let mut rng = Rng::new(1);
        let tree = policy.build(
            &mut draft,
            &testutil::prefix(),
            &EngineConfig::default(),
            &mut rng,
        );
        assert_eq!(tree.size(), 0);
    }
}
