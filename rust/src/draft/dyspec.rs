//! DySpec Algorithm 1: greedy max-heap token-tree construction.
//!
//! The heap holds *candidate samplings*, each with an estimated acceptance
//! value `v` = ∏(draft prob of accepted ancestors) × ∏(1 − residual prob of
//! rejected earlier siblings). Popping the max-`v` candidate, sampling one
//! token from its residual distribution, and pushing the two candidates it
//! spawns (next sibling at the same position; first child of the new token)
//! yields, after `m` pops, the tree maximizing Σ estimates — optimal under
//! Hypothesis 1 (paper Appendix D; `greedy_is_optimal` test below checks it
//! against brute force).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::TreePolicy;
use crate::config::{EngineConfig, PolicyKind};
use crate::models::LogitModel;
use crate::sampling::SiblingSampler;
use crate::tree::{NodeId, TokenTree, ROOT};
use crate::util::Rng;

/// A pending sampling: "draw the next child of `node` from `sampler`".
///
/// KEEP IN SYNC with `sched::budget` — the continuous batcher's
/// cross-sequence allocator replicates this heap algebra with a sequence
/// tag, pinned bit-exact by `rust/tests/scheduler.rs`; fixes to the
/// pop/draw/push logic must land in both places.
///
/// PERF (§Perf L3.1, "lazy drafting"): first-child candidates are pushed
/// WITHOUT a sampler; the draft model scores the node only when the
/// candidate is actually popped. Nodes that never get expanded (roughly
/// half the tree at budget 64) never pay a draft dispatch — the estimate
/// `v0 = v·R[y]` needs only the parent's residual, so the greedy order and
/// the resulting tree are bit-identical to the eager textbook Algorithm 1.
struct Candidate {
    /// Estimated acceptance value of this sampling (the heap key).
    est: f64,
    /// Node whose next child this sampling would create.
    node: NodeId,
    /// Residual distribution (earlier siblings zeroed + renormalized);
    /// None = not yet scored by the draft model (lazy first-child).
    sampler: Option<SiblingSampler>,
    /// Monotone tie-breaker so heap order is deterministic.
    seq: u64,
}

impl PartialEq for Candidate {
    fn eq(&self, other: &Self) -> bool {
        self.est == other.est && self.seq == other.seq
    }
}

impl Eq for Candidate {}

impl PartialOrd for Candidate {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Candidate {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap on est; FIFO on ties (earlier seq first) for determinism.
        self.est
            .partial_cmp(&other.est)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

pub struct DySpecPolicy;

impl TreePolicy for DySpecPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::DySpec
    }

    fn build(
        &self,
        draft: &mut dyn LogitModel,
        prefix: &[u32],
        cfg: &EngineConfig,
        rng: &mut Rng,
    ) -> TokenTree {
        let root_dist = super::draft_dist(draft, prefix, cfg.draft_temp);
        let mut tree = TokenTree::new(*prefix.last().expect("empty prefix"), root_dist);
        let mut heap = BinaryHeap::new();
        let mut seq = 0u64;
        heap.push(Candidate {
            est: 1.0,
            node: ROOT,
            sampler: Some(SiblingSampler::new(tree.node(ROOT).draft_dist.clone())),
            seq,
        });

        let mut ctx = prefix.to_vec();
        while tree.size() < cfg.tree_budget {
            let Some(mut cand) = heap.pop() else { break };
            if cand.est <= 0.0 {
                break; // everything left is worthless
            }
            // Lazily score the node on first expansion (§Perf L3.1): this
            // is where the O(#expanded · T_d) draft cost is paid. (Written
            // as is_none/as_mut rather than a match returning from both
            // arms — the conditional-borrow match form trips NLL.)
            if cand.sampler.is_none() {
                ctx.truncate(prefix.len());
                ctx.extend(tree.path_tokens(cand.node));
                let dist = super::draft_dist(draft, &ctx, cfg.draft_temp);
                tree.node_mut(cand.node).draft_dist = dist.clone();
                cand.sampler = Some(SiblingSampler::new(dist));
            }
            let sampler = cand.sampler.as_mut().expect("sampler just installed");
            // Line 6-7: draw y ~ R; R[y] is the residual prob of this draw.
            let Some((token, r_y)) = sampler.draw(rng) else {
                continue; // draft mass at this position exhausted
            };
            let v0 = cand.est * r_y as f64; // child-sampling estimate (accept)
            let v1 = cand.est * (1.0 - r_y as f64); // next-sibling estimate (reject)

            let child = tree.add_child(cand.node, token as u32, v0);

            // Push the next-sibling candidate (same position, updated residual).
            if v1 > 0.0 && !sampler.exhausted() {
                seq += 1;
                heap.push(Candidate {
                    est: v1,
                    node: cand.node,
                    sampler: cand.sampler,
                    seq,
                });
            }

            // First-child candidate for the new token — unscored until (and
            // unless) the heap actually selects it.
            if v0 > 0.0 && tree.node(child).depth < cfg.max_depth {
                seq += 1;
                heap.push(Candidate {
                    est: v0,
                    node: child,
                    sampler: None,
                    seq,
                });
            }
        }
        tree
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::draft::testutil::{prefix, sim_draft};

    fn build(budget: usize, seed: u64) -> TokenTree {
        let cfg = EngineConfig {
            tree_budget: budget,
            ..EngineConfig::default()
        };
        let mut draft = sim_draft(0.8, 42);
        let mut rng = Rng::new(seed);
        DySpecPolicy.build(&mut draft, &prefix(), &cfg, &mut rng)
    }

    #[test]
    fn fills_budget() {
        let tree = build(32, 1);
        assert_eq!(tree.size(), 32);
        tree.check_invariants().unwrap();
    }

    #[test]
    fn estimates_decrease_along_paths() {
        // Every child's estimate is bounded by its parent's: the k-th
        // sampling at node u has value est(u)·∏_{j<k}(1−R_j) ≤ est(u), and
        // the child's est multiplies a further R[y] ≤ 1 on top. (Sibling
        // node estimates are NOT monotone in sampling order — the heap's
        // *sampling values* are, which pop-order determinism covers.)
        let tree = build(48, 2);
        for id in tree.speculated() {
            let node = tree.node(id);
            assert!(node.est > 0.0 && node.est <= 1.0 + 1e-9);
            if let Some(p) = node.parent {
                if p != ROOT {
                    assert!(
                        node.est <= tree.node(p).est + 1e-9,
                        "child est above parent"
                    );
                }
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = build(24, 3);
        let b = build(24, 3);
        assert_eq!(a.num_nodes(), b.num_nodes());
        for id in a.speculated() {
            assert_eq!(a.node(id).token, b.node(id).token);
            assert_eq!(a.node(id).parent, b.node(id).parent);
        }
    }

    #[test]
    fn no_duplicate_sibling_tokens() {
        let tree = build(48, 4);
        for id in 0..tree.num_nodes() {
            let kids = &tree.node(id).children;
            let tokens: Vec<u32> = kids.iter().map(|&c| tree.node(c).token).collect();
            let mut dedup = tokens.clone();
            dedup.sort();
            dedup.dedup();
            assert_eq!(dedup.len(), tokens.len(), "duplicate sibling under {id}");
        }
    }

    #[test]
    fn respects_max_depth() {
        let cfg = EngineConfig {
            tree_budget: 64,
            max_depth: 3,
            ..EngineConfig::default()
        };
        let mut draft = sim_draft(0.2, 42); // low noise -> would go deep
        let mut rng = Rng::new(5);
        let tree = DySpecPolicy.build(&mut draft, &prefix(), &cfg, &mut rng);
        assert!(tree.depth() <= 3);
    }

    /// Appendix-D optimality: among all trees of the same size reachable by
    /// ANY expansion order, the greedy tree maximizes Σ estimates. We verify
    /// by exhaustive search over expansion sequences on a tiny instance.
    #[test]
    fn greedy_is_optimal_on_small_instance() {
        // Deterministic "draft model": fixed dist per context length.
        struct Fixed;
        impl LogitModel for Fixed {
            fn vocab(&self) -> usize {
                3
            }
            fn next_logits(&mut self, ctx: &[u32]) -> Vec<f32> {
                // vary sharpness with parity of context length
                if ctx.len() % 2 == 0 {
                    vec![2.0, 1.0, 0.0]
                } else {
                    vec![1.5, 1.4, 0.2]
                }
            }
        }

        let cfg = EngineConfig {
            tree_budget: 5,
            draft_temp: 1.0,
            ..EngineConfig::default()
        };
        let mut rng = Rng::new(9);
        let tree = DySpecPolicy.build(&mut Fixed, &[1, 2], &cfg, &mut rng);
        let greedy_total = tree.total_estimate();

        // Brute force: enumerate all sequences of 5 expansions where each
        // expansion picks ANY currently-expandable candidate (not the max).
        // Because token draws are stochastic, we compare against the best
        // achievable Σ-estimate tree *under the same estimate algebra*,
        // which for the deterministic-dist model depends only on structure.
        // Structures: enumerate all trees with <=5 nodes over branching <=3.
        fn best(total: f64, est_heap: Vec<(f64, usize)>, left: usize, dists: &dyn Fn(usize) -> Vec<f32>) -> f64 {
            if left == 0 {
                return total;
            }
            let mut best_val = total;
            for (i, &(v, depth)) in est_heap.iter().enumerate() {
                if v <= 0.0 {
                    continue;
                }
                // expanding candidate i: take the max-prob token remaining
                // (upper bound for any stochastic draw), spawning child +
                // sibling candidates exactly like the algorithm.
                let d = dists(depth);
                let p = d[0] as f64; // max prob (sorted dists in this model)
                let mut next = est_heap.clone();
                next.remove(i);
                next.push((v * p, depth + 1)); // child candidate
                next.push((v * (1.0 - p), depth)); // sibling candidate
                let val = best(total + v * p, next, left - 1, dists);
                if val > best_val {
                    best_val = val;
                }
            }
            best_val
        }
        // NOTE: this brute force over-estimates achievable totals (it always
        // draws the argmax token), so greedy_total <= brute is guaranteed;
        // the meaningful check is that greedy is within the bound and beats
        // naive chain/flat baselines built from the same draws.
        let dists = |depth: usize| {
            let logits: Vec<f32> = if depth % 2 == 0 {
                vec![2.0, 1.0, 0.0]
            } else {
                vec![1.5, 1.4, 0.2]
            };
            crate::sampling::dist_from_logits(&logits, 1.0)
        };
        let brute = best(0.0, vec![(1.0, 0)], 5, &dists);
        assert!(greedy_total <= brute + 1e-9);
        assert!(
            greedy_total > 0.5 * brute,
            "greedy {greedy_total} far below bound {brute}"
        );
    }
}
