//! SpecInfer baseline (Miao et al. 2023): fixed k-ary token tree with
//! configurable per-layer branch widths — every layer-l node receives
//! `widths[l]` children, irrespective of the draft distribution. The
//! simplest fixed-structure baseline the paper compares against.

use super::TreePolicy;
use crate::config::{EngineConfig, PolicyKind};
use crate::models::LogitModel;
use crate::sampling::SiblingSampler;
use crate::tree::{NodeId, TokenTree, ROOT};
use crate::util::Rng;

pub struct SpecInferPolicy;

impl TreePolicy for SpecInferPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::SpecInfer
    }

    fn build(
        &self,
        draft: &mut dyn LogitModel,
        prefix: &[u32],
        cfg: &EngineConfig,
        rng: &mut Rng,
    ) -> TokenTree {
        let root_dist = super::draft_dist(draft, prefix, cfg.draft_temp);
        let mut tree = TokenTree::new(*prefix.last().expect("empty prefix"), root_dist);
        let mut ctx = prefix.to_vec();
        let mut frontier: Vec<NodeId> = vec![ROOT];

        for layer in 0..cfg.max_depth {
            let width = *cfg
                .specinfer_widths
                .get(layer)
                .or(cfg.specinfer_widths.last())
                .unwrap_or(&1);
            if width == 0 || frontier.is_empty() || tree.size() >= cfg.tree_budget {
                break;
            }
            let mut next = Vec::new();
            for &node in &frontier {
                if tree.node(node).draft_dist.is_empty() {
                    ctx.truncate(prefix.len());
                    ctx.extend(tree.path_tokens(node));
                    let dist = super::draft_dist(draft, &ctx, cfg.draft_temp);
                    tree.node_mut(node).draft_dist = dist;
                }
                let mut sampler =
                    SiblingSampler::new(tree.node(node).draft_dist.clone());
                // Estimated value for bookkeeping only (structure is fixed).
                let mut v = if node == ROOT { 1.0 } else { tree.node(node).est };
                for _ in 0..width {
                    if tree.size() >= cfg.tree_budget {
                        break;
                    }
                    let Some((token, p)) = sampler.draw(rng) else { break };
                    let child = tree.add_child(node, token as u32, v * p as f64);
                    v *= 1.0 - p as f64;
                    next.push(child);
                }
            }
            frontier = next;
        }
        tree
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::draft::testutil::{prefix, sim_draft};

    fn build(widths: Vec<usize>, budget: usize) -> TokenTree {
        let cfg = EngineConfig {
            tree_budget: budget,
            specinfer_widths: widths,
            ..EngineConfig::default()
        };
        let mut draft = sim_draft(0.8, 42);
        let mut rng = Rng::new(1);
        SpecInferPolicy.build(&mut draft, &prefix(), &cfg, &mut rng)
    }

    #[test]
    fn layer_widths_follow_config() {
        let tree = build(vec![4, 2, 1], 64);
        tree.check_invariants().unwrap();
        let widths = tree.layer_widths();
        assert_eq!(widths[0], 4);
        // each of the 4 layer-1 nodes gets 2 children
        assert_eq!(widths[1], 8);
        // layer 3 onward reuses the last width (1 child each)
        assert_eq!(widths[2], 8);
    }

    #[test]
    fn budget_truncates_fixed_shape() {
        let tree = build(vec![4, 4, 4], 10);
        assert!(tree.size() <= 10);
    }

    #[test]
    fn structure_is_input_independent() {
        // widths identical across different prefixes (fixed-pattern tree) —
        // the limitation DySpec's dynamic trees remove.
        let cfg = EngineConfig {
            tree_budget: 64,
            specinfer_widths: vec![3, 2, 1],
            ..EngineConfig::default()
        };
        let mut rng = Rng::new(2);
        let mut draft = sim_draft(0.8, 42);
        let t1 = SpecInferPolicy.build(&mut draft, &[1, 2, 3], &cfg, &mut rng);
        let t2 = SpecInferPolicy.build(&mut draft, &[9, 8, 7], &cfg, &mut rng);
        assert_eq!(t1.layer_widths(), t2.layer_widths());
    }
}
