//! DySpec Algorithm 2: layer-by-layer construction with an estimate
//! threshold.
//!
//! Greedy Algorithm 1 calls the draft model once per node (O(N·T_d)); when
//! T_t/T_d is small that dominates. Observing that Algorithm 1 admits
//! exactly the nodes whose estimate exceeds the final heap cutoff, fixing a
//! threshold `t` up front lets us expand whole layers at a time — one draft
//! dispatch per LAYER (O(D·T_d), D ≪ N) at the cost of not exactly filling
//! the budget (paper §4.4 and Appendix B.1 discuss the resulting tree-size
//! slack, our Fig-5 bench reproduces it).

use super::TreePolicy;
use crate::config::{EngineConfig, PolicyKind};
use crate::models::LogitModel;
use crate::sampling::SiblingSampler;
use crate::tree::{NodeId, TokenTree};
use crate::util::Rng;

pub struct ThresholdPolicy;

impl TreePolicy for ThresholdPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::DySpecThreshold
    }

    fn build(
        &self,
        draft: &mut dyn LogitModel,
        prefix: &[u32],
        cfg: &EngineConfig,
        rng: &mut Rng,
    ) -> TokenTree {
        let threshold = cfg.threshold.max(1e-12);
        let root_dist = super::draft_dist(draft, prefix, cfg.draft_temp);
        let mut tree = TokenTree::new(*prefix.last().expect("empty prefix"), root_dist);

        // Frontier of (node, node-estimate) pairs whose children we expand.
        let mut frontier: Vec<(NodeId, f64)> = vec![(crate::tree::ROOT, 1.0)];
        let mut ctx = prefix.to_vec();
        let mut layer = 0;

        while !frontier.is_empty() && tree.size() < cfg.tree_budget && layer < cfg.max_depth {
            let mut next_frontier = Vec::new();
            for &(node, node_est) in &frontier {
                // One draft dispatch per frontier node per layer. The root
                // dist was already computed; deeper nodes are scored here.
                if tree.node(node).draft_dist.is_empty() {
                    ctx.truncate(prefix.len());
                    ctx.extend(tree.path_tokens(node));
                    let dist = super::draft_dist(draft, &ctx, cfg.draft_temp);
                    tree.node_mut(node).draft_dist = dist;
                }
                let mut sampler =
                    SiblingSampler::new(tree.node(node).draft_dist.clone());

                // Expand siblings while the SAMPLING estimate clears the
                // threshold (`v_i` in Algorithm 2).
                let mut v = node_est;
                while v >= threshold && tree.size() < cfg.tree_budget {
                    let Some((token, p)) = sampler.draw(rng) else { break };
                    let child_est = v * p as f64;
                    let child = tree.add_child(node, token as u32, child_est);
                    if child_est >= threshold {
                        next_frontier.push((child, child_est));
                    }
                    v *= 1.0 - p as f64;
                }
            }
            frontier = next_frontier;
            layer += 1;
        }
        tree
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::draft::dyspec::DySpecPolicy;
    use crate::draft::testutil::{prefix, sim_draft};

    fn cfg(budget: usize, threshold: f64) -> EngineConfig {
        EngineConfig {
            tree_budget: budget,
            threshold,
            ..EngineConfig::default()
        }
    }

    #[test]
    fn all_kept_nodes_clear_threshold_estimate() {
        let mut draft = sim_draft(0.8, 42);
        let mut rng = Rng::new(1);
        let c = cfg(256, 0.01);
        let tree = ThresholdPolicy.build(&mut draft, &prefix(), &c, &mut rng);
        tree.check_invariants().unwrap();
        for id in tree.speculated() {
            let node = tree.node(id);
            // The SAMPLING estimate that produced this node cleared the
            // threshold; the node estimate itself is sampling-est × p, so it
            // may be below — but its parent's sampling estimate was >= t.
            let parent_est = node
                .parent
                .map(|p| if p == crate::tree::ROOT { 1.0 } else { tree.node(p).est })
                .unwrap();
            assert!(parent_est >= c.threshold - 1e-12);
        }
    }

    #[test]
    fn threshold_one_keeps_only_first_layer_greedy_mass() {
        let mut draft = sim_draft(0.8, 42);
        let mut rng = Rng::new(2);
        // t = 0.9: only samplings with est >= 0.9 happen — just the root's
        // first few draws whose cumulative rejection mass stays >= 0.9.
        let tree = ThresholdPolicy.build(&mut draft, &prefix(), &cfg(64, 0.9), &mut rng);
        assert!(tree.size() <= 4, "tree unexpectedly large: {}", tree.size());
        assert!(tree.depth() <= 2);
    }

    #[test]
    fn lower_threshold_grows_bigger_trees() {
        let mut rng = Rng::new(3);
        let sizes: Vec<usize> = [0.2, 0.02, 0.002]
            .iter()
            .map(|&t| {
                let mut draft = sim_draft(0.8, 42);
                ThresholdPolicy
                    .build(&mut draft, &prefix(), &cfg(768, t), &mut rng)
                    .size()
            })
            .collect();
        assert!(sizes[0] < sizes[1] && sizes[1] <= sizes[2], "{sizes:?}");
    }

    #[test]
    fn uses_fewer_draft_dispatches_than_greedy() {
        // The paper's entire point for Algorithm 2: O(#inner nodes) (layered
        // batches in a real deployment) instead of O(N) dispatches.
        let c = cfg(64, 1.0 / 64.0);
        let mut rng = Rng::new(4);

        let mut d1 = sim_draft(0.8, 42);
        let greedy = DySpecPolicy.build(&mut d1, &prefix(), &c, &mut rng);
        let greedy_calls = d1.call_counts().dispatches;

        let mut d2 = sim_draft(0.8, 42);
        let layered = ThresholdPolicy.build(&mut d2, &prefix(), &c, &mut rng);
        let layered_calls = d2.call_counts().dispatches;

        assert!(greedy.size() > 0 && layered.size() > 0);
        // Lazy drafting (§Perf L3.1) means greedy scores only nodes the heap
        // actually expands — well under one dispatch per node; layered
        // scores only expanded inner nodes. Both must be far below the
        // textbook O(N) = size+1 dispatches.
        assert!(
            (greedy_calls as usize) < greedy.size() / 2 + 2,
            "greedy {greedy_calls} dispatches for {} nodes — lazy drafting broken",
            greedy.size()
        );
        assert!(
            (layered_calls as usize) < layered.size() / 2 + 2,
            "layered {layered_calls} dispatches for {} nodes",
            layered.size()
        );
    }

    #[test]
    fn budget_is_hard_cap() {
        let mut draft = sim_draft(0.8, 42);
        let mut rng = Rng::new(5);
        let tree = ThresholdPolicy.build(&mut draft, &prefix(), &cfg(16, 1e-6), &mut rng);
        assert!(tree.size() <= 16);
    }
}
