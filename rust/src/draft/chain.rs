//! Chain speculation (classic speculative decoding, Leviathan et al. 2023 /
//! Chen et al. 2023): a single path of `tree_budget` tokens — the degenerate
//! token "tree" of Figure 1a/1b. Also `NoSpeculation`, the autoregressive
//! baseline that builds an empty tree (the engine then just samples one
//! target token per step).

use super::TreePolicy;
use crate::config::{EngineConfig, PolicyKind};
use crate::models::LogitModel;
use crate::sampling::sample;
use crate::tree::{TokenTree, ROOT};
use crate::util::Rng;

pub struct ChainPolicy;

impl TreePolicy for ChainPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Chain
    }

    fn build(
        &self,
        draft: &mut dyn LogitModel,
        prefix: &[u32],
        cfg: &EngineConfig,
        rng: &mut Rng,
    ) -> TokenTree {
        let root_dist = super::draft_dist(draft, prefix, cfg.draft_temp);
        let mut tree = TokenTree::new(*prefix.last().expect("empty prefix"), root_dist);
        let mut ctx = prefix.to_vec();
        let mut node = ROOT;
        let depth_cap = cfg.tree_budget.min(cfg.max_depth);
        for _ in 0..depth_cap {
            let dist = tree.node(node).draft_dist.clone();
            if dist.iter().sum::<f32>() <= 0.0 {
                break;
            }
            let token = sample(&dist, rng) as u32;
            let est = tree.node(node).est * dist[token as usize] as f64;
            let child = tree.add_child(node, token, est);
            ctx.push(token);
            let child_dist = super::draft_dist(draft, &ctx, cfg.draft_temp);
            tree.node_mut(child).draft_dist = child_dist;
            node = child;
        }
        tree
    }
}

/// Autoregressive baseline: no speculation at all.
pub struct NoSpeculation;

impl TreePolicy for NoSpeculation {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Baseline
    }

    fn build(
        &self,
        draft: &mut dyn LogitModel,
        prefix: &[u32],
        cfg: &EngineConfig,
        _rng: &mut Rng,
    ) -> TokenTree {
        let root_dist = super::draft_dist(draft, prefix, cfg.draft_temp);
        TokenTree::new(*prefix.last().expect("empty prefix"), root_dist)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::draft::testutil::{prefix, sim_draft};

    #[test]
    fn chain_is_a_path() {
        let cfg = EngineConfig {
            tree_budget: 12,
            ..EngineConfig::default()
        };
        let mut draft = sim_draft(0.8, 42);
        let mut rng = Rng::new(1);
        let tree = ChainPolicy.build(&mut draft, &prefix(), &cfg, &mut rng);
        tree.check_invariants().unwrap();
        assert_eq!(tree.size(), 12);
        assert_eq!(tree.depth(), 12);
        for id in tree.speculated() {
            assert!(tree.node(id).children.len() <= 1);
        }
    }

    #[test]
    fn chain_estimates_are_path_products() {
        let cfg = EngineConfig {
            tree_budget: 6,
            ..EngineConfig::default()
        };
        let mut draft = sim_draft(0.8, 42);
        let mut rng = Rng::new(2);
        let tree = ChainPolicy.build(&mut draft, &prefix(), &cfg, &mut rng);
        for id in tree.speculated() {
            let node = tree.node(id);
            if let Some(p) = node.parent {
                if p != ROOT {
                    assert!(node.est <= tree.node(p).est + 1e-12);
                }
            }
        }
    }
}
