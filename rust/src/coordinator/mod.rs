//! Serving coordinator: router tier → per-worker admission queues →
//! scheduler → worker threads running speculative engines → per-request
//! event routing + metrics.
//!
//! Since the router tier (DESIGN.md §Router Tier) each worker owns its
//! OWN bounded [`RequestQueue`] (and, behind it, its own engine/batcher
//! and KV block pool); admitted requests are routed by consistent-
//! hashing their prompt prefix so a worker's cache concentrates
//! residency for the prefixes it owns (`route=affinity`, the default;
//! `route=rr` round-robins for comparison). The router also owns worker
//! health: spill off an overloaded owner, deterministic failover off a
//! dead one, and [`Coordinator::kill_worker`] to take a worker down
//! mid-run with its in-flight requests cancelled cleanly.
//!
//! The scheduler is config-selectable (`scheduler = fcfs | continuous`):
//! FCFS runs one request per worker to completion; continuous runs a
//! step-level batcher per worker that multiplexes sequences into shared
//! verification dispatches (see `sched/`).
//!
//! Requests stream: [`Coordinator::try_submit`] returns a
//! [`RequestHandle`] whose channel yields one [`GenEvent::Chunk`] per
//! speculation round and a final [`GenEvent::Done`]; the handle's
//! [`CancelToken`] cancels the request at round granularity (slot and KV
//! residency released immediately). [`Coordinator::generate`] is the
//! blocking convenience that drains the stream.
//!
//! Each worker owns its own (draft, target) model pair — PJRT handles are
//! not `Send`, so the model *factory* crosses the thread boundary and the
//! models are constructed inside the worker (vLLM-router-style process
//! topology, scaled to threads). Backpressure: `try_submit` fails fast when
//! the queue is full, and the TCP server surfaces that as an error frame.

pub mod metrics;
pub mod queue;
pub mod worker;

pub use metrics::Metrics;
pub use queue::{
    CancelToken, EventSink, FinishReason, GenEvent, GenParams, Request,
    RequestHandle, RequestQueue, Response, RoundStats,
};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::config::{Config, ServerConfig};
use crate::models::LogitModel;
use crate::obs::Observatory;
use crate::router::Router;
use crate::util::json::Json;

/// Constructs a (draft, target) pair inside a worker thread.
pub type ModelFactory =
    Arc<dyn Fn() -> (Box<dyn LogitModel>, Box<dyn LogitModel>) + Send + Sync>;

/// Running coordinator handle.
pub struct Coordinator {
    /// Prefix-affinity router over the per-worker admission queues.
    router: Router,
    pub metrics: Arc<Metrics>,
    /// Tracing + acceptance observatory shared by every worker (spans are
    /// recorded only when `obs.trace = on`; counters always).
    obs: Arc<Observatory>,
    shutdown: Arc<AtomicBool>,
    /// Worker join handles; a slot goes `None` once that worker has been
    /// killed and joined ([`Coordinator::kill_worker`]).
    workers: Mutex<Vec<Option<JoinHandle<()>>>>,
    /// Serving-layer knobs the TCP transport reads back (reactor pool
    /// size, connection/outbox limits).
    server_cfg: ServerConfig,
}

impl Coordinator {
    /// Start `cfg.server.workers` workers, each over its own admission
    /// queue (capacity `cfg.server.queue_capacity` PER worker) and its
    /// own `factory`-built model pair, behind the router tier.
    pub fn start(cfg: Config, factory: ModelFactory) -> Self {
        let server_cfg = cfg.server.clone();
        let n = cfg.server.workers.max(1);
        let metrics = Arc::new(Metrics::new());
        let obs = Arc::new(Observatory::new(n, cfg.obs.trace, cfg.obs.trace_ring));
        let shutdown = Arc::new(AtomicBool::new(false));
        // One id counter across every shard queue: ids stay unique and
        // increasing per coordinator, exactly as in the single-queue era.
        let ids = Arc::new(AtomicU64::new(1));

        let mut queues = Vec::with_capacity(n);
        let mut workers = Vec::with_capacity(n);
        for wid in 0..n {
            let (queue, rx) =
                RequestQueue::new(cfg.server.queue_capacity, metrics.clone());
            queues.push(
                queue.with_tracing(cfg.obs.trace).with_ids(ids.clone()),
            );
            let rx = Arc::new(Mutex::new(rx));
            let factory = factory.clone();
            let metrics = metrics.clone();
            let obs = obs.clone();
            let shutdown = shutdown.clone();
            let cfg = cfg.clone();
            workers.push(Some(
                std::thread::Builder::new()
                    .name(format!("dyspec-worker-{wid}"))
                    .spawn(move || {
                        worker::run_worker(
                            wid, cfg, factory, rx, metrics, obs, shutdown,
                        )
                    })
                    .expect("spawning worker"),
            ));
        }
        let router = Router::new(cfg.route.clone(), queues, metrics.clone());

        Self {
            router,
            metrics,
            obs,
            shutdown,
            workers: Mutex::new(workers),
            server_cfg,
        }
    }

    /// The serving-layer configuration this coordinator was started with.
    pub fn server_config(&self) -> &ServerConfig {
        &self.server_cfg
    }

    /// The shared observatory (stage quantiles, acceptance counters,
    /// span flight recorder).
    pub fn observatory(&self) -> &Arc<Observatory> {
        &self.obs
    }

    /// The router tier (ring ownership, per-worker load, health). Tests
    /// and the loadtest harness read routing decisions through this.
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Prometheus text exposition of the full metrics snapshot plus the
    /// observatory series and the per-worker router rows (the
    /// `{"cmd":"metrics"}` payload).
    pub fn prometheus(&self) -> String {
        crate::obs::render_prometheus(
            &self.metrics.snapshot(),
            &self.obs,
            &self.router.worker_stats(),
        )
    }

    /// Flight-recorder dump (the `{"cmd":"trace"}` payload): recorded
    /// spans sorted by start time, plus the overflow-drop counter.
    pub fn trace_json(&self) -> Json {
        self.obs.trace_json()
    }

    /// Submit a request; events arrive on the returned handle's channel.
    /// Fails fast (backpressure) when the admission queue is full.
    pub fn try_submit(
        &self,
        prompt: Vec<u32>,
        params: GenParams,
    ) -> Result<RequestHandle, String> {
        let (events, rx) = mpsc::channel();
        let (id, cancel) = self.router.submit(prompt, params, Box::new(events))?;
        Ok(RequestHandle {
            id,
            events: rx,
            cancel,
        })
    }

    /// Submit a request whose events land in a caller-supplied sink (the
    /// reactor transport pushes frames straight into connection outboxes
    /// this way — no per-request forwarder thread). Returns the
    /// server-side id and the shared cancel token.
    pub fn try_submit_sink(
        &self,
        prompt: Vec<u32>,
        params: GenParams,
        events: Box<dyn EventSink>,
    ) -> Result<(u64, CancelToken), String> {
        self.router.submit(prompt, params, events)
    }

    /// Blocking convenience: submit and wait for the final response.
    pub fn generate(
        &self,
        prompt: Vec<u32>,
        max_new_tokens: usize,
        temperature: f32,
    ) -> Result<Response, String> {
        self.try_submit(prompt, GenParams::simple(max_new_tokens, temperature))?
            .wait()
    }

    /// Take one worker down mid-run: mark it dead on the ring (its
    /// prefixes re-own to the next live worker), cancel everything
    /// queued or in flight on its shard via the shared [`CancelToken`]s
    /// (clients get a prompt `finish=cancelled` done frame — or a
    /// sink-drop error if the worker dies without answering), close its
    /// queue, and join its thread. Returns `false` if the worker was
    /// already dead or out of range.
    pub fn kill_worker(&self, wid: usize) -> bool {
        if !self.router.kill(wid) {
            return false;
        }
        let handle = self
            .workers
            .lock()
            .unwrap()
            .get_mut(wid)
            .and_then(|slot| slot.take());
        if let Some(h) = handle {
            let _ = h.join();
        }
        true
    }

    /// Drain and stop all workers: every shard queue closes, workers
    /// finish what they hold (graceful drain), then exit.
    pub fn shutdown(self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.router.close_all();
        for w in self.workers.lock().unwrap().drain(..).flatten() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::sim::{SimModel, SimSpec};

    fn sim_factory(noise: f32) -> ModelFactory {
        Arc::new(move || {
            let spec = SimSpec::new(64, 2.0, noise, 77);
            let (d, t) = SimModel::pair(spec);
            (
                Box::new(d) as Box<dyn LogitModel>,
                Box::new(t) as Box<dyn LogitModel>,
            )
        })
    }

    fn test_cfg(workers: usize, capacity: usize) -> Config {
        let mut cfg = Config::new();
        cfg.server.workers = workers;
        cfg.server.queue_capacity = capacity;
        cfg.engine.tree_budget = 8;
        cfg
    }

    #[test]
    fn serves_one_request() {
        let coord = Coordinator::start(test_cfg(1, 8), sim_factory(0.5));
        let resp = coord.generate(vec![1, 2, 3], 16, 0.6).unwrap();
        assert_eq!(resp.tokens.len(), 16);
        assert!(resp.emitted_per_step >= 1.0);
        assert_eq!(resp.finish, FinishReason::Length);
        assert_eq!(coord.metrics.completed(), 1);
        assert!(coord.metrics.chunks() >= 1);
        coord.shutdown();
    }

    #[test]
    fn serves_concurrent_requests_across_workers() {
        let coord = Coordinator::start(test_cfg(3, 32), sim_factory(0.5));
        let handles: Vec<_> = (0..9)
            .map(|i| {
                coord
                    .try_submit(vec![1 + i, 2, 3], GenParams::simple(12, 0.6))
                    .unwrap()
            })
            .collect();
        for h in handles {
            let resp = h.wait().unwrap();
            assert_eq!(resp.tokens.len(), 12);
        }
        assert_eq!(coord.metrics.completed(), 9);
        assert_eq!(coord.metrics.total_tokens(), 9 * 12);
        coord.shutdown();
    }

    #[test]
    fn streamed_chunks_concatenate_to_response_tokens() {
        let coord = Coordinator::start(test_cfg(1, 8), sim_factory(0.5));
        let h = coord
            .try_submit(vec![5, 6, 7], GenParams::simple(16, 0.6))
            .unwrap();
        let mut streamed = Vec::new();
        let resp = loop {
            match h.events.recv().unwrap() {
                GenEvent::Chunk { tokens, stats } => {
                    assert!(stats.round >= 1);
                    streamed.extend_from_slice(&tokens);
                }
                GenEvent::Done(resp) => break *resp,
            }
        };
        assert_eq!(streamed, resp.tokens, "chunk concat != final tokens");
        coord.shutdown();
    }

    #[test]
    fn cancellation_finishes_early_with_partial_output() {
        let coord = Coordinator::start(test_cfg(1, 8), sim_factory(0.5));
        let h = coord
            .try_submit(vec![1, 2, 3], GenParams::simple(4096, 0.6))
            .unwrap();
        // Cancel after the first chunk arrives.
        let resp = loop {
            match h.events.recv().unwrap() {
                GenEvent::Chunk { .. } => h.cancel.cancel(),
                GenEvent::Done(resp) => break *resp,
            }
        };
        assert_eq!(resp.finish, FinishReason::Cancelled);
        assert!(
            resp.tokens.len() < 4096,
            "cancelled request ran to completion"
        );
        assert_eq!(coord.metrics.cancelled(), 1);
        assert_eq!(coord.metrics.completed(), 0);
        coord.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut cfg = test_cfg(1, 2);
        cfg.engine.tree_budget = 4;
        let coord = Coordinator::start(cfg, sim_factory(0.5));
        let mut rejected = false;
        let mut pending = Vec::new();
        for i in 0..64 {
            match coord.try_submit(vec![i, 2, 3], GenParams::simple(64, 0.6)) {
                Ok(h) => pending.push(h),
                Err(_) => {
                    rejected = true;
                    break;
                }
            }
        }
        assert!(rejected, "queue of capacity 2 never pushed back");
        for h in pending {
            let _ = h.wait();
        }
        assert!(coord.metrics.rejected() >= 1);
        coord.shutdown();
    }

    fn continuous_cfg(max_active: usize, capacity: usize) -> Config {
        let mut cfg = test_cfg(1, capacity);
        cfg.sched.kind = crate::config::SchedKind::Continuous;
        cfg.sched.max_active = max_active;
        cfg.sched.idle_tick_ms = 5;
        cfg
    }

    #[test]
    fn continuous_serves_concurrent_requests_on_one_worker() {
        let coord =
            Coordinator::start(continuous_cfg(8, 32), sim_factory(0.5));
        let handles: Vec<_> = (0..8)
            .map(|i| {
                coord
                    .try_submit(vec![1 + i, 2, 3], GenParams::simple(12, 0.6))
                    .unwrap()
            })
            .collect();
        for h in handles {
            let resp = h.wait().unwrap();
            assert_eq!(resp.tokens.len(), 12);
            assert!(resp.emitted_per_step >= 1.0);
        }
        assert_eq!(coord.metrics.completed(), 8);
        assert_eq!(coord.metrics.total_tokens(), 8 * 12);
        // the whole point: dispatches served more than one sequence each
        assert!(
            coord.metrics.batch_occupancy() > 1.0,
            "occupancy {} not batched",
            coord.metrics.batch_occupancy()
        );
        assert_eq!(coord.metrics.tokens_in_flight(), 0);
        coord.shutdown();
    }

    #[test]
    fn continuous_shutdown_drains_in_flight_sequences() {
        let coord =
            Coordinator::start(continuous_cfg(8, 32), sim_factory(0.5));
        let handles: Vec<_> = (0..6)
            .map(|i| {
                coord
                    .try_submit(vec![9 + i, 8, 7], GenParams::simple(16, 0.6))
                    .unwrap()
            })
            .collect();
        // Shut down immediately: in-flight + queued sequences must still
        // complete (the batcher drains instead of dropping).
        coord.shutdown();
        for h in handles {
            let resp = h.wait().expect("request dropped during shutdown");
            assert_eq!(resp.tokens.len(), 16);
        }
    }

    #[test]
    fn kill_worker_cancels_in_flight_and_reroutes_the_prefix() {
        let coord = Coordinator::start(test_cfg(2, 32), sim_factory(0.5));
        let prompt = vec![11, 12, 13, 14];
        let owner = coord.router().route(&prompt).unwrap().worker;
        let h = coord
            .try_submit(prompt.clone(), GenParams::simple(4096, 0.6))
            .unwrap();
        // Wait until the request is demonstrably in flight on the owner.
        match h.events.recv().unwrap() {
            GenEvent::Chunk { .. } => {}
            GenEvent::Done(_) => panic!("4096-token request finished instantly"),
        }
        assert!(coord.kill_worker(owner));
        assert!(!coord.kill_worker(owner), "second kill is a no-op");
        // The in-flight request finishes promptly and cleanly cancelled.
        let resp = loop {
            match h.events.recv() {
                Ok(GenEvent::Done(resp)) => break *resp,
                Ok(GenEvent::Chunk { .. }) => continue,
                Err(_) => panic!("killed worker dropped the stream without Done"),
            }
        };
        assert_eq!(resp.finish, FinishReason::Cancelled);
        assert!(resp.tokens.len() < 4096);
        // The dead shard's gauges have drained to zero.
        let stats = &coord.router().worker_stats()[owner];
        assert!(!stats.alive);
        assert_eq!((stats.queued, stats.inflight), (0, 0));
        // Same-prefix traffic is re-owned by the survivor and still serves.
        let d = coord.router().route(&prompt).unwrap();
        assert_ne!(d.worker, owner);
        let resp = coord.generate(prompt, 8, 0.0).unwrap();
        assert_eq!(resp.tokens.len(), 8);
        assert_eq!(resp.worker, d.worker);
        coord.shutdown();
    }

    #[test]
    fn deterministic_tokens_for_same_request() {
        let coord = Coordinator::start(test_cfg(1, 8), sim_factory(0.4));
        let a = coord.generate(vec![5, 6, 7], 10, 0.0).unwrap();
        let b = coord.generate(vec![5, 6, 7], 10, 0.0).unwrap();
        // temp 0 + same sim spec: identical greedy continuations
        assert_eq!(a.tokens, b.tokens);
        coord.shutdown();
    }

    #[test]
    fn per_request_seed_pins_sampled_streams() {
        let coord = Coordinator::start(test_cfg(1, 8), sim_factory(0.5));
        let params = GenParams {
            seed: Some(1234),
            ..GenParams::simple(12, 0.6)
        };
        let a = coord
            .try_submit(vec![4, 5], params.clone())
            .unwrap()
            .wait()
            .unwrap();
        let b = coord
            .try_submit(vec![4, 5], params)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(a.tokens, b.tokens, "seeded requests diverged at temp 0.6");
        coord.shutdown();
    }
}
