//! Serving coordinator: bounded admission queue → scheduler → worker
//! threads running speculative engines → per-request event routing +
//! metrics.
//!
//! The scheduler is config-selectable (`scheduler = fcfs | continuous`):
//! FCFS runs one request per worker to completion; continuous runs a
//! step-level batcher per worker that multiplexes sequences into shared
//! verification dispatches (see `sched/`).
//!
//! Requests stream: [`Coordinator::try_submit`] returns a
//! [`RequestHandle`] whose channel yields one [`GenEvent::Chunk`] per
//! speculation round and a final [`GenEvent::Done`]; the handle's
//! [`CancelToken`] cancels the request at round granularity (slot and KV
//! residency released immediately). [`Coordinator::generate`] is the
//! blocking convenience that drains the stream.
//!
//! Each worker owns its own (draft, target) model pair — PJRT handles are
//! not `Send`, so the model *factory* crosses the thread boundary and the
//! models are constructed inside the worker (vLLM-router-style process
//! topology, scaled to threads). Backpressure: `try_submit` fails fast when
//! the queue is full, and the TCP server surfaces that as an error frame.

pub mod metrics;
pub mod queue;
pub mod worker;

pub use metrics::Metrics;
pub use queue::{
    CancelToken, EventSink, FinishReason, GenEvent, GenParams, Request,
    RequestHandle, RequestQueue, Response, RoundStats,
};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::config::{Config, ServerConfig};
use crate::models::LogitModel;
use crate::obs::Observatory;
use crate::util::json::Json;

/// Constructs a (draft, target) pair inside a worker thread.
pub type ModelFactory =
    Arc<dyn Fn() -> (Box<dyn LogitModel>, Box<dyn LogitModel>) + Send + Sync>;

/// Running coordinator handle.
pub struct Coordinator {
    queue: RequestQueue,
    pub metrics: Arc<Metrics>,
    /// Tracing + acceptance observatory shared by every worker (spans are
    /// recorded only when `obs.trace = on`; counters always).
    obs: Arc<Observatory>,
    shutdown: Arc<AtomicBool>,
    workers: Vec<JoinHandle<()>>,
    /// Serving-layer knobs the TCP transport reads back (reactor pool
    /// size, connection/outbox limits).
    server_cfg: ServerConfig,
}

impl Coordinator {
    /// Start `cfg.server.workers` workers over `factory`-built models.
    pub fn start(cfg: Config, factory: ModelFactory) -> Self {
        let server_cfg = cfg.server.clone();
        let metrics = Arc::new(Metrics::new());
        let obs = Arc::new(Observatory::new(
            cfg.server.workers.max(1),
            cfg.obs.trace,
            cfg.obs.trace_ring,
        ));
        let shutdown = Arc::new(AtomicBool::new(false));
        let (queue, rx) = RequestQueue::new(cfg.server.queue_capacity, metrics.clone());
        let queue = queue.with_tracing(cfg.obs.trace);
        let shared_rx = Arc::new(std::sync::Mutex::new(rx));

        let workers = (0..cfg.server.workers.max(1))
            .map(|wid| {
                let rx = shared_rx.clone();
                let factory = factory.clone();
                let metrics = metrics.clone();
                let obs = obs.clone();
                let shutdown = shutdown.clone();
                let cfg = cfg.clone();
                std::thread::Builder::new()
                    .name(format!("dyspec-worker-{wid}"))
                    .spawn(move || {
                        worker::run_worker(
                            wid, cfg, factory, rx, metrics, obs, shutdown,
                        )
                    })
                    .expect("spawning worker")
            })
            .collect();

        Self {
            queue,
            metrics,
            obs,
            shutdown,
            workers,
            server_cfg,
        }
    }

    /// The serving-layer configuration this coordinator was started with.
    pub fn server_config(&self) -> &ServerConfig {
        &self.server_cfg
    }

    /// The shared observatory (stage quantiles, acceptance counters,
    /// span flight recorder).
    pub fn observatory(&self) -> &Arc<Observatory> {
        &self.obs
    }

    /// Prometheus text exposition of the full metrics snapshot plus the
    /// observatory series (the `{"cmd":"metrics"}` payload).
    pub fn prometheus(&self) -> String {
        crate::obs::render_prometheus(&self.metrics.snapshot(), &self.obs)
    }

    /// Flight-recorder dump (the `{"cmd":"trace"}` payload): recorded
    /// spans sorted by start time, plus the overflow-drop counter.
    pub fn trace_json(&self) -> Json {
        self.obs.trace_json()
    }

    /// Submit a request; events arrive on the returned handle's channel.
    /// Fails fast (backpressure) when the admission queue is full.
    pub fn try_submit(
        &self,
        prompt: Vec<u32>,
        params: GenParams,
    ) -> Result<RequestHandle, String> {
        self.queue.try_submit(prompt, params)
    }

    /// Submit a request whose events land in a caller-supplied sink (the
    /// reactor transport pushes frames straight into connection outboxes
    /// this way — no per-request forwarder thread). Returns the
    /// server-side id and the shared cancel token.
    pub fn try_submit_sink(
        &self,
        prompt: Vec<u32>,
        params: GenParams,
        events: Box<dyn EventSink>,
    ) -> Result<(u64, CancelToken), String> {
        self.queue.try_submit_sink(prompt, params, events)
    }

    /// Blocking convenience: submit and wait for the final response.
    pub fn generate(
        &self,
        prompt: Vec<u32>,
        max_new_tokens: usize,
        temperature: f32,
    ) -> Result<Response, String> {
        self.try_submit(prompt, GenParams::simple(max_new_tokens, temperature))?
            .wait()
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::sim::{SimModel, SimSpec};

    fn sim_factory(noise: f32) -> ModelFactory {
        Arc::new(move || {
            let spec = SimSpec::new(64, 2.0, noise, 77);
            let (d, t) = SimModel::pair(spec);
            (
                Box::new(d) as Box<dyn LogitModel>,
                Box::new(t) as Box<dyn LogitModel>,
            )
        })
    }

    fn test_cfg(workers: usize, capacity: usize) -> Config {
        let mut cfg = Config::new();
        cfg.server.workers = workers;
        cfg.server.queue_capacity = capacity;
        cfg.engine.tree_budget = 8;
        cfg
    }

    #[test]
    fn serves_one_request() {
        let coord = Coordinator::start(test_cfg(1, 8), sim_factory(0.5));
        let resp = coord.generate(vec![1, 2, 3], 16, 0.6).unwrap();
        assert_eq!(resp.tokens.len(), 16);
        assert!(resp.emitted_per_step >= 1.0);
        assert_eq!(resp.finish, FinishReason::Length);
        assert_eq!(coord.metrics.completed(), 1);
        assert!(coord.metrics.chunks() >= 1);
        coord.shutdown();
    }

    #[test]
    fn serves_concurrent_requests_across_workers() {
        let coord = Coordinator::start(test_cfg(3, 32), sim_factory(0.5));
        let handles: Vec<_> = (0..9)
            .map(|i| {
                coord
                    .try_submit(vec![1 + i, 2, 3], GenParams::simple(12, 0.6))
                    .unwrap()
            })
            .collect();
        for h in handles {
            let resp = h.wait().unwrap();
            assert_eq!(resp.tokens.len(), 12);
        }
        assert_eq!(coord.metrics.completed(), 9);
        assert_eq!(coord.metrics.total_tokens(), 9 * 12);
        coord.shutdown();
    }

    #[test]
    fn streamed_chunks_concatenate_to_response_tokens() {
        let coord = Coordinator::start(test_cfg(1, 8), sim_factory(0.5));
        let h = coord
            .try_submit(vec![5, 6, 7], GenParams::simple(16, 0.6))
            .unwrap();
        let mut streamed = Vec::new();
        let resp = loop {
            match h.events.recv().unwrap() {
                GenEvent::Chunk { tokens, stats } => {
                    assert!(stats.round >= 1);
                    streamed.extend_from_slice(&tokens);
                }
                GenEvent::Done(resp) => break *resp,
            }
        };
        assert_eq!(streamed, resp.tokens, "chunk concat != final tokens");
        coord.shutdown();
    }

    #[test]
    fn cancellation_finishes_early_with_partial_output() {
        let coord = Coordinator::start(test_cfg(1, 8), sim_factory(0.5));
        let h = coord
            .try_submit(vec![1, 2, 3], GenParams::simple(4096, 0.6))
            .unwrap();
        // Cancel after the first chunk arrives.
        let resp = loop {
            match h.events.recv().unwrap() {
                GenEvent::Chunk { .. } => h.cancel.cancel(),
                GenEvent::Done(resp) => break *resp,
            }
        };
        assert_eq!(resp.finish, FinishReason::Cancelled);
        assert!(
            resp.tokens.len() < 4096,
            "cancelled request ran to completion"
        );
        assert_eq!(coord.metrics.cancelled(), 1);
        assert_eq!(coord.metrics.completed(), 0);
        coord.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut cfg = test_cfg(1, 2);
        cfg.engine.tree_budget = 4;
        let coord = Coordinator::start(cfg, sim_factory(0.5));
        let mut rejected = false;
        let mut pending = Vec::new();
        for i in 0..64 {
            match coord.try_submit(vec![i, 2, 3], GenParams::simple(64, 0.6)) {
                Ok(h) => pending.push(h),
                Err(_) => {
                    rejected = true;
                    break;
                }
            }
        }
        assert!(rejected, "queue of capacity 2 never pushed back");
        for h in pending {
            let _ = h.wait();
        }
        assert!(coord.metrics.rejected() >= 1);
        coord.shutdown();
    }

    fn continuous_cfg(max_active: usize, capacity: usize) -> Config {
        let mut cfg = test_cfg(1, capacity);
        cfg.sched.kind = crate::config::SchedKind::Continuous;
        cfg.sched.max_active = max_active;
        cfg.sched.idle_tick_ms = 5;
        cfg
    }

    #[test]
    fn continuous_serves_concurrent_requests_on_one_worker() {
        let coord =
            Coordinator::start(continuous_cfg(8, 32), sim_factory(0.5));
        let handles: Vec<_> = (0..8)
            .map(|i| {
                coord
                    .try_submit(vec![1 + i, 2, 3], GenParams::simple(12, 0.6))
                    .unwrap()
            })
            .collect();
        for h in handles {
            let resp = h.wait().unwrap();
            assert_eq!(resp.tokens.len(), 12);
            assert!(resp.emitted_per_step >= 1.0);
        }
        assert_eq!(coord.metrics.completed(), 8);
        assert_eq!(coord.metrics.total_tokens(), 8 * 12);
        // the whole point: dispatches served more than one sequence each
        assert!(
            coord.metrics.batch_occupancy() > 1.0,
            "occupancy {} not batched",
            coord.metrics.batch_occupancy()
        );
        assert_eq!(coord.metrics.tokens_in_flight(), 0);
        coord.shutdown();
    }

    #[test]
    fn continuous_shutdown_drains_in_flight_sequences() {
        let coord =
            Coordinator::start(continuous_cfg(8, 32), sim_factory(0.5));
        let handles: Vec<_> = (0..6)
            .map(|i| {
                coord
                    .try_submit(vec![9 + i, 8, 7], GenParams::simple(16, 0.6))
                    .unwrap()
            })
            .collect();
        // Shut down immediately: in-flight + queued sequences must still
        // complete (the batcher drains instead of dropping).
        coord.shutdown();
        for h in handles {
            let resp = h.wait().expect("request dropped during shutdown");
            assert_eq!(resp.tokens.len(), 16);
        }
    }

    #[test]
    fn deterministic_tokens_for_same_request() {
        let coord = Coordinator::start(test_cfg(1, 8), sim_factory(0.4));
        let a = coord.generate(vec![5, 6, 7], 10, 0.0).unwrap();
        let b = coord.generate(vec![5, 6, 7], 10, 0.0).unwrap();
        // temp 0 + same sim spec: identical greedy continuations
        assert_eq!(a.tokens, b.tokens);
        coord.shutdown();
    }

    #[test]
    fn per_request_seed_pins_sampled_streams() {
        let coord = Coordinator::start(test_cfg(1, 8), sim_factory(0.5));
        let params = GenParams {
            seed: Some(1234),
            ..GenParams::simple(12, 0.6)
        };
        let a = coord
            .try_submit(vec![4, 5], params.clone())
            .unwrap()
            .wait()
            .unwrap();
        let b = coord
            .try_submit(vec![4, 5], params)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(a.tokens, b.tokens, "seeded requests diverged at temp 0.6");
        coord.shutdown();
    }
}
