//! Worker loop. Each worker owns one (draft, target) model pair built via
//! the `ModelFactory` and serves the shared queue with the configured
//! scheduler:
//!
//!   - `scheduler = fcfs` — pull one request at a time and run the
//!     speculative engine to completion (the classic loop);
//!   - `scheduler = continuous` — run a step-level batcher that multiplexes
//!     up to `sched.max_active` sequences per target dispatch
//!     (`sched::Batcher`).
//!
//! Both stream: every speculation round's accepted chunk is pushed through
//! the request's event channel as it lands (`GenEvent::Chunk`), and the
//! final `GenEvent::Done` carries the aggregate `Response`. Both honor the
//! request's `CancelToken` at round granularity — a cancelled request is
//! finished early with `FinishReason::Cancelled`, its partial output
//! attached, and its scheduler slot + KV residency released immediately.
//!
//! Both poll the queue with `sched.idle_tick_ms` while idle so shutdown is
//! observed, and both drain: FCFS finishes the buffered queue before
//! exiting, the batcher additionally finishes every in-flight sequence.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::metrics::Metrics;
use super::queue::Request;
use super::ModelFactory;
use crate::config::{Config, SchedKind};
use crate::engine::{EventSink, FinishReason, GenEvent, Response, SpecEngine};
use crate::log_debug;
use crate::models::LogitModel;
use crate::obs::Observatory;
use crate::sched::Batcher;

pub fn run_worker(
    wid: usize,
    cfg: Config,
    factory: ModelFactory,
    rx: Arc<Mutex<mpsc::Receiver<Request>>>,
    metrics: Arc<Metrics>,
    obs: Arc<Observatory>,
    shutdown: Arc<AtomicBool>,
) {
    let (draft, target) = factory();
    match cfg.sched.kind {
        SchedKind::Continuous => {
            let mut batcher = Batcher::new(wid, cfg, draft, target, metrics)
                .with_obs(obs);
            batcher.run(&rx, &shutdown);
        }
        SchedKind::Fcfs => {
            run_fcfs(wid, cfg, draft, target, rx, metrics, obs, shutdown)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_fcfs(
    wid: usize,
    cfg: Config,
    draft: Box<dyn LogitModel>,
    target: Box<dyn LogitModel>,
    rx: Arc<Mutex<mpsc::Receiver<Request>>>,
    metrics: Arc<Metrics>,
    obs: Arc<Observatory>,
    shutdown: Arc<AtomicBool>,
) {
    let mut engine = SpecEngine::new(draft, target, cfg.engine.clone(), cfg.regime)
        .with_cache(&cfg.cache)
        .with_adapt(&cfg.adapt)
        .with_obs(obs, wid);
    let idle = Duration::from_millis(cfg.sched.idle_tick_ms.max(1));
    log_debug!("worker {wid} up (fcfs, policy={})", cfg.engine.policy);

    loop {
        // Pull one request; poll with the idle tick so shutdown is observed
        // even while the queue is empty.
        let req = {
            let guard = rx.lock().expect("queue receiver poisoned");
            guard.recv_timeout(idle)
        };
        match req {
            Ok(req) => serve_one(wid, &cfg, &mut engine, req, &metrics),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    log_debug!("worker {wid} down");
}

/// Run one request to completion (or cancellation) on the FCFS engine,
/// streaming chunk events as rounds land.
fn serve_one(
    wid: usize,
    cfg: &Config,
    engine: &mut SpecEngine,
    req: Request,
    metrics: &Arc<Metrics>,
) {
    let queue_secs = req.submitted_at.elapsed().as_secs_f64();

    // Cancelled while still queued: release the slot without spinning up
    // the engine, but still close the stream with a `Done`.
    if req.cancel.is_cancelled() {
        metrics.on_started(queue_secs); // it did leave the queue
        metrics.on_cancelled();
        let _ = req.events.send(GenEvent::Done(Box::new(Response {
            id: req.id,
            worker: wid,
            tokens: Vec::new(),
            steps: 0,
            emitted_per_step: 0.0,
            queue_secs,
            gen_secs: 0.0,
            ttft_secs: 0.0,
            virtual_secs: 0.0,
            cache_hits: 0,
            finish: FinishReason::Cancelled,
        })));
        return;
    }
    metrics.on_started(queue_secs);

    // Per-request parameters over the worker's base engine config.
    engine.cfg.target_temp = req.params.temperature;
    engine.cfg.max_new_tokens = req.params.max_new_tokens;
    engine.cfg.stop_tokens = if req.params.stop_tokens.is_empty() {
        cfg.engine.stop_tokens.clone()
    } else {
        req.params.stop_tokens.clone()
    };
    engine.cfg.tree_budget = match req.params.token_budget {
        Some(cap) if cap > 0 => cfg.engine.tree_budget.min(cap),
        _ => cfg.engine.tree_budget,
    };
    // `drafter` pins the request's rounds; `None` leaves resolution to
    // the engine (adaptive controller when enabled, else the worker's
    // configured policy).
    engine.set_request_drafter(req.params.drafter);
    if let Some(seed) = req.params.seed {
        engine.reseed(seed);
    }
    // Tag this request's round spans with its admission-minted trace id
    // (0 when tracing is off — the observatory then records no spans).
    engine.set_trace(req.trace);

    let t = Instant::now();
    let mut ttft_secs = 0.0f64;
    let mut chunks = 0u64;
    let (stats, finish) = {
        let events = &req.events;
        let metrics_ref = metrics.as_ref();
        engine.generate_streamed(&req.prompt, Some(&req.cancel), |ev| {
            if chunks == 0 {
                // TTFT = queue wait + wall time to the first emitted chunk
                // (the token actually leaves the server here).
                ttft_secs = queue_secs + t.elapsed().as_secs_f64();
                metrics_ref.on_first_token(ttft_secs);
            }
            chunks += 1;
            metrics_ref.on_chunk();
            // Receiver may have given up; generation still completes (the
            // cancel path is explicit, not inferred from a closed channel).
            let _ = events.send(ev);
        })
    };
    let gen_secs = t.elapsed().as_secs_f64();

    let virtual_secs = stats.total_virtual_secs();
    let spec_tokens: u64 = stats.steps.iter().map(|s| s.tree_size as u64).sum();
    let steps = stats.steps.len() as u64;
    metrics.on_dispatches(
        steps,
        steps, // occupancy 1: each dispatch serves one sequence
        spec_tokens,
        steps * engine.cfg.tree_budget as u64,
        virtual_secs,
    );
    metrics.on_cache(
        stats.total_cached_positions(),
        stats.total_billed_positions(),
        engine.cache().used_blocks() as u64,
    );
    // FCFS chunked prefill: the generation is synchronous, so by the time
    // stats land here every chunk has already committed — the in-flight
    // gauge stays 0 and only the totals accrue.
    let prefill_chunks = stats.total_prefill_chunks();
    if prefill_chunks > 0 {
        metrics.on_prefill(prefill_chunks, stats.total_prefill_tokens());
    }
    // One radix admission per FCFS generation (the engine re-admits its
    // sequence at the first round); warm tokens come from the per-step
    // aggregate, which is nonzero only on that first step.
    if engine.cache().radix_enabled() {
        let warm = stats.total_warm_start_tokens();
        let g = engine.cache().radix_gauges();
        metrics.on_radix(
            1,
            (warm > 0) as u64,
            warm,
            g.nodes as u64,
            g.depth_tokens as u64,
            g.shared_blocks as u64,
        );
    }
    match finish {
        FinishReason::Cancelled => metrics.on_cancelled(),
        _ => metrics.on_completed(stats.tokens.len(), gen_secs),
    }

    let resp = Response {
        id: req.id,
        worker: wid,
        steps: stats.steps.len(),
        emitted_per_step: stats.mean_emitted_per_step(),
        cache_hits: stats.total_cached_positions(),
        tokens: stats.tokens,
        queue_secs,
        gen_secs,
        ttft_secs,
        virtual_secs,
        finish,
    };
    // Receiver may have given up; that's fine.
    let _ = req.events.send(GenEvent::Done(Box::new(resp)));
}
