//! Worker loop: pull requests FCFS from the shared queue, run the
//! speculative engine, send responses. One engine (and model pair) per
//! worker thread, constructed via the `ModelFactory`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::metrics::Metrics;
use super::queue::{Request, Response};
use super::ModelFactory;
use crate::config::Config;
use crate::engine::SpecEngine;
use crate::log_debug;

pub fn run_worker(
    wid: usize,
    cfg: Config,
    factory: ModelFactory,
    rx: Arc<Mutex<mpsc::Receiver<Request>>>,
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
) {
    let (draft, target) = factory();
    let mut engine = SpecEngine::new(draft, target, cfg.engine.clone(), cfg.regime);
    log_debug!("worker {wid} up (policy={})", cfg.engine.policy);

    loop {
        // Pull one request; poll with timeout so shutdown is observed even
        // while the queue is idle.
        let req = {
            let guard = rx.lock().expect("queue receiver poisoned");
            guard.recv_timeout(Duration::from_millis(50))
        };
        match req {
            Ok(req) => {
                let queue_secs = req.submitted_at.elapsed().as_secs_f64();
                metrics.on_started(queue_secs);

                engine.cfg.target_temp = req.temperature;
                engine.cfg.max_new_tokens = req.max_new_tokens;

                let t = Instant::now();
                let stats = engine.generate(&req.prompt);
                let gen_secs = t.elapsed().as_secs_f64();

                metrics.on_completed(stats.tokens.len(), gen_secs);
                let resp = Response {
                    id: req.id,
                    worker: wid,
                    steps: stats.steps.len(),
                    emitted_per_step: stats.mean_emitted_per_step(),
                    tokens: stats.tokens,
                    queue_secs,
                    gen_secs,
                };
                // Receiver may have given up; that's fine.
                let _ = req.respond.send(resp);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    log_debug!("worker {wid} down");
}
