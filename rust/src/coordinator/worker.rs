//! Worker loop. Each worker owns one (draft, target) model pair built via
//! the `ModelFactory` and serves the shared queue with the configured
//! scheduler:
//!
//!   - `scheduler = fcfs` — pull one request at a time and run the
//!     speculative engine to completion (the classic loop);
//!   - `scheduler = continuous` — run a step-level batcher that multiplexes
//!     up to `sched.max_active` sequences per target dispatch
//!     (`sched::Batcher`).
//!
//! Both poll the queue with `sched.idle_tick_ms` while idle so shutdown is
//! observed, and both drain: FCFS finishes the buffered queue before
//! exiting, the batcher additionally finishes every in-flight sequence.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::metrics::Metrics;
use super::queue::{Request, Response};
use super::ModelFactory;
use crate::config::{Config, SchedKind};
use crate::engine::SpecEngine;
use crate::log_debug;
use crate::models::LogitModel;
use crate::sched::Batcher;

pub fn run_worker(
    wid: usize,
    cfg: Config,
    factory: ModelFactory,
    rx: Arc<Mutex<mpsc::Receiver<Request>>>,
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
) {
    let (draft, target) = factory();
    match cfg.sched.kind {
        SchedKind::Continuous => {
            let mut batcher = Batcher::new(wid, cfg, draft, target, metrics);
            batcher.run(&rx, &shutdown);
        }
        SchedKind::Fcfs => {
            run_fcfs(wid, cfg, draft, target, rx, metrics, shutdown)
        }
    }
}

fn run_fcfs(
    wid: usize,
    cfg: Config,
    draft: Box<dyn LogitModel>,
    target: Box<dyn LogitModel>,
    rx: Arc<Mutex<mpsc::Receiver<Request>>>,
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
) {
    let mut engine = SpecEngine::new(draft, target, cfg.engine.clone(), cfg.regime)
        .with_cache(&cfg.cache);
    let idle = Duration::from_millis(cfg.sched.idle_tick_ms.max(1));
    log_debug!("worker {wid} up (fcfs, policy={})", cfg.engine.policy);

    loop {
        // Pull one request; poll with the idle tick so shutdown is observed
        // even while the queue is empty.
        let req = {
            let guard = rx.lock().expect("queue receiver poisoned");
            guard.recv_timeout(idle)
        };
        match req {
            Ok(req) => {
                let queue_secs = req.submitted_at.elapsed().as_secs_f64();
                metrics.on_started(queue_secs);

                engine.cfg.target_temp = req.temperature;
                engine.cfg.max_new_tokens = req.max_new_tokens;

                let t = Instant::now();
                let stats = engine.generate(&req.prompt);
                let gen_secs = t.elapsed().as_secs_f64();

                // TTFT = queue wait + the first engine step's wall time.
                let ttft_secs = queue_secs
                    + stats.steps.first().map(|s| s.times.total()).unwrap_or(0.0);
                metrics.on_first_token(ttft_secs);
                let virtual_secs = stats.total_virtual_secs();
                let spec_tokens: u64 =
                    stats.steps.iter().map(|s| s.tree_size as u64).sum();
                let steps = stats.steps.len() as u64;
                metrics.on_dispatches(
                    steps,
                    steps, // occupancy 1: each dispatch serves one sequence
                    spec_tokens,
                    steps * cfg.engine.tree_budget as u64,
                    virtual_secs,
                );
                metrics.on_cache(
                    stats.total_cached_positions(),
                    stats.total_billed_positions(),
                    engine.cache().used_blocks() as u64,
                );
                metrics.on_completed(stats.tokens.len(), gen_secs);

                let resp = Response {
                    id: req.id,
                    worker: wid,
                    steps: stats.steps.len(),
                    emitted_per_step: stats.mean_emitted_per_step(),
                    cache_hits: stats.total_cached_positions(),
                    tokens: stats.tokens,
                    queue_secs,
                    gen_secs,
                    ttft_secs,
                    virtual_secs,
                };
                // Receiver may have given up; that's fine.
                let _ = req.respond.send(resp);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    log_debug!("worker {wid} down");
}
