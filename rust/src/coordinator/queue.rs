//! Bounded admission queue with fail-fast backpressure.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use super::metrics::Metrics;

/// One admitted generation request.
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub submitted_at: Instant,
    pub respond: mpsc::Sender<Response>,
}

/// Completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub worker: usize,
    pub tokens: Vec<u32>,
    /// Engine steps taken (target-model dispatches).
    pub steps: usize,
    pub emitted_per_step: f64,
    /// Seconds spent queued before a worker picked the request up.
    pub queue_secs: f64,
    /// Seconds of engine time.
    pub gen_secs: f64,
    /// Seconds from submission to the first emitted token (queue wait
    /// included) — the serving-layer TTFT.
    pub ttft_secs: f64,
    /// Virtual hardware-regime seconds this request experienced (sum of
    /// the step costs of every dispatch it took part in; 0 without a
    /// regime). Under continuous batching a dispatch's cost is shared by
    /// all co-batched sequences, so this is the per-request latency the
    /// serving bench compares across schedulers.
    pub virtual_secs: f64,
    /// Prefix positions this request served from the KV cache across its
    /// dispatches (its share of the worker's hit-rate metric).
    pub cache_hits: u64,
}

/// Sender half (held by the coordinator/server).
pub struct RequestQueue {
    tx: Option<mpsc::SyncSender<Request>>,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
}

impl RequestQueue {
    pub fn new(capacity: usize, metrics: Arc<Metrics>) -> (Self, mpsc::Receiver<Request>) {
        let (tx, rx) = mpsc::sync_channel(capacity.max(1));
        (
            Self {
                tx: Some(tx),
                next_id: AtomicU64::new(1),
                metrics,
            },
            rx,
        )
    }

    /// Admit a request or reject immediately if the queue is full
    /// (backpressure — the caller decides whether to retry).
    pub fn try_submit(
        &self,
        prompt: Vec<u32>,
        max_new_tokens: usize,
        temperature: f32,
    ) -> Result<mpsc::Receiver<Response>, String> {
        if prompt.is_empty() {
            return Err("empty prompt".into());
        }
        let (respond, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            prompt,
            max_new_tokens,
            temperature,
            submitted_at: Instant::now(),
            respond,
        };
        let tx = self.tx.as_ref().ok_or("queue closed")?;
        match tx.try_send(req) {
            Ok(()) => {
                self.metrics.on_admitted();
                Ok(rx)
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.on_rejected();
                Err("queue full".into())
            }
            Err(mpsc::TrySendError::Disconnected(_)) => Err("queue closed".into()),
        }
    }

    /// Close the queue: workers drain remaining requests, then exit.
    pub fn close(&mut self) {
        self.tx = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_empty_prompt() {
        let metrics = Arc::new(Metrics::new());
        let (q, _rx) = RequestQueue::new(4, metrics);
        assert!(q.try_submit(vec![], 8, 0.0).is_err());
    }

    #[test]
    fn ids_are_unique_and_increasing() {
        let metrics = Arc::new(Metrics::new());
        let (q, rx) = RequestQueue::new(4, metrics);
        q.try_submit(vec![1], 8, 0.0).unwrap();
        q.try_submit(vec![2], 8, 0.0).unwrap();
        let a = rx.recv().unwrap();
        let b = rx.recv().unwrap();
        assert!(b.id > a.id);
    }

    #[test]
    fn full_queue_rejects_and_counts() {
        let metrics = Arc::new(Metrics::new());
        let (q, _rx) = RequestQueue::new(1, metrics.clone());
        q.try_submit(vec![1], 8, 0.0).unwrap();
        assert!(q.try_submit(vec![2], 8, 0.0).is_err());
        assert_eq!(metrics.rejected(), 1);
        assert_eq!(metrics.admitted(), 1);
    }

    #[test]
    fn close_disconnects() {
        let metrics = Arc::new(Metrics::new());
        let (mut q, rx) = RequestQueue::new(1, metrics);
        q.close();
        assert!(q.try_submit(vec![1], 8, 0.0).is_err());
        assert!(rx.recv().is_err());
    }
}
