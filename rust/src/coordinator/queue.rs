//! Bounded admission queue with fail-fast backpressure.
//!
//! Since the streaming redesign (DESIGN.md §Serving API v1) a request no
//! longer carries a one-shot response sender: it carries an *event* sink
//! ([`GenEvent`] per speculation round, then `Done`) and a shared
//! [`CancelToken`]. Two submission surfaces share one admission path:
//!
//!   - [`RequestQueue::try_submit`] — the in-process API: builds an mpsc
//!     pair and returns a [`RequestHandle`] owning the receiving half and
//!     the token (dropping the handle does NOT cancel the request; the
//!     server cancels explicitly on client disconnect);
//!   - [`RequestQueue::try_submit_sink`] — the reactor transport: the
//!     caller supplies its own [`EventSink`] (a connection outbox), so
//!     worker events land there directly with no forwarder thread.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use super::metrics::Metrics;

pub use crate::engine::events::{
    CancelToken, EventSink, FinishReason, GenEvent, GenParams, Response,
    RoundStats,
};

/// One admitted generation request.
pub struct Request {
    /// Server-side id (unique per coordinator; protocol-v1 clients use
    /// their own `req_id` namespace per connection, mapped by the server).
    pub id: u64,
    pub prompt: Vec<u32>,
    pub params: GenParams,
    pub submitted_at: Instant,
    /// Cooperative cancellation: checked by workers between rounds.
    pub cancel: CancelToken,
    /// Per-request event stream: chunks, then exactly one `Done`.
    pub events: Box<dyn EventSink>,
    /// Trace id minted at admission when tracing is enabled (0 when off).
    /// Workers tag the request's round spans with it; wire sinks echo it
    /// in every frame.
    pub trace: u64,
}

/// Submitter's half of an admitted request.
pub struct RequestHandle {
    pub id: u64,
    pub events: mpsc::Receiver<GenEvent>,
    pub cancel: CancelToken,
}

impl RequestHandle {
    /// Drain the stream to completion and return the final response
    /// (the legacy blocking call, now a fold over events).
    pub fn wait(self) -> Result<Response, String> {
        loop {
            match self.events.recv() {
                Ok(GenEvent::Done(resp)) => return Ok(*resp),
                Ok(GenEvent::Chunk { .. }) => continue,
                Err(_) => return Err("worker dropped request".into()),
            }
        }
    }
}

/// Sender half (held by the coordinator/server).
pub struct RequestQueue {
    /// Interior-mutable so the router tier can close one shard's queue
    /// (worker kill / drain) through a shared reference.
    tx: Mutex<Option<mpsc::SyncSender<Request>>>,
    /// Id source — per-queue by default; the router shares ONE counter
    /// across all shard queues (via [`RequestQueue::with_ids`]) so ids
    /// stay unique per coordinator no matter which worker owns them.
    next_id: Arc<AtomicU64>,
    metrics: Arc<Metrics>,
    tracing: bool,
}

impl RequestQueue {
    pub fn new(capacity: usize, metrics: Arc<Metrics>) -> (Self, mpsc::Receiver<Request>) {
        let (tx, rx) = mpsc::sync_channel(capacity.max(1));
        (
            Self {
                tx: Mutex::new(Some(tx)),
                next_id: Arc::new(AtomicU64::new(1)),
                metrics,
                tracing: false,
            },
            rx,
        )
    }

    /// Enable trace-id minting at admission (`obs.trace = on`). Off by
    /// default so existing construction sites and tests are unchanged.
    pub fn with_tracing(mut self, tracing: bool) -> Self {
        self.tracing = tracing;
        self
    }

    /// Mint ids from a shared counter instead of this queue's own. The
    /// router tier hands every shard queue the same counter, preserving
    /// the "unique and increasing per coordinator" id contract of the
    /// single-queue era.
    pub fn with_ids(mut self, ids: Arc<AtomicU64>) -> Self {
        self.next_id = ids;
        self
    }

    /// Admit a request or reject immediately if the queue is full
    /// (backpressure — the caller decides whether to retry).
    pub fn try_submit(
        &self,
        prompt: Vec<u32>,
        params: GenParams,
    ) -> Result<RequestHandle, String> {
        let (events, rx) = mpsc::channel();
        let (id, cancel) =
            self.try_submit_sink(prompt, params, Box::new(events))?;
        Ok(RequestHandle {
            id,
            events: rx,
            cancel,
        })
    }

    /// Admit a request whose events go to a caller-supplied sink (the
    /// reactor transport's connection outbox). Returns the server-side id
    /// and the shared cancel token.
    pub fn try_submit_sink(
        &self,
        prompt: Vec<u32>,
        params: GenParams,
        events: Box<dyn EventSink>,
    ) -> Result<(u64, CancelToken), String> {
        if prompt.is_empty() {
            return Err("empty prompt".into());
        }
        if params.max_new_tokens == 0 {
            return Err("max_new_tokens must be >= 1".into());
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let cancel = CancelToken::new();
        // Mint the trace id before enqueueing so the sink knows it for
        // every frame it will ever emit (no chunk/attach race). With
        // tracing off nothing is minted or attached: the wire stream is
        // bit-identical to a build without observability.
        let trace = if self.tracing {
            let t = crate::obs::TraceId::mint(id);
            events.attach_trace(t.0);
            t.0
        } else {
            0
        };
        let req = Request {
            id,
            prompt,
            params,
            submitted_at: Instant::now(),
            cancel: cancel.clone(),
            events,
            trace,
        };
        // Clone the sender out of the lock so a closing shard never
        // blocks behind an in-flight try_send (the transient clone keeps
        // the channel open only for the duration of this call).
        let tx = self
            .tx
            .lock()
            .unwrap()
            .clone()
            .ok_or("queue closed")?;
        match tx.try_send(req) {
            Ok(()) => {
                self.metrics.on_admitted();
                Ok((id, cancel))
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.on_rejected();
                Err("queue full".into())
            }
            Err(mpsc::TrySendError::Disconnected(_)) => Err("queue closed".into()),
        }
    }

    /// Close the queue: workers drain remaining requests, then exit.
    /// Shared-reference so the router can close one shard at a time
    /// (worker kill) as well as all of them (coordinator shutdown).
    pub fn close(&self) {
        *self.tx.lock().unwrap() = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_empty_prompt_and_zero_length() {
        let metrics = Arc::new(Metrics::new());
        let (q, _rx) = RequestQueue::new(4, metrics);
        assert!(q.try_submit(vec![], GenParams::simple(8, 0.0)).is_err());
        assert!(q.try_submit(vec![1], GenParams::simple(0, 0.0)).is_err());
    }

    #[test]
    fn ids_are_unique_and_increasing() {
        let metrics = Arc::new(Metrics::new());
        let (q, rx) = RequestQueue::new(4, metrics);
        q.try_submit(vec![1], GenParams::simple(8, 0.0)).unwrap();
        q.try_submit(vec![2], GenParams::simple(8, 0.0)).unwrap();
        let a = rx.recv().unwrap();
        let b = rx.recv().unwrap();
        assert!(b.id > a.id);
    }

    #[test]
    fn full_queue_rejects_and_counts() {
        let metrics = Arc::new(Metrics::new());
        let (q, _rx) = RequestQueue::new(1, metrics.clone());
        q.try_submit(vec![1], GenParams::simple(8, 0.0)).unwrap();
        assert!(q.try_submit(vec![2], GenParams::simple(8, 0.0)).is_err());
        assert_eq!(metrics.rejected(), 1);
        assert_eq!(metrics.admitted(), 1);
    }

    #[test]
    fn shared_id_counter_spans_queues() {
        let metrics = Arc::new(Metrics::new());
        let ids = Arc::new(AtomicU64::new(1));
        let (qa, rxa) = RequestQueue::new(4, metrics.clone());
        let qa = qa.with_ids(ids.clone());
        let (qb, rxb) = RequestQueue::new(4, metrics);
        let qb = qb.with_ids(ids);
        qa.try_submit(vec![1], GenParams::simple(8, 0.0)).unwrap();
        qb.try_submit(vec![2], GenParams::simple(8, 0.0)).unwrap();
        let a = rxa.recv().unwrap();
        let b = rxb.recv().unwrap();
        assert_eq!(b.id, a.id + 1, "shard queues must share one id space");
    }

    #[test]
    fn close_disconnects() {
        let metrics = Arc::new(Metrics::new());
        let (q, rx) = RequestQueue::new(1, metrics);
        q.close();
        assert!(q.try_submit(vec![1], GenParams::simple(8, 0.0)).is_err());
        assert!(rx.recv().is_err());
    }

    #[test]
    fn trace_ids_are_minted_only_when_tracing_is_on() {
        let metrics = Arc::new(Metrics::new());
        let (q, rx) = RequestQueue::new(2, metrics.clone());
        q.try_submit(vec![1], GenParams::simple(8, 0.0)).unwrap();
        assert_eq!(rx.recv().unwrap().trace, 0);

        let (q, rx) = RequestQueue::new(2, metrics).with_tracing(true);
        q.try_submit(vec![1], GenParams::simple(8, 0.0)).unwrap();
        q.try_submit(vec![2], GenParams::simple(8, 0.0)).unwrap();
        let a = rx.recv().unwrap();
        let b = rx.recv().unwrap();
        assert_ne!(a.trace, 0);
        assert_ne!(b.trace, 0);
        assert_ne!(a.trace, b.trace);
        assert_eq!(a.trace, crate::obs::TraceId::mint(a.id).0);
    }

    #[test]
    fn cancel_token_is_shared_with_the_worker_side() {
        let metrics = Arc::new(Metrics::new());
        let (q, rx) = RequestQueue::new(1, metrics);
        let handle = q.try_submit(vec![1], GenParams::simple(8, 0.0)).unwrap();
        handle.cancel.cancel();
        let req = rx.recv().unwrap();
        assert!(req.cancel.is_cancelled());
    }
}
