//! Aggregate serving metrics: admission/rejection counters, completed
//! requests, token throughput, queue-wait and generation-latency
//! histograms. Lock granularity is coarse (one mutex per histogram) —
//! recording happens once per request, far off the token hot path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::Histogram;

pub struct Metrics {
    started_at: Instant,
    admitted: AtomicU64,
    rejected: AtomicU64,
    started: AtomicU64,
    completed: AtomicU64,
    tokens: AtomicU64,
    queue_wait: Mutex<Histogram>,
    gen_latency: Mutex<Histogram>,
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            started_at: Instant::now(),
            admitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            started: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            tokens: AtomicU64::new(0),
            queue_wait: Mutex::new(Histogram::new()),
            gen_latency: Mutex::new(Histogram::new()),
        }
    }

    pub fn on_admitted(&self) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_started(&self, queue_secs: f64) {
        self.started.fetch_add(1, Ordering::Relaxed);
        self.queue_wait.lock().unwrap().record(queue_secs);
    }

    pub fn on_completed(&self, tokens: usize, gen_secs: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.tokens.fetch_add(tokens as u64, Ordering::Relaxed);
        self.gen_latency.lock().unwrap().record(gen_secs);
    }

    pub fn admitted(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn total_tokens(&self) -> u64 {
        self.tokens.load(Ordering::Relaxed)
    }

    /// Pending = admitted − started (queued, not yet picked up).
    pub fn queue_depth(&self) -> u64 {
        self.admitted()
            .saturating_sub(self.started.load(Ordering::Relaxed))
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let secs = self.started_at.elapsed().as_secs_f64().max(1e-9);
        self.total_tokens() as f64 / secs
    }

    /// Snapshot as JSON (served by the `stats` protocol command).
    pub fn snapshot(&self) -> Json {
        let mut qw = self.queue_wait.lock().unwrap().clone();
        let mut gl = self.gen_latency.lock().unwrap().clone();
        Json::obj(vec![
            ("admitted", Json::Num(self.admitted() as f64)),
            ("rejected", Json::Num(self.rejected() as f64)),
            ("completed", Json::Num(self.completed() as f64)),
            ("queue_depth", Json::Num(self.queue_depth() as f64)),
            ("total_tokens", Json::Num(self.total_tokens() as f64)),
            ("tokens_per_sec", Json::Num(self.tokens_per_sec())),
            ("queue_wait_p50", Json::Num(qw.p50())),
            ("queue_wait_p99", Json::Num(qw.p99())),
            ("gen_latency_p50", Json::Num(gl.p50())),
            ("gen_latency_p99", Json::Num(gl.p99())),
        ])
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_flow() {
        let m = Metrics::new();
        m.on_admitted();
        m.on_admitted();
        m.on_rejected();
        m.on_started(0.1);
        m.on_completed(128, 2.0);
        assert_eq!(m.admitted(), 2);
        assert_eq!(m.rejected(), 1);
        assert_eq!(m.completed(), 1);
        assert_eq!(m.total_tokens(), 128);
        assert_eq!(m.queue_depth(), 1);
    }

    #[test]
    fn snapshot_is_json_object() {
        let m = Metrics::new();
        m.on_admitted();
        m.on_started(0.5);
        m.on_completed(10, 1.0);
        let snap = m.snapshot();
        assert_eq!(snap.get("completed").unwrap().as_usize(), Some(1));
        assert_eq!(snap.get("total_tokens").unwrap().as_usize(), Some(10));
        assert!(snap.get("gen_latency_p50").unwrap().as_f64().unwrap() > 0.0);
    }
}
