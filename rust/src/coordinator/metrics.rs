//! Aggregate serving metrics: admission/rejection counters, completed
//! requests, token throughput, queue-wait and generation-latency
//! histograms. Lock granularity is coarse (one mutex per histogram) —
//! recording happens once per request, far off the token hot path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::{AtomicF64, Histogram};

pub struct Metrics {
    started_at: Instant,
    admitted: AtomicU64,
    rejected: AtomicU64,
    started: AtomicU64,
    completed: AtomicU64,
    /// Requests finished early by cancellation (client command or
    /// disconnect) — not counted in `completed`.
    cancelled: AtomicU64,
    /// Chunk events streamed across all requests (one per speculation
    /// round per request).
    chunks: AtomicU64,
    tokens: AtomicU64,
    queue_wait: Mutex<Histogram>,
    gen_latency: Mutex<Histogram>,
    ttft: Mutex<Histogram>,
    /// Tokens already generated for requests still in flight (gauge).
    tokens_in_flight: AtomicU64,
    /// Target verification dispatches across all workers.
    dispatches: AtomicU64,
    /// Σ over dispatches of the sequences each one served (occupancy num).
    seq_steps: AtomicU64,
    /// Σ speculated tokens actually allocated / Σ budget offered.
    budget_used: AtomicU64,
    budget_total: AtomicU64,
    /// Virtual hardware-regime seconds consumed (full-precision atomic
    /// f64 accumulator — sub-microsecond costs are never truncated).
    virtual_secs: AtomicF64,
    /// KV-cache accounting: prefix positions served from residency vs
    /// verification positions actually computed, and the current
    /// resident-block gauge (DESIGN.md §KV cache).
    cache_hit_positions: AtomicU64,
    cache_billed_positions: AtomicU64,
    cache_resident_blocks: AtomicU64,
    /// Reactor transport (DESIGN.md §Transport): open-connection gauge,
    /// connections refused by `max_conns` admission control, frames
    /// currently queued across all connection outboxes, connections
    /// closed because a client stopped draining (outbox overflow), and
    /// the fixed event-loop pool size — the "threads are O(pool), not
    /// O(connections)" invariant, readable over the stats surface.
    open_conns: AtomicU64,
    conns_rejected: AtomicU64,
    outbox_frames: AtomicU64,
    backpressure_closed: AtomicU64,
    transport_threads: AtomicU64,
    /// Router tier (DESIGN.md §Router Tier): requests routed to a shard
    /// (admitted through the ring or rr cursor), requests spilled off an
    /// overloaded owner to the least-loaded healthy worker, and failover
    /// events (a routing decision that had to skip a dead owner, plus
    /// one count per worker kill).
    router_routed: AtomicU64,
    router_spilled: AtomicU64,
    router_failover: AtomicU64,
    /// Cross-request radix prefix cache (DESIGN.md §Radix Prefix Cache):
    /// admission lookups, lookups that matched a usable shared prefix,
    /// warm-start tokens granted, and the tree-shape gauges (node count,
    /// deepest resident run in tokens, blocks held by the shared tree).
    radix_lookups: AtomicU64,
    radix_hits: AtomicU64,
    radix_warm_tokens: AtomicU64,
    radix_nodes: AtomicU64,
    radix_depth: AtomicU64,
    radix_shared_blocks: AtomicU64,
    /// Chunked prefill (DESIGN.md §Chunked Prefill): chunk rows dispatched,
    /// prompt positions those rows computed, and a gauge of prompt
    /// positions already resident for sequences still mid-prefill.
    prefill_chunks: AtomicU64,
    prefill_tokens: AtomicU64,
    prefill_tokens_in_flight: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            started_at: Instant::now(),
            admitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            started: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            chunks: AtomicU64::new(0),
            tokens: AtomicU64::new(0),
            queue_wait: Mutex::new(Histogram::new()),
            gen_latency: Mutex::new(Histogram::new()),
            ttft: Mutex::new(Histogram::new()),
            tokens_in_flight: AtomicU64::new(0),
            dispatches: AtomicU64::new(0),
            seq_steps: AtomicU64::new(0),
            budget_used: AtomicU64::new(0),
            budget_total: AtomicU64::new(0),
            virtual_secs: AtomicF64::new(0.0),
            cache_hit_positions: AtomicU64::new(0),
            cache_billed_positions: AtomicU64::new(0),
            cache_resident_blocks: AtomicU64::new(0),
            open_conns: AtomicU64::new(0),
            conns_rejected: AtomicU64::new(0),
            outbox_frames: AtomicU64::new(0),
            backpressure_closed: AtomicU64::new(0),
            transport_threads: AtomicU64::new(0),
            router_routed: AtomicU64::new(0),
            router_spilled: AtomicU64::new(0),
            router_failover: AtomicU64::new(0),
            radix_lookups: AtomicU64::new(0),
            radix_hits: AtomicU64::new(0),
            radix_warm_tokens: AtomicU64::new(0),
            radix_nodes: AtomicU64::new(0),
            radix_depth: AtomicU64::new(0),
            radix_shared_blocks: AtomicU64::new(0),
            prefill_chunks: AtomicU64::new(0),
            prefill_tokens: AtomicU64::new(0),
            prefill_tokens_in_flight: AtomicU64::new(0),
        }
    }

    /// Record chunked-prefill work: `chunks` bare prefill rows that
    /// computed `tokens` prompt positions this dispatch.
    pub fn on_prefill(&self, chunks: u64, tokens: u64) {
        self.prefill_chunks.fetch_add(chunks, Ordering::Relaxed);
        self.prefill_tokens.fetch_add(tokens, Ordering::Relaxed);
    }

    /// Publish the mid-prefill in-flight gauge (prompt positions already
    /// computed for sequences that have not yet sampled a token).
    pub fn set_prefill_in_flight(&self, tokens: u64) {
        self.prefill_tokens_in_flight
            .store(tokens, Ordering::Relaxed);
    }

    pub fn prefill_chunks(&self) -> u64 {
        self.prefill_chunks.load(Ordering::Relaxed)
    }

    pub fn prefill_tokens(&self) -> u64 {
        self.prefill_tokens.load(Ordering::Relaxed)
    }

    pub fn prefill_tokens_in_flight(&self) -> u64 {
        self.prefill_tokens_in_flight.load(Ordering::Relaxed)
    }

    /// Record radix prefix-cache activity: `lookups` admission lookups of
    /// which `hits` matched a usable shared prefix granting `warm_tokens`
    /// warm-start tokens, plus the worker's current tree-shape gauges
    /// (last writer wins across workers, fine for a dashboard gauge).
    pub fn on_radix(
        &self,
        lookups: u64,
        hits: u64,
        warm_tokens: u64,
        nodes: u64,
        depth: u64,
        shared_blocks: u64,
    ) {
        self.radix_lookups.fetch_add(lookups, Ordering::Relaxed);
        self.radix_hits.fetch_add(hits, Ordering::Relaxed);
        self.radix_warm_tokens
            .fetch_add(warm_tokens, Ordering::Relaxed);
        self.radix_nodes.store(nodes, Ordering::Relaxed);
        self.radix_depth.store(depth, Ordering::Relaxed);
        self.radix_shared_blocks
            .store(shared_blocks, Ordering::Relaxed);
    }

    pub fn radix_lookups(&self) -> u64 {
        self.radix_lookups.load(Ordering::Relaxed)
    }

    pub fn radix_hits(&self) -> u64 {
        self.radix_hits.load(Ordering::Relaxed)
    }

    pub fn radix_warm_tokens(&self) -> u64 {
        self.radix_warm_tokens.load(Ordering::Relaxed)
    }

    /// Fraction of admission lookups that started warm (0 when the radix
    /// tree is off or nothing was recorded).
    pub fn radix_hit_rate(&self) -> f64 {
        let lookups = self.radix_lookups() as f64;
        if lookups <= 0.0 {
            0.0
        } else {
            self.radix_hits() as f64 / lookups
        }
    }

    /// Router-tier counters (`router/`).
    pub fn on_routed(&self) {
        self.router_routed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_route_spilled(&self) {
        self.router_spilled.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_route_failover(&self) {
        self.router_failover.fetch_add(1, Ordering::Relaxed);
    }

    pub fn router_routed(&self) -> u64 {
        self.router_routed.load(Ordering::Relaxed)
    }

    pub fn router_spilled(&self) -> u64 {
        self.router_spilled.load(Ordering::Relaxed)
    }

    pub fn router_failover(&self) -> u64 {
        self.router_failover.load(Ordering::Relaxed)
    }

    /// Transport gauges/counters (reactor, `server/`).
    pub fn on_conn_open(&self) {
        self.open_conns.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_conn_closed(&self) {
        let _ = self.open_conns.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |cur| Some(cur.saturating_sub(1)),
        );
    }

    pub fn on_conn_rejected(&self) {
        self.conns_rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn outbox_inc(&self) {
        self.outbox_frames.fetch_add(1, Ordering::Relaxed);
    }

    pub fn outbox_dec(&self, n: u64) {
        let _ = self.outbox_frames.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |cur| Some(cur.saturating_sub(n)),
        );
    }

    pub fn on_backpressure_closed(&self) {
        self.backpressure_closed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn set_transport_threads(&self, n: u64) {
        self.transport_threads.store(n, Ordering::Relaxed);
    }

    pub fn open_conns(&self) -> u64 {
        self.open_conns.load(Ordering::Relaxed)
    }

    pub fn conns_rejected(&self) -> u64 {
        self.conns_rejected.load(Ordering::Relaxed)
    }

    pub fn outbox_frames(&self) -> u64 {
        self.outbox_frames.load(Ordering::Relaxed)
    }

    pub fn backpressure_closed(&self) -> u64 {
        self.backpressure_closed.load(Ordering::Relaxed)
    }

    pub fn transport_threads(&self) -> u64 {
        self.transport_threads.load(Ordering::Relaxed)
    }

    pub fn on_admitted(&self) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_started(&self, queue_secs: f64) {
        self.started.fetch_add(1, Ordering::Relaxed);
        self.queue_wait.lock().unwrap().record(queue_secs);
    }

    pub fn on_completed(&self, tokens: usize, gen_secs: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.tokens.fetch_add(tokens as u64, Ordering::Relaxed);
        self.gen_latency.lock().unwrap().record(gen_secs);
    }

    /// Record a request's time-to-first-token (queue wait included).
    pub fn on_first_token(&self, secs: f64) {
        self.ttft.lock().unwrap().record(secs);
    }

    /// Record a request retired by cancellation.
    pub fn on_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one streamed chunk event.
    pub fn on_chunk(&self) {
        self.chunks.fetch_add(1, Ordering::Relaxed);
    }

    pub fn cancelled(&self) -> u64 {
        self.cancelled.load(Ordering::Relaxed)
    }

    pub fn chunks(&self) -> u64 {
        self.chunks.load(Ordering::Relaxed)
    }

    /// Record `dispatches` target dispatches that together served
    /// `seq_steps` sequence-steps, allocated `used` of `budget` speculated
    /// tokens, and cost `virtual_secs` regime seconds. The continuous
    /// batcher calls this once per step with dispatches = 1 and seq_steps =
    /// the batch size; the FCFS worker calls it once per request with
    /// dispatches = seq_steps = the engine step count.
    pub fn on_dispatches(
        &self,
        dispatches: u64,
        seq_steps: u64,
        used: u64,
        budget: u64,
        virtual_secs: f64,
    ) {
        self.dispatches.fetch_add(dispatches, Ordering::Relaxed);
        self.seq_steps.fetch_add(seq_steps, Ordering::Relaxed);
        self.budget_used.fetch_add(used, Ordering::Relaxed);
        self.budget_total.fetch_add(budget, Ordering::Relaxed);
        self.virtual_secs.add(virtual_secs);
    }

    /// Record one dispatch round's KV-cache outcome: `hit` prefix
    /// positions served from residency, `billed` positions computed, and
    /// the worker's current resident-block count (gauge; with several
    /// workers the last writer wins, which is fine for a dashboard gauge).
    pub fn on_cache(&self, hit: u64, billed: u64, resident_blocks: u64) {
        self.cache_hit_positions.fetch_add(hit, Ordering::Relaxed);
        self.cache_billed_positions
            .fetch_add(billed, Ordering::Relaxed);
        self.cache_resident_blocks
            .store(resident_blocks, Ordering::Relaxed);
    }

    /// Refresh the resident-block gauge alone (sequence retirement frees
    /// blocks outside any dispatch, and the leak checks in
    /// rust/tests/protocol_v1.rs read the gauge over the stats surface).
    pub fn on_resident_blocks(&self, resident_blocks: u64) {
        self.cache_resident_blocks
            .store(resident_blocks, Ordering::Relaxed);
    }

    /// Fraction of prefix-or-computed verification positions served from
    /// the KV cache (0 when nothing was recorded).
    pub fn cache_hit_rate(&self) -> f64 {
        let hit = self.cache_hit_positions.load(Ordering::Relaxed) as f64;
        let billed =
            self.cache_billed_positions.load(Ordering::Relaxed) as f64;
        if hit + billed <= 0.0 {
            0.0
        } else {
            hit / (hit + billed)
        }
    }

    pub fn cache_resident_blocks(&self) -> u64 {
        self.cache_resident_blocks.load(Ordering::Relaxed)
    }

    /// Adjust the tokens-in-flight gauge as steps emit (`+`) and requests
    /// retire (`-`).
    pub fn tokens_in_flight_add(&self, n: u64) {
        self.tokens_in_flight.fetch_add(n, Ordering::Relaxed);
    }

    pub fn tokens_in_flight_sub(&self, n: u64) {
        // Saturating: retire may race a concurrent add on another worker.
        let _ = self.tokens_in_flight.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |cur| Some(cur.saturating_sub(n)),
        );
    }

    pub fn tokens_in_flight(&self) -> u64 {
        self.tokens_in_flight.load(Ordering::Relaxed)
    }

    pub fn dispatches(&self) -> u64 {
        self.dispatches.load(Ordering::Relaxed)
    }

    /// Mean sequences served per target dispatch (1.0 for FCFS; > 1 is the
    /// continuous-batching win).
    pub fn batch_occupancy(&self) -> f64 {
        let d = self.dispatches();
        if d == 0 {
            0.0
        } else {
            self.seq_steps.load(Ordering::Relaxed) as f64 / d as f64
        }
    }

    /// Fraction of the offered speculation budget actually allocated.
    pub fn budget_utilization(&self) -> f64 {
        let total = self.budget_total.load(Ordering::Relaxed);
        if total == 0 {
            0.0
        } else {
            self.budget_used.load(Ordering::Relaxed) as f64 / total as f64
        }
    }

    /// Virtual hardware-regime seconds consumed across all workers.
    pub fn virtual_secs(&self) -> f64 {
        self.virtual_secs.load()
    }

    /// Tokens per virtual regime second (0 when no regime is configured).
    pub fn virtual_tokens_per_sec(&self) -> f64 {
        let v = self.virtual_secs();
        if v <= 0.0 {
            0.0
        } else {
            self.total_tokens() as f64 / v
        }
    }

    pub fn admitted(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn total_tokens(&self) -> u64 {
        self.tokens.load(Ordering::Relaxed)
    }

    /// Pending = admitted − started (queued, not yet picked up).
    pub fn queue_depth(&self) -> u64 {
        self.admitted()
            .saturating_sub(self.started.load(Ordering::Relaxed))
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let secs = self.started_at.elapsed().as_secs_f64().max(1e-9);
        self.total_tokens() as f64 / secs
    }

    /// Snapshot as JSON (served by the `stats` protocol command).
    pub fn snapshot(&self) -> Json {
        let qw = self.queue_wait.lock().unwrap().clone();
        let gl = self.gen_latency.lock().unwrap().clone();
        let tt = self.ttft.lock().unwrap().clone();
        Json::obj(vec![
            ("admitted", Json::Num(self.admitted() as f64)),
            ("rejected", Json::Num(self.rejected() as f64)),
            ("completed", Json::Num(self.completed() as f64)),
            ("cancelled", Json::Num(self.cancelled() as f64)),
            ("chunks", Json::Num(self.chunks() as f64)),
            ("queue_depth", Json::Num(self.queue_depth() as f64)),
            ("total_tokens", Json::Num(self.total_tokens() as f64)),
            ("tokens_per_sec", Json::Num(self.tokens_per_sec())),
            ("queue_wait_p50", Json::Num(qw.p50())),
            ("queue_wait_p99", Json::Num(qw.p99())),
            ("gen_latency_p50", Json::Num(gl.p50())),
            ("gen_latency_p99", Json::Num(gl.p99())),
            ("ttft_p50", Json::Num(tt.p50())),
            ("ttft_p99", Json::Num(tt.p99())),
            (
                "tokens_in_flight",
                Json::Num(self.tokens_in_flight() as f64),
            ),
            ("dispatches", Json::Num(self.dispatches() as f64)),
            ("batch_occupancy", Json::Num(self.batch_occupancy())),
            (
                "budget_utilization",
                Json::Num(self.budget_utilization()),
            ),
            ("virtual_secs", Json::Num(self.virtual_secs())),
            (
                "virtual_tokens_per_sec",
                Json::Num(self.virtual_tokens_per_sec()),
            ),
            ("cache_hit_rate", Json::Num(self.cache_hit_rate())),
            (
                "cache_hit_positions",
                Json::Num(
                    self.cache_hit_positions.load(Ordering::Relaxed) as f64,
                ),
            ),
            (
                "cache_billed_positions",
                Json::Num(
                    self.cache_billed_positions.load(Ordering::Relaxed)
                        as f64,
                ),
            ),
            (
                "cache_resident_blocks",
                Json::Num(self.cache_resident_blocks() as f64),
            ),
            ("open_conns", Json::Num(self.open_conns() as f64)),
            ("conns_rejected", Json::Num(self.conns_rejected() as f64)),
            ("outbox_frames", Json::Num(self.outbox_frames() as f64)),
            (
                "backpressure_closed",
                Json::Num(self.backpressure_closed() as f64),
            ),
            (
                "transport_threads",
                Json::Num(self.transport_threads() as f64),
            ),
            ("router_routed", Json::Num(self.router_routed() as f64)),
            ("router_spilled", Json::Num(self.router_spilled() as f64)),
            (
                "router_failover",
                Json::Num(self.router_failover() as f64),
            ),
            ("radix_lookups", Json::Num(self.radix_lookups() as f64)),
            ("radix_hits", Json::Num(self.radix_hits() as f64)),
            ("radix_hit_rate", Json::Num(self.radix_hit_rate())),
            (
                "radix_warm_tokens",
                Json::Num(self.radix_warm_tokens() as f64),
            ),
            (
                "radix_nodes",
                Json::Num(self.radix_nodes.load(Ordering::Relaxed) as f64),
            ),
            (
                "radix_depth",
                Json::Num(self.radix_depth.load(Ordering::Relaxed) as f64),
            ),
            (
                "radix_shared_blocks",
                Json::Num(
                    self.radix_shared_blocks.load(Ordering::Relaxed) as f64,
                ),
            ),
            ("prefill_chunks", Json::Num(self.prefill_chunks() as f64)),
            ("prefill_tokens", Json::Num(self.prefill_tokens() as f64)),
            (
                "prefill_tokens_in_flight",
                Json::Num(self.prefill_tokens_in_flight() as f64),
            ),
        ])
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_flow() {
        let m = Metrics::new();
        m.on_admitted();
        m.on_admitted();
        m.on_rejected();
        m.on_started(0.1);
        m.on_completed(128, 2.0);
        assert_eq!(m.admitted(), 2);
        assert_eq!(m.rejected(), 1);
        assert_eq!(m.completed(), 1);
        assert_eq!(m.total_tokens(), 128);
        assert_eq!(m.queue_depth(), 1);
        m.on_cancelled();
        m.on_chunk();
        m.on_chunk();
        assert_eq!(m.cancelled(), 1);
        assert_eq!(m.chunks(), 2);
        let snap = m.snapshot();
        assert_eq!(snap.get("cancelled").unwrap().as_usize(), Some(1));
        assert_eq!(snap.get("chunks").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn prefill_counters_flow() {
        let m = Metrics::new();
        m.on_prefill(2, 64);
        m.on_prefill(1, 32);
        m.set_prefill_in_flight(96);
        assert_eq!(m.prefill_chunks(), 3);
        assert_eq!(m.prefill_tokens(), 96);
        assert_eq!(m.prefill_tokens_in_flight(), 96);
        m.set_prefill_in_flight(0); // gauge drains on retire
        assert_eq!(m.prefill_tokens_in_flight(), 0);
        let snap = m.snapshot();
        assert_eq!(snap.get("prefill_chunks").unwrap().as_usize(), Some(3));
        assert_eq!(snap.get("prefill_tokens").unwrap().as_usize(), Some(96));
        assert_eq!(
            snap.get("prefill_tokens_in_flight").unwrap().as_usize(),
            Some(0)
        );
    }

    #[test]
    fn scheduler_gauges_flow() {
        let m = Metrics::new();
        // one continuous step serving 4 seqs on a budget of 32, then one
        // FCFS request of 10 engine steps at tree budget 8
        m.on_dispatches(1, 4, 24, 32, 0.0225);
        m.on_dispatches(10, 10, 60, 80, 0.3);
        assert_eq!(m.dispatches(), 11);
        assert!((m.batch_occupancy() - 14.0 / 11.0).abs() < 1e-9);
        assert!((m.budget_utilization() - 84.0 / 112.0).abs() < 1e-9);
        // Full f64 precision: the old microsecond stand-in only got
        // within 1e-4 of this.
        assert!((m.virtual_secs() - 0.3225).abs() < 1e-12);
        m.on_first_token(0.2);
        m.on_cache(90, 30, 12);
        m.on_cache(30, 10, 7);
        assert!((m.cache_hit_rate() - 120.0 / 160.0).abs() < 1e-9);
        assert_eq!(m.cache_resident_blocks(), 7);
        m.tokens_in_flight_add(12);
        m.tokens_in_flight_sub(5);
        assert_eq!(m.tokens_in_flight(), 7);
        m.tokens_in_flight_sub(100); // saturates, never wraps
        assert_eq!(m.tokens_in_flight(), 0);
    }

    #[test]
    fn transport_gauges_flow() {
        let m = Metrics::new();
        m.set_transport_threads(4);
        m.on_conn_open();
        m.on_conn_open();
        m.on_conn_rejected();
        m.outbox_inc();
        m.outbox_inc();
        m.outbox_inc();
        m.outbox_dec(2);
        m.on_backpressure_closed();
        m.on_conn_closed();
        assert_eq!(m.open_conns(), 1);
        assert_eq!(m.conns_rejected(), 1);
        assert_eq!(m.outbox_frames(), 1);
        assert_eq!(m.backpressure_closed(), 1);
        assert_eq!(m.transport_threads(), 4);
        // Gauges saturate instead of wrapping when decrements race.
        m.on_conn_closed();
        m.on_conn_closed();
        assert_eq!(m.open_conns(), 0);
        m.outbox_dec(100);
        assert_eq!(m.outbox_frames(), 0);
        let snap = m.snapshot();
        assert_eq!(snap.get("open_conns").unwrap().as_usize(), Some(0));
        assert_eq!(
            snap.get("transport_threads").unwrap().as_usize(),
            Some(4)
        );
        assert_eq!(
            snap.get("backpressure_closed").unwrap().as_usize(),
            Some(1)
        );
    }

    #[test]
    fn router_counters_flow() {
        let m = Metrics::new();
        m.on_routed();
        m.on_routed();
        m.on_route_spilled();
        m.on_route_failover();
        assert_eq!(m.router_routed(), 2);
        assert_eq!(m.router_spilled(), 1);
        assert_eq!(m.router_failover(), 1);
        let snap = m.snapshot();
        assert_eq!(snap.get("router_routed").unwrap().as_usize(), Some(2));
        assert_eq!(snap.get("router_spilled").unwrap().as_usize(), Some(1));
        assert_eq!(snap.get("router_failover").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn radix_counters_flow() {
        let m = Metrics::new();
        assert_eq!(m.radix_hit_rate(), 0.0, "empty rate must be 0");
        m.on_radix(3, 1, 64, 5, 80, 20);
        m.on_radix(1, 1, 16, 6, 96, 24);
        assert_eq!(m.radix_lookups(), 4);
        assert_eq!(m.radix_hits(), 2);
        assert_eq!(m.radix_warm_tokens(), 80);
        assert!((m.radix_hit_rate() - 0.5).abs() < 1e-12);
        let snap = m.snapshot();
        assert_eq!(snap.get("radix_lookups").unwrap().as_usize(), Some(4));
        assert_eq!(snap.get("radix_hits").unwrap().as_usize(), Some(2));
        assert_eq!(
            snap.get("radix_warm_tokens").unwrap().as_usize(),
            Some(80)
        );
        // Gauges take the last writer's value.
        assert_eq!(snap.get("radix_nodes").unwrap().as_usize(), Some(6));
        assert_eq!(snap.get("radix_depth").unwrap().as_usize(), Some(96));
        assert_eq!(
            snap.get("radix_shared_blocks").unwrap().as_usize(),
            Some(24)
        );
    }

    #[test]
    fn snapshot_is_json_object() {
        let m = Metrics::new();
        m.on_admitted();
        m.on_started(0.5);
        m.on_completed(10, 1.0);
        let snap = m.snapshot();
        assert_eq!(snap.get("completed").unwrap().as_usize(), Some(1));
        assert_eq!(snap.get("total_tokens").unwrap().as_usize(), Some(10));
        assert!(snap.get("gen_latency_p50").unwrap().as_f64().unwrap() > 0.0);
    }

    /// The exposition contract: every field of the metrics snapshot
    /// appears as a `dyspec_<field>` series in the Prometheus rendering,
    /// alongside the stage-latency and acceptance series (their line
    /// syntax is pinned in obs::tests).
    #[test]
    fn prometheus_exposition_covers_every_snapshot_field() {
        let m = Metrics::new();
        m.on_admitted();
        m.on_started(0.25);
        m.on_first_token(0.3);
        m.on_completed(16, 1.5);
        m.on_dispatches(2, 3, 10, 16, 0.125);
        m.on_cache(5, 10, 2);
        let obs = crate::obs::Observatory::new(1, false, 16);
        let snap = m.snapshot();
        let text = crate::obs::render_prometheus(&snap, &obs, &[]);
        let Json::Obj(map) = &snap else {
            panic!("snapshot must be an object")
        };
        assert!(map.len() >= 25, "snapshot lost fields: {}", map.len());
        for key in map.keys() {
            let needle = format!("\ndyspec_{key} ");
            assert!(
                text.contains(&needle) || text.starts_with(&needle[1..]),
                "snapshot field {key} missing from exposition"
            );
        }
        for series in [
            "dyspec_round_stage_seconds",
            "dyspec_accept_depth_proposed_total",
            "dyspec_accept_prob_accepted_total",
            "dyspec_tracing_enabled",
            "dyspec_trace_spans_dropped_total",
        ] {
            assert!(
                text.contains(&format!("# TYPE {series} ")),
                "series {series} missing from exposition"
            );
        }
    }
}
