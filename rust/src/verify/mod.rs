//! Multi-branch rejection verification (paper Algorithm 3).
//!
//! Walks the speculated tree from the root; at each node, children are
//! tried in sampling order with acceptance probability min(1, R[y]/D[y])
//! where R starts as the target distribution and is residualized
//! (`norm(relu(R − D))`) after every rejection while D has the rejected
//! token zeroed + renormalized. The walk guarantees the emitted sequence is
//! distributed EXACTLY as target-only decoding (the unbiasedness property
//! tests in rust/tests/unbiasedness.rs check this end to end).
//!
//! DySpec-specific detail (paper A.3): if D's mass hits zero mid-node, we
//! return immediately — the corresponding construction estimate is 0 and
//! such branches are never extended.

use crate::tree::{NodeId, TokenTree, ROOT};
use crate::util::math::{argmax, normalize, residual};
use crate::util::Rng;

/// Result of one verification walk.
#[derive(Clone, Debug, PartialEq)]
pub struct VerifyOutcome {
    /// Speculated tokens accepted, in order along the root path.
    pub accepted: Vec<u32>,
    /// Node ids matching `accepted`.
    pub accepted_nodes: Vec<NodeId>,
    /// The extra token emitted at the end (from the target or residual
    /// distribution) — speculative decoding always emits >= 1 token/step.
    pub bonus: u32,
    /// Total emitted tokens = accepted.len() + 1.
    pub emitted: usize,
}

/// Verify a speculated tree.
///
/// `target_dists` row 0 is the (temperature-applied) target distribution at
/// the root; row `row_of[id]` is the distribution at node `id`. `row_of`
/// comes from the verification order used to score the tree.
pub fn verify_tree(
    tree: &TokenTree,
    target_dists: &[Vec<f32>],
    row_of: &[usize],
    rng: &mut Rng,
) -> VerifyOutcome {
    let mut accepted = Vec::new();
    let mut accepted_nodes = Vec::new();
    let mut current = ROOT;

    loop {
        let node = tree.node(current);
        let row = if current == ROOT { 0 } else { row_of[current] };
        let target = &target_dists[row];

        if node.children.is_empty() {
            // Everything on this path accepted: bonus from the target.
            let bonus = sample_checked(target, rng);
            return VerifyOutcome {
                emitted: accepted.len() + 1,
                accepted,
                accepted_nodes,
                bonus,
            };
        }

        let mut d = node.draft_dist.clone();
        debug_assert_eq!(d.len(), target.len(), "draft/target vocab mismatch");
        let mut r = target.clone();
        let mut moved = false;

        for &child in &node.children {
            let y = tree.node(child).token as usize;
            let d_y = d[y];
            let accept_prob = if d_y > 0.0 {
                (r[y] / d_y).min(1.0)
            } else {
                // Draft claims zero mass for a token it sampled — only
                // possible via float underflow; treat as reject.
                0.0
            };
            if (rng.next_f64() as f32) < accept_prob {
                accepted.push(y as u32);
                accepted_nodes.push(child);
                current = child;
                moved = true;
                break;
            }
            // Reject: residualize target, zero draft.
            let mut res = Vec::new();
            if residual(&r, &d, &mut res) {
                r = res;
            } else {
                // Residual empty (target mass fully covered): emit argmax of
                // the remaining target as a numerically-safe fallback.
                r = vec![0.0; d.len()];
                r[argmax(target)] = 1.0;
            }
            d[y] = 0.0;
            if !normalize(&mut d) {
                // DySpec early return: draft mass exhausted (paper A.3).
                let bonus = sample_checked(&r, rng);
                return VerifyOutcome {
                    emitted: accepted.len() + 1,
                    accepted,
                    accepted_nodes,
                    bonus,
                };
            }
        }

        if !moved {
            // All children rejected: bonus from the final residual.
            let bonus = sample_checked(&r, rng);
            return VerifyOutcome {
                emitted: accepted.len() + 1,
                accepted,
                accepted_nodes,
                bonus,
            };
        }
    }
}

fn sample_checked(dist: &[f32], rng: &mut Rng) -> u32 {
    if dist.iter().sum::<f32>() <= 0.0 {
        return argmax(dist) as u32;
    }
    crate::sampling::sample(dist, rng) as u32
}

/// Convenience: build `row_of` (node id -> target_dists row) from the
/// verification order used to score the tree.
pub fn row_map(tree: &TokenTree, order: &[NodeId]) -> Vec<usize> {
    let mut row_of = vec![usize::MAX; tree.num_nodes()];
    row_of[ROOT] = 0;
    for (i, &id) in order.iter().enumerate() {
        row_of[id] = i + 1;
    }
    row_of
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::ROOT;

    fn onehot(v: usize, i: usize) -> Vec<f32> {
        let mut d = vec![0.0; v];
        d[i] = 1.0;
        d
    }

    /// Chain with draft == target: everything must be accepted.
    #[test]
    fn perfect_draft_accepts_all() {
        let v = 8;
        let mut tree = TokenTree::new(0, onehot(v, 1));
        let a = tree.add_child(ROOT, 1, 1.0);
        tree.node_mut(a).draft_dist = onehot(v, 2);
        let b = tree.add_child(a, 2, 1.0);
        tree.node_mut(b).draft_dist = onehot(v, 3);
        let order = vec![a, b];
        let dists = vec![onehot(v, 1), onehot(v, 2), onehot(v, 3)];
        let row_of = row_map(&tree, &order);
        let mut rng = Rng::new(1);
        let out = verify_tree(&tree, &dists, &row_of, &mut rng);
        assert_eq!(out.accepted, vec![1, 2]);
        assert_eq!(out.bonus, 3);
        assert_eq!(out.emitted, 3);
    }

    /// Target disagrees at the first token: nothing accepted, bonus follows
    /// the residual (= target since the rejected draft token has target
    /// mass 0).
    #[test]
    fn disjoint_support_rejects_all() {
        let v = 8;
        let mut tree = TokenTree::new(0, onehot(v, 1));
        let a = tree.add_child(ROOT, 1, 1.0);
        tree.node_mut(a).draft_dist = onehot(v, 2);
        let order = vec![a];
        let dists = vec![onehot(v, 5), onehot(v, 6)];
        let row_of = row_map(&tree, &order);
        let mut rng = Rng::new(2);
        let out = verify_tree(&tree, &dists, &row_of, &mut rng);
        assert!(out.accepted.is_empty());
        assert_eq!(out.bonus, 5);
        assert_eq!(out.emitted, 1);
    }

    /// Two siblings where target favors the SECOND: the walk must reject
    /// the first and accept the second via the residual rule.
    #[test]
    fn sibling_residual_walk() {
        let v = 4;
        let draft = vec![0.5, 0.5, 0.0, 0.0];
        let target = vec![0.0, 1.0, 0.0, 0.0];
        let mut tree = TokenTree::new(0, draft.clone());
        let a = tree.add_child(ROOT, 0, 0.5); // draft's token 0 first
        let b = tree.add_child(ROOT, 1, 0.25);
        tree.node_mut(a).draft_dist = onehot(v, 2);
        tree.node_mut(b).draft_dist = onehot(v, 3);
        let order = vec![a, b];
        let dists = vec![target, onehot(v, 2), onehot(v, 3)];
        let row_of = row_map(&tree, &order);
        let mut rng = Rng::new(3);
        let out = verify_tree(&tree, &dists, &row_of, &mut rng);
        // token 0: accept prob min(1, 0/0.5) = 0 -> rejected
        // residual: relu(target - draft) = [0, .5, 0, 0] -> norm [0,1,0,0]
        // D: zero token 0, renorm -> [0,1,0,0]; child b token 1: prob 1 -> accept
        assert_eq!(out.accepted, vec![1]);
        assert_eq!(out.accepted_nodes, vec![b]);
        assert_eq!(out.bonus, 3); // leaf target
    }

    /// Draft exhaustion mid-node triggers the DySpec early return.
    #[test]
    fn draft_exhaustion_early_return() {
        let v = 4;
        // Draft is one-hot on token 0; target one-hot on token 1.
        let mut tree = TokenTree::new(0, onehot(v, 0));
        let a = tree.add_child(ROOT, 0, 1.0);
        tree.node_mut(a).draft_dist = onehot(v, 0);
        let order = vec![a];
        let dists = vec![onehot(v, 1), onehot(v, 1)];
        let row_of = row_map(&tree, &order);
        let mut rng = Rng::new(4);
        let out = verify_tree(&tree, &dists, &row_of, &mut rng);
        // reject token 0 (target mass 0); D zeroed everywhere -> early return
        assert!(out.accepted.is_empty());
        assert_eq!(out.bonus, 1);
    }

    /// Accepted tokens always form a root path.
    #[test]
    fn accepted_is_root_path() {
        let v = 16;
        let mut rng = Rng::new(5);
        for seed in 0..50u64 {
            let mut c = Rng::new(seed);
            // random 2-level tree with random dists
            let rand_dist = |rng: &mut Rng| {
                let mut d: Vec<f32> = (0..v).map(|_| rng.next_f32().max(1e-3)).collect();
                crate::util::math::normalize(&mut d);
                d
            };
            let mut tree = TokenTree::new(0, rand_dist(&mut c));
            let a = tree.add_child(ROOT, c.next_below(v) as u32, 0.5);
            let b = tree.add_child(ROOT, (c.next_below(v - 1) + 1) as u32, 0.3);
            tree.node_mut(a).draft_dist = rand_dist(&mut c);
            tree.node_mut(b).draft_dist = rand_dist(&mut c);
            let x = tree.add_child(a, c.next_below(v) as u32, 0.2);
            tree.node_mut(x).draft_dist = rand_dist(&mut c);
            let order = vec![a, b, x];
            let dists: Vec<Vec<f32>> = (0..4).map(|_| rand_dist(&mut c)).collect();
            let row_of = row_map(&tree, &order);
            let out = verify_tree(&tree, &dists, &row_of, &mut rng);
            // verify path property
            for w in out.accepted_nodes.windows(2) {
                assert_eq!(tree.node(w[1]).parent, Some(w[0]));
            }
            if let Some(&first) = out.accepted_nodes.first() {
                assert_eq!(tree.node(first).parent, Some(ROOT));
            }
            assert_eq!(out.emitted, out.accepted.len() + 1);
        }
    }
}
