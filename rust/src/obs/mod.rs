//! Observability: end-to-end tracing, the acceptance observatory, and a
//! Prometheus-style text exposition surface (DESIGN.md §Observability).
//!
//! Three independent layers, all dependency-free:
//!
//! - **Structured tracing** — a per-request [`TraceId`] is minted at
//!   submission (`coordinator::queue`) and echoed in every reply frame of
//!   that request. Each worker owns a bounded flight-recorder ring
//!   ([`SpanRing`]) into which one [`Span`] per round-pipeline stage
//!   (`plan → draft → dispatch → verify → commit`) is pushed after every
//!   speculation round. The ring is dumpable as JSONL over the wire
//!   (`{"cmd":"trace"}`) for postmortems. Tracing is off by default and
//!   checked before any lock is taken, so the disabled path costs one
//!   branch — token streams are bit-identical either way (pinned by
//!   rust/tests/obs_differential.rs).
//! - **Acceptance observatory** — per-drafter × per-tree-depth acceptance
//!   counters plus draft-probability-bucket → acceptance cells, folded in
//!   from every round's [`AcceptanceRecord`] (computed in
//!   `round::conclude_round` from the verified tree). This measures the
//!   paper's core claim — acceptance tracks estimated draft probability
//!   (§3, Fig. 2) — online, and is the data contract for the ROADMAP's
//!   adaptive-drafter policy.
//! - **Exposition** — [`render_prometheus`] serializes the whole
//!   `coordinator::Metrics` snapshot plus per-stage latency quantiles and
//!   the acceptance series in Prometheus text format, served via
//!   `{"cmd":"metrics"}` and the `client --metrics` flag.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::config::PolicyKind;
use crate::util::json::Json;
use crate::util::timer::ComponentTimes;
use crate::util::Histogram;

/// Round-pipeline stages, in pipeline order. `plan` covers tree
/// construction + mask generation, `draft` the draft-model forward passes,
/// `dispatch` the batched target scoring, `verify` sampling + the
/// multi-branch verification walk, and `commit` the KV accept/rollback —
/// the Fig-4 buckets regrouped along the `round::` pipeline seams.
pub const STAGES: [&str; 5] = ["plan", "draft", "dispatch", "verify", "commit"];

/// Map the engine's Fig-4 component labels onto the five pipeline stages.
pub fn stage_secs(times: &ComponentTimes) -> [f64; 5] {
    [
        times.get("tree_construct") + times.get("mask"),
        times.get("draft_infer"),
        times.get("target_infer"),
        times.get("sample") + times.get("verify"),
        times.get("commit"),
    ]
}

/// Tracked tree depths (deeper nodes clamp into the last cell).
pub const MAX_DEPTH: usize = 16;
/// Draft-probability buckets: bucket `b` covers `[2^(b-8), 2^(b-7))`,
/// except the top bucket which closes at 1 and the bottom which opens
/// at 0.
pub const PROB_BUCKETS: usize = 8;

/// Per-request trace identifier. Zero means "no trace attached" and is
/// never minted, so a `u64` can double as an optional slot in atomics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceId(pub u64);

impl TraceId {
    /// Mint the trace id for a request id: a splitmix64 scramble, so ids
    /// are deterministic (same request id → same trace id, which keeps
    /// the differential suite and postmortems reproducible) yet visibly
    /// distinct from the sequential request counter.
    pub fn mint(req_id: u64) -> Self {
        let mut z = req_id.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        Self(z.max(1))
    }

    pub fn is_set(&self) -> bool {
        self.0 != 0
    }

    /// Wire form: 16 lowercase hex digits.
    pub fn to_hex(self) -> String {
        format!("{:016x}", self.0)
    }
}

/// One recorded stage of one speculation round.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    /// Worker that ran the round.
    pub worker: usize,
    /// Per-worker round counter (monotonic since worker start).
    pub round: u64,
    /// One of [`STAGES`].
    pub stage: &'static str,
    /// Microseconds since the observatory epoch.
    pub start_us: u64,
    pub dur_us: u64,
    /// Sequences served by the round (1 on FCFS, batch size on
    /// continuous).
    pub seqs: usize,
    /// Trace id of the request, 0 for multi-sequence rounds (a batched
    /// dispatch belongs to every co-scheduled request at once).
    pub trace: u64,
}

impl Span {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("worker", Json::Num(self.worker as f64)),
            ("round", Json::Num(self.round as f64)),
            ("stage", Json::Str(self.stage.to_string())),
            ("start_us", Json::Num(self.start_us as f64)),
            ("dur_us", Json::Num(self.dur_us as f64)),
            ("seqs", Json::Num(self.seqs as f64)),
            (
                "trace",
                Json::Str(TraceId(self.trace).to_hex()),
            ),
        ])
    }
}

/// Bounded flight recorder: the newest `cap` spans win, overflow is
/// counted, never silently lost.
#[derive(Debug)]
pub struct SpanRing {
    cap: usize,
    spans: VecDeque<Span>,
    dropped: u64,
}

impl SpanRing {
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Self {
            cap,
            spans: VecDeque::with_capacity(cap.min(1024)),
            dropped: 0,
        }
    }

    pub fn push(&mut self, span: Span) {
        if self.spans.len() == self.cap {
            self.spans.pop_front();
            self.dropped += 1;
        }
        self.spans.push_back(span);
    }

    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn iter(&self) -> impl Iterator<Item = &Span> {
        self.spans.iter()
    }
}

/// What one round's verification said about its speculated nodes, bucketed
/// the way the adaptive-drafter policy will consume it: by tree depth and
/// by the construction-time acceptance estimate (`Node::est`, the product
/// of draft probabilities along the path — the paper's Fig. 2 x-axis).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AcceptanceRecord {
    pub depth_proposed: [u64; MAX_DEPTH],
    pub depth_accepted: [u64; MAX_DEPTH],
    pub prob_proposed: [u64; PROB_BUCKETS],
    pub prob_accepted: [u64; PROB_BUCKETS],
}

impl AcceptanceRecord {
    /// Bucket for an acceptance estimate in (0, 1]: log2-spaced, the top
    /// bucket holding [1/2, 1] and everything below 2^-7 pooling into
    /// bucket 0.
    pub fn prob_bucket(est: f64) -> usize {
        let mut b = PROB_BUCKETS - 1;
        let mut lo = 0.5;
        while b > 0 && est < lo {
            lo *= 0.5;
            b -= 1;
        }
        b
    }

    /// Record one speculated node's verdict.
    pub fn note(&mut self, depth: usize, est: f64, accepted: bool) {
        let d = depth.saturating_sub(1).min(MAX_DEPTH - 1);
        let p = Self::prob_bucket(est);
        self.depth_proposed[d] += 1;
        self.prob_proposed[p] += 1;
        if accepted {
            self.depth_accepted[d] += 1;
            self.prob_accepted[p] += 1;
        }
    }

    pub fn merge(&mut self, other: &AcceptanceRecord) {
        for i in 0..MAX_DEPTH {
            self.depth_proposed[i] += other.depth_proposed[i];
            self.depth_accepted[i] += other.depth_accepted[i];
        }
        for i in 0..PROB_BUCKETS {
            self.prob_proposed[i] += other.prob_proposed[i];
            self.prob_accepted[i] += other.prob_accepted[i];
        }
    }

    pub fn proposed(&self) -> u64 {
        self.depth_proposed.iter().sum()
    }

    pub fn accepted(&self) -> u64 {
        self.depth_accepted.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.proposed() == 0
    }

    /// Laplace-smoothed cumulative acceptance rate,
    /// `(accepted + 1) / (proposed + 2)` — 0.5 with no data, converging
    /// on the empirical rate as samples land. This is the estimate the
    /// adaptive policy scores drafters by (`round::adapt`), and what the
    /// `dyspec_adaptive_drafter_estimate` gauge exposes.
    pub fn smoothed_rate(&self) -> f64 {
        (self.accepted() + 1) as f64 / (self.proposed() + 2) as f64
    }

    /// Fraction of this drafter's proposed mass that sat in probability
    /// buckets whose smoothed acceptance rate clears `cut` — the budget
    /// retune signal: low-probability buckets that verification keeps
    /// rejecting are wasted tree nodes, so the effective budget shrinks
    /// toward the useful mass. 1.0 with no data (never shrink blind).
    pub fn useful_fraction(&self, cut: f64) -> f64 {
        let total: u64 = self.prob_proposed.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let useful: u64 = (0..PROB_BUCKETS)
            .filter(|&b| {
                (self.prob_accepted[b] + 1) as f64
                    / (self.prob_proposed[b] + 2) as f64
                    >= cut
            })
            .map(|b| self.prob_proposed[b])
            .sum();
        useful as f64 / total as f64
    }
}

/// Shared observability state for one coordinator: per-worker span rings,
/// per-stage latency histograms, and the per-drafter acceptance table.
/// Stage timing and acceptance are always on (they feed the metrics
/// exposition); span recording only happens when `tracing` is enabled.
pub struct Observatory {
    tracing: bool,
    epoch: Instant,
    rings: Vec<Mutex<SpanRing>>,
    rounds: Vec<AtomicU64>,
    stage_hist: Vec<Mutex<Histogram>>,
    accept: Mutex<BTreeMap<&'static str, AcceptanceRecord>>,
}

impl Observatory {
    pub fn new(workers: usize, tracing: bool, ring_cap: usize) -> Self {
        let workers = workers.max(1);
        Self {
            tracing,
            epoch: Instant::now(),
            rings: (0..workers)
                .map(|_| Mutex::new(SpanRing::new(ring_cap)))
                .collect(),
            rounds: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            stage_hist: STAGES
                .iter()
                .map(|_| Mutex::new(Histogram::new()))
                .collect(),
            accept: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn tracing(&self) -> bool {
        self.tracing
    }

    /// Record one finished speculation round: fold the stage times into
    /// the latency histograms, the acceptance record into the drafter
    /// table, and — when tracing — five spans into the worker's ring.
    /// Purely observational: touches no RNG and no request state.
    pub fn record_round(
        &self,
        wid: usize,
        trace: TraceId,
        seqs: usize,
        drafter: PolicyKind,
        times: &ComponentTimes,
        accept: &AcceptanceRecord,
    ) {
        let secs = stage_secs(times);
        for (hist, &s) in self.stage_hist.iter().zip(secs.iter()) {
            hist.lock().expect("stage hist poisoned").record(s);
        }
        if !accept.is_empty() {
            self.accept
                .lock()
                .expect("accept table poisoned")
                .entry(drafter.name())
                .or_default()
                .merge(accept);
        }
        if !self.tracing {
            return;
        }
        let wid = wid.min(self.rings.len() - 1);
        let round = self.rounds[wid].fetch_add(1, Ordering::Relaxed);
        // Synthesize a contiguous timeline ending now: the stages ran
        // back-to-back inside the round, so cumulative offsets from
        // (now − total) reconstruct their wall-clock placement.
        let end_us = self.epoch.elapsed().as_micros() as u64;
        let total_us: u64 =
            secs.iter().map(|s| (s.max(0.0) * 1e6) as u64).sum();
        let mut cursor = end_us.saturating_sub(total_us);
        let mut ring = self.rings[wid].lock().expect("span ring poisoned");
        for (stage, &s) in STAGES.iter().zip(secs.iter()) {
            let dur = (s.max(0.0) * 1e6) as u64;
            ring.push(Span {
                worker: wid,
                round,
                stage,
                start_us: cursor,
                dur_us: dur,
                seqs,
                trace: trace.0,
            });
            cursor += dur;
        }
    }

    /// All recorded spans across workers, ordered by start time, plus the
    /// total overflow count.
    pub fn dump_spans(&self) -> (Vec<Span>, u64) {
        let mut spans = Vec::new();
        let mut dropped = 0;
        for ring in &self.rings {
            let ring = ring.lock().expect("span ring poisoned");
            spans.extend(ring.iter().cloned());
            dropped += ring.dropped();
        }
        spans.sort_by_key(|s| (s.start_us, s.worker, s.round));
        (spans, dropped)
    }

    /// The `{"cmd":"trace"}` reply body.
    pub fn trace_json(&self) -> Json {
        let (spans, dropped) = self.dump_spans();
        Json::obj(vec![
            ("tracing", Json::Bool(self.tracing)),
            ("dropped", Json::Num(dropped as f64)),
            (
                "spans",
                Json::Arr(spans.iter().map(Span::to_json).collect()),
            ),
        ])
    }

    /// Per-stage latency quantiles: (stage, count, sum, p50, p95, p99).
    pub fn stage_quantiles(&self) -> Vec<(&'static str, u64, f64, f64, f64, f64)> {
        STAGES
            .iter()
            .zip(self.stage_hist.iter())
            .map(|(&stage, hist)| {
                let h = hist.lock().expect("stage hist poisoned");
                (
                    stage,
                    h.len() as u64,
                    h.sum(),
                    h.p50(),
                    h.p95(),
                    h.p99(),
                )
            })
            .collect()
    }

    /// Snapshot of the per-drafter acceptance table.
    pub fn acceptance(&self) -> Vec<(&'static str, AcceptanceRecord)> {
        self.accept
            .lock()
            .expect("accept table poisoned")
            .iter()
            .map(|(&k, v)| (k, v.clone()))
            .collect()
    }

    /// Per-drafter `(name, samples, smoothed acceptance rate)` estimates —
    /// the same estimator the adaptive policy runs per worker, computed
    /// over the observatory's cumulative cells for the metrics surface.
    pub fn estimates(&self) -> Vec<(&'static str, u64, f64)> {
        self.acceptance()
            .iter()
            .map(|(k, r)| (*k, r.proposed(), r.smoothed_rate()))
            .collect()
    }

    /// Total spans dropped to ring overflow (tests, exposition).
    pub fn spans_dropped(&self) -> u64 {
        self.rings
            .iter()
            .map(|r| r.lock().expect("span ring poisoned").dropped())
            .sum()
    }
}

fn prom_value(out: &mut String, v: f64) {
    if !v.is_finite() {
        out.push('0');
    } else if v.fract() == 0.0 && v.abs() < 1e15 {
        out.push_str(&format!("{}", v as i64));
    } else {
        out.push_str(&format!("{v}"));
    }
}

fn prom_gauge(out: &mut String, name: &str, help: &str, v: f64) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} "));
    prom_value(out, v);
    out.push('\n');
}

fn prom_header(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

fn prom_row(out: &mut String, name: &str, labels: &[(&str, String)], v: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, val)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("{k}=\"{val}\""));
        }
        out.push('}');
    }
    out.push(' ');
    prom_value(out, v);
    out.push('\n');
}

/// Lower bound of probability bucket `b` (0 for the open bottom bucket).
fn bucket_lo(b: usize) -> f64 {
    if b == 0 {
        0.0
    } else {
        2f64.powi(b as i32 - PROB_BUCKETS as i32)
    }
}

fn bucket_hi(b: usize) -> f64 {
    2f64.powi(b as i32 + 1 - PROB_BUCKETS as i32)
}

/// One worker's router-tier row in the exposition: health, load gauges,
/// and routed/spilled counters, labeled `worker="<id>"` so the scrape is
/// disaggregable per shard. Produced by `router::Router::worker_stats`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkerStat {
    pub worker: usize,
    pub alive: bool,
    pub queued: u64,
    pub inflight: u64,
    pub routed: u64,
    pub spilled: u64,
}

/// Render the full telemetry surface in Prometheus text exposition
/// format: every scalar of the `Metrics` snapshot as a `dyspec_*` gauge,
/// per-stage round-latency summaries, the acceptance observatory
/// series, and per-worker router rows. `snapshot` is the JSON object
/// from `Metrics::snapshot()`, so new metrics fields appear here
/// automatically; `workers` is empty for surfaces without a router tier
/// (direct engine benches, unit tests).
pub fn render_prometheus(
    snapshot: &Json,
    obs: &Observatory,
    workers: &[WorkerStat],
) -> String {
    let mut out = String::new();
    if let Json::Obj(map) = snapshot {
        for (key, val) in map {
            let v = match val {
                Json::Num(x) => *x,
                Json::Bool(b) => {
                    if *b {
                        1.0
                    } else {
                        0.0
                    }
                }
                _ => continue,
            };
            let name = format!("dyspec_{key}");
            prom_gauge(&mut out, &name, "coordinator metrics snapshot field", v);
        }
    }

    prom_header(
        &mut out,
        "dyspec_round_stage_seconds",
        "per-stage speculation-round latency (plan|draft|dispatch|verify|commit)",
        "summary",
    );
    for (stage, n, sum, p50, p95, p99) in obs.stage_quantiles() {
        let label = |q: &str| {
            vec![
                ("stage", stage.to_string()),
                ("quantile", q.to_string()),
            ]
        };
        prom_row(&mut out, "dyspec_round_stage_seconds", &label("0.5"), p50);
        prom_row(&mut out, "dyspec_round_stage_seconds", &label("0.95"), p95);
        prom_row(&mut out, "dyspec_round_stage_seconds", &label("0.99"), p99);
        let stage_label = vec![("stage", stage.to_string())];
        prom_row(&mut out, "dyspec_round_stage_seconds_sum", &stage_label, sum);
        prom_row(
            &mut out,
            "dyspec_round_stage_seconds_count",
            &stage_label,
            n as f64,
        );
    }

    let table = obs.acceptance();
    prom_header(
        &mut out,
        "dyspec_accept_depth_proposed_total",
        "speculated nodes proposed, by drafter and tree depth",
        "counter",
    );
    prom_header(
        &mut out,
        "dyspec_accept_depth_accepted_total",
        "speculated nodes accepted by verification, by drafter and tree depth",
        "counter",
    );
    for (drafter, rec) in &table {
        for d in 0..MAX_DEPTH {
            if rec.depth_proposed[d] == 0 {
                continue;
            }
            let labels = vec![
                ("drafter", drafter.to_string()),
                ("depth", (d + 1).to_string()),
            ];
            prom_row(
                &mut out,
                "dyspec_accept_depth_proposed_total",
                &labels,
                rec.depth_proposed[d] as f64,
            );
            prom_row(
                &mut out,
                "dyspec_accept_depth_accepted_total",
                &labels,
                rec.depth_accepted[d] as f64,
            );
        }
    }
    prom_header(
        &mut out,
        "dyspec_accept_prob_proposed_total",
        "speculated nodes proposed, by drafter and estimated-acceptance bucket",
        "counter",
    );
    prom_header(
        &mut out,
        "dyspec_accept_prob_accepted_total",
        "speculated nodes accepted, by drafter and estimated-acceptance bucket",
        "counter",
    );
    for (drafter, rec) in &table {
        for b in 0..PROB_BUCKETS {
            if rec.prob_proposed[b] == 0 {
                continue;
            }
            let labels = vec![
                ("drafter", drafter.to_string()),
                ("bucket", b.to_string()),
                ("lo", format!("{}", bucket_lo(b))),
                ("hi", format!("{}", bucket_hi(b))),
            ];
            prom_row(
                &mut out,
                "dyspec_accept_prob_proposed_total",
                &labels,
                rec.prob_proposed[b] as f64,
            );
            prom_row(
                &mut out,
                "dyspec_accept_prob_accepted_total",
                &labels,
                rec.prob_accepted[b] as f64,
            );
        }
    }

    prom_header(
        &mut out,
        "dyspec_adaptive_drafter_estimate",
        "smoothed acceptance-rate estimate the adaptive policy scores drafters by",
        "gauge",
    );
    prom_header(
        &mut out,
        "dyspec_adaptive_drafter_samples_total",
        "proposed-node samples behind each drafter's estimate",
        "counter",
    );
    for (drafter, samples, rate) in obs.estimates() {
        let labels = vec![("drafter", drafter.to_string())];
        prom_row(
            &mut out,
            "dyspec_adaptive_drafter_estimate",
            &labels,
            rate,
        );
        prom_row(
            &mut out,
            "dyspec_adaptive_drafter_samples_total",
            &labels,
            samples as f64,
        );
    }

    if !workers.is_empty() {
        prom_header(
            &mut out,
            "dyspec_worker_alive",
            "1 while the worker is healthy on the router ring",
            "gauge",
        );
        prom_header(
            &mut out,
            "dyspec_worker_queue_depth",
            "requests admitted to the worker's shard queue, not yet started",
            "gauge",
        );
        prom_header(
            &mut out,
            "dyspec_worker_inflight",
            "requests the worker is actively generating",
            "gauge",
        );
        prom_header(
            &mut out,
            "dyspec_worker_routed_total",
            "requests routed to this worker (spill-ins included)",
            "counter",
        );
        prom_header(
            &mut out,
            "dyspec_worker_spilled_total",
            "requests this worker absorbed by spill rather than ring ownership",
            "counter",
        );
        for w in workers {
            let labels = vec![("worker", w.worker.to_string())];
            let rows: [(&str, f64); 5] = [
                ("dyspec_worker_alive", if w.alive { 1.0 } else { 0.0 }),
                ("dyspec_worker_queue_depth", w.queued as f64),
                ("dyspec_worker_inflight", w.inflight as f64),
                ("dyspec_worker_routed_total", w.routed as f64),
                ("dyspec_worker_spilled_total", w.spilled as f64),
            ];
            for (name, v) in rows {
                prom_row(&mut out, name, &labels, v);
            }
        }
    }

    prom_gauge(
        &mut out,
        "dyspec_tracing_enabled",
        "1 when span tracing is on",
        if obs.tracing() { 1.0 } else { 0.0 },
    );
    prom_gauge(
        &mut out,
        "dyspec_trace_spans_dropped_total",
        "spans lost to flight-recorder ring overflow",
        obs.spans_dropped() as f64,
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn times(plan: f64, draft: f64, disp: f64, verify: f64, commit: f64) -> ComponentTimes {
        let mut t = ComponentTimes::new();
        t.add("tree_construct", plan);
        t.add("draft_infer", draft);
        t.add("target_infer", disp);
        t.add("verify", verify);
        t.add("commit", commit);
        t
    }

    #[test]
    fn trace_ids_are_deterministic_nonzero_and_distinct() {
        assert_eq!(TraceId::mint(7), TraceId::mint(7));
        assert_ne!(TraceId::mint(7), TraceId::mint(8));
        for id in 0..100 {
            assert!(TraceId::mint(id).is_set());
        }
        assert_eq!(TraceId::mint(1).to_hex().len(), 16);
        assert!(!TraceId::default().is_set());
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let mut ring = SpanRing::new(3);
        for i in 0..5 {
            ring.push(Span {
                worker: 0,
                round: i,
                stage: "plan",
                start_us: i * 10,
                dur_us: 1,
                seqs: 1,
                trace: 0,
            });
        }
        assert_eq!(ring.len(), 3);
        assert_eq!(ring.dropped(), 2);
        let rounds: Vec<u64> = ring.iter().map(|s| s.round).collect();
        assert_eq!(rounds, vec![2, 3, 4], "oldest spans must go first");
    }

    #[test]
    fn spans_come_out_in_pipeline_order_with_contiguous_offsets() {
        let obs = Observatory::new(1, true, 64);
        let t = times(0.001, 0.002, 0.004, 0.001, 0.0005);
        obs.record_round(
            0,
            TraceId::mint(1),
            1,
            PolicyKind::DySpec,
            &t,
            &AcceptanceRecord::default(),
        );
        let (spans, dropped) = obs.dump_spans();
        assert_eq!(dropped, 0);
        assert_eq!(spans.len(), STAGES.len());
        let stages: Vec<&str> = spans.iter().map(|s| s.stage).collect();
        assert_eq!(stages, STAGES.to_vec());
        for w in spans.windows(2) {
            assert_eq!(
                w[0].start_us + w[0].dur_us,
                w[1].start_us,
                "stages must tile the round back-to-back"
            );
        }
        assert_eq!(spans[1].dur_us, 2000, "draft_infer is the draft stage");
        assert_eq!(spans[2].dur_us, 4000, "target_infer is the dispatch stage");
        assert!(spans.iter().all(|s| s.trace == TraceId::mint(1).0));
        assert!(spans.iter().all(|s| s.round == 0));
    }

    #[test]
    fn tracing_off_records_no_spans_but_keeps_stage_stats() {
        let obs = Observatory::new(2, false, 64);
        let t = times(0.001, 0.002, 0.004, 0.001, 0.0005);
        obs.record_round(
            1,
            TraceId::default(),
            3,
            PolicyKind::DySpec,
            &t,
            &AcceptanceRecord::default(),
        );
        let (spans, _) = obs.dump_spans();
        assert!(spans.is_empty());
        let q = obs.stage_quantiles();
        assert!(q.iter().all(|&(_, n, ..)| n == 1));
        let dispatch = q.iter().find(|&&(s, ..)| s == "dispatch").unwrap();
        assert!(dispatch.2 > 0.0039 && dispatch.2 < 0.0041);
    }

    #[test]
    fn observatory_ring_overflow_is_visible_in_dump() {
        let obs = Observatory::new(1, true, 7); // not a multiple of 5
        let t = times(0.001, 0.001, 0.001, 0.001, 0.001);
        for i in 0..4 {
            obs.record_round(
                0,
                TraceId::mint(i),
                1,
                PolicyKind::Chain,
                &t,
                &AcceptanceRecord::default(),
            );
        }
        let (spans, dropped) = obs.dump_spans();
        assert_eq!(spans.len(), 7);
        assert_eq!(dropped, 20 - 7);
        assert_eq!(obs.spans_dropped(), 13);
    }

    #[test]
    fn prob_buckets_are_log2_spaced() {
        assert_eq!(AcceptanceRecord::prob_bucket(1.0), 7);
        assert_eq!(AcceptanceRecord::prob_bucket(0.6), 7);
        assert_eq!(AcceptanceRecord::prob_bucket(0.5), 7);
        assert_eq!(AcceptanceRecord::prob_bucket(0.49), 6);
        assert_eq!(AcceptanceRecord::prob_bucket(0.25), 6);
        assert_eq!(AcceptanceRecord::prob_bucket(0.1), 4);
        assert_eq!(AcceptanceRecord::prob_bucket(1.0 / 128.0), 0);
        assert_eq!(AcceptanceRecord::prob_bucket(1e-9), 0);
        assert_eq!(AcceptanceRecord::prob_bucket(0.0), 0);
        for b in 0..PROB_BUCKETS {
            assert!(bucket_lo(b) < bucket_hi(b));
        }
        assert_eq!(bucket_hi(PROB_BUCKETS - 1), 1.0);
        assert_eq!(bucket_lo(0), 0.0);
    }

    #[test]
    fn acceptance_record_notes_and_merges() {
        let mut a = AcceptanceRecord::default();
        a.note(1, 0.9, true);
        a.note(2, 0.3, false);
        a.note(99, 0.3, true); // depth clamps into the last cell
        assert_eq!(a.proposed(), 3);
        assert_eq!(a.accepted(), 2);
        assert_eq!(a.depth_proposed[0], 1);
        assert_eq!(a.depth_proposed[MAX_DEPTH - 1], 1);
        assert_eq!(a.prob_proposed[7], 1);
        assert_eq!(a.prob_proposed[6], 2);
        assert_eq!(a.prob_accepted[6], 1);
        let mut b = AcceptanceRecord::default();
        b.note(1, 0.9, false);
        a.merge(&b);
        assert_eq!(a.proposed(), 4);
        assert_eq!(a.accepted(), 2);
    }

    #[test]
    fn acceptance_table_is_per_drafter() {
        let obs = Observatory::new(1, false, 8);
        let mut rec = AcceptanceRecord::default();
        rec.note(1, 0.9, true);
        let t = ComponentTimes::new();
        obs.record_round(0, TraceId::default(), 1, PolicyKind::DySpec, &t, &rec);
        obs.record_round(0, TraceId::default(), 1, PolicyKind::Chain, &t, &rec);
        obs.record_round(0, TraceId::default(), 1, PolicyKind::DySpec, &t, &rec);
        let table = obs.acceptance();
        assert_eq!(table.len(), 2);
        let dyspec = table.iter().find(|(k, _)| *k == "dyspec").unwrap();
        assert_eq!(dyspec.1.proposed(), 2);
        let chain = table.iter().find(|(k, _)| *k == "chain").unwrap();
        assert_eq!(chain.1.proposed(), 1);
    }

    #[test]
    fn trace_json_shape() {
        let obs = Observatory::new(1, true, 16);
        let t = times(0.001, 0.001, 0.001, 0.001, 0.001);
        obs.record_round(
            0,
            TraceId::mint(3),
            1,
            PolicyKind::DySpec,
            &t,
            &AcceptanceRecord::default(),
        );
        let doc = obs.trace_json();
        assert_eq!(doc.get("tracing"), Some(&Json::Bool(true)));
        assert_eq!(doc.get("dropped").unwrap().as_usize(), Some(0));
        let spans = doc.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans.len(), 5);
        assert_eq!(
            spans[0].get("trace").unwrap().as_str(),
            Some(TraceId::mint(3).to_hex().as_str())
        );
        // JSONL round trip: every span line reparses.
        for s in spans {
            assert!(crate::util::json::parse(&s.to_string()).is_ok());
        }
    }

    /// Every emitted line is either a comment or `name{labels} value` with
    /// a parseable float value — the syntactic half of the exposition
    /// contract (the field-coverage half lives in
    /// coordinator/metrics.rs tests).
    #[test]
    fn prometheus_output_is_line_valid() {
        let obs = Observatory::new(1, true, 16);
        let mut rec = AcceptanceRecord::default();
        rec.note(1, 0.9, true);
        rec.note(3, 0.01, false);
        let t = times(0.001, 0.002, 0.004, 0.001, 0.0005);
        obs.record_round(0, TraceId::mint(1), 1, PolicyKind::DySpec, &t, &rec);
        let snapshot = Json::obj(vec![
            ("admitted", Json::Num(3.0)),
            ("tokens_per_sec", Json::Num(12.5)),
        ]);
        let workers = [
            WorkerStat {
                worker: 0,
                alive: true,
                queued: 2,
                inflight: 1,
                routed: 7,
                spilled: 0,
            },
            WorkerStat {
                worker: 1,
                alive: false,
                queued: 0,
                inflight: 0,
                routed: 3,
                spilled: 2,
            },
        ];
        let text = render_prometheus(&snapshot, &obs, &workers);
        assert!(text.ends_with('\n'));
        for line in text.lines() {
            if line.starts_with("# HELP ") || line.starts_with("# TYPE ") {
                continue;
            }
            let (series, value) =
                line.rsplit_once(' ').expect("sample line has a value");
            value.parse::<f64>().expect("value parses as float");
            let name = series.split('{').next().unwrap();
            assert!(!name.is_empty());
            assert!(
                name.chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "bad metric name: {name}"
            );
            assert!(name.starts_with("dyspec_"), "unprefixed series: {name}");
            if let Some(rest) = series.strip_prefix(name) {
                if !rest.is_empty() {
                    assert!(rest.starts_with('{') && rest.ends_with('}'));
                }
            }
        }
        assert!(text.contains("dyspec_admitted 3\n"));
        assert!(text.contains("dyspec_tokens_per_sec 12.5\n"));
        assert!(text.contains(
            "dyspec_round_stage_seconds{stage=\"dispatch\",quantile=\"0.95\"}"
        ));
        assert!(text.contains(
            "dyspec_accept_depth_proposed_total{drafter=\"dyspec\",depth=\"1\"} 1\n"
        ));
        assert!(text.contains("dyspec_accept_prob_accepted_total{drafter=\"dyspec\",bucket=\"7\""));
        assert!(text.contains(
            "dyspec_adaptive_drafter_estimate{drafter=\"dyspec\"} 0.5\n"
        ));
        assert!(text.contains(
            "dyspec_adaptive_drafter_samples_total{drafter=\"dyspec\"} 2\n"
        ));
        assert!(text.contains("dyspec_tracing_enabled 1\n"));
        // Per-worker router rows carry the worker label.
        assert!(text.contains("dyspec_worker_alive{worker=\"0\"} 1\n"));
        assert!(text.contains("dyspec_worker_alive{worker=\"1\"} 0\n"));
        assert!(text.contains("dyspec_worker_queue_depth{worker=\"0\"} 2\n"));
        assert!(text.contains("dyspec_worker_inflight{worker=\"0\"} 1\n"));
        assert!(text.contains("dyspec_worker_routed_total{worker=\"1\"} 3\n"));
        assert!(text.contains("dyspec_worker_spilled_total{worker=\"1\"} 2\n"));
        // Without a router tier the worker series are absent entirely.
        let bare = render_prometheus(&snapshot, &obs, &[]);
        assert!(!bare.contains("dyspec_worker_"));
    }

    #[test]
    fn smoothed_rate_starts_at_half_and_tracks_samples() {
        let rec = AcceptanceRecord::default();
        assert!((rec.smoothed_rate() - 0.5).abs() < 1e-12);
        let mut rec = AcceptanceRecord::default();
        for _ in 0..98 {
            rec.note(1, 0.9, true);
        }
        // 98 accepted of 98: (99)/(100) = 0.99
        assert!((rec.smoothed_rate() - 0.99).abs() < 1e-12);
        for _ in 0..98 {
            rec.note(1, 0.9, false);
        }
        // 98 of 196: (99)/(198) = 0.5
        assert!((rec.smoothed_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn useful_fraction_discounts_rejected_buckets() {
        let rec = AcceptanceRecord::default();
        assert!((rec.useful_fraction(0.25) - 1.0).abs() < 1e-12);
        let mut rec = AcceptanceRecord::default();
        // Bucket 7 (est >= 0.5): 30 proposed, all accepted.
        for _ in 0..30 {
            rec.note(1, 0.9, true);
        }
        // Bucket 0 (est << 1): 10 proposed, none accepted.
        for _ in 0..10 {
            rec.note(2, 1e-4, false);
        }
        // Bucket 0's smoothed rate 1/12 < 0.25: its quarter of the mass
        // is wasted.
        let u = rec.useful_fraction(0.25);
        assert!((u - 0.75).abs() < 1e-12, "useful fraction {u}");
        // A permissive cut counts everything; an impossible cut nothing.
        assert!((rec.useful_fraction(0.0) - 1.0).abs() < 1e-12);
        assert!(rec.useful_fraction(1.0) < 1e-12);
    }

    #[test]
    fn estimates_cover_every_recorded_drafter() {
        let obs = Observatory::new(1, false, 8);
        let t = ComponentTimes::new();
        let mut rec = AcceptanceRecord::default();
        rec.note(1, 0.9, true);
        rec.note(2, 0.9, false);
        obs.record_round(0, TraceId::default(), 1, PolicyKind::DySpec, &t, &rec);
        obs.record_round(0, TraceId::default(), 1, PolicyKind::Chain, &t, &rec);
        obs.record_round(0, TraceId::default(), 1, PolicyKind::DySpec, &t, &rec);
        let est = obs.estimates();
        assert_eq!(est.len(), 2);
        let dy = est.iter().find(|(k, ..)| *k == "dyspec").unwrap();
        assert_eq!(dy.1, 4);
        assert!((dy.2 - 3.0 / 6.0).abs() < 1e-12);
        let ch = est.iter().find(|(k, ..)| *k == "chain").unwrap();
        assert_eq!(ch.1, 2);
        assert!((ch.2 - 2.0 / 4.0).abs() < 1e-12);
    }
}
