//! Sampling primitives for speculative decoding: temperature application,
//! categorical draws, and the zero-and-renormalize scheme used when drawing
//! multiple sibling tokens from one distribution (Algorithm 1 lines 9-11).

use crate::util::math::softmax_temp;
use crate::util::Rng;

/// Convert logits to a sampling distribution at `temp` (0 = greedy one-hot).
pub fn dist_from_logits(logits: &[f32], temp: f32) -> Vec<f32> {
    softmax_temp(logits, temp)
}

/// Draw one index from a normalized distribution via inverse CDF.
/// Falls back to the last positive entry under floating-point slack.
pub fn sample(dist: &[f32], rng: &mut Rng) -> usize {
    debug_assert!(!dist.is_empty());
    let u = rng.next_f64() as f32;
    let mut acc = 0.0f32;
    let mut last_pos = 0;
    for (i, &p) in dist.iter().enumerate() {
        if p > 0.0 {
            last_pos = i;
            acc += p;
            if u < acc {
                return i;
            }
        }
    }
    last_pos
}

/// A distribution we progressively zero-and-renormalize as sibling samples
/// are drawn (the "-/-" residual of Figure 3).
///
/// PERF (§Perf L3.2): the residual is kept UN-normalized with a running
/// `mass`; renormalization is implicit in the scaled inverse-CDF draw and
/// the returned probability `dist[tok]/mass`. This removes two full
/// vocab-length passes (zero + renormalize) per sibling draw versus the
/// textbook Algorithm-1 lines 9-11, with identical semantics (unit tests
/// pin the equivalence).
#[derive(Clone, Debug)]
pub struct SiblingSampler {
    dist: Vec<f32>,
    /// Remaining (un-normalized) mass of `dist`.
    mass: f32,
    exhausted: bool,
}

impl SiblingSampler {
    pub fn new(dist: Vec<f32>) -> Self {
        let mass = dist.iter().sum::<f32>();
        Self {
            exhausted: mass <= 0.0,
            dist,
            mass,
        }
    }

    pub fn exhausted(&self) -> bool {
        self.exhausted
    }

    /// Probability the CURRENT renormalized residual assigns to `tok`.
    pub fn current_prob(&self, tok: usize) -> f32 {
        if self.mass <= 0.0 {
            0.0
        } else {
            self.dist[tok] / self.mass
        }
    }

    /// Draw the next sibling: sample from the current residual, then zero
    /// it out. Returns (token, prob-under-current-residual) — the `R[y]` of
    /// Algorithm 1 line 7 — or None when the draft mass is exhausted.
    pub fn draw(&mut self, rng: &mut Rng) -> Option<(usize, f32)> {
        if self.exhausted {
            return None;
        }
        // Scaled inverse-CDF over the un-normalized residual.
        let u = rng.next_f64() as f32 * self.mass;
        let mut acc = 0.0f32;
        let mut tok = usize::MAX;
        let mut last_pos = usize::MAX;
        for (i, &p) in self.dist.iter().enumerate() {
            if p > 0.0 {
                last_pos = i;
                acc += p;
                if u < acc {
                    tok = i;
                    break;
                }
            }
        }
        if tok == usize::MAX {
            tok = last_pos; // float slack fallback
        }
        if tok == usize::MAX {
            self.exhausted = true;
            return None;
        }
        let p_raw = self.dist[tok];
        let p = (p_raw / self.mass).min(1.0); // float slack on the last token
        self.dist[tok] = 0.0;
        self.mass -= p_raw;
        if self.mass <= 1e-12 {
            self.exhausted = true;
        }
        Some((tok, p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_respects_distribution() {
        let mut rng = Rng::new(1);
        let dist = vec![0.1, 0.6, 0.3];
        let n = 30_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[sample(&dist, &mut rng)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let freq = c as f32 / n as f32;
            assert!((freq - dist[i]).abs() < 0.02, "i={i} freq={freq}");
        }
    }

    #[test]
    fn sample_onehot_is_deterministic() {
        let mut rng = Rng::new(2);
        let dist = vec![0.0, 0.0, 1.0, 0.0];
        for _ in 0..100 {
            assert_eq!(sample(&dist, &mut rng), 2);
        }
    }

    #[test]
    fn sibling_sampler_never_repeats() {
        let mut rng = Rng::new(3);
        let dist = vec![0.4, 0.3, 0.2, 0.1];
        let mut s = SiblingSampler::new(dist);
        let mut seen = Vec::new();
        while let Some((tok, p)) = s.draw(&mut rng) {
            assert!(!seen.contains(&tok), "repeated {tok}");
            assert!(p > 0.0 && p <= 1.0);
            seen.push(tok);
        }
        assert_eq!(seen.len(), 4);
        assert!(s.exhausted());
    }

    #[test]
    fn sibling_sampler_residual_probs_renormalize() {
        // After drawing the 0.5 token, the other entry must have prob 1.
        let mut rng = Rng::new(4);
        let mut s = SiblingSampler::new(vec![0.5, 0.5]);
        let (first, p1) = s.draw(&mut rng).unwrap();
        assert!((p1 - 0.5).abs() < 1e-6);
        let (second, p2) = s.draw(&mut rng).unwrap();
        assert_ne!(first, second);
        assert!((p2 - 1.0).abs() < 1e-6);
        assert!(s.draw(&mut rng).is_none());
    }

    #[test]
    fn onehot_exhausts_after_one_draw() {
        let mut rng = Rng::new(5);
        let mut s = SiblingSampler::new(vec![0.0, 1.0, 0.0]);
        assert_eq!(s.draw(&mut rng).unwrap().0, 1);
        assert!(s.draw(&mut rng).is_none());
    }

    #[test]
    fn dist_from_logits_temp0() {
        let d = dist_from_logits(&[1.0, 5.0, 2.0], 0.0);
        assert_eq!(d, vec![0.0, 1.0, 0.0]);
    }
}
