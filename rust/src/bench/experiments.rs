//! Experiment runners — one per paper table/figure. See DESIGN.md §5 for
//! the experiment index (paper object → workload → modules → bench target).

use std::sync::Arc;

use crate::bench::table::BenchTable;
use crate::config::{
    CacheConfig, Config, EngineConfig, LatencyRegime, PolicyKind, SchedKind,
};
use crate::coordinator::{
    CancelToken, Coordinator, GenEvent, GenParams, Metrics, ModelFactory,
    Request,
};
use crate::sched::Batcher;
use crate::server::{Client, Server};
use crate::data::markov::Corpus;
use crate::data::prompts::PromptSet;
use crate::engine::stats::RunAggregate;
use crate::engine::SpecEngine;
use crate::models::sim::{SimModel, SimSpec};
use crate::models::LogitModel;
use crate::sampling::{dist_from_logits, sample};
use crate::tree::{block_count, block_count_with_prefix, dfs_order, insertion_order, TokenTree, TreeMask, ROOT};
use crate::util::{Histogram, Rng, Timer};

/// Shared experiment options (CLI-overridable).
#[derive(Clone, Debug)]
pub struct ExpOpts {
    /// Prompts per table cell (the paper uses 1000; default trades accuracy
    /// for runtime — crank it up for final numbers).
    pub prompts: usize,
    pub max_new_tokens: usize,
    /// Draft-noise dial for the sim backend (KL(D‖T) knob, paper Eq. 1).
    pub noise: f32,
    pub seed: u64,
    pub out: Option<String>,
}

impl Default for ExpOpts {
    fn default() -> Self {
        Self {
            prompts: 6,
            max_new_tokens: 128,
            noise: 1.2,
            seed: 1,
            out: None,
        }
    }
}

const DATASETS: [&str; 3] = ["c4", "owt", "cnn"];
const TEMPS: [f32; 2] = [0.0, 0.6];

/// Dispatch by experiment name; returns the rendered table(s).
pub fn run_experiment(name: &str, opts: &ExpOpts) -> Result<Vec<BenchTable>, String> {
    let tables = match name {
        "table1" => vec![latency_table(
            "Table 1: latency/token (emitted/step), JF68M->7B regime, budget 64",
            LatencyRegime::pair_7b(),
            64,
            PolicyKind::DySpec,
            opts,
        )],
        "table2" => vec![latency_table(
            "Table 2: latency/token (emitted/step), JF68M->13B regime, budget 64",
            LatencyRegime::pair_13b(),
            64,
            PolicyKind::DySpec,
            opts,
        )],
        "table3" => vec![latency_table(
            "Table 3: latency/token (emitted/step), 7B->70B-offload regime, budget 64",
            LatencyRegime::pair_70b_offload(),
            64,
            PolicyKind::DySpec,
            opts,
        )],
        "table4" => vec![latency_table(
            "Table 4: latency/token (emitted/step), 70B-offload regime, budget 768 (threshold)",
            LatencyRegime::pair_70b_offload(),
            768,
            PolicyKind::DySpecThreshold,
            opts,
        )],
        "table5" | "fig8" => vec![table5_attention(opts)],
        "fig2" => fig2_correlation(opts),
        "fig4" => vec![fig4_breakdown(opts)],
        "fig5" => vec![fig5_treesize(opts)],
        "fig7" => vec![fig7_mask_orders(opts)],
        "fig9" => vec![fig9_blockcount(opts)],
        "ablation" | "ablation_budget" => vec![ablation_budget(opts)],
        "serve" => vec![serve_concurrency(opts)],
        "cache" | "cache_context" => vec![cache_context(opts)],
        "stream" | "stream_latency" => vec![stream_latency(opts)],
        "adaptive" | "adaptive_policy" => vec![adaptive_policy(opts)],
        "route" | "route_affinity" => vec![route_affinity(opts)],
        other => return Err(format!("unknown experiment: {other}")),
    };
    if let Some(out) = &opts.out {
        for (i, t) in tables.iter().enumerate() {
            let path = if tables.len() == 1 {
                out.clone()
            } else {
                format!("{out}.{i}")
            };
            t.write_json(&path).map_err(|e| e.to_string())?;
        }
    }
    Ok(tables)
}

fn build_engine(
    dataset: &str,
    policy: PolicyKind,
    budget: usize,
    temp: f32,
    regime: LatencyRegime,
    opts: &ExpOpts,
) -> SpecEngine {
    let spec = SimSpec::for_dataset(dataset, opts.noise, opts.seed ^ 0xDA7A);
    let (draft, target) = SimModel::pair(spec);
    let cfg = EngineConfig {
        policy,
        tree_budget: budget,
        threshold: if budget >= 512 { 0.001 } else { 1.0 / budget.max(1) as f64 },
        max_depth: if budget >= 512 { 48 } else { 24 },
        target_temp: temp,
        max_new_tokens: opts.max_new_tokens,
        seed: opts.seed,
        ..EngineConfig::default()
    };
    SpecEngine::new(Box::new(draft), Box::new(target), cfg, Some(regime))
}

fn run_cell(
    dataset: &str,
    policy: PolicyKind,
    budget: usize,
    temp: f32,
    regime: LatencyRegime,
    opts: &ExpOpts,
) -> RunAggregate {
    let prompts = PromptSet::by_name(dataset, opts.prompts, 128, opts.seed)
        .expect("dataset profile");
    let mut engine = build_engine(dataset, policy, budget, temp, regime, opts);
    let mut agg = RunAggregate::default();
    for p in prompts.iter() {
        let stats = engine.generate(p);
        agg.add(&stats);
    }
    agg
}

/// Tables 1-4: latency per token with emitted-per-step in parentheses, per
/// dataset × temperature × method.
pub fn latency_table(
    title: &str,
    regime: LatencyRegime,
    budget: usize,
    ours: PolicyKind,
    opts: &ExpOpts,
) -> BenchTable {
    let methods: [(&str, PolicyKind); 5] = [
        ("Ours", ours),
        ("Sequoia", PolicyKind::Sequoia),
        ("Specinfer", PolicyKind::SpecInfer),
        ("Chain", PolicyKind::Chain),
        ("Baseline", PolicyKind::Baseline),
    ];
    let mut table = BenchTable::new(
        title,
        &["Dataset", "Temp", "Ours", "Sequoia", "Specinfer", "Chain", "Baseline"],
    );
    for dataset in DATASETS {
        for temp in TEMPS {
            let mut cells = vec![dataset.to_string(), format!("{temp}")];
            for (_, policy) in methods {
                let agg = run_cell(dataset, policy, budget, temp, regime, opts);
                cells.push(format!(
                    "{:.5}({:.2})",
                    agg.virtual_latency_per_token(),
                    agg.emitted_per_step()
                ));
            }
            table.row(cells);
        }
    }
    table
}

/// Fig 2: (left) acceptance rate vs draft probability; (right) target
/// probability mass vs draft probability — the Hypothesis-1 evidence.
pub fn fig2_correlation(opts: &ExpOpts) -> Vec<BenchTable> {
    let spec = SimSpec::for_dataset("cnn", opts.noise, opts.seed);
    let corpus = Corpus::by_name("cnn").unwrap();
    let mut rng = Rng::new(opts.seed ^ 0xF162);
    const BINS: usize = 10;
    let mut accept_sum = vec![0.0f64; BINS];
    let mut target_sum = vec![0.0f64; BINS];
    let mut count = vec![0usize; BINS];

    let n_ctx = (opts.prompts * 200).max(1000);
    for i in 0..n_ctx {
        let ctx = corpus.generate(16, opts.seed ^ (i as u64 + 1));
        // Paper protocol (§5.1): draft temperature 0.6; we measure against
        // the matching-temperature target rows (the temp-0.6 table setting).
        let d = dist_from_logits(&spec.draft_logits(&ctx), 0.6);
        let t = dist_from_logits(&spec.target_logits(&ctx), 0.6);
        // sample a draft token like the tree builder would
        let y = sample(&d, &mut rng);
        let (dy, ty) = (d[y], t[y]);
        let accept = (ty / dy).min(1.0) as f64;
        let bin = ((dy * BINS as f32) as usize).min(BINS - 1);
        accept_sum[bin] += accept;
        target_sum[bin] += ty as f64;
        count[bin] += 1;
    }

    let mut left = BenchTable::new(
        "Fig 2 (left): acceptance rate vs draft probability (cnn profile)",
        &["draft_prob_bin", "samples", "mean_accept_rate"],
    );
    let mut right = BenchTable::new(
        "Fig 2 (right): target probability vs draft probability (cnn profile)",
        &["draft_prob_bin", "samples", "mean_target_prob"],
    );
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for b in 0..BINS {
        let lo = b as f64 / BINS as f64;
        let hi = (b + 1) as f64 / BINS as f64;
        let n = count[b].max(1) as f64;
        left.row(vec![
            format!("[{lo:.1},{hi:.1})"),
            format!("{}", count[b]),
            format!("{:.4}", accept_sum[b] / n),
        ]);
        right.row(vec![
            format!("[{lo:.1},{hi:.1})"),
            format!("{}", count[b]),
            format!("{:.4}", target_sum[b] / n),
        ]);
        if count[b] > 0 {
            xs.push((lo + hi) / 2.0);
            ys.push(accept_sum[b] / n);
        }
    }
    // Monotone-trend summary row: Pearson r over bin means.
    let r = pearson(&xs, &ys);
    left.row(vec!["pearson_r".into(), format!("{}", xs.len()), format!("{r:.4}")]);
    vec![left, right]
}

fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let cov: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let vx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let vy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    cov / (vx.sqrt() * vy.sqrt()).max(1e-12)
}

/// Fig 4: execution-time breakdown per component, per model-pair regime.
pub fn fig4_breakdown(opts: &ExpOpts) -> BenchTable {
    let mut table = BenchTable::new(
        "Fig 4: component share of step time (virtual regime accounting)",
        &["pair", "draft", "target", "tree_construct", "mask", "sample", "verify"],
    );
    for regime in [
        LatencyRegime::pair_7b(),
        LatencyRegime::pair_13b(),
        LatencyRegime::pair_70b_offload(),
    ] {
        let mut agg = RunAggregate::default();
        let mut draft_dispatch = 0u64;
        let mut steps = 0usize;
        let mut engine = build_engine("c4", PolicyKind::DySpec, 64, 0.6, regime, opts);
        let prompts = PromptSet::by_name("c4", opts.prompts.min(4), 128, opts.seed).unwrap();
        for p in prompts.iter() {
            let stats = engine.generate(p);
            draft_dispatch += stats.total_draft_dispatches();
            steps += stats.steps.len();
            agg.add(&stats);
        }
        let draft_secs = regime.draft_step_secs * draft_dispatch as f64;
        let target_secs = regime.target_step_secs * steps as f64;
        let construct = agg.times.get("tree_construct");
        let mask = agg.times.get("mask");
        let sampling = agg.times.get("sample");
        let verify = agg.times.get("verify");
        let total = draft_secs + target_secs + construct + mask + sampling + verify;
        let pct = |x: f64| format!("{:.2}%", 100.0 * x / total.max(1e-12));
        table.row(vec![
            regime.name.to_string(),
            pct(draft_secs),
            pct(target_secs),
            pct(construct),
            pct(mask),
            pct(sampling),
            pct(verify),
        ]);
    }
    table
}

/// Fig 5: tree size + accepted tokens per step over a long generation
/// (threshold construction, budget 768, thr 0.001, owt, temp 0.6).
pub fn fig5_treesize(opts: &ExpOpts) -> BenchTable {
    let regime = LatencyRegime::pair_70b_offload();
    let mut engine = build_engine("owt", PolicyKind::DySpecThreshold, 768, 0.6, regime, opts);
    engine.cfg.threshold = 0.001;
    engine.cfg.max_depth = 48;
    let prompts = PromptSet::by_name("owt", 1, 128, opts.seed).unwrap();
    let stats = engine.generate(prompts.get(0));

    let mut table = BenchTable::new(
        "Fig 5: per-step tree size and accepted tokens (owt, temp 0.6, budget 768, thr 0.001)",
        &["step", "tree_size", "emitted"],
    );
    let mut sum = 0.0;
    for (i, s) in stats.steps.iter().enumerate() {
        sum += s.tree_size as f64;
        table.row(vec![
            format!("{i}"),
            format!("{}", s.tree_size),
            format!("{}", s.emitted),
        ]);
    }
    table.row(vec![
        "mean".into(),
        format!("{:.2}", sum / stats.steps.len().max(1) as f64),
        format!("{:.2}", stats.mean_emitted_per_step()),
    ]);
    table
}

/// Random tree with uniform random parents (the paper's Table-5 workload).
pub fn random_tree(n: usize, seed: u64) -> TokenTree {
    let mut rng = Rng::new(seed);
    let mut t = TokenTree::new(0, vec![]);
    for i in 0..n {
        let parent = if i == 0 { ROOT } else { rng.next_below(t.num_nodes()) };
        t.add_child(parent, rng.next_below(512) as u32, 0.5);
    }
    t
}

/// Table 5 / Fig 8: block count with/without DFS reorder on random trees,
/// block size 32, sizes 256..2048; plus the projected kernel-time ratio
/// (time ∝ occupied blocks — the kernel-wall-time column is measured by
/// `python -m compile.bench_kernel`, see EXPERIMENTS.md).
pub fn table5_attention(opts: &ExpOpts) -> BenchTable {
    let mut table = BenchTable::new(
        "Table 5 / Fig 8: tree-attention block count, block 32, random trees (mean of 10)",
        &["tree_size", "reorder", "block_count", "reduction", "projected_speedup"],
    );
    for size in [256usize, 512, 1024, 2048] {
        let mut orig = 0.0;
        let mut reord = 0.0;
        const TRIALS: usize = 10;
        for trial in 0..TRIALS {
            let tree = random_tree(size, opts.seed ^ ((size * 31 + trial) as u64));
            let m_orig = TreeMask::from_tree(&tree, &insertion_order(&tree));
            let m_dfs = TreeMask::from_tree(&tree, &dfs_order(&tree));
            orig += block_count(&m_orig, 32) as f64;
            reord += block_count(&m_dfs, 32) as f64;
        }
        orig /= TRIALS as f64;
        reord /= TRIALS as f64;
        table.row(vec![
            format!("{size}"),
            "False".into(),
            format!("{orig:.1}"),
            "1.00x".into(),
            "1.00x".into(),
        ]);
        table.row(vec![
            format!("{size}"),
            "True".into(),
            format!("{reord:.1}"),
            format!("{:.2}x", orig / reord),
            format!("{:.2}x", orig / reord),
        ]);
    }
    table
}

/// Fig 6/7: visualize one tree's attention mask under both orders (density
/// per block row) — numeric stand-in for the paper's mask pictures.
pub fn fig7_mask_orders(opts: &ExpOpts) -> BenchTable {
    let tree = random_tree(128, opts.seed);
    let orders = [
        ("original", insertion_order(&tree)),
        ("dfs", dfs_order(&tree)),
    ];
    let mut table = BenchTable::new(
        "Fig 6/7: mask block occupancy by order (tree 128, block 16)",
        &["order", "block_count", "occupancy_bitmap"],
    );
    for (name, order) in orders {
        let mask = TreeMask::from_tree(&tree, &order);
        let occ = crate::tree::occupancy(&mask, 16);
        let bitmap: String = occ
            .iter()
            .map(|row| {
                row.iter().map(|&b| if b { '#' } else { '.' }).collect::<String>()
            })
            .collect::<Vec<_>>()
            .join("/");
        table.row(vec![
            name.into(),
            format!("{}", block_count(&mask, 16)),
            bitmap,
        ]);
    }
    table
}

/// Fig 9: block count vs prefix length for DySpec-built trees (768/1024),
/// with and without reorder.
pub fn fig9_blockcount(opts: &ExpOpts) -> BenchTable {
    let mut table = BenchTable::new(
        "Fig 9: block count (block 32) vs prefix length, DySpec trees",
        &["tree_size", "prefix", "original", "dfs_reorder", "reduction"],
    );
    for budget in [768usize, 1024] {
        // Build a real workload tree with the greedy policy (the paper's
        // Fig-9 masks come from DySpec runs; greedy trees carry the deep,
        // skewed structure the reorder exploits).
        let spec = SimSpec::for_dataset("owt", opts.noise, opts.seed);
        let (mut draft, _) = SimModel::pair(spec);
        let cfg = EngineConfig {
            policy: PolicyKind::DySpec,
            tree_budget: budget,
            max_depth: 48,
            seed: opts.seed,
            ..EngineConfig::default()
        };
        let policy = crate::draft::dyspec::DySpecPolicy;
        let mut rng = Rng::new(opts.seed);
        let prompts = PromptSet::by_name("owt", 1, 128, opts.seed).unwrap();
        use crate::draft::TreePolicy;
        let tree = policy.build(&mut draft, prompts.get(0), &cfg, &mut rng);

        let m_orig = TreeMask::from_tree(&tree, &insertion_order(&tree));
        let m_dfs = TreeMask::from_tree(&tree, &dfs_order(&tree));
        for prefix in [0usize, 256, 512, 1024, 2048] {
            let orig = block_count_with_prefix(&m_orig, prefix, 32);
            let dfs = block_count_with_prefix(&m_dfs, prefix, 32);
            table.row(vec![
                format!("{} (built {})", budget, tree.size()),
                format!("{prefix}"),
                format!("{orig}"),
                format!("{dfs}"),
                format!("{:.2}x", orig as f64 / dfs as f64),
            ]);
        }
    }
    table
}

/// One serving cell: closed-loop clients against an in-process coordinator
/// (one worker, sim models, 7b virtual-regime accounting). Returns
/// (tokens, wall_secs, worker_virtual_secs, occupancy, per-request virtual
/// latency histogram, per-request TTFT histogram).
fn serve_cell(
    kind: SchedKind,
    clients: usize,
    per_client: usize,
    opts: &ExpOpts,
) -> (usize, f64, f64, f64, Histogram, Histogram) {
    let mut cfg = Config::new();
    cfg.sched.kind = kind;
    cfg.sched.max_active = 16;
    cfg.sched.idle_tick_ms = 2;
    cfg.server.workers = 1;
    cfg.server.queue_capacity = 1024;
    cfg.engine.tree_budget = 24;
    cfg.engine.seed = opts.seed;
    cfg.regime = Some(LatencyRegime::pair_7b());

    let noise = opts.noise;
    let seed = opts.seed;
    let factory: ModelFactory = Arc::new(move || {
        let spec = SimSpec::for_dataset("c4", noise, seed ^ 0xDA7A);
        let (d, t) = SimModel::pair(spec);
        (
            Box::new(d) as Box<dyn LogitModel>,
            Box::new(t) as Box<dyn LogitModel>,
        )
    });
    let coord = Arc::new(Coordinator::start(cfg, factory));
    let prompts = PromptSet::by_name("c4", clients * per_client, 64, opts.seed)
        .expect("dataset profile");

    let t0 = Timer::start();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let coord = coord.clone();
            let mine: Vec<Vec<u32>> = (0..per_client)
                .map(|k| prompts.get(c * per_client + k).to_vec())
                .collect();
            let max_new = opts.max_new_tokens;
            std::thread::spawn(move || {
                let mut out = Vec::new();
                for p in mine {
                    if let Ok(r) = coord.generate(p, max_new, 0.6) {
                        out.push((r.virtual_secs, r.ttft_secs, r.tokens.len()));
                    }
                }
                out
            })
        })
        .collect();

    let mut lat_v = Histogram::new();
    let mut ttft = Histogram::new();
    let mut tokens = 0usize;
    for h in handles {
        for (v, t, n) in h.join().expect("client thread") {
            lat_v.record(v);
            ttft.record(t);
            tokens += n;
        }
    }
    let wall = t0.elapsed_secs();
    let vsecs = coord.metrics.virtual_secs();
    let occupancy = coord.metrics.batch_occupancy();
    shutdown_coordinator(coord);
    (tokens, wall, vsecs, occupancy, lat_v, ttft)
}

/// One reactor cell: `conns` concurrent client connections over REAL
/// sockets against a continuous-batching server on a fixed
/// `reactor_threads`-loop transport — the high-connection regime the
/// thread-per-connection transport could not enter without spawning
/// O(conns) server threads. Each connection streams `per_client`
/// requests back to back. Returns (tokens, wall_secs, virtual_secs,
/// occupancy, per-request virtual-latency histogram, client-observed
/// TTFT histogram, server transport-thread gauge).
#[allow(clippy::type_complexity)]
fn reactor_cell(
    conns: usize,
    per_client: usize,
    reactor_threads: usize,
    opts: &ExpOpts,
) -> (usize, f64, f64, f64, Histogram, Histogram, u64) {
    let mut cfg = Config::new();
    cfg.sched.kind = SchedKind::Continuous;
    cfg.sched.max_active = 32;
    cfg.sched.idle_tick_ms = 2;
    cfg.server.workers = 1;
    cfg.server.queue_capacity = 4096;
    cfg.server.reactor_threads = reactor_threads;
    cfg.server.max_conns = conns + 8; // head-room for the stats client
    cfg.engine.tree_budget = 24;
    cfg.engine.seed = opts.seed;
    cfg.regime = Some(LatencyRegime::pair_7b());

    let noise = opts.noise;
    let seed = opts.seed;
    let factory: ModelFactory = Arc::new(move || {
        let spec = SimSpec::for_dataset("c4", noise, seed ^ 0xDA7A);
        let (d, t) = SimModel::pair(spec);
        (
            Box::new(d) as Box<dyn LogitModel>,
            Box::new(t) as Box<dyn LogitModel>,
        )
    });
    let coord = Arc::new(Coordinator::start(cfg, factory));
    let server =
        Server::bind("127.0.0.1:0", coord.clone()).expect("bind reactor bench");
    let addr = server.local_addr().expect("local addr").to_string();
    let server_thread = std::thread::spawn(move || {
        let _ = server.run();
    });
    let prompts = PromptSet::by_name("c4", conns * per_client, 64, opts.seed)
        .expect("dataset profile");

    let t0 = Timer::start();
    let handles: Vec<_> = (0..conns)
        .map(|c| {
            let addr = addr.clone();
            let mine: Vec<Vec<u32>> = (0..per_client)
                .map(|k| prompts.get(c * per_client + k).to_vec())
                .collect();
            let max_new = opts.max_new_tokens;
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).expect("connect");
                let mut out = Vec::new();
                for (k, p) in mine.iter().enumerate() {
                    let params = GenParams::simple(max_new, 0.6);
                    let sent = Timer::start();
                    let mut first = None;
                    if let Ok((tokens, done)) = client
                        .generate_stream(k as u64 + 1, p, &params, |_| {
                            if first.is_none() {
                                first = Some(sent.elapsed_secs());
                            }
                        })
                    {
                        let vsecs = done
                            .body
                            .get("virtual_secs")
                            .and_then(crate::util::json::Json::as_f64)
                            .unwrap_or(0.0);
                        out.push((
                            vsecs,
                            first.unwrap_or_else(|| sent.elapsed_secs()),
                            tokens.len(),
                        ));
                    }
                }
                out
            })
        })
        .collect();

    let mut lat_v = Histogram::new();
    let mut ttft = Histogram::new();
    let mut tokens = 0usize;
    for h in handles {
        for (v, t, n) in h.join().expect("client thread") {
            lat_v.record(v);
            ttft.record(t);
            tokens += n;
        }
    }
    let wall = t0.elapsed_secs();
    let vsecs = coord.metrics.virtual_secs();
    let occupancy = coord.metrics.batch_occupancy();
    let transport_threads = coord.metrics.transport_threads();
    let mut shut = Client::connect(&addr).expect("shutdown conn");
    shut.shutdown().expect("shutdown");
    server_thread.join().expect("server thread");
    shutdown_coordinator(coord);
    (tokens, wall, vsecs, occupancy, lat_v, ttft, transport_threads)
}

/// Mixed-workload cell (ISSUE 10 acceptance): 15 chatter requests
/// (64-token prompts) stream on the continuous batcher; three steps in, a
/// cold 4096-token prompt arrives. With `chunk=0` its whole prompt lands
/// inside one co-batched dispatch (the chatters' inter-token gap spikes
/// by the full prefill bill); with chunking on it enters as
/// `chunk`-token rows under the prefill budget split. Driven on a bare
/// `Batcher` so admission timing — and therefore the virtual-time
/// accounting — is deterministic. Returns (tokens, wall, vsecs,
/// occupancy, per-request virtual-latency hist, chatter virtual-TTFT
/// hist, chatter inter-chunk virtual-gap hist, long request's virtual
/// TTFT).
#[allow(clippy::type_complexity)]
fn serve_mixed_cell(
    chunk: usize,
    opts: &ExpOpts,
) -> (usize, f64, f64, f64, Histogram, Histogram, Histogram, f64) {
    const CHATTERS: usize = 15;
    let mut cfg = Config::new();
    cfg.sched.kind = SchedKind::Continuous;
    cfg.sched.max_active = 16;
    // Budget split: speculation keeps a healthy pool even while the
    // reserved prefill tokens are in use.
    cfg.sched.global_budget = 320;
    cfg.sched.prefill_budget = chunk;
    cfg.engine.prefill_chunk = chunk;
    cfg.engine.tree_budget = 24;
    cfg.engine.seed = opts.seed;
    cfg.regime = Some(LatencyRegime::pair_7b());

    let spec = SimSpec::for_dataset("c4", opts.noise, opts.seed ^ 0xDA7A);
    let (d, t) = SimModel::pair(spec);
    let metrics = Arc::new(Metrics::new());
    let mut b = Batcher::new(
        0,
        cfg,
        Box::new(d),
        Box::new(t),
        metrics.clone(),
    );
    let prompts = PromptSet::by_name("c4", CHATTERS, 64, opts.seed)
        .expect("dataset profile");

    struct Tracked {
        rx: std::sync::mpsc::Receiver<GenEvent>,
        admitted_virt: f64,
        first_virt: Option<f64>,
        long: bool,
        resp: Option<Box<crate::coordinator::Response>>,
    }
    let submit = |b: &mut Batcher,
                  id: u64,
                  prompt: Vec<u32>,
                  max_new: usize,
                  long: bool,
                  virt: f64| {
        let (tx, rx) = std::sync::mpsc::channel();
        b.admit(Request {
            id,
            prompt,
            params: GenParams::simple(max_new, 0.6),
            submitted_at: std::time::Instant::now(),
            cancel: CancelToken::new(),
            events: Box::new(tx),
            trace: 0,
        });
        Tracked {
            rx,
            admitted_virt: virt,
            first_virt: None,
            long,
            resp: None,
        }
    };

    let t0 = Timer::start();
    let mut virt_acc = 0.0f64;
    let mut tracked: Vec<Tracked> = (0..CHATTERS)
        .map(|c| {
            submit(
                &mut b,
                c as u64 + 1,
                prompts.get(c).to_vec(),
                32,
                false,
                virt_acc,
            )
        })
        .collect();
    let mut itl = Histogram::new();
    let drain = |tracked: &mut Vec<Tracked>,
                 itl: &mut Histogram,
                 virt_acc: f64| {
        for tr in tracked.iter_mut() {
            loop {
                match tr.rx.try_recv() {
                    Ok(GenEvent::Chunk { stats, .. }) => {
                        if tr.first_virt.is_none() {
                            tr.first_virt = Some(virt_acc - tr.admitted_virt);
                        } else if !tr.long {
                            itl.record(stats.virtual_secs);
                        }
                    }
                    Ok(GenEvent::Done(resp)) => tr.resp = Some(resp),
                    Err(_) => break,
                }
            }
        }
    };
    // Three warm steps, then the long prompt arrives mid-stream.
    for _ in 0..3 {
        virt_acc += b.step().virtual_secs;
        drain(&mut tracked, &mut itl, virt_acc);
    }
    let long_prompt: Vec<u32> =
        (0..4096u32).map(|k| (k * 11 + 3) % 64).collect();
    tracked.push(submit(
        &mut b,
        CHATTERS as u64 + 1,
        long_prompt,
        16,
        true,
        virt_acc,
    ));
    while b.active() > 0 {
        virt_acc += b.step().virtual_secs;
        drain(&mut tracked, &mut itl, virt_acc);
    }
    drain(&mut tracked, &mut itl, virt_acc);
    let wall = t0.elapsed_secs();

    let mut lat_v = Histogram::new();
    let mut ttft = Histogram::new();
    let mut ttft_long = 0.0f64;
    let mut tokens = 0usize;
    for tr in &tracked {
        let resp = tr.resp.as_ref().expect("request did not complete");
        tokens += resp.tokens.len();
        lat_v.record(resp.virtual_secs);
        let first = tr.first_virt.expect("request never emitted");
        if tr.long {
            ttft_long = first;
        } else {
            ttft.record(first);
        }
    }
    let vsecs = metrics.virtual_secs();
    let occupancy = metrics.batch_occupancy();
    (tokens, wall, vsecs, occupancy, lat_v, ttft, itl, ttft_long)
}

/// Serving benchmark (ROADMAP "heavy traffic" deliverable): throughput and
/// latency vs concurrency, fcfs vs continuous, on the sim model pair with
/// 7b-regime virtual accounting. Throughput is tokens per VIRTUAL second —
/// the regime-correct metric: continuous batching packs every active
/// sequence into one target dispatch, so it strictly beats FCFS once
/// clients > 1. The trailing `continuous+reactor` rows drive REAL sockets
/// at 64/256 concurrent connections over a 4-loop reactor transport
/// (srv_threads stays 4, not O(conns)). `--out BENCH_serve.json` records
/// the trajectory.
pub fn serve_concurrency(opts: &ExpOpts) -> BenchTable {
    let mut table = BenchTable::new(
        "Serve: throughput/latency vs concurrency, fcfs vs continuous (sim, 7b regime, 1 worker); reactor rows over real sockets; mixed rows = 15 chatters + 1x4096-token arrival, chunked prefill off/on",
        &[
            "scheduler",
            "clients",
            "requests",
            "tokens",
            "tok_per_vsec",
            "wall_tok_per_sec",
            "lat_p50_vsec",
            "lat_p99_vsec",
            "ttft_p50_s",
            "occupancy",
            "srv_threads",
            "itl_p95",
            "ttft_long",
        ],
    );
    let per_client = opts.prompts.max(1);
    for kind in [SchedKind::Fcfs, SchedKind::Continuous] {
        for clients in [1usize, 4, 16] {
            let (tokens, wall, vsecs, occupancy, lat_v, ttft) =
                serve_cell(kind, clients, per_client, opts);
            table.row(vec![
                kind.name().into(),
                format!("{clients}"),
                format!("{}", clients * per_client),
                format!("{tokens}"),
                format!("{:.1}", tokens as f64 / vsecs.max(1e-9)),
                format!("{:.1}", tokens as f64 / wall.max(1e-9)),
                format!("{:.4}", lat_v.p50()),
                format!("{:.4}", lat_v.p99()),
                format!("{:.4}", ttft.p50()),
                format!("{:.2}", occupancy),
                "-".into(), // in-process cells: no transport
                "-".into(), // itl_p95: mixed rows only
                "-".into(), // ttft_long: mixed rows only
            ]);
        }
    }
    const REACTOR_THREADS: usize = 4;
    for conns in [64usize, 256] {
        let (tokens, wall, vsecs, occupancy, lat_v, ttft, threads) =
            reactor_cell(conns, per_client, REACTOR_THREADS, opts);
        table.row(vec![
            "continuous+reactor".into(),
            format!("{conns}"),
            format!("{}", conns * per_client),
            format!("{tokens}"),
            format!("{:.1}", tokens as f64 / vsecs.max(1e-9)),
            format!("{:.1}", tokens as f64 / wall.max(1e-9)),
            format!("{:.4}", lat_v.p50()),
            format!("{:.4}", lat_v.p99()),
            format!("{:.4}", ttft.p50()),
            format!("{:.2}", occupancy),
            format!("{threads}"),
            "-".into(),
            "-".into(),
        ]);
    }
    // Mixed rows (ISSUE 10): a long cold arrival mid-stream; the chunked
    // row must show strictly lower chatter inter-token p95 (virtual secs
    // per co-batched round) at <= 5% total-virtual-time regression.
    for chunk in [0usize, 256] {
        let (tokens, wall, vsecs, occupancy, lat_v, ttft, itl, ttft_long) =
            serve_mixed_cell(chunk, opts);
        table.row(vec![
            if chunk == 0 {
                "mixed".into()
            } else {
                format!("mixed+chunk{chunk}")
            },
            "16".into(),
            "16".into(),
            format!("{tokens}"),
            format!("{:.1}", tokens as f64 / vsecs.max(1e-9)),
            format!("{:.1}", tokens as f64 / wall.max(1e-9)),
            format!("{:.4}", lat_v.p50()),
            format!("{:.4}", lat_v.p99()),
            format!("{:.4}", ttft.p50()),
            format!("{:.2}", occupancy),
            "-".into(),
            format!("{:.5}", itl.p95()),
            format!("{:.4}", ttft_long),
        ]);
    }
    table
}

/// Shut the coordinator down once the last Arc clone outside this call
/// dies. Detached server connection threads hold clones for a few ms
/// after `Server::run` returns, so a bare `Arc::try_unwrap` would
/// silently skip the shutdown and leak an idle-polling worker thread
/// into the next bench cell.
fn shutdown_coordinator(mut coord: Arc<Coordinator>) {
    for _ in 0..2000 {
        match Arc::try_unwrap(coord) {
            Ok(c) => {
                c.shutdown();
                return;
            }
            Err(shared) => {
                coord = shared;
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
    }
    crate::log_warn!("bench coordinator still shared after 4s; leaking workers");
}

/// One streaming cell: closed-loop clients over REAL sockets against a
/// continuous-batching server, measuring client-observed latencies.
/// Returns (tokens, ttft histogram, inter-chunk-gap histogram, e2e
/// histogram). `stream=false` drives the same protocol-v1 envelope with
/// one-shot replies, so its "TTFT" is the full-reply arrival — the
/// baseline the streaming surface beats.
fn stream_cell(
    clients: usize,
    per_client: usize,
    stream: bool,
    opts: &ExpOpts,
) -> (usize, Histogram, Histogram, Histogram) {
    let mut cfg = Config::new();
    cfg.sched.kind = SchedKind::Continuous;
    cfg.sched.max_active = 16;
    cfg.sched.idle_tick_ms = 2;
    cfg.server.workers = 1;
    cfg.server.queue_capacity = 1024;
    cfg.engine.tree_budget = 8;
    cfg.engine.seed = opts.seed;
    cfg.regime = Some(LatencyRegime::pair_7b());

    let noise = opts.noise;
    let seed = opts.seed;
    let factory: ModelFactory = Arc::new(move || {
        let spec = SimSpec::for_dataset("c4", noise, seed ^ 0xDA7A);
        let (d, t) = SimModel::pair(spec);
        (
            Box::new(d) as Box<dyn LogitModel>,
            Box::new(t) as Box<dyn LogitModel>,
        )
    });
    let coord = Arc::new(Coordinator::start(cfg, factory));
    let server =
        Server::bind("127.0.0.1:0", coord.clone()).expect("bind stream bench");
    let addr = server.local_addr().expect("local addr").to_string();
    let server_thread = std::thread::spawn(move || {
        let _ = server.run();
    });
    let prompts = PromptSet::by_name("c4", clients * per_client, 64, opts.seed)
        .expect("dataset profile");

    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let addr = addr.clone();
            let mine: Vec<Vec<u32>> = (0..per_client)
                .map(|k| prompts.get(c * per_client + k).to_vec())
                .collect();
            let max_new = opts.max_new_tokens;
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).expect("connect");
                let mut out = Vec::new();
                for (k, p) in mine.iter().enumerate() {
                    let params = GenParams::simple(max_new, 0.6);
                    let t0 = Timer::start();
                    let mut arrivals: Vec<f64> = Vec::new();
                    let result = if stream {
                        client.generate_stream(k as u64 + 1, p, &params, |_| {
                            arrivals.push(t0.elapsed_secs());
                        })
                    } else {
                        client.generate_oneshot(k as u64 + 1, p, &params).map(
                            |(tokens, done)| {
                                arrivals.push(t0.elapsed_secs());
                                (tokens, done)
                            },
                        )
                    };
                    let e2e = t0.elapsed_secs();
                    if let Ok((tokens, _done)) = result {
                        out.push((arrivals, e2e, tokens.len()));
                    }
                }
                out
            })
        })
        .collect();

    let mut ttft = Histogram::new();
    let mut gap = Histogram::new();
    let mut e2e_hist = Histogram::new();
    let mut tokens = 0usize;
    for h in handles {
        for (arrivals, e2e, n) in h.join().expect("client thread") {
            if let Some(&first) = arrivals.first() {
                ttft.record(first);
            }
            for w in arrivals.windows(2) {
                gap.record(w[1] - w[0]);
            }
            e2e_hist.record(e2e);
            tokens += n;
        }
    }
    let mut shut = Client::connect(&addr).expect("shutdown conn");
    shut.shutdown().expect("shutdown");
    server_thread.join().expect("server thread");
    shutdown_coordinator(coord);
    (tokens, ttft, gap, e2e_hist)
}

/// Streaming benchmark (ISSUE 3 deliverable): client-observed TTFT and
/// inter-chunk latency, streaming vs one-shot, at 1/4/16 closed-loop
/// clients over real TCP. Streaming's first token leaves the server at the
/// first accepted round, so its TTFT undercuts the one-shot reply arrival
/// by roughly the round count. `--out BENCH_stream.json` records the
/// trajectory.
pub fn stream_latency(opts: &ExpOpts) -> BenchTable {
    let mut table = BenchTable::new(
        "Stream: client-observed TTFT + inter-chunk latency, streaming vs one-shot (continuous, sim, 1 worker)",
        &[
            "mode",
            "clients",
            "requests",
            "tokens",
            "ttft_p50_s",
            "ttft_p99_s",
            "gap_p50_s",
            "gap_p99_s",
            "e2e_p50_s",
        ],
    );
    let per_client = opts.prompts.max(1);
    for stream in [false, true] {
        for clients in [1usize, 4, 16] {
            let (tokens, ttft, gap, e2e) =
                stream_cell(clients, per_client, stream, opts);
            table.row(vec![
                if stream { "stream" } else { "oneshot" }.into(),
                format!("{clients}"),
                format!("{}", clients * per_client),
                format!("{tokens}"),
                format!("{:.5}", ttft.p50()),
                format!("{:.5}", ttft.p99()),
                format!("{:.5}", gap.p50()),
                format!("{:.5}", gap.p99()),
                format!("{:.5}", e2e.p50()),
            ]);
        }
    }
    table
}

/// One cache-bench cell: mean billed verify positions/step, virtual
/// latency/token, and cache hit rate for a prompt length.
fn cache_cell(
    prompt_len: usize,
    enabled: bool,
    opts: &ExpOpts,
) -> (f64, f64, f64) {
    let spec = SimSpec::for_dataset("c4", opts.noise, opts.seed ^ 0xDA7A);
    let (draft, target) = SimModel::pair(spec);
    let cfg = EngineConfig {
        policy: PolicyKind::DySpec,
        tree_budget: 32,
        max_new_tokens: opts.max_new_tokens,
        target_temp: 0.6,
        seed: opts.seed,
        ..EngineConfig::default()
    };
    let mut engine =
        SpecEngine::new(Box::new(draft), Box::new(target), cfg, Some(LatencyRegime::pair_7b()))
            .with_cache(&CacheConfig {
                enabled,
                ..CacheConfig::default()
            });
    let prompts =
        PromptSet::by_name("c4", opts.prompts.max(1), prompt_len, opts.seed)
            .expect("dataset profile");
    let (mut billed, mut cached, mut steps, mut vsecs, mut tokens) =
        (0u64, 0u64, 0usize, 0.0f64, 0usize);
    for p in prompts.iter() {
        let stats = engine.generate(p);
        billed += stats.total_billed_positions();
        cached += stats.total_cached_positions();
        steps += stats.steps.len();
        vsecs += stats.total_virtual_secs();
        tokens += stats.tokens.len();
    }
    let pos_per_step = billed as f64 / steps.max(1) as f64;
    let lat = vsecs / tokens.max(1) as f64;
    let hit = if billed + cached == 0 {
        0.0
    } else {
        cached as f64 / (billed + cached) as f64
    };
    (pos_per_step, lat, hit)
}

/// One shared-prefix cell: `clients` sequential requests on one engine,
/// every prompt = one shared system prompt of `prompt_len` tokens + a
/// per-client suffix, KV cache on, radix tree on/off. Returns (mean
/// billed positions/step, virtual latency/token, cache hit rate, total
/// warm-start tokens, radix hit rate).
fn shared_prefix_cell(
    prompt_len: usize,
    clients: usize,
    radix: bool,
    opts: &ExpOpts,
) -> (f64, f64, f64, u64, f64) {
    let spec = SimSpec::for_dataset("c4", opts.noise, opts.seed ^ 0xDA7A);
    let (draft, target) = SimModel::pair(spec);
    let cfg = EngineConfig {
        policy: PolicyKind::DySpec,
        tree_budget: 32,
        max_new_tokens: opts.max_new_tokens,
        target_temp: 0.6,
        seed: opts.seed,
        ..EngineConfig::default()
    };
    let mut engine =
        SpecEngine::new(Box::new(draft), Box::new(target), cfg, Some(LatencyRegime::pair_7b()))
            .with_cache(&CacheConfig {
                enabled: true,
                radix,
                ..CacheConfig::default()
            });
    let system = PromptSet::by_name("c4", 1, prompt_len, opts.seed)
        .expect("dataset profile")
        .iter()
        .next()
        .expect("one prompt")
        .to_vec();
    let (mut billed, mut cached, mut steps, mut vsecs, mut tokens) =
        (0u64, 0u64, 0usize, 0.0f64, 0usize);
    let mut warm = 0u64;
    for c in 0..clients {
        // Same per-client seed radix on and off: the streams (and hence
        // the step counts) are identical, only the billing moves.
        engine.reseed(opts.seed ^ (c as u64 + 1));
        let mut p = system.clone();
        p.push((c as u32 % 32) + 1);
        let stats = engine.generate(&p);
        billed += stats.total_billed_positions();
        cached += stats.total_cached_positions();
        steps += stats.steps.len();
        vsecs += stats.total_virtual_secs();
        tokens += stats.tokens.len();
        warm += stats.total_warm_start_tokens();
    }
    let s = engine.cache().radix_stats();
    let radix_hit_rate = if s.lookups == 0 {
        0.0
    } else {
        s.hits as f64 / s.lookups as f64
    };
    let pos_per_step = billed as f64 / steps.max(1) as f64;
    let lat = vsecs / tokens.max(1) as f64;
    let hit = if billed + cached == 0 {
        0.0
    } else {
        cached as f64 / (billed + cached) as f64
    };
    (pos_per_step, lat, hit, warm, radix_hit_rate)
}

/// Cache experiment (the tentpole bench), two sweeps in one table:
///
///   - `context` rows — cached vs uncached verification cost as ONE
///     request's context grows. Uncached scoring re-bills the whole
///     prefix every round, so billed positions/step and virtual
///     latency/token climb with context length; with the KV prefix cache
///     both stay proportional to the speculated tree.
///   - `shared` rows — N clients sharing a system prompt, radix prefix
///     cache off vs on (KV cache on in both): with the radix tree every
///     client after the first starts warm at the shared prefix, so the
///     first-round prompt bill collapses and `warm_start_tokens` /
///     `radix_hit_rate` report the cross-request reuse.
///
/// `--out BENCH_cache.json` records the trajectory.
pub fn cache_context(opts: &ExpOpts) -> BenchTable {
    let mut table = BenchTable::new(
        "Cache: verify cost vs context length (cache off vs on) and vs shared prefixes (radix off vs on) (c4, dyspec, budget 32, 7b regime)",
        &[
            "scenario",
            "prompt_len",
            "clients",
            "uncached_pos_per_step",
            "cached_pos_per_step",
            "pos_reduction",
            "uncached_lat_per_tok",
            "cached_lat_per_tok",
            "lat_speedup",
            "hit_rate",
            "warm_start_tokens",
            "radix_hit_rate",
        ],
    );
    for prompt_len in [64usize, 256, 512, 1024] {
        let (cold_pos, cold_lat, _) = cache_cell(prompt_len, false, opts);
        let (warm_pos, warm_lat, hit) = cache_cell(prompt_len, true, opts);
        table.row(vec![
            "context".into(),
            format!("{prompt_len}"),
            "1".into(),
            format!("{cold_pos:.1}"),
            format!("{warm_pos:.1}"),
            format!("{:.2}x", cold_pos / warm_pos.max(1e-9)),
            format!("{cold_lat:.5}"),
            format!("{warm_lat:.5}"),
            format!("{:.2}x", cold_lat / warm_lat.max(1e-12)),
            format!("{hit:.3}"),
            "0".into(),
            "0.000".into(),
        ]);
    }
    // Shared-prefix sweep: "uncached" = radix off, "cached" = radix on.
    let clients = 4usize;
    for prompt_len in [64usize, 256, 1024] {
        let (cold_pos, cold_lat, _, _, _) =
            shared_prefix_cell(prompt_len, clients, false, opts);
        let (warm_pos, warm_lat, hit, warm_tokens, radix_hit) =
            shared_prefix_cell(prompt_len, clients, true, opts);
        table.row(vec![
            "shared".into(),
            format!("{prompt_len}"),
            format!("{clients}"),
            format!("{cold_pos:.1}"),
            format!("{warm_pos:.1}"),
            format!("{:.2}x", cold_pos / warm_pos.max(1e-9)),
            format!("{cold_lat:.5}"),
            format!("{warm_lat:.5}"),
            format!("{:.2}x", cold_lat / warm_lat.max(1e-12)),
            format!("{hit:.3}"),
            format!("{warm_tokens}"),
            format!("{radix_hit:.3}"),
        ]);
    }
    table
}

/// One adaptive-bench cell: a mixed workload (temperatures 0.0/0.6/1.0
/// interleaved across closed-loop clients) through an in-process
/// continuous coordinator. `policy: Some(k)` pins the static drafter;
/// `None` runs `policy_mode=adaptive` over `drafters`. Returns
/// (tokens, rounds, virtual_secs).
fn adaptive_cell(
    policy: Option<PolicyKind>,
    drafters: &str,
    opts: &ExpOpts,
) -> (usize, usize, f64) {
    let mut cfg = Config::new();
    cfg.sched.kind = SchedKind::Continuous;
    cfg.sched.max_active = 8;
    cfg.sched.idle_tick_ms = 2;
    cfg.server.workers = 1;
    cfg.server.queue_capacity = 1024;
    cfg.engine.tree_budget = 24;
    cfg.engine.seed = opts.seed;
    cfg.regime = Some(LatencyRegime::pair_7b());
    match policy {
        Some(p) => cfg.engine.policy = p,
        None => {
            cfg.set("policy_mode", "adaptive").expect("mode key");
            cfg.set("adapt_drafters", drafters).expect("drafter key");
            // Bench-scale exploration: warm every arm within the first
            // few rounds so exploitation dominates the measurement.
            cfg.set("adapt_min_samples", "16").expect("samples key");
        }
    }

    let noise = opts.noise;
    let seed = opts.seed;
    let factory: ModelFactory = Arc::new(move || {
        let spec = SimSpec::for_dataset("c4", noise, seed ^ 0xDA7A);
        let (d, t) = SimModel::pair(spec);
        (
            Box::new(d) as Box<dyn LogitModel>,
            Box::new(t) as Box<dyn LogitModel>,
        )
    });
    let coord = Arc::new(Coordinator::start(cfg, factory));
    const CLIENTS: usize = 4;
    let per_client = opts.prompts.max(1);
    let prompts =
        PromptSet::by_name("c4", CLIENTS * per_client, 64, opts.seed)
            .expect("dataset profile");
    const TEMPS_MIX: [f32; 3] = [0.0, 0.6, 1.0];

    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let coord = coord.clone();
            let mine: Vec<Vec<u32>> = (0..per_client)
                .map(|k| prompts.get(c * per_client + k).to_vec())
                .collect();
            let max_new = opts.max_new_tokens;
            std::thread::spawn(move || {
                let mut out = Vec::new();
                for (k, p) in mine.into_iter().enumerate() {
                    let temp = TEMPS_MIX[(c + k) % TEMPS_MIX.len()];
                    if let Ok(r) = coord.generate(p, max_new, temp) {
                        out.push((r.tokens.len(), r.steps, r.virtual_secs));
                    }
                }
                out
            })
        })
        .collect();

    let (mut tokens, mut rounds, mut vsecs) = (0usize, 0usize, 0.0f64);
    for h in handles {
        for (n, s, v) in h.join().expect("client thread") {
            tokens += n;
            rounds += s;
            vsecs += v;
        }
    }
    shutdown_coordinator(coord);
    (tokens, rounds, vsecs)
}

/// Adaptive-policy benchmark (ISSUE 7 tentpole): per-round accepted-token
/// rate on a mixed workload, each static drafter vs the online-adaptive
/// controller over the same drafter set. The acceptance criterion is that
/// the adaptive row's rate lands at or above the best static row's within
/// noise — it pays a bounded exploration tax to find that drafter online.
/// `--out BENCH_adaptive.json` records the trajectory.
pub fn adaptive_policy(opts: &ExpOpts) -> BenchTable {
    const DRAFTERS: &str = "dyspec,chain,specinfer";
    let mut table = BenchTable::new(
        "Adaptive: accepted tokens/round, static drafters vs online-adaptive selection (mixed temps, continuous, sim, 7b regime)",
        &[
            "policy",
            "requests",
            "tokens",
            "rounds",
            "accepted_per_round",
            "lat_per_tok_vsec",
        ],
    );
    let per_client = opts.prompts.max(1);
    let cells: [(String, Option<PolicyKind>); 4] = [
        ("dyspec".into(), Some(PolicyKind::DySpec)),
        ("chain".into(), Some(PolicyKind::Chain)),
        ("specinfer".into(), Some(PolicyKind::SpecInfer)),
        (format!("adaptive({DRAFTERS})"), None),
    ];
    for (name, policy) in cells {
        let (tokens, rounds, vsecs) = adaptive_cell(policy, DRAFTERS, opts);
        table.row(vec![
            name,
            format!("{}", 4 * per_client),
            format!("{tokens}"),
            format!("{rounds}"),
            format!("{:.3}", tokens as f64 / rounds.max(1) as f64),
            format!("{:.5}", vsecs / tokens.max(1) as f64),
        ]);
    }
    table
}

/// One route-bench cell: a shared-prefix workload (4 prefix groups, each
/// request = its group's 16-token prefix + a unique 48-token suffix)
/// through an FCFS coordinator with `workers` workers under `mode`
/// routing. Per-request seeds pin every generation deterministic
/// regardless of which worker serves it, so cross-mode differences
/// isolate routing. Returns (tokens, rounds, cache_hit_rate,
/// prefix_locality, spilled) where prefix_locality is the mean fraction
/// of a group's requests served by the group's modal worker (affinity →
/// 1.0 minus spills; rr at 4 workers → ≈ 0.25–0.5).
fn route_cell(
    workers: usize,
    mode: &str,
    opts: &ExpOpts,
) -> (usize, usize, f64, f64, u64) {
    const GROUPS: usize = 4;
    const PREFIX: usize = 16;
    let per_group = opts.prompts.max(1);
    let total = GROUPS * per_group;

    let mut cfg = Config::new();
    cfg.server.workers = workers;
    cfg.server.queue_capacity = 1024;
    cfg.engine.tree_budget = 24;
    cfg.engine.seed = opts.seed;
    cfg.regime = Some(LatencyRegime::pair_7b());
    cfg.set("route", mode).expect("route key");
    cfg.set("route_prefix_len", &PREFIX.to_string())
        .expect("route_prefix_len key");

    let noise = opts.noise;
    let seed = opts.seed;
    let factory: ModelFactory = Arc::new(move || {
        let spec = SimSpec::for_dataset("c4", noise, seed ^ 0xDA7A);
        let (d, t) = SimModel::pair(spec);
        (
            Box::new(d) as Box<dyn LogitModel>,
            Box::new(t) as Box<dyn LogitModel>,
        )
    });
    let coord = Arc::new(Coordinator::start(cfg, factory));

    let prefixes = PromptSet::by_name("c4", GROUPS, PREFIX, opts.seed)
        .expect("dataset profile");
    let suffixes = PromptSet::by_name("c4", total, 48, opts.seed ^ 0x51F)
        .expect("dataset profile");

    let handles: Vec<_> = (0..total)
        .map(|i| {
            // Blocked group assignment (g, g, g, ... per group) so the
            // rr baseline's cursor cannot accidentally align with the
            // group period and fake affinity.
            let g = i / per_group;
            let mut p = prefixes.get(g).to_vec();
            p.extend_from_slice(suffixes.get(i));
            let params = GenParams {
                seed: Some(opts.seed ^ (0x9E37 * (i as u64 + 1))),
                ..GenParams::simple(opts.max_new_tokens, 0.6)
            };
            (g, coord.try_submit(p, params).expect("route admission"))
        })
        .collect();

    let mut group_workers =
        vec![std::collections::BTreeMap::<usize, usize>::new(); GROUPS];
    let (mut tokens, mut rounds) = (0usize, 0usize);
    for (g, h) in handles {
        let r = h.wait().expect("routed request completed");
        tokens += r.tokens.len();
        rounds += r.steps;
        *group_workers[g].entry(r.worker).or_insert(0) += 1;
    }
    let locality = group_workers
        .iter()
        .map(|m| {
            m.values().copied().max().unwrap_or(0) as f64 / per_group as f64
        })
        .sum::<f64>()
        / GROUPS as f64;
    let hit = coord.metrics.cache_hit_rate();
    let spilled = coord.metrics.router_spilled();
    shutdown_coordinator(coord);
    (tokens, rounds, hit, locality, spilled)
}

/// Route benchmark (ISSUE 8 tentpole): 1 vs 4 workers × affinity vs
/// round-robin on the shared-prefix workload. With today's per-sequence
/// KV cache the hit-rate criterion is parity (affinity ≥ rr: a request's
/// residency never depends on which worker holds it when generation is
/// seeded), while `prefix_locality` shows the property affinity actually
/// buys — each prefix group concentrates on one worker, which is what
/// the planned cross-request radix cache converts into warm starts.
/// `--out BENCH_route.json` records the grid.
pub fn route_affinity(opts: &ExpOpts) -> BenchTable {
    let mut table = BenchTable::new(
        "Route: prefix-affinity vs round-robin, 1 vs 4 workers (shared-prefix workload, fcfs, sim, 7b regime)",
        &[
            "workers",
            "route",
            "requests",
            "tokens",
            "cache_hit_rate",
            "accepted_per_round",
            "prefix_locality",
            "spilled",
        ],
    );
    for (workers, mode) in
        [(1usize, "affinity"), (1, "rr"), (4, "affinity"), (4, "rr")]
    {
        let (tokens, rounds, hit, locality, spilled) =
            route_cell(workers, mode, opts);
        table.row(vec![
            format!("{workers}"),
            mode.into(),
            format!("{}", 4 * opts.prompts.max(1)),
            format!("{tokens}"),
            format!("{hit:.3}"),
            format!("{:.3}", tokens as f64 / rounds.max(1) as f64),
            format!("{locality:.3}"),
            format!("{spilled}"),
        ]);
    }
    table
}

/// Ablation (DESIGN.md §5 footnote): accepted tokens/step and 7B-regime
/// latency as the speculative budget grows, dynamic (DySpec) vs the best
/// fixed-shape baseline (Sequoia) — the paper's §1 motivation that fixed
/// trees' acceptance stalls as tree size grows while dynamic trees keep
/// converting budget into accepted tokens.
pub fn ablation_budget(opts: &ExpOpts) -> BenchTable {
    let regime = LatencyRegime::pair_7b();
    let mut table = BenchTable::new(
        "Ablation: accepted/step and latency vs budget (c4, temp 0.6, 7b regime)",
        &["budget", "dyspec", "dyspec_lat", "sequoia", "sequoia_lat", "dynamic_gain"],
    );
    for budget in [8usize, 16, 32, 64, 128, 256] {
        let dy = run_cell("c4", PolicyKind::DySpec, budget, 0.6, regime, opts);
        let seq = run_cell("c4", PolicyKind::Sequoia, budget, 0.6, regime, opts);
        table.row(vec![
            format!("{budget}"),
            format!("{:.2}", dy.emitted_per_step()),
            format!("{:.5}", dy.virtual_latency_per_token()),
            format!("{:.2}", seq.emitted_per_step()),
            format!("{:.5}", seq.virtual_latency_per_token()),
            format!("{:.2}x", dy.emitted_per_step() / seq.emitted_per_step()),
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> ExpOpts {
        ExpOpts {
            prompts: 2,
            max_new_tokens: 16,
            ..ExpOpts::default()
        }
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(run_experiment("table99", &quick()).is_err());
    }

    #[test]
    fn table1_has_all_cells() {
        let t = &run_experiment("table1", &quick()).unwrap()[0];
        assert_eq!(t.rows.len(), 6); // 3 datasets x 2 temps
        assert_eq!(t.headers.len(), 7);
        // every cell parses as "lat(acc)"
        for row in &t.rows {
            for cell in &row[2..] {
                assert!(cell.contains('('), "cell {cell}");
            }
        }
    }

    #[test]
    fn fig2_shows_positive_correlation() {
        let tables = run_experiment("fig2", &quick()).unwrap();
        let left = &tables[0];
        let last = left.rows.last().unwrap();
        assert_eq!(last[0], "pearson_r");
        let r: f64 = last[2].parse().unwrap();
        assert!(r > 0.5, "hypothesis-1 correlation too weak: {r}");
    }

    #[test]
    fn table5_reorder_reduces_blocks() {
        let t = &run_experiment("table5", &quick()).unwrap()[0];
        // rows alternate False/True per size; True must not exceed False
        for pair in t.rows.chunks(2) {
            let orig: f64 = pair[0][2].parse().unwrap();
            let reord: f64 = pair[1][2].parse().unwrap();
            assert!(reord <= orig, "reorder increased blocks: {reord} > {orig}");
        }
    }

    #[test]
    fn fig9_reorder_helps_at_zero_prefix() {
        let t = &run_experiment("fig9", &quick()).unwrap()[0];
        let zero_prefix_rows: Vec<_> =
            t.rows.iter().filter(|r| r[1] == "0").collect();
        for row in zero_prefix_rows {
            let orig: f64 = row[2].parse().unwrap();
            let dfs: f64 = row[3].parse().unwrap();
            assert!(dfs <= orig);
        }
    }

    #[test]
    fn ablation_dynamic_gain_grows_with_budget() {
        let t = &run_experiment("ablation", &quick()).unwrap()[0];
        assert_eq!(t.rows.len(), 6);
        let gain = |row: &Vec<String>| -> f64 {
            row[5].trim_end_matches('x').parse().unwrap()
        };
        // dynamic trees must not fall behind the fixed shape as budget
        // grows (the paper's central motivation).
        let first = gain(&t.rows[0]);
        let last = gain(t.rows.last().unwrap());
        assert!(last >= first * 0.8, "gain shrank: {first} -> {last}");
    }

    /// The serving acceptance criterion: at 16 concurrent clients the
    /// continuous scheduler converts the shared dispatches into strictly
    /// higher virtual-regime throughput than FCFS on the same workload.
    #[test]
    fn serve_continuous_beats_fcfs_at_16_clients() {
        let opts = ExpOpts {
            prompts: 1,
            max_new_tokens: 24,
            ..ExpOpts::default()
        };
        let t = &run_experiment("serve", &opts).unwrap()[0];
        // 2 schedulers x 3 in-process concurrency levels + 2 reactor rows
        // + 2 mixed-workload rows (chunked prefill off/on)
        assert_eq!(t.rows.len(), 10);
        let tput = |row: &Vec<String>| -> f64 { row[4].parse().unwrap() };
        let fcfs16 = &t.rows[2];
        let cont16 = &t.rows[5];
        assert_eq!((fcfs16[0].as_str(), fcfs16[1].as_str()), ("fcfs", "16"));
        assert_eq!(
            (cont16[0].as_str(), cont16[1].as_str()),
            ("continuous", "16")
        );
        // both schedulers served the full workload
        assert_eq!(fcfs16[3], cont16[3]);
        assert!(
            tput(cont16) > tput(fcfs16),
            "continuous {} <= fcfs {} tokens/vsec at 16 clients",
            tput(cont16),
            tput(fcfs16)
        );
        // The reactor rows: every request of the 64- and 256-connection
        // socket workloads completed, served by a 4-thread transport.
        for (row, conns) in [(&t.rows[6], 64usize), (&t.rows[7], 256)] {
            assert_eq!(row[0], "continuous+reactor");
            assert_eq!(row[1], format!("{conns}"));
            let requests: usize = row[2].parse().unwrap();
            let tokens: usize = row[3].parse().unwrap();
            assert_eq!(tokens, requests * opts.max_new_tokens);
            assert_eq!(row[10], "4", "transport not O(pool): {}", row[10]);
        }
        // The chunked-prefill acceptance (ISSUE 10): with a 4096-token
        // arrival landing mid-stream, chunking must strictly lower the
        // co-batched chatters' inter-token p95 while total virtual time
        // regresses at most 5%.
        let oneshot = &t.rows[8];
        let chunked = &t.rows[9];
        assert_eq!(oneshot[0], "mixed");
        assert!(chunked[0].starts_with("mixed+chunk"));
        // both variants served the full 16-request workload
        assert_eq!(oneshot[3], chunked[3]);
        let itl = |row: &Vec<String>| -> f64 { row[11].parse().unwrap() };
        assert!(
            itl(chunked) < itl(oneshot),
            "chunked itl_p95 {} not below one-shot {}",
            chunked[11],
            oneshot[11]
        );
        // equal tokens, so tput ratio == inverse virtual-time ratio
        assert!(
            tput(chunked) >= tput(oneshot) / 1.05,
            "chunking cost >5% virtual time: {} vs {} tok/vsec",
            chunked[4],
            oneshot[4]
        );
        // the long request's own TTFT is finite in both modes
        for row in [oneshot, chunked] {
            let ttft_long: f64 = row[12].parse().unwrap();
            assert!(ttft_long > 0.0, "long request never emitted");
        }
    }

    /// The streaming acceptance shape: the first token reaches the client
    /// strictly before the one-shot reply would, because it leaves the
    /// server at the first accepted round rather than the last.
    #[test]
    fn stream_ttft_beats_oneshot_reply_arrival() {
        let opts = ExpOpts {
            prompts: 3,
            max_new_tokens: 48,
            ..ExpOpts::default()
        };
        let t = &run_experiment("stream", &opts).unwrap()[0];
        assert_eq!(t.rows.len(), 6); // 2 modes x 3 concurrency levels
        let num = |cell: &str| -> f64 { cell.parse().unwrap() };
        let oneshot1 = &t.rows[0];
        let stream1 = &t.rows[3];
        assert_eq!((oneshot1[0].as_str(), oneshot1[1].as_str()), ("oneshot", "1"));
        assert_eq!((stream1[0].as_str(), stream1[1].as_str()), ("stream", "1"));
        // both modes served the full workload
        assert_eq!(oneshot1[3], stream1[3]);
        assert!(
            num(&stream1[4]) < num(&oneshot1[4]),
            "streaming ttft {} not below one-shot {}",
            stream1[4],
            oneshot1[4]
        );
        // streamed rows actually measured inter-chunk gaps
        assert!(num(&stream1[6]) >= 0.0);
    }

    /// The tentpole acceptance shape: cached verify cost must undercut
    /// uncached at every context length, with a gap that widens as the
    /// context grows (per-round cost proportional to the tree, not the
    /// prefix).
    #[test]
    fn cache_experiment_flattens_context_scaling() {
        let t = &run_experiment("cache", &quick()).unwrap()[0];
        assert_eq!(t.rows.len(), 4 + 3); // context sweep + shared sweep
        let num = |cell: &str| -> f64 { cell.parse().unwrap() };
        let ratio = |row: &Vec<String>| -> f64 {
            row[5].trim_end_matches('x').parse().unwrap()
        };
        let context: Vec<&Vec<String>> =
            t.rows.iter().filter(|r| r[0] == "context").collect();
        assert_eq!(context.len(), 4);
        for row in &context {
            assert!(
                num(&row[4]) < num(&row[3]),
                "cached {} not below uncached {}",
                row[4],
                row[3]
            );
            assert!(num(&row[9]) > 0.0, "zero hit rate");
            assert_eq!(row[10], "0", "context rows must not warm-start");
        }
        assert!(
            ratio(context.last().unwrap()) > ratio(context[0]),
            "position reduction did not grow with context"
        );
        // Shared-prefix sweep: radix on bills less than radix off, every
        // client past the first starts warm, and the warm tokens grow
        // with the shared prompt.
        let shared: Vec<&Vec<String>> =
            t.rows.iter().filter(|r| r[0] == "shared").collect();
        assert_eq!(shared.len(), 3);
        for row in &shared {
            assert!(
                num(&row[4]) < num(&row[3]),
                "radix on billed {} not below radix off {}",
                row[4],
                row[3]
            );
            let prompt_len = num(&row[1]);
            let clients = num(&row[2]);
            assert!(
                num(&row[10]) >= prompt_len * (clients - 1.0),
                "warm tokens {} below shared-prefix floor",
                row[10]
            );
            assert!(
                (num(&row[11]) - (clients - 1.0) / clients).abs() < 1e-9,
                "radix hit rate {} off (first client is a cold miss)",
                row[11]
            );
        }
        assert!(
            num(&shared[2][10]) > num(&shared[0][10]),
            "warm tokens did not grow with the shared prompt"
        );
    }

    /// The tentpole acceptance criterion: on the mixed workload the
    /// online-adaptive policy's accepted-token rate lands at or above
    /// the best single static drafter's, within a noise margin that
    /// covers the bounded exploration tax.
    #[test]
    fn adaptive_matches_best_static_drafter_within_noise() {
        let opts = ExpOpts {
            prompts: 3,
            max_new_tokens: 48,
            ..ExpOpts::default()
        };
        let t = &run_experiment("adaptive", &opts).unwrap()[0];
        assert_eq!(t.rows.len(), 4); // 3 static drafters + adaptive
        let rate = |row: &Vec<String>| -> f64 { row[4].parse().unwrap() };
        let best_static = t.rows[..3]
            .iter()
            .map(rate)
            .fold(f64::NEG_INFINITY, f64::max);
        let adaptive = rate(t.rows.last().unwrap());
        assert!(t.rows[3][0].starts_with("adaptive"));
        assert!(
            adaptive >= best_static * 0.9,
            "adaptive {adaptive} below best static {best_static}"
        );
        // every cell served the full workload
        for row in &t.rows {
            let requests: usize = row[1].parse().unwrap();
            assert_eq!(requests, 4 * opts.prompts);
        }
    }

    /// The router acceptance criterion (ISSUE 8): on the shared-prefix
    /// workload at 4 workers, affinity routing's cache hit rate is at
    /// least round-robin's (per-sequence residency → parity today; the
    /// cross-request radix cache turns locality into strict wins), and
    /// prefix locality — the property affinity actually buys — is
    /// strictly higher. Single-worker rows are mode-independent by the
    /// ring short-circuit.
    #[test]
    fn route_affinity_concentrates_prefixes_without_losing_hits() {
        let opts = ExpOpts {
            prompts: 3,
            max_new_tokens: 24,
            ..ExpOpts::default()
        };
        let t = &run_experiment("route", &opts).unwrap()[0];
        assert_eq!(t.rows.len(), 4); // {1,4} workers x {affinity,rr}
        let cell = |r: usize, c: usize| -> f64 { t.rows[r][c].parse().unwrap() };
        // rows: 0 = 1/affinity, 1 = 1/rr, 2 = 4/affinity, 3 = 4/rr
        assert_eq!((t.rows[2][0].as_str(), t.rows[2][1].as_str()), ("4", "affinity"));
        assert_eq!((t.rows[3][0].as_str(), t.rows[3][1].as_str()), ("4", "rr"));
        // 1 worker: routing mode cannot matter (short-circuit before hash).
        assert_eq!(t.rows[0][3], t.rows[1][3], "1-worker tokens diverged");
        assert_eq!(t.rows[0][4], t.rows[1][4], "1-worker hit rate diverged");
        // 4 workers: affinity hit rate >= rr, locality strictly higher.
        let (hit_aff, hit_rr) = (cell(2, 4), cell(3, 4));
        assert!(
            hit_aff >= hit_rr - 1e-9,
            "affinity hit rate {hit_aff} below rr {hit_rr}"
        );
        let (loc_aff, loc_rr) = (cell(2, 6), cell(3, 6));
        assert!(
            loc_aff > loc_rr,
            "affinity locality {loc_aff} not above rr {loc_rr}"
        );
        assert!((loc_aff - 1.0).abs() < 1e-9 || cell(2, 7) > 0.0);
        // every cell served the full workload
        for row in &t.rows {
            let requests: usize = row[2].parse().unwrap();
            assert_eq!(requests, 4 * opts.prompts);
            assert!(row[3].parse::<usize>().unwrap() >= requests * opts.max_new_tokens);
        }
    }

    #[test]
    fn fig4_shares_sum_to_100() {
        let t = &run_experiment("fig4", &quick()).unwrap()[0];
        for row in &t.rows {
            let total: f64 = row[1..]
                .iter()
                .map(|c| c.trim_end_matches('%').parse::<f64>().unwrap())
                .sum();
            assert!((total - 100.0).abs() < 0.5, "shares sum {total}");
        }
    }
}
