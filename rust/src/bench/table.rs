//! Aligned-table rendering + JSON export for bench reports.

use crate::util::json::Json;

/// A rendered benchmark table.
#[derive(Clone, Debug, Default)]
pub struct BenchTable {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl BenchTable {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with per-column alignment.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// JSON export (one object per row).
    pub fn to_json(&self) -> Json {
        let rows = self
            .rows
            .iter()
            .map(|row| {
                Json::Obj(
                    self.headers
                        .iter()
                        .zip(row)
                        .map(|(h, c)| {
                            let v = c
                                .parse::<f64>()
                                .map(Json::Num)
                                .unwrap_or_else(|_| Json::Str(c.clone()));
                            (h.clone(), v)
                        })
                        .collect(),
                )
            })
            .collect();
        Json::obj(vec![
            ("title", Json::Str(self.title.clone())),
            ("rows", Json::Arr(rows)),
        ])
    }

    /// Write JSON to a file, creating parent dirs.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = BenchTable::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1.5".into()]);
        t.row(vec!["longer".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("longer"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = BenchTable::new("demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn json_export_types_numbers() {
        let mut t = BenchTable::new("demo", &["k", "v"]);
        t.row(vec!["x".into(), "1.25".into()]);
        let j = t.to_json();
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].get("v").unwrap().as_f64(), Some(1.25));
        assert_eq!(rows[0].get("k").unwrap().as_str(), Some("x"));
    }
}
