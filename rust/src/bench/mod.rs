//! Benchmark harness + experiment runners for every table and figure in the
//! paper's evaluation. `rust/benches/*.rs` and the `dyspec bench` CLI both
//! dispatch into [`run_experiment`], so a table regenerates identically from
//! either entry point.
//!
//! Measurement protocol: each cell does warmup + repeated timed runs and
//! reports the paper's metrics — virtual latency/token under the configured
//! hardware regime (DESIGN.md §3 explains the regime mapping) and emitted
//! tokens per target step (the paper's parenthesized values).

pub mod experiments;
pub mod table;

pub use experiments::run_experiment;
pub use table::BenchTable;

use crate::util::Timer;

/// warmup + timed repetition helper for micro-measurements.
pub fn time_repeated<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let t = Timer::start();
    for _ in 0..reps {
        f();
    }
    t.elapsed_secs() / reps.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_repeated_returns_mean() {
        let mut n = 0u64;
        let per = time_repeated(2, 10, || {
            n += 1;
        });
        assert_eq!(n, 12);
        assert!(per >= 0.0);
    }
}
