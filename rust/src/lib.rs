//! # DySpec — faster speculative decoding with dynamic token tree structure
//!
//! A production-quality Rust + JAX + Pallas reproduction of
//! *DySpec: Faster Speculative Decoding with Dynamic Token Tree Structure*
//! (Xiong et al., 2024), organized as a three-layer serving stack:
//!
//! - **L3 (this crate)** — the coordinator: draft-tree construction
//!   ([`draft`], Algorithms 1 & 2 plus the Sequoia/SpecInfer/chain
//!   baselines), unbiased multi-branch verification ([`verify`],
//!   Algorithm 3), the shared speculation-round pipeline ([`round`]) with
//!   its FCFS front end ([`engine`]), tree attention masks +
//!   block-sparsity reorders ([`tree`], Appendix C), and a request router
//!   with a step-level continuous-batching scheduler ([`coordinator`],
//!   [`sched`], [`server`]).
//! - **L2** — a JAX transformer (`python/compile/model.py`), AOT-lowered to
//!   HLO text and executed from rust via PJRT ([`runtime`], [`models::hlo`]).
//! - **L1** — a Pallas block-sparse tree-attention kernel
//!   (`python/compile/kernels/tree_attention.py`) inlined into the L2 graph.
//!
//! Python runs once at build time (`make artifacts`); the serving binary is
//! pure rust. See DESIGN.md for the paper-to-module map and EXPERIMENTS.md
//! for reproduction results.

pub mod bench;
pub mod cache;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod draft;
pub mod engine;
pub mod models;
pub mod obs;
pub mod round;
pub mod router;
pub mod runtime;
pub mod sampling;
pub mod sched;
pub mod server;
pub mod tree;
pub mod util;
pub mod verify;
