//! Tree-aware KV prefix cache (DESIGN.md §KV cache).
//!
//! DySpec's per-round verification cost must scale with the *speculated
//! tree*, not the full context: Sequoia-style systems get there by keeping
//! the accepted prefix resident in the target's KV cache across rounds.
//! This module is that subsystem, backend-independent:
//!
//!   - [`pool`] — refcounted paged block allocator under a global budget;
//!   - [`manager`] — per-worker residency: accepted-prefix chains retained
//!     across speculation rounds, pin-aware eviction, per-sequence drop;
//!   - [`radix`] — cross-request radix prefix tree (`radix=on`): committed
//!     prefixes are published into a shared block-aligned token tree so
//!     the next request starts resident at its longest shared prefix
//!     (DESIGN.md §Radix Prefix Cache);
//!   - [`lease`] — transient copy-on-write block assignment for one
//!     speculated tree (branches share ancestor blocks exactly where the
//!     `tree::mask` attention mask lets them attend);
//!   - [`verify_bill`] — the cost-model split of one dispatch into
//!     computed vs cached positions and fetched vs written blocks, which
//!     the virtual ledgers price with the `LatencyRegime` cache terms.
//!
//! The sim backend produces bit-identical logits with the cache on or off
//! (pinned by `rust/tests/cache_equivalence.rs`); what the cache changes is
//! the *billing* — per-round cost proportional to speculated tokens — and
//! the block-level bookkeeping that a real PJRT KV wiring will inherit
//! (currently stubbed; see ROADMAP).

pub mod lease;
pub mod manager;
pub mod pool;
pub mod radix;

pub use lease::TreeLease;
pub use manager::{CacheManager, RadixStats};
pub use pool::{BlockId, CacheStats, KvPool};
pub use radix::{RadixGauges, RadixTree};

/// Per-dispatch verify-cost split for one sequence's slice.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct VerifyBill {
    /// Positions actually computed: the non-resident prefix plus every
    /// speculated tree row.
    pub billed_positions: usize,
    /// Prefix positions served from the resident KV cache.
    pub cached_positions: usize,
    /// Resident blocks fetched to serve the cached prefix.
    pub fetched_blocks: usize,
    /// Blocks (re)written by this dispatch — every computed position
    /// materializes KV, cached or not, so uncached re-scoring rewrites the
    /// full context's blocks while cached scoring writes only new ones.
    pub written_blocks: usize,
}

/// Split one verification dispatch for a sequence with `prefix_len` context
/// positions (of which `cached_len` are resident) and `rows` speculated
/// tree rows, at `block_tokens` positions per block.
///
/// With the built-in regimes (`cache_fetch_secs <= target_pos_secs *
/// block_tokens` and `cache_fetch_secs <= cache_write_secs`) the priced
/// bill is monotone in `cached_len`: enabling the cache never costs more
/// on any dispatch, and bills strictly fewer positions whenever anything
/// is resident — the acceptance criterion `rust/tests/cache_equivalence.rs`
/// pins.
pub fn verify_bill(
    prefix_len: usize,
    cached_len: usize,
    rows: usize,
    block_tokens: usize,
) -> VerifyBill {
    let b = block_tokens.max(1);
    let cached = cached_len.min(prefix_len);
    let miss = prefix_len - cached;
    VerifyBill {
        billed_positions: miss + rows,
        cached_positions: cached,
        fetched_blocks: cached / b,
        written_blocks: (miss + rows).div_ceil(b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uncached_bills_everything() {
        let bill = verify_bill(100, 0, 12, 16);
        assert_eq!(bill.billed_positions, 112);
        assert_eq!(bill.cached_positions, 0);
        assert_eq!(bill.fetched_blocks, 0);
        assert_eq!(bill.written_blocks, 7);
    }

    #[test]
    fn cached_bills_only_miss_and_rows() {
        let bill = verify_bill(100, 99, 12, 16);
        assert_eq!(bill.billed_positions, 13);
        assert_eq!(bill.cached_positions, 99);
        assert_eq!(bill.fetched_blocks, 6);
        assert_eq!(bill.written_blocks, 1);
    }

    #[test]
    fn cached_len_clamps_to_prefix() {
        let bill = verify_bill(10, 50, 0, 4);
        assert_eq!(bill.cached_positions, 10);
        assert_eq!(bill.billed_positions, 0);
        assert_eq!(bill.written_blocks, 0);
    }

    #[test]
    fn billed_positions_strictly_decrease_with_residency() {
        for cached in 1..=64usize {
            let warm = verify_bill(64, cached, 8, 16);
            let cold = verify_bill(64, 0, 8, 16);
            assert!(warm.billed_positions < cold.billed_positions);
        }
    }
}
