//! Per-worker cache manager: one [`KvPool`] shared by every sequence the
//! worker multiplexes, a per-sequence resident-prefix chain retained across
//! speculation rounds, and LRU eviction under the global block budget.
//!
//! Residency protocol per speculation round:
//!   1. [`begin_round`] — returns how many prefix positions are resident
//!      (the dispatch bills only the rest);
//!   2. [`lease_tree`] — transient COW block assignment for the speculated
//!      branches (see [`super::lease`]);
//!   3. after verification, [`commit`] — extends residency to
//!      `prefix_len + accepted` (everything the dispatch scored: the miss
//!      region plus the accepted path; the bonus token has not been a model
//!      *input* yet, so it is not resident), allocating blocks and evicting
//!      colder sequences when the budget is tight;
//!   4. on retirement, [`drop_seq`] — releases the chain (leak-freedom is
//!      pinned by the scheduler tests).
//!
//! Eviction releases only the victim's own references; a block whose
//! refcount is still held elsewhere (e.g. by an in-flight lease) survives
//! until that reference is dropped, so eviction can never free a block a
//! live sequence still reads.

use std::collections::HashMap;

use super::lease::TreeLease;
use super::pool::{CacheStats, KvPool};
use crate::config::CacheConfig;
use crate::tree::TokenTree;

#[derive(Debug, Default)]
struct SeqKv {
    blocks: Vec<usize>,
    /// Prefix positions resident (<= blocks.len() * block_tokens).
    resident: usize,
    last_used: u64,
}

/// Worker-scoped KV cache state (see module docs).
#[derive(Debug)]
pub struct CacheManager {
    pool: KvPool,
    enabled: bool,
    seqs: HashMap<u64, SeqKv>,
    clock: u64,
}

impl CacheManager {
    pub fn new(cfg: &CacheConfig) -> Self {
        Self {
            pool: KvPool::new(cfg.block_tokens, cfg.max_blocks),
            enabled: cfg.enabled,
            seqs: HashMap::new(),
            clock: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn block_tokens(&self) -> usize {
        self.pool.block_tokens()
    }

    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    pub fn stats(&self) -> CacheStats {
        self.pool.stats
    }

    pub fn used_blocks(&self) -> usize {
        self.pool.used_blocks()
    }

    /// Resident prefix positions for `id` (0 when disabled or unknown).
    pub fn resident(&self, id: u64) -> usize {
        self.seqs.get(&id).map(|e| e.resident).unwrap_or(0)
    }

    /// Start a round for `id`: touches the LRU clock and reports residency.
    pub fn begin_round(&mut self, id: u64) -> usize {
        if !self.enabled {
            return 0;
        }
        self.clock += 1;
        let clock = self.clock;
        let e = self.seqs.entry(id).or_default();
        e.last_used = clock;
        e.resident
    }

    /// Record a dispatch's prefix hit/miss split (metrics feed).
    pub fn record_lookup(&mut self, hit_tokens: u64, miss_tokens: u64) {
        self.pool.stats.hit_tokens += hit_tokens;
        self.pool.stats.miss_tokens += miss_tokens;
    }

    /// Transient COW lease for this round's speculated tree.
    pub fn lease_tree(&mut self, tree: &TokenTree) -> TreeLease {
        if !self.enabled {
            return TreeLease::empty();
        }
        TreeLease::build(&mut self.pool, tree)
    }

    /// Rollback rejected branches, then release the whole lease (the
    /// accepted path is re-packed by [`commit`], billed as cache writes).
    pub fn end_lease(
        &mut self,
        mut lease: TreeLease,
        tree: &TokenTree,
        accepted: &[crate::tree::NodeId],
    ) {
        lease.release_rejected(&mut self.pool, tree, accepted);
        lease.end(&mut self.pool);
    }

    /// Extend `id`'s residency to `prefix_len + accepted` positions,
    /// allocating blocks (evicting colder sequences if needed). Under an
    /// exhausted budget residency only grows as far as blocks allow.
    ///
    /// `cached_len` is the resident snapshot the round's dispatch was
    /// billed against: the dispatch wrote KV only for
    /// `[cached_len, prefix_len)` plus the accepted path. If this sequence
    /// was evicted mid-round (its resident mark dropped below that
    /// snapshot), the written region no longer attaches to a full prefix,
    /// so residency must NOT grow — the sequence re-scores from scratch
    /// next round (pinned by `mid_round_eviction_blocks_resurrection`).
    pub fn commit(
        &mut self,
        id: u64,
        cached_len: usize,
        prefix_len: usize,
        accepted: usize,
    ) {
        if !self.enabled {
            return;
        }
        self.clock += 1;
        let clock = self.clock;
        let cur = self.seqs.get(&id).map(|e| e.resident).unwrap_or(0);
        if cur < cached_len.min(prefix_len) {
            if let Some(e) = self.seqs.get_mut(&id) {
                e.last_used = clock;
            }
            return;
        }
        let b = self.pool.block_tokens();
        let target = prefix_len + accepted;
        let need = target.div_ceil(b);
        loop {
            let have = self.seqs.entry(id).or_default().blocks.len();
            if have >= need {
                break;
            }
            if let Some(blk) = self.pool.try_alloc() {
                self.seqs.entry(id).or_default().blocks.push(blk);
            } else if !self.evict_lru(id) {
                break;
            }
        }
        let e = self.seqs.entry(id).or_default();
        e.resident = target.min(e.blocks.len() * b);
        e.last_used = clock;
    }

    /// Release everything `id` holds (sequence retired or reset).
    pub fn drop_seq(&mut self, id: u64) {
        if let Some(e) = self.seqs.remove(&id) {
            for blk in e.blocks {
                self.pool.release(blk);
            }
        }
    }

    /// Evict the least-recently-used sequence other than `protect`.
    /// Returns false when there is no evictable sequence left.
    pub fn evict_lru(&mut self, protect: u64) -> bool {
        let victim = self
            .seqs
            .iter()
            .filter(|(k, v)| **k != protect && !v.blocks.is_empty())
            .min_by_key(|(_, v)| v.last_used)
            .map(|(k, _)| *k);
        let Some(vid) = victim else {
            return false;
        };
        let blocks = {
            let e = self.seqs.get_mut(&vid).expect("victim exists");
            e.resident = 0;
            std::mem::take(&mut e.blocks)
        };
        for blk in blocks {
            self.pool.release(blk);
        }
        self.pool.stats.evictions += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(blocks: usize) -> CacheConfig {
        CacheConfig {
            enabled: true,
            block_tokens: 4,
            max_blocks: blocks,
        }
    }

    #[test]
    fn residency_grows_with_commits_and_drops_clean() {
        let mut m = CacheManager::new(&cfg(64));
        assert_eq!(m.begin_round(1), 0);
        m.commit(1, 0, 10, 3); // 13 tokens -> 4 blocks
        assert_eq!(m.resident(1), 13);
        assert_eq!(m.used_blocks(), 4);
        // next round: prefix grew to 14 (accepted 3 + bonus), 13 resident
        assert_eq!(m.begin_round(1), 13);
        m.commit(1, 13, 14, 2); // 16 tokens -> 4 blocks, no new alloc
        assert_eq!(m.resident(1), 16);
        assert_eq!(m.used_blocks(), 4);
        m.drop_seq(1);
        assert_eq!(m.used_blocks(), 0, "retired sequence leaked blocks");
    }

    #[test]
    fn disabled_manager_is_inert() {
        let mut m = CacheManager::new(&CacheConfig {
            enabled: false,
            block_tokens: 4,
            max_blocks: 8,
        });
        assert_eq!(m.begin_round(1), 0);
        m.commit(1, 0, 100, 10);
        assert_eq!(m.resident(1), 0);
        assert_eq!(m.used_blocks(), 0);
    }

    #[test]
    fn budget_pressure_evicts_lru_sequence() {
        let mut m = CacheManager::new(&cfg(4)); // 16 tokens total
        m.begin_round(1);
        m.commit(1, 0, 8, 0); // 2 blocks
        m.begin_round(2);
        m.commit(2, 0, 8, 0); // 2 blocks; pool full
        assert_eq!(m.used_blocks(), 4);
        // Seq 3 needs space: seq 1 is LRU and must be evicted.
        m.begin_round(3);
        m.commit(3, 0, 8, 0);
        assert_eq!(m.resident(3), 8);
        assert_eq!(m.resident(1), 0, "LRU sequence not evicted");
        assert_eq!(m.resident(2), 8, "warmer sequence wrongly evicted");
        assert_eq!(m.stats().evictions, 1);
        assert_eq!(m.used_blocks(), 4, "budget exceeded");
    }

    #[test]
    fn mid_round_eviction_blocks_resurrection() {
        let mut m = CacheManager::new(&cfg(64));
        m.begin_round(1);
        m.commit(1, 0, 8, 0);
        let snap = m.begin_round(1);
        assert_eq!(snap, 8);
        // Another sequence's pressure evicts seq 1 mid-round…
        assert!(m.evict_lru(2));
        // …so committing against the stale snapshot must NOT mark the
        // never-rewritten region resident again.
        m.commit(1, snap, 9, 3);
        assert_eq!(m.resident(1), 0, "residency resurrected after eviction");
        // The next round re-scores from scratch and residency grows again.
        assert_eq!(m.begin_round(1), 0);
        m.commit(1, 0, 9, 3);
        assert_eq!(m.resident(1), 12);
        m.drop_seq(1);
        assert_eq!(m.used_blocks(), 0);
    }

    #[test]
    fn eviction_cannot_free_leased_blocks() {
        use crate::tree::{TokenTree, ROOT};
        let mut m = CacheManager::new(&cfg(3));
        m.begin_round(1);
        m.commit(1, 0, 4, 0); // seq 1 holds 1 block
        // A tree lease for seq 2 takes the remaining blocks.
        let mut tree = TokenTree::new(0, vec![]);
        let a = tree.add_child(ROOT, 1, 0.9);
        let _b = tree.add_child(ROOT, 2, 0.5); // sibling: separate chain
        let lease = m.lease_tree(&tree);
        let leased = lease.node_tail(a).unwrap();
        assert_eq!(m.used_blocks(), 3);
        // Committing a huge prefix for seq 3 evicts seq 1 but can never
        // free the leased blocks: refcounts protect them.
        m.begin_round(3);
        m.commit(3, 0, 12, 0);
        assert!(m.pool().refcount(leased) > 0, "leased block freed");
        assert_eq!(m.resident(1), 0);
        // Seq 3 got only what eviction could free (1 block = 4 tokens).
        assert_eq!(m.resident(3), 4);
        m.end_lease(lease, &tree, &[]);
        m.drop_seq(3);
        assert_eq!(m.used_blocks(), 0);
    }
}
