//! Per-worker cache manager: one [`KvPool`] shared by every sequence the
//! worker multiplexes, a per-sequence resident-prefix chain retained across
//! speculation rounds, LRU eviction under the global block budget — and,
//! with `radix=on`, a cross-request radix prefix tree so a new request
//! starts resident at its longest shared prefix instead of zero.
//!
//! Residency protocol per speculation round:
//!   1. [`begin_round`] — touches the LRU clock and reports residency; on
//!      a sequence's *first* round with radix on, admission walks the
//!      radix tree over the prompt, pins the matched path, and starts the
//!      sequence warm at the block-aligned longest shared prefix;
//!   2. [`lease_tree`] — transient COW block assignment for the speculated
//!      branches (see [`super::lease`]);
//!   3. after verification, [`commit`] — extends residency to
//!      `prefix.len() + accepted.len()` (everything the dispatch scored:
//!      the miss region plus the accepted path; the bonus token has not
//!      been a model *input* yet, so it is not resident), allocating
//!      blocks and evicting when the budget is tight; with radix on the
//!      block-aligned accepted prefix is *published* into the tree
//!      (private block ownership transfers, duplicates of runs another
//!      sequence already published are released — cross-request dedup);
//!   4. on retirement, [`drop_seq`] — releases the private chain and
//!      unpins the radix path, but leaves shared nodes resident for the
//!      next request (leak-freedom with radix off is pinned by the
//!      scheduler tests; radix retention by the tests here).
//!
//! Eviction is pin-aware on two axes: [`evict_lru`] never touches a
//! sequence that is mid-round (`begin_round` called, `commit` not yet) —
//! a pinned *set*, not a single protected id — and never frees a radix
//! node on any live sequence's pinned path (leaf-first, coldest
//! `last_touch` first). Refcounts independently protect blocks an
//! in-flight lease still reads.

use std::collections::HashMap;

use super::lease::TreeLease;
use super::pool::{CacheStats, KvPool};
use super::radix::{RadixGauges, RadixTree, RADIX_ROOT};
use crate::config::CacheConfig;
use crate::tree::TokenTree;

/// Cumulative cross-request radix counters (metrics + bench feed).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RadixStats {
    /// Admission lookups (one per fresh sequence with radix on).
    pub lookups: u64,
    /// Lookups that matched at least `radix_min_tokens`.
    pub hits: u64,
    /// Total warm-start tokens granted at admission.
    pub warm_tokens: u64,
    /// Radix nodes freed by leaf eviction.
    pub evicted_nodes: u64,
}

#[derive(Debug, Default)]
struct SeqKv {
    /// Private blocks covering `[warm_len, resident)`.
    blocks: Vec<usize>,
    /// Prefix positions resident (warm path + private chain).
    resident: usize,
    /// Block-aligned positions covered by the pinned radix path.
    warm_len: usize,
    /// Deepest pinned radix node (meaningful iff `warm_len > 0`).
    pinned: usize,
    /// Admission result not yet consumed by [`CacheManager::take_warm_start`].
    warm_pending: Option<usize>,
    /// Mid-round guard: set by `begin_round`, cleared by `commit` /
    /// `drop_seq`; `evict_lru` never picks a pinned sequence.
    round_pinned: bool,
    last_used: u64,
}

/// Worker-scoped KV cache state (see module docs).
#[derive(Debug)]
pub struct CacheManager {
    pool: KvPool,
    enabled: bool,
    radix_on: bool,
    radix_min_tokens: usize,
    radix: RadixTree,
    radix_stats: RadixStats,
    seqs: HashMap<u64, SeqKv>,
    clock: u64,
}

impl CacheManager {
    pub fn new(cfg: &CacheConfig) -> Self {
        Self {
            pool: KvPool::new(cfg.block_tokens, cfg.max_blocks),
            enabled: cfg.enabled,
            radix_on: cfg.radix,
            radix_min_tokens: cfg.radix_min_tokens,
            radix: RadixTree::new(cfg.block_tokens.max(1)),
            radix_stats: RadixStats::default(),
            seqs: HashMap::new(),
            clock: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// True when the cross-request radix tree participates in admission.
    pub fn radix_enabled(&self) -> bool {
        self.enabled && self.radix_on
    }

    pub fn block_tokens(&self) -> usize {
        self.pool.block_tokens()
    }

    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    pub fn stats(&self) -> CacheStats {
        self.pool.stats
    }

    /// Cumulative radix admission counters.
    pub fn radix_stats(&self) -> RadixStats {
        RadixStats {
            evicted_nodes: self.radix.evicted_nodes,
            ..self.radix_stats
        }
    }

    /// Current radix tree shape (nodes / depth / shared blocks).
    pub fn radix_gauges(&self) -> RadixGauges {
        self.radix.gauges()
    }

    pub fn used_blocks(&self) -> usize {
        self.pool.used_blocks()
    }

    /// Resident prefix positions for `id` (0 when disabled or unknown).
    pub fn resident(&self, id: u64) -> usize {
        self.seqs.get(&id).map(|e| e.resident).unwrap_or(0)
    }

    /// Start a round for `id`: touches the LRU clock, marks the sequence
    /// mid-round (protected from eviction until `commit`), and reports
    /// residency. A sequence's first round with radix on additionally
    /// walks the radix tree over `prefix` and, on a match of at least
    /// `radix_min_tokens`, pins the matched path and starts resident at
    /// the block-aligned longest shared prefix.
    pub fn begin_round(&mut self, id: u64, prefix: &[u32]) -> usize {
        if !self.enabled {
            return 0;
        }
        self.clock += 1;
        let clock = self.clock;
        if self.radix_on && !self.seqs.contains_key(&id) {
            let (node, matched) = self.radix.match_prefix(prefix, clock);
            self.radix_stats.lookups += 1;
            let e = self.seqs.entry(id).or_default();
            if matched > 0 && matched >= self.radix_min_tokens {
                self.radix.pin_path(node);
                e.warm_len = matched;
                e.resident = matched;
                e.pinned = node;
                e.warm_pending = Some(matched);
                self.radix_stats.hits += 1;
                self.radix_stats.warm_tokens += matched as u64;
            } else {
                e.warm_pending = Some(0);
            }
        }
        let e = self.seqs.entry(id).or_default();
        e.last_used = clock;
        e.round_pinned = true;
        e.resident
    }

    /// Consume the admission result recorded by the `begin_round` that
    /// freshly admitted `id`: `Some(warm_tokens)` when a radix lookup ran
    /// (0 = miss), `None` otherwise (known sequence, or radix off).
    pub fn take_warm_start(&mut self, id: u64) -> Option<usize> {
        self.seqs.get_mut(&id).and_then(|e| e.warm_pending.take())
    }

    /// Record a dispatch's prefix hit/miss split (metrics feed).
    pub fn record_lookup(&mut self, hit_tokens: u64, miss_tokens: u64) {
        self.pool.stats.hit_tokens += hit_tokens;
        self.pool.stats.miss_tokens += miss_tokens;
    }

    /// Transient COW lease for this round's speculated tree.
    pub fn lease_tree(&mut self, tree: &TokenTree) -> TreeLease {
        if !self.enabled {
            return TreeLease::empty();
        }
        TreeLease::build(&mut self.pool, tree)
    }

    /// Rollback rejected branches, then release the whole lease (the
    /// accepted path is re-packed by [`commit`], billed as cache writes).
    pub fn end_lease(
        &mut self,
        mut lease: TreeLease,
        tree: &TokenTree,
        accepted: &[crate::tree::NodeId],
    ) {
        lease.release_rejected(&mut self.pool, tree, accepted);
        lease.end(&mut self.pool);
    }

    /// Extend `id`'s residency to `prefix.len() + accepted.len()`
    /// positions, allocating blocks (evicting unpinned residency when the
    /// budget is tight) and — with radix on — publishing the block-aligned
    /// accepted prefix into the shared tree. Under an exhausted budget
    /// residency only grows as far as blocks allow. Clears the mid-round
    /// eviction pin.
    ///
    /// `cached_len` is the resident snapshot the round's dispatch was
    /// billed against: the dispatch wrote KV only for
    /// `[cached_len, prefix.len())` plus the accepted path. If this
    /// sequence's residency was force-dropped mid-round (below that
    /// snapshot), the written region no longer attaches to a full prefix,
    /// so residency must NOT grow — the sequence re-scores from scratch
    /// next round (pinned by `mid_round_eviction_blocks_resurrection`).
    pub fn commit(&mut self, id: u64, cached_len: usize, prefix: &[u32], accepted: &[u32]) {
        if !self.enabled {
            return;
        }
        self.clock += 1;
        let clock = self.clock;
        let prefix_len = prefix.len();
        let cur = self.seqs.get(&id).map(|e| e.resident).unwrap_or(0);
        if cur < cached_len.min(prefix_len) {
            if let Some(e) = self.seqs.get_mut(&id) {
                e.last_used = clock;
                e.round_pinned = false;
            }
            return;
        }
        let b = self.pool.block_tokens();
        let target = prefix_len + accepted.len();
        // Self-protect while allocating: the committing sequence must
        // never become its own eviction victim.
        self.seqs.entry(id).or_default().round_pinned = true;
        let warm_len = self.seqs.get(&id).map(|e| e.warm_len).unwrap_or(0);
        let need = target.saturating_sub(warm_len).div_ceil(b);
        loop {
            let have = self.seqs.entry(id).or_default().blocks.len();
            if have >= need {
                break;
            }
            if let Some(blk) = self.pool.try_alloc() {
                self.seqs.entry(id).or_default().blocks.push(blk);
            } else if !self.evict_lru() {
                break;
            }
        }
        let e = self.seqs.entry(id).or_default();
        e.resident = target.min(e.warm_len + e.blocks.len() * b);
        e.last_used = clock;
        e.round_pinned = false;
        if self.radix_on {
            self.publish_seq(id, prefix, accepted, clock);
        }
    }

    /// Publish `id`'s block-aligned resident prefix past the already-warm
    /// path into the radix tree: private block ownership transfers to the
    /// tree (duplicates of runs another sequence already published are
    /// released back to the pool), and the pin moves to the deeper node.
    fn publish_seq(&mut self, id: u64, prefix: &[u32], accepted: &[u32], clock: u64) {
        let b = self.pool.block_tokens();
        let Some(e) = self.seqs.get_mut(&id) else {
            return;
        };
        let aligned = (e.resident / b) * b;
        if aligned <= e.warm_len {
            return;
        }
        let donated: Vec<usize> = e.blocks.drain(..(aligned - e.warm_len) / b).collect();
        let warm_len = e.warm_len;
        let old_pin = if warm_len > 0 { Some(e.pinned) } else { None };
        let mut run: Vec<u32> = Vec::with_capacity(aligned);
        run.extend_from_slice(&prefix[..prefix.len().min(aligned)]);
        if run.len() < aligned {
            run.extend_from_slice(&accepted[..aligned - run.len()]);
        }
        let (node, covered) = self
            .radix
            .publish(&run, warm_len, donated, &mut self.pool, clock);
        if let Some(old) = old_pin {
            self.radix.unpin_path(old);
        }
        if node != RADIX_ROOT {
            self.radix.pin_path(node);
        }
        let e = self.seqs.get_mut(&id).expect("publishing a live sequence");
        e.pinned = node;
        e.warm_len = covered;
    }

    /// Release `id`'s private chain and unpin its radix path. Shared
    /// radix nodes stay resident — the whole point of the tree is that a
    /// retired request's prefix warms the next one; `evict_lru` reclaims
    /// them leaf-first under budget pressure.
    pub fn drop_seq(&mut self, id: u64) {
        if let Some(e) = self.seqs.remove(&id) {
            for blk in e.blocks {
                self.pool.release(blk);
            }
            if e.warm_len > 0 {
                self.radix.unpin_path(e.pinned);
            }
        }
    }

    /// Evict one victim under budget pressure, pin-aware on both axes:
    /// first the coldest *unpinned* radix leaf (a shared prefix no live
    /// sequence reads), then the least-recently-used sequence that is not
    /// mid-round. Returns false when nothing is evictable (everything
    /// left is pinned by live sequences).
    pub fn evict_lru(&mut self) -> bool {
        if self.radix_on && self.radix.evict_leaf(&mut self.pool) > 0 {
            self.pool.stats.evictions += 1;
            return true;
        }
        let victim = self
            .seqs
            .iter()
            .filter(|(_, v)| !v.round_pinned && !v.blocks.is_empty())
            .min_by_key(|(_, v)| v.last_used)
            .map(|(k, _)| *k);
        let Some(vid) = victim else {
            return false;
        };
        self.evict_residency(vid)
    }

    /// Force-drop `id`'s private residency back to its pinned warm path
    /// (ops hook + external-pressure tests; normal pressure goes through
    /// the pin-aware [`evict_lru`]). Returns false if `id` holds no
    /// private blocks.
    pub fn evict_residency(&mut self, id: u64) -> bool {
        let Some(e) = self.seqs.get_mut(&id) else {
            return false;
        };
        if e.blocks.is_empty() {
            return false;
        }
        e.resident = e.warm_len;
        let blocks = std::mem::take(&mut e.blocks);
        for blk in blocks {
            self.pool.release(blk);
        }
        self.pool.stats.evictions += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(blocks: usize) -> CacheConfig {
        CacheConfig {
            enabled: true,
            block_tokens: 4,
            max_blocks: blocks,
            ..CacheConfig::default()
        }
    }

    fn radix_cfg(blocks: usize) -> CacheConfig {
        CacheConfig {
            radix: true,
            radix_min_tokens: 4,
            ..cfg(blocks)
        }
    }

    fn toks(n: usize) -> Vec<u32> {
        (0..n as u32).collect()
    }

    #[test]
    fn residency_grows_with_commits_and_drops_clean() {
        let mut m = CacheManager::new(&cfg(64));
        assert_eq!(m.begin_round(1, &toks(10)), 0);
        m.commit(1, 0, &toks(10), &toks(3)); // 13 tokens -> 4 blocks
        assert_eq!(m.resident(1), 13);
        assert_eq!(m.used_blocks(), 4);
        // next round: prefix grew to 14 (accepted 3 + bonus), 13 resident
        assert_eq!(m.begin_round(1, &toks(14)), 13);
        m.commit(1, 13, &toks(14), &toks(2)); // 16 tokens -> 4 blocks, no new alloc
        assert_eq!(m.resident(1), 16);
        assert_eq!(m.used_blocks(), 4);
        m.drop_seq(1);
        assert_eq!(m.used_blocks(), 0, "retired sequence leaked blocks");
    }

    #[test]
    fn disabled_manager_is_inert() {
        let mut m = CacheManager::new(&CacheConfig {
            enabled: false,
            block_tokens: 4,
            max_blocks: 8,
            ..CacheConfig::default()
        });
        assert_eq!(m.begin_round(1, &toks(100)), 0);
        m.commit(1, 0, &toks(100), &toks(10));
        assert_eq!(m.resident(1), 0);
        assert_eq!(m.used_blocks(), 0);
    }

    #[test]
    fn budget_pressure_evicts_lru_sequence() {
        let mut m = CacheManager::new(&cfg(4)); // 16 tokens total
        m.begin_round(1, &toks(8));
        m.commit(1, 0, &toks(8), &[]); // 2 blocks
        m.begin_round(2, &toks(8));
        m.commit(2, 0, &toks(8), &[]); // 2 blocks; pool full
        assert_eq!(m.used_blocks(), 4);
        // Seq 3 needs space: seq 1 is LRU and must be evicted.
        m.begin_round(3, &toks(8));
        m.commit(3, 0, &toks(8), &[]);
        assert_eq!(m.resident(3), 8);
        assert_eq!(m.resident(1), 0, "LRU sequence not evicted");
        assert_eq!(m.resident(2), 8, "warmer sequence wrongly evicted");
        assert_eq!(m.stats().evictions, 1);
        assert_eq!(m.used_blocks(), 4, "budget exceeded");
    }

    #[test]
    fn mid_round_eviction_blocks_resurrection() {
        let mut m = CacheManager::new(&cfg(64));
        m.begin_round(1, &toks(8));
        m.commit(1, 0, &toks(8), &[]);
        let snap = m.begin_round(1, &toks(9));
        assert_eq!(snap, 8);
        // External pressure force-drops seq 1's residency mid-round
        // (normal `evict_lru` pressure can no longer pick a mid-round
        // sequence — that path is pinned)…
        assert!(m.evict_residency(1));
        // …so committing against the stale snapshot must NOT mark the
        // never-rewritten region resident again.
        m.commit(1, snap, &toks(9), &toks(3));
        assert_eq!(m.resident(1), 0, "residency resurrected after eviction");
        // The next round re-scores from scratch and residency grows again.
        assert_eq!(m.begin_round(1, &toks(9)), 0);
        m.commit(1, 0, &toks(9), &toks(3));
        assert_eq!(m.resident(1), 12);
        m.drop_seq(1);
        assert_eq!(m.used_blocks(), 0);
    }

    #[test]
    fn mid_round_sequences_survive_pressure_together() {
        // Regression for the old `evict_lru(protect: u64)` single-id
        // guard: with several sequences mid-round, pressure from one
        // commit must not evict any *other* live round's residency.
        let mut m = CacheManager::new(&cfg(2)); // 8 tokens total
        m.begin_round(2, &toks(4));
        m.commit(2, 0, &toks(4), &[]); // 1 block
        m.begin_round(3, &toks(4));
        m.commit(3, 0, &toks(4), &[]); // 1 block; pool full
        // Next batched round: all three sequences begin before any commits.
        m.begin_round(1, &toks(4));
        assert_eq!(m.begin_round(2, &toks(5)), 4);
        assert_eq!(m.begin_round(3, &toks(5)), 4);
        // Seq 1's commit finds the pool full and NO evictable victim:
        // seqs 2 and 3 are mid-round (the old code would have evicted
        // seq 2 here, protecting only the committing id).
        m.commit(1, 0, &toks(4), &[]);
        assert_eq!(m.resident(1), 0, "seq 1 must wait, not steal");
        assert_eq!(m.resident(2), 4, "mid-round sequence evicted");
        assert_eq!(m.resident(3), 4, "mid-round sequence evicted");
        assert_eq!(m.stats().evictions, 0);
        m.commit(2, 4, &toks(5), &[]);
        m.commit(3, 4, &toks(5), &[]);
        assert!(m.resident(2) >= 4);
        assert!(m.resident(3) >= 4);
    }

    #[test]
    fn eviction_cannot_free_leased_blocks() {
        use crate::tree::{TokenTree, ROOT};
        let mut m = CacheManager::new(&cfg(3));
        m.begin_round(1, &toks(4));
        m.commit(1, 0, &toks(4), &[]); // seq 1 holds 1 block
        // A tree lease for seq 2 takes the remaining blocks.
        let mut tree = TokenTree::new(0, vec![]);
        let a = tree.add_child(ROOT, 1, 0.9);
        let _b = tree.add_child(ROOT, 2, 0.5); // sibling: separate chain
        let lease = m.lease_tree(&tree);
        let leased = lease.node_tail(a).unwrap();
        assert_eq!(m.used_blocks(), 3);
        // Committing a huge prefix for seq 3 evicts seq 1 but can never
        // free the leased blocks: refcounts protect them.
        m.begin_round(3, &toks(12));
        m.commit(3, 0, &toks(12), &[]);
        assert!(m.pool().refcount(leased) > 0, "leased block freed");
        assert_eq!(m.resident(1), 0);
        // Seq 3 got only what eviction could free (1 block = 4 tokens).
        assert_eq!(m.resident(3), 4);
        m.end_lease(lease, &tree, &[]);
        m.drop_seq(3);
        assert_eq!(m.used_blocks(), 0);
    }

    #[test]
    fn second_request_starts_warm_at_the_shared_prefix() {
        let mut m = CacheManager::new(&radix_cfg(64));
        // Request 1: 10-token prompt, 3 accepted; then it retires.
        let prompt1 = toks(10);
        assert_eq!(m.begin_round(1, &prompt1), 0, "cold tree: no warm start");
        assert_eq!(m.take_warm_start(1), Some(0));
        m.commit(1, 0, &prompt1, &[90, 91, 92]);
        assert_eq!(m.resident(1), 13);
        m.drop_seq(1);
        // The block-aligned accepted prefix (12 tokens = 3 blocks) stays
        // resident in the tree after retirement.
        assert_eq!(m.used_blocks(), 3, "shared nodes freed on drop");
        assert_eq!(m.radix_gauges().shared_blocks, 3);
        // Request 2 shares the first 8 prompt tokens, then diverges.
        let mut prompt2 = toks(8);
        prompt2.extend([500, 501, 502, 503]);
        let warm = m.begin_round(2, &prompt2);
        assert_eq!(warm, 8, "admission missed the shared prefix");
        assert_eq!(m.take_warm_start(2), Some(8));
        assert_eq!(m.take_warm_start(2), None, "warm start consumed twice");
        // Billing: request 2's first dispatch computes strictly fewer
        // positions than request 1's (the acceptance criterion).
        let rows = 4;
        let cold = super::super::verify_bill(prompt1.len(), 0, rows, 4);
        let warm_bill = super::super::verify_bill(prompt2.len(), warm, rows, 4);
        assert!(warm_bill.billed_positions < cold.billed_positions);
        assert_eq!(warm_bill.cached_positions, 8);
        let s = m.radix_stats();
        assert_eq!((s.lookups, s.hits, s.warm_tokens), (2, 1, 8));
        m.commit(2, warm, &prompt2, &[]);
        m.drop_seq(2);
    }

    #[test]
    fn radix_blocks_drain_to_zero_after_all_sharers_retire() {
        let mut m = CacheManager::new(&radix_cfg(64));
        let shared = toks(8);
        // Two concurrent sequences share the prompt; the second is
        // admitted warm off the first's published prefix.
        m.begin_round(1, &shared);
        m.commit(1, 0, &shared, &[]); // publishes 2 blocks
        assert_eq!(m.begin_round(2, &shared), 8, "second sharer starts warm");
        m.commit(2, 8, &shared, &[40, 41, 42, 43]);
        // Dedup: the shared 2 blocks exist once; seq 2 published 1 more.
        assert_eq!(m.used_blocks(), 3);
        m.drop_seq(1);
        m.drop_seq(2);
        assert_eq!(m.used_blocks(), 3, "retirement must not free shared nodes");
        // With no pins left, eviction drains the tree leaf-first to zero.
        while m.evict_lru() {}
        assert_eq!(m.used_blocks(), 0, "refcounts leaked after all sharers retired");
        assert_eq!(m.radix_gauges().shared_blocks, 0);
        assert!(m.radix_stats().evicted_nodes >= 2);
    }

    #[test]
    fn eviction_never_frees_a_live_pinned_radix_path() {
        let mut m = CacheManager::new(&radix_cfg(3));
        let shared = toks(8);
        m.begin_round(1, &shared);
        m.commit(1, 0, &shared, &[]); // 2 blocks published + pinned by seq 1
        // Seq 2 (disjoint prompt) needs all 3 blocks; only the 1
        // unpinned block of headroom exists, so its residency is capped —
        // seq 1's pinned path must survive untouched.
        let other: Vec<u32> = (900..912).collect();
        m.begin_round(2, &other);
        m.commit(2, 0, &other, &[]);
        assert_eq!(m.resident(1), 8, "pinned radix path evicted");
        assert!(m.radix_gauges().shared_blocks >= 2);
        assert!(m.resident(2) <= 4);
        m.drop_seq(1);
        m.drop_seq(2);
        while m.evict_lru() {}
        assert_eq!(m.used_blocks(), 0);
    }

    #[test]
    fn short_matches_below_radix_min_tokens_stay_cold() {
        let mut m = CacheManager::new(&CacheConfig {
            radix: true,
            radix_min_tokens: 8,
            ..cfg(64)
        });
        let shared = toks(8);
        m.begin_round(1, &shared);
        m.commit(1, 0, &shared, &[]);
        m.drop_seq(1);
        // Only one block (4 tokens) is shared — below the 8-token floor.
        let mut short = toks(4);
        short.extend([700, 701, 702, 703]);
        assert_eq!(m.begin_round(2, &short), 0, "sub-threshold match pinned");
        assert_eq!(m.take_warm_start(2), Some(0));
        // A full 8-token match clears the floor.
        assert_eq!(m.begin_round(3, &shared), 8);
        m.drop_seq(2);
        m.drop_seq(3);
    }
}
