//! Copy-on-write block lease for one speculated tree during one
//! verification dispatch.
//!
//! Tree tokens occupy KV positions after the sequence prefix; in the
//! dispatch layout (`tree::forest`) they form their own row segment, so the
//! lease starts them on a fresh block boundary. Along any root path the
//! tokens are packed contiguously into blocks; branching follows the
//! attention mask (`tree/mask.rs`): a node shares every *ancestor* block of
//! its path — exactly the keys its mask row attends to — and never a
//! sibling's. Concretely:
//!
//!   - the first child of a node with a partially-filled tail block appends
//!     in place (the tail block is *shared*: refcount bumped);
//!   - later siblings copy-on-write: they allocate a fresh block standing
//!     for a copy of the shared tail prefix (counted in
//!     `CacheStats::cow_copies`) and append there;
//!   - a child of a node whose tail is full starts a fresh block.
//!
//! Leases are transient: after verification the accepted path is re-packed
//! into the sequence's resident chain by `CacheManager::commit` (billed as
//! cache writes) and every lease reference is released — rejected branches
//! must drive their blocks' refcounts back to zero, which the allocator
//! property tests pin.
//!
//! Lease allocation never evicts resident prefixes (speculative blocks are
//! transient; residency has priority). When the pool is exhausted a node is
//! simply left untracked and its children restart chains when space allows.
//!
//! The same refcount discipline applied on the *inter-request* axis is the
//! cross-request radix tree (`super::radix`): a lease shares blocks between
//! branches of one speculated tree for one dispatch, the radix tree shares
//! blocks between requests across their whole lifetimes — both only ever
//! free a block when the last reader's reference drops.

use super::pool::{BlockId, KvPool};
use crate::tree::{NodeId, TokenTree, ROOT};

#[derive(Clone, Debug, Default)]
struct LeaseNode {
    /// Block holding this node's token (None = untracked: pool exhausted).
    tail: Option<BlockId>,
    /// Tokens in `tail` after this node's token (1..=block_tokens).
    fill: usize,
    /// References this node must release (its tail, shared or owned).
    owned: Vec<BlockId>,
    /// Whether a first child already extended this node's tail in place.
    tail_extended: bool,
    /// Chain tracking is live at this node (ROOT starts true; breaks when
    /// the pool runs out mid-branch).
    valid: bool,
}

/// Per-dispatch block assignment for a speculated tree.
#[derive(Debug, Default)]
pub struct TreeLease {
    nodes: Vec<LeaseNode>,
    block_tokens: usize,
}

impl TreeLease {
    /// Empty lease (cache disabled): tracks nothing, releases nothing.
    pub fn empty() -> Self {
        Self::default()
    }

    /// Assign blocks to every speculated node of `tree` (arena order —
    /// parents precede children by construction).
    pub fn build(pool: &mut KvPool, tree: &TokenTree) -> Self {
        let b = pool.block_tokens();
        let mut nodes = vec![LeaseNode::default(); tree.num_nodes()];
        nodes[ROOT].valid = true; // empty chain at a fresh block boundary
        for id in 1..tree.num_nodes() {
            let parent = tree.node(id).parent.expect("non-root has a parent");
            let (p_tail, p_fill, p_valid, p_extended) = {
                let p = &nodes[parent];
                (p.tail, p.fill, p.valid, p.tail_extended)
            };
            if !p_valid {
                continue; // chain broken upstream; leave untracked
            }
            let entry = match p_tail {
                Some(t) if p_fill < b => {
                    if !p_extended {
                        // First child: append into the shared tail.
                        pool.retain(t);
                        nodes[parent].tail_extended = true;
                        LeaseNode {
                            tail: Some(t),
                            fill: p_fill + 1,
                            owned: vec![t],
                            tail_extended: false,
                            valid: true,
                        }
                    } else if let Some(nb) = pool.try_alloc() {
                        // Later sibling: copy-on-write fork of the tail.
                        pool.stats.cow_copies += 1;
                        LeaseNode {
                            tail: Some(nb),
                            fill: p_fill + 1,
                            owned: vec![nb],
                            tail_extended: false,
                            valid: true,
                        }
                    } else {
                        LeaseNode::default()
                    }
                }
                // Tail full (or ROOT boundary): start a fresh block.
                _ => {
                    if let Some(nb) = pool.try_alloc() {
                        LeaseNode {
                            tail: Some(nb),
                            fill: 1,
                            owned: vec![nb],
                            tail_extended: false,
                            valid: true,
                        }
                    } else {
                        LeaseNode::default()
                    }
                }
            };
            nodes[id] = entry;
        }
        Self {
            nodes,
            block_tokens: b,
        }
    }

    /// Block holding `id`'s token, if tracked.
    pub fn node_tail(&self, id: NodeId) -> Option<BlockId> {
        self.nodes.get(id).and_then(|n| n.tail)
    }

    /// References held on behalf of `id`.
    pub fn owned(&self, id: NodeId) -> &[BlockId] {
        self.nodes.get(id).map(|n| n.owned.as_slice()).unwrap_or(&[])
    }

    /// Distinct blocks along the root path to `id` (the tree-local part of
    /// the chain its attention row may read).
    pub fn chain(&self, tree: &TokenTree, id: NodeId) -> Vec<BlockId> {
        let mut out = Vec::new();
        let mut cur = Some(id);
        while let Some(n) = cur {
            if n == ROOT {
                break;
            }
            if let Some(t) = self.node_tail(n) {
                if out.last() != Some(&t) && !out.contains(&t) {
                    out.push(t);
                }
            }
            cur = tree.node(n).parent;
        }
        out.reverse();
        out
    }

    /// Total lease references still held.
    pub fn refs_held(&self) -> usize {
        self.nodes.iter().map(|n| n.owned.len()).sum()
    }

    /// Rollback: release every node NOT on the accepted root path. The
    /// accepted path (and ROOT) keeps its references until [`end`].
    pub fn release_rejected(
        &mut self,
        pool: &mut KvPool,
        _tree: &TokenTree,
        accepted: &[NodeId],
    ) {
        if self.nodes.is_empty() {
            return; // empty lease (cache disabled): nothing to roll back
        }
        let mut keep = vec![false; self.nodes.len()];
        keep[ROOT] = true;
        for &id in accepted {
            keep[id] = true;
        }
        for (id, node) in self.nodes.iter_mut().enumerate() {
            if !keep[id] {
                for blk in node.owned.drain(..) {
                    pool.release(blk);
                }
            }
        }
    }

    /// Release every remaining reference; the lease is spent afterwards.
    pub fn end(&mut self, pool: &mut KvPool) {
        for node in &mut self.nodes {
            for blk in node.owned.drain(..) {
                pool.release(blk);
            }
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// root -> a -> b ; root -> c (sibling of a); a -> d (sibling of b).
    fn sample_tree() -> (TokenTree, NodeId, NodeId, NodeId, NodeId) {
        let mut t = TokenTree::new(0, vec![]);
        let a = t.add_child(ROOT, 1, 0.9);
        let b = t.add_child(a, 2, 0.8);
        let c = t.add_child(ROOT, 3, 0.5);
        let d = t.add_child(a, 4, 0.4);
        (t, a, b, c, d)
    }

    #[test]
    fn paths_share_ancestor_blocks_siblings_fork() {
        let mut pool = KvPool::new(4, 64);
        let (tree, a, b, c, d) = sample_tree();
        let mut lease = TreeLease::build(&mut pool, &tree);

        // a starts a fresh block; b (first child) appends in place.
        let ta = lease.node_tail(a).unwrap();
        let tb = lease.node_tail(b).unwrap();
        assert_eq!(ta, tb, "first child shares the parent tail");
        assert_eq!(pool.refcount(ta), 2);

        // c is a later child of ROOT: ROOT has no tail, so fresh block —
        // disjoint from a's branch.
        let tc = lease.node_tail(c).unwrap();
        assert_ne!(tc, ta);

        // d is a's SECOND child: copy-on-write fork, not sharing b's block.
        let td = lease.node_tail(d).unwrap();
        assert_ne!(td, tb);
        assert_eq!(pool.stats.cow_copies, 1);

        // chain(b) extends chain(a); chains of unrelated nodes disjoint.
        let chain_a = lease.chain(&tree, a);
        let chain_b = lease.chain(&tree, b);
        assert!(chain_b.starts_with(&chain_a));
        let chain_c = lease.chain(&tree, c);
        assert!(chain_a.iter().all(|x| !chain_c.contains(x)));

        lease.end(&mut pool);
        assert_eq!(pool.used_blocks(), 0, "lease leaked blocks");
    }

    #[test]
    fn rollback_of_rejected_branches_zeroes_refcounts() {
        let mut pool = KvPool::new(4, 64);
        let (tree, a, b, c, d) = sample_tree();
        let mut lease = TreeLease::build(&mut pool, &tree);
        let shared = lease.node_tail(a).unwrap();
        let tc = lease.node_tail(c).unwrap();
        let td = lease.node_tail(d).unwrap();

        // Accept the path root->a->b; reject c and d.
        lease.release_rejected(&mut pool, &tree, &[a, b]);
        assert_eq!(pool.refcount(tc), 0, "rejected c still referenced");
        assert_eq!(pool.refcount(td), 0, "rejected d still referenced");
        // The accepted path's shared tail keeps both its references.
        assert_eq!(pool.refcount(shared), 2);

        lease.end(&mut pool);
        assert_eq!(pool.used_blocks(), 0);
        assert_eq!(pool.stats.allocated, pool.stats.freed);
    }

    #[test]
    fn deep_chain_packs_blocks_contiguously() {
        let mut pool = KvPool::new(2, 64);
        let mut tree = TokenTree::new(0, vec![]);
        let mut p = ROOT;
        let mut path = Vec::new();
        for i in 0..5 {
            p = tree.add_child(p, i, 0.5);
            path.push(p);
        }
        let mut lease = TreeLease::build(&mut pool, &tree);
        // 5 tokens at 2/block: blocks used along the chain = 3, shared
        // in-place (no COW on a pure chain).
        assert_eq!(lease.chain(&tree, *path.last().unwrap()).len(), 3);
        assert_eq!(pool.stats.cow_copies, 0);
        lease.end(&mut pool);
        assert_eq!(pool.used_blocks(), 0);
    }

    #[test]
    fn exhausted_pool_degrades_to_untracked() {
        let mut pool = KvPool::new(1, 2);
        let mut tree = TokenTree::new(0, vec![]);
        let a = tree.add_child(ROOT, 1, 0.9);
        let b = tree.add_child(ROOT, 2, 0.5);
        let c = tree.add_child(b, 3, 0.4);
        let mut lease = TreeLease::build(&mut pool, &tree);
        assert!(lease.node_tail(a).is_some());
        assert!(lease.node_tail(b).is_some());
        assert!(lease.node_tail(c).is_none(), "third block must not exist");
        lease.end(&mut pool);
        assert_eq!(pool.used_blocks(), 0);
    }
}
