//! Cross-request radix prefix tree (DESIGN.md §Radix Prefix Cache).
//!
//! The pool (`pool.rs`) retains accepted prefixes *per sequence*; at
//! many-users scale the dominant reuse is *across* requests — shared
//! system prompts, few-shot templates, multi-turn resumption. This tree
//! extends the PR 2 refcount discipline to the inter-request axis: nodes
//! own block-aligned token runs with one pool reference per block, held
//! by the tree itself, so a prefix stays resident after every sequence
//! that produced it has retired.
//!
//! Invariants:
//!
//!   - every non-root node's run is a whole number of blocks
//!     (`tokens.len() == blocks.len() * block_tokens`); the root owns the
//!     empty run and no blocks;
//!   - children of one node start with pairwise-distinct tokens, so
//!     longest-prefix matching is deterministic;
//!   - `pins` counts live sequences whose pinned path passes through the
//!     node; eviction (`evict_leaf`) only ever frees an *unpinned leaf*,
//!     coldest `last_touch` first, so it can never free a node on any
//!     live sequence's pinned path;
//!   - splitting (`match_prefix` at a mid-node divergence) rewires but
//!     never changes the token spelling of any root-to-node path, so a
//!     pinned node id stays valid across splits performed by other
//!     sequences.

use super::pool::{BlockId, KvPool};

/// Node id of the root (empty run; never evicted, never holds blocks).
pub const RADIX_ROOT: usize = 0;

/// Gauges over the current tree shape, read per step for the metrics
/// snapshot (`dyspec_radix_*`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RadixGauges {
    /// Live nodes, excluding the root.
    pub nodes: usize,
    /// Longest root-to-leaf path, in tokens.
    pub depth_tokens: usize,
    /// KV blocks owned by the tree (shared across requests).
    pub shared_blocks: usize,
}

#[derive(Debug, Default)]
struct RadixNode {
    /// Token run owned by this node (block-aligned except the root).
    tokens: Vec<u32>,
    /// One pool reference per block of the run, held by the tree.
    blocks: Vec<BlockId>,
    children: Vec<usize>,
    parent: usize,
    /// Live sequences whose pinned path passes through this node.
    pins: u32,
    /// Manager clock at the last admission/publish touching this node.
    last_touch: u64,
    /// Slot liveness — evicted slots are recycled through `free_slots`.
    live: bool,
}

/// Block-aligned token radix tree over the refcounted pool.
#[derive(Debug)]
pub struct RadixTree {
    nodes: Vec<RadixNode>,
    free_slots: Vec<usize>,
    block_tokens: usize,
    resident_blocks: usize,
    /// Nodes freed by leaf eviction (monotone counter).
    pub evicted_nodes: u64,
}

impl RadixTree {
    pub fn new(block_tokens: usize) -> Self {
        let root = RadixNode {
            live: true,
            ..RadixNode::default()
        };
        Self {
            nodes: vec![root],
            free_slots: Vec::new(),
            block_tokens: block_tokens.max(1),
            resident_blocks: 0,
            evicted_nodes: 0,
        }
    }

    /// KV blocks currently owned by the tree.
    pub fn resident_blocks(&self) -> usize {
        self.resident_blocks
    }

    pub fn gauges(&self) -> RadixGauges {
        RadixGauges {
            nodes: self.nodes.iter().filter(|n| n.live).count() - 1,
            depth_tokens: self.max_depth_tokens(),
            shared_blocks: self.resident_blocks,
        }
    }

    fn max_depth_tokens(&self) -> usize {
        let mut max = 0;
        let mut stack = vec![(RADIX_ROOT, 0usize)];
        while let Some((id, depth)) = stack.pop() {
            max = max.max(depth);
            for &c in &self.nodes[id].children {
                stack.push((c, depth + self.nodes[c].tokens.len()));
            }
        }
        max
    }

    fn alloc_node(&mut self, node: RadixNode) -> usize {
        debug_assert!(node.live);
        debug_assert_eq!(
            node.tokens.len(),
            node.blocks.len() * self.block_tokens,
            "radix run must be block-aligned"
        );
        if let Some(slot) = self.free_slots.pop() {
            self.nodes[slot] = node;
            slot
        } else {
            self.nodes.push(node);
            self.nodes.len() - 1
        }
    }

    /// Walk `tokens` from the root, splitting a node at the block-aligned
    /// divergence point if the match ends mid-run, and return the deepest
    /// node on the matched path plus the matched token count (a multiple
    /// of `block_tokens`). Touches every node on the path.
    pub fn match_prefix(&mut self, tokens: &[u32], clock: u64) -> (usize, usize) {
        let b = self.block_tokens;
        let mut cur = RADIX_ROOT;
        let mut matched = 0usize;
        self.nodes[cur].last_touch = clock;
        loop {
            let rest = &tokens[matched..];
            if rest.len() < b {
                break;
            }
            let Some(&child) = self.nodes[cur]
                .children
                .iter()
                .find(|&&c| self.nodes[c].tokens[0] == rest[0])
            else {
                break;
            };
            let run = &self.nodes[child].tokens;
            let common = run.iter().zip(rest.iter()).take_while(|(x, y)| x == y).count();
            let aligned = (common / b) * b;
            if aligned == 0 {
                break;
            }
            if aligned == run.len() {
                matched += aligned;
                cur = child;
                self.nodes[cur].last_touch = clock;
                continue;
            }
            // Divergence inside the run: split so the shared head becomes
            // its own node. The tail (and any deeper query tokens) diverge
            // within one block, so no further whole-block match exists.
            let upper = self.split(child, aligned, clock);
            matched += aligned;
            cur = upper;
            break;
        }
        (cur, matched)
    }

    /// Split `child` at `at_tokens` (block-aligned, strictly inside the
    /// run): a new upper node takes the head run + blocks, `child` keeps
    /// the tail. Pinned paths through `child` pass through the new upper
    /// node, so it inherits the pin count.
    fn split(&mut self, child: usize, at_tokens: usize, clock: u64) -> usize {
        let b = self.block_tokens;
        debug_assert!(at_tokens % b == 0);
        debug_assert!(at_tokens > 0 && at_tokens < self.nodes[child].tokens.len());
        let parent = self.nodes[child].parent;
        let head_tokens: Vec<u32> = self.nodes[child].tokens.drain(..at_tokens).collect();
        let head_blocks: Vec<BlockId> = self.nodes[child].blocks.drain(..at_tokens / b).collect();
        let upper = self.alloc_node(RadixNode {
            tokens: head_tokens,
            blocks: head_blocks,
            children: vec![child],
            parent,
            pins: self.nodes[child].pins,
            last_touch: self.nodes[child].last_touch.max(clock),
            live: true,
        });
        let slot = self.nodes[parent]
            .children
            .iter()
            .position(|&c| c == child)
            .expect("split child missing from parent");
        self.nodes[parent].children[slot] = upper;
        self.nodes[child].parent = upper;
        upper
    }

    /// Publish `tokens` (block-aligned length) into the tree. The caller
    /// donates one already-owned pool block per run block past
    /// `from_tokens` (ownership transfers to the tree); donations for
    /// ranges the tree already holds are released back to the pool
    /// (cross-request dedup). Returns the deepest node covering the run
    /// and the covered token count.
    pub fn publish(
        &mut self,
        tokens: &[u32],
        from_tokens: usize,
        donated: Vec<BlockId>,
        pool: &mut KvPool,
        clock: u64,
    ) -> (usize, usize) {
        let b = self.block_tokens;
        debug_assert!(tokens.len() % b == 0 && from_tokens % b == 0);
        debug_assert_eq!(donated.len() * b, tokens.len() - from_tokens);
        let (node, matched) = self.match_prefix(tokens, clock);
        debug_assert!(
            matched >= from_tokens,
            "pinned path missing from radix tree"
        );
        let mut donor = donated.into_iter();
        // Another sequence already published [from_tokens, matched): the
        // donor's private copies of those blocks are redundant.
        for _ in 0..(matched - from_tokens) / b {
            if let Some(blk) = donor.next() {
                pool.release(blk);
            }
        }
        let rest: Vec<BlockId> = donor.collect();
        if rest.is_empty() {
            return (node, matched);
        }
        let run = tokens[matched..matched + rest.len() * b].to_vec();
        self.resident_blocks += rest.len();
        let child = self.alloc_node(RadixNode {
            tokens: run,
            blocks: rest,
            children: Vec::new(),
            parent: node,
            pins: 0,
            last_touch: clock,
            live: true,
        });
        let covered = matched + self.nodes[child].tokens.len();
        self.nodes[node].children.push(child);
        (child, covered)
    }

    /// Pin the root-to-`id` path for one live sequence.
    pub fn pin_path(&mut self, mut id: usize) {
        while id != RADIX_ROOT {
            self.nodes[id].pins += 1;
            id = self.nodes[id].parent;
        }
    }

    /// Drop one sequence's pin on the root-to-`id` path.
    pub fn unpin_path(&mut self, mut id: usize) {
        while id != RADIX_ROOT {
            debug_assert!(self.nodes[id].pins > 0, "unpin of an unpinned node");
            self.nodes[id].pins = self.nodes[id].pins.saturating_sub(1);
            id = self.nodes[id].parent;
        }
    }

    /// Evict the coldest unpinned leaf, releasing its blocks to the pool.
    /// Returns the number of blocks freed (0 = nothing evictable: every
    /// remaining node is on a live sequence's pinned path, or the tree is
    /// empty). Repeated calls walk up the tree as parents become leaves.
    pub fn evict_leaf(&mut self, pool: &mut KvPool) -> usize {
        let victim = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(i, n)| {
                *i != RADIX_ROOT && n.live && n.pins == 0 && n.children.is_empty()
            })
            .min_by_key(|(_, n)| n.last_touch)
            .map(|(i, _)| i);
        let Some(v) = victim else {
            return 0;
        };
        let node = std::mem::take(&mut self.nodes[v]);
        let freed = node.blocks.len();
        for blk in node.blocks {
            pool.release(blk);
        }
        self.resident_blocks -= freed;
        let parent = node.parent;
        self.nodes[parent].children.retain(|&c| c != v);
        self.free_slots.push(v);
        self.evicted_nodes += 1;
        freed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const B: usize = 4;

    fn pool() -> KvPool {
        KvPool::new(B, 64)
    }

    /// Allocate `n` pool blocks to donate.
    fn donate(pool: &mut KvPool, n: usize) -> Vec<BlockId> {
        (0..n).map(|_| pool.try_alloc().unwrap()).collect()
    }

    fn run(start: u32, len: usize) -> Vec<u32> {
        (0..len as u32).map(|i| start + i).collect()
    }

    #[test]
    fn publish_then_match_full_prefix() {
        let mut p = pool();
        let mut t = RadixTree::new(B);
        let toks = run(100, 8);
        let d = donate(&mut p, 2);
        let (node, covered) = t.publish(&toks, 0, d, &mut p, 1);
        assert_eq!(covered, 8);
        assert_ne!(node, RADIX_ROOT);
        assert_eq!(t.resident_blocks(), 2);
        // A longer query matches exactly the published 8 tokens.
        let mut q = toks.clone();
        q.extend(run(900, 4));
        let (m, matched) = t.match_prefix(&q, 2);
        assert_eq!((m, matched), (node, 8));
        // A disjoint query matches nothing.
        let (m2, matched2) = t.match_prefix(&run(500, 8), 3);
        assert_eq!((m2, matched2), (RADIX_ROOT, 0));
    }

    #[test]
    fn node_splits_at_block_aligned_divergence() {
        let mut p = pool();
        let mut t = RadixTree::new(B);
        // First publish: 3 blocks [100..112).
        let a = run(100, 12);
        let d = donate(&mut p, 3);
        t.publish(&a, 0, d, &mut p, 1);
        assert_eq!(t.gauges().nodes, 1);
        // Second run shares the first block, diverges in the second.
        let mut b2 = run(100, B);
        b2.extend(run(700, 8));
        let d = donate(&mut p, 3);
        let (nb, covered) = t.publish(&b2, 0, d, &mut p, 2);
        assert_eq!(covered, 12);
        // Split produced: shared head (1 block) + old tail + new tail.
        let g = t.gauges();
        assert_eq!(g.nodes, 3);
        // One shared block was deduped back to the pool: 3 + 3 donated,
        // 1 released, 5 resident in the tree.
        assert_eq!(g.shared_blocks, 5);
        assert_eq!(p.used_blocks(), 5);
        // Both full runs still match end to end.
        let (ma, la) = t.match_prefix(&a, 3);
        assert_eq!(la, 12);
        assert_ne!(ma, RADIX_ROOT);
        let (mb, lb) = t.match_prefix(&b2, 4);
        assert_eq!((mb, lb), (nb, 12));
        assert_eq!(g.depth_tokens, 12);
    }

    #[test]
    fn eviction_is_leaf_first_and_never_frees_a_pinned_path() {
        let mut p = pool();
        let mut t = RadixTree::new(B);
        let a = run(100, 8);
        let d = donate(&mut p, 2);
        let (na, _) = t.publish(&a, 0, d, &mut p, 1);
        // A colder sibling branch, unpinned.
        let b2 = run(300, 8);
        let d = donate(&mut p, 2);
        let (nb, _) = t.publish(&b2, 0, d, &mut p, 2);
        t.pin_path(na);
        // Touch the unpinned branch so it is *newer* — pins, not
        // recency, must protect the pinned path.
        t.match_prefix(&b2, 5);
        assert_eq!(t.evict_leaf(&mut p), 2, "unpinned leaf goes first");
        assert_eq!(t.gauges().nodes, 1);
        // Only the pinned path remains: nothing evictable.
        assert_eq!(t.evict_leaf(&mut p), 0);
        let (m, l) = t.match_prefix(&a, 6);
        assert_eq!((m, l), (na, 8));
        // Unpinning releases it for eviction; the tree drains to zero.
        t.unpin_path(na);
        assert_eq!(t.evict_leaf(&mut p), 2);
        assert_eq!(t.resident_blocks(), 0);
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(t.evicted_nodes, 2);
        let _ = nb;
    }

    #[test]
    fn split_preserves_pins_on_the_shared_head() {
        let mut p = pool();
        let mut t = RadixTree::new(B);
        let a = run(100, 8);
        let d = donate(&mut p, 2);
        let (na, _) = t.publish(&a, 0, d, &mut p, 1);
        t.pin_path(na);
        // A second request diverges after the first block, splitting the
        // pinned node. The pinned path must survive eviction pressure.
        let mut b2 = run(100, B);
        b2.extend(run(800, B));
        let d = donate(&mut p, 2);
        let (nb, _) = t.publish(&b2, 0, d, &mut p, 2);
        // Evict everything evictable: only the unpinned fork may go.
        let mut freed = 0;
        while let n @ 1.. = t.evict_leaf(&mut p) {
            freed += n;
        }
        assert_eq!(freed, 1, "only the unpinned divergent block is evictable");
        let (m, l) = t.match_prefix(&a, 9);
        assert_eq!(l, 8, "pinned run intact across the split");
        assert_eq!(m, na, "pinned node id survives the split");
        let _ = nb;
    }

    #[test]
    fn partial_block_tail_is_not_published_or_matched() {
        let mut p = pool();
        let mut t = RadixTree::new(B);
        let toks = run(100, 8);
        let d = donate(&mut p, 2);
        t.publish(&toks, 0, d, &mut p, 1);
        // Query shares 6 tokens (1.5 blocks): match stops at the block edge.
        let mut q = run(100, 6);
        q.extend(run(900, 6));
        let (_, matched) = t.match_prefix(&q, 2);
        assert_eq!(matched, B);
    }
}
