//! Refcounted paged KV block pool.
//!
//! Blocks are fixed-size slabs of `block_tokens` KV positions; the pool
//! hands out block *ids* (slot indices) under a hard global budget
//! (`max_blocks`). Ownership is reference-counted: a sequence's resident
//! prefix holds one reference per block, and a speculation-round tree lease
//! adds references wherever branches share an ancestor's tail block
//! (copy-on-write forks allocate instead), and the cross-request radix
//! tree (`cache::radix`) holds one reference per block of every published
//! run it retains. A block returns to the free list only when its refcount
//! hits zero — eviction can therefore never free a block that a live
//! lease, sequence, or radix node still references.

/// Identifier of one KV block (a slot index into the pool).
pub type BlockId = usize;

/// Pool-wide bookkeeping counters (monotone except where noted).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Blocks handed out by `try_alloc`.
    pub allocated: u64,
    /// Blocks whose refcount hit zero and returned to the free list.
    pub freed: u64,
    /// Copy-on-write forks (sibling branch copied a partially-filled
    /// ancestor tail block instead of sharing it).
    pub cow_copies: u64,
    /// Sequences whose resident prefix was evicted under budget pressure.
    pub evictions: u64,
    /// Prefix positions served from cache across all dispatches.
    pub hit_tokens: u64,
    /// Prefix positions re-scored because they were not resident.
    pub miss_tokens: u64,
}

/// Fixed-capacity refcounted block allocator.
#[derive(Debug)]
pub struct KvPool {
    block_tokens: usize,
    max_blocks: usize,
    /// Refcount per slot; 0 = free (slot is then on the free list or
    /// beyond the high-water mark).
    refs: Vec<u32>,
    free: Vec<BlockId>,
    in_use: usize,
    pub stats: CacheStats,
}

impl KvPool {
    pub fn new(block_tokens: usize, max_blocks: usize) -> Self {
        Self {
            block_tokens: block_tokens.max(1),
            max_blocks: max_blocks.max(1),
            refs: Vec::new(),
            free: Vec::new(),
            in_use: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn capacity(&self) -> usize {
        self.max_blocks
    }

    /// Blocks with refcount > 0.
    pub fn used_blocks(&self) -> usize {
        self.in_use
    }

    pub fn free_blocks(&self) -> usize {
        self.max_blocks - self.in_use
    }

    pub fn refcount(&self, id: BlockId) -> u32 {
        self.refs.get(id).copied().unwrap_or(0)
    }

    /// Allocate one block with refcount 1, or None at the global budget.
    pub fn try_alloc(&mut self) -> Option<BlockId> {
        let id = if let Some(id) = self.free.pop() {
            id
        } else if self.refs.len() < self.max_blocks {
            self.refs.push(0);
            self.refs.len() - 1
        } else {
            return None;
        };
        debug_assert_eq!(self.refs[id], 0, "allocated block had live refs");
        self.refs[id] = 1;
        self.in_use += 1;
        self.stats.allocated += 1;
        Some(id)
    }

    /// Add one reference to a live block (branch sharing).
    pub fn retain(&mut self, id: BlockId) {
        assert!(self.refs[id] > 0, "retain of a free block {id}");
        self.refs[id] += 1;
    }

    /// Drop one reference; the block is freed when the count reaches zero.
    pub fn release(&mut self, id: BlockId) {
        assert!(self.refs[id] > 0, "release of a free block {id}");
        self.refs[id] -= 1;
        if self.refs[id] == 0 {
            self.free.push(id);
            self.in_use -= 1;
            self.stats.freed += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_until_budget_then_none() {
        let mut p = KvPool::new(16, 3);
        let a = p.try_alloc().unwrap();
        let b = p.try_alloc().unwrap();
        let c = p.try_alloc().unwrap();
        assert_eq!(p.used_blocks(), 3);
        assert!(p.try_alloc().is_none());
        assert_ne!(a, b);
        assert_ne!(b, c);
        p.release(b);
        assert_eq!(p.free_blocks(), 1);
        let d = p.try_alloc().unwrap();
        assert_eq!(d, b, "freed slot is reused");
    }

    #[test]
    fn refcounts_share_and_free_at_zero() {
        let mut p = KvPool::new(8, 4);
        let a = p.try_alloc().unwrap();
        p.retain(a);
        p.retain(a);
        assert_eq!(p.refcount(a), 3);
        p.release(a);
        p.release(a);
        assert_eq!(p.used_blocks(), 1, "still referenced");
        p.release(a);
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.stats.allocated, 1);
        assert_eq!(p.stats.freed, 1);
    }

    #[test]
    #[should_panic]
    fn releasing_free_block_panics() {
        let mut p = KvPool::new(8, 2);
        let a = p.try_alloc().unwrap();
        p.release(a);
        p.release(a);
    }
}
