//! Wire protocol v1: JSON-line envelopes and multiplexed reply frames
//! (DESIGN.md §Serving API v1).
//!
//! Requests (one JSON object per line):
//!
//!   {"v":1,"req_id":7,"prompt":[1,2,3],"stream":true,
//!    "max_new_tokens":64,"temperature":0.6,"seed":42,
//!    "stop_tokens":[0],"drafter":"dyspec","token_budget":32}
//!   {"cmd":"cancel","req_id":7}
//!   {"cmd":"stats"} | {"cmd":"shutdown"}
//!
//! `req_id` is client-assigned and scoped to the connection; one
//! connection can hold many in-flight requests, their reply frames
//! interleaved. Unknown fields are ignored (forward compatibility).
//!
//! Reply frames (one JSON object per line, each carrying the `req_id`):
//!
//!   {"v":1,"req_id":7,"event":"chunk","tokens":[..],"round":1,...}
//!   {"v":1,"req_id":7,"event":"done","finish":"length",...}
//!   {"v":1,"req_id":7,"event":"error","error":"..."}
//!
//! Every request stream ends with exactly one `done` (or `error` when it
//! never started); a cancelled request's `done` has `finish:"cancelled"`.
//!
//! Legacy compatibility: a bare `{"prompt":[..],...}` line (no `req_id`,
//! no `v`) is served exactly as before — one blocking one-shot reply
//! object with the full `tokens` array and no `event` wrapper.

use crate::config::PolicyKind;
use crate::coordinator::{FinishReason, GenParams, Response, RoundStats};
use crate::util::json::{parse, Json};

/// Protocol version spoken by this server.
pub const PROTOCOL_VERSION: u64 = 1;

/// Ceiling on one wire line (request envelope or reply frame). A peer
/// that streams more than this without a newline is violating the
/// protocol; the reactor closes the connection instead of buffering
/// without bound (the old `BufRead::read_line` transport had no guard).
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// Why the incremental decoder gave up on a connection's byte stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeError {
    /// More than `max_line` bytes arrived without a line terminator.
    Oversized(usize),
    /// A complete line was not valid UTF-8 (the blocking transport's
    /// `read_line` rejected these too — the connection closes).
    Utf8,
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Oversized(n) => {
                write!(f, "line exceeds {n} bytes without newline")
            }
            Self::Utf8 => f.write_str("line is not valid utf-8"),
        }
    }
}

/// Incremental frame decoder: bytes in, complete newline-terminated
/// lines out. The reactor transport feeds whatever each nonblocking read
/// returns — a line may arrive one byte at a time or many lines may land
/// in one read — and pops frames as they complete. A trailing fragment
/// (no newline yet) stays buffered across calls. `\r\n` is accepted as a
/// terminator (`\r` stripped), matching what `BufRead::read_line` +
/// `trim` tolerated before.
#[derive(Debug)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// Consumed prefix of `buf` (bytes of already-popped lines). Popping
    /// a line only advances this cursor; the buffer is compacted once
    /// per `push` — one memmove per socket read, not one per line, so a
    /// 16 KB read full of short lines costs O(bytes), not O(lines ×
    /// buffer).
    start: usize,
    /// Bytes of `buf` already scanned for a newline (restart point, so
    /// repeated pushes of a long fragment stay O(new bytes)). Invariant:
    /// `start <= scanned <= buf.len()`.
    scanned: usize,
    max_line: usize,
}

impl Default for FrameDecoder {
    fn default() -> Self {
        Self::new(MAX_LINE_BYTES)
    }
}

impl FrameDecoder {
    pub fn new(max_line: usize) -> Self {
        Self {
            buf: Vec::new(),
            start: 0,
            scanned: 0,
            max_line: max_line.max(1),
        }
    }

    /// Feed bytes off the wire.
    pub fn push(&mut self, bytes: &[u8]) {
        if self.start == self.buf.len() {
            self.buf.clear();
        } else if self.start > 0 {
            self.buf.drain(..self.start);
        }
        self.scanned -= self.start;
        self.start = 0;
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet popped as lines (tests, backpressure
    /// accounting).
    pub fn pending(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Pop the next complete line, `Ok(None)` when more bytes are needed.
    /// After an `Err` the stream is unrecoverable (framing is lost): the
    /// caller closes the connection.
    pub fn next_line(&mut self) -> Result<Option<String>, DecodeError> {
        match self.buf[self.scanned..]
            .iter()
            .position(|&b| b == b'\n')
        {
            Some(off) => {
                let end = self.scanned + off;
                let mut line: Vec<u8> = self.buf[self.start..end].to_vec();
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                self.start = end + 1;
                self.scanned = self.start;
                if line.len() > self.max_line {
                    return Err(DecodeError::Oversized(self.max_line));
                }
                match String::from_utf8(line) {
                    Ok(s) => Ok(Some(s)),
                    Err(_) => Err(DecodeError::Utf8),
                }
            }
            None => {
                self.scanned = self.buf.len();
                // Content length so far: a trailing '\r' may be the
                // first half of a `\r\n` terminator still in flight, so
                // it does not count against the ceiling — keeping the
                // verdict identical however the stream is split (a line
                // of exactly `max_line` bytes must pass whether its
                // `\r\n` arrives in the same read or byte by byte).
                let pending = self.pending()
                    - usize::from(self.buf.last() == Some(&b'\r'));
                if pending > self.max_line {
                    Err(DecodeError::Oversized(self.max_line))
                } else {
                    Ok(None)
                }
            }
        }
    }
}

/// Messages a client may send.
#[derive(Clone, Debug, PartialEq)]
pub enum ClientMessage {
    Generate {
        /// Client-assigned id (connection-scoped). `None` only on the
        /// legacy un-enveloped form.
        req_id: Option<u64>,
        prompt: Vec<u32>,
        params: GenParams,
        /// Stream chunk frames as rounds land (v1 envelopes only; the
        /// legacy form always gets a single one-shot reply).
        stream: bool,
    },
    Cancel {
        req_id: u64,
    },
    Stats,
    /// Prometheus text exposition of the metrics snapshot + observatory
    /// series, delivered as one `{"prometheus":"<text>"}` reply line.
    Metrics,
    /// Flight-recorder dump: `{"tracing":bool,"dropped":n,"spans":[..]}`.
    Trace,
    Shutdown,
}

/// Replies (already JSON-shaped; kept as an alias for readability).
pub type ServerReply = Json;

fn parse_prompt(doc: &Json) -> Result<Vec<u32>, String> {
    doc.get("prompt")
        .and_then(Json::as_arr)
        .ok_or("missing prompt")?
        .iter()
        .map(|t| {
            t.as_usize()
                .map(|v| v as u32)
                .ok_or_else(|| "non-numeric token".to_string())
        })
        .collect()
}

fn parse_u32_list(doc: &Json, key: &str) -> Result<Vec<u32>, String> {
    match doc.get(key) {
        None => Ok(Vec::new()),
        Some(v) => v
            .as_arr()
            .ok_or_else(|| format!("{key} must be an array"))?
            .iter()
            .map(|t| {
                t.as_usize()
                    .map(|v| v as u32)
                    .ok_or_else(|| format!("non-numeric {key} entry"))
            })
            .collect(),
    }
}

/// Parse the per-request parameter fields. v1 envelopes (`strict`) reject
/// wrong-typed fields; the legacy shim keeps v0's behavior bit-for-bit —
/// optional fields it cannot read fall back to their defaults silently.
fn parse_params(doc: &Json, strict: bool) -> Result<GenParams, String> {
    let mut p = GenParams::default();
    match doc.get("max_new_tokens").map(Json::as_usize) {
        Some(Some(v)) => p.max_new_tokens = v,
        Some(None) if strict => {
            return Err("max_new_tokens must be a number".into())
        }
        _ => {}
    }
    match doc.get("temperature").map(Json::as_f64) {
        Some(Some(v)) => p.temperature = v as f32,
        Some(None) if strict => {
            return Err("temperature must be a number".into())
        }
        _ => {}
    }
    match doc.get("seed").map(Json::as_f64) {
        Some(Some(v)) => p.seed = Some(v as u64),
        Some(None) if strict => return Err("seed must be a number".into()),
        _ => {}
    }
    match parse_u32_list(doc, "stop_tokens") {
        Ok(toks) => p.stop_tokens = toks,
        Err(e) if strict => return Err(e),
        Err(_) => {}
    }
    match doc.get("drafter").map(Json::as_str) {
        Some(Some(name)) => match PolicyKind::parse(name) {
            Some(kind) => p.drafter = Some(kind),
            None if strict => return Err(format!("unknown drafter: {name}")),
            None => {}
        },
        Some(None) if strict => return Err("drafter must be a string".into()),
        _ => {}
    }
    match doc.get("token_budget").map(Json::as_usize) {
        Some(Some(cap)) if cap > 0 => p.token_budget = Some(cap),
        Some(_) if strict => {
            return Err("token_budget must be a number >= 1".into())
        }
        _ => {}
    }
    Ok(p)
}

pub fn parse_client_message(line: &str) -> Result<ClientMessage, String> {
    let doc = parse(line).map_err(|e| format!("bad json: {e}"))?;
    if let Some(v) = doc.get("v") {
        let v = v.as_usize().ok_or("v must be a number")? as u64;
        if v != PROTOCOL_VERSION {
            return Err(format!("unsupported protocol version: {v}"));
        }
    }
    if let Some(cmd) = doc.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "stats" => Ok(ClientMessage::Stats),
            "metrics" => Ok(ClientMessage::Metrics),
            "trace" => Ok(ClientMessage::Trace),
            "shutdown" => Ok(ClientMessage::Shutdown),
            "cancel" => {
                let req_id = doc
                    .get("req_id")
                    .and_then(Json::as_f64)
                    .ok_or("cancel requires req_id")?;
                Ok(ClientMessage::Cancel {
                    req_id: req_id as u64,
                })
            }
            "generate" => parse_generate(&doc, true),
            other => Err(format!("unknown cmd: {other}")),
        };
    }
    // Envelope detection without "cmd": a v1 generate carries "req_id" or
    // "v"; a bare prompt object is the legacy one-shot form.
    let enveloped = doc.get("req_id").is_some() || doc.get("v").is_some();
    parse_generate(&doc, enveloped)
}

fn parse_generate(doc: &Json, enveloped: bool) -> Result<ClientMessage, String> {
    let prompt = parse_prompt(doc)?;
    let params = parse_params(doc, enveloped)?;
    let req_id = match doc.get("req_id") {
        Some(v) => Some(v.as_f64().ok_or("req_id must be a number")? as u64),
        None => None,
    };
    if enveloped && req_id.is_none() {
        return Err("generate envelope requires req_id".into());
    }
    let stream = doc
        .get("stream")
        .map(|v| matches!(v, Json::Bool(true)))
        .unwrap_or(false);
    if stream && !enveloped {
        return Err("streaming requires a v1 envelope with req_id".into());
    }
    Ok(ClientMessage::Generate {
        req_id,
        prompt,
        params,
        stream,
    })
}

/// Shared fields of every v1 frame.
fn frame(req_id: u64, event: &str, mut fields: Vec<(&str, Json)>) -> Json {
    let mut all = vec![
        ("v", Json::Num(PROTOCOL_VERSION as f64)),
        ("req_id", Json::Num(req_id as f64)),
        ("event", Json::Str(event.to_string())),
    ];
    all.append(&mut fields);
    Json::obj(all)
}

/// One accepted chunk (streamed per speculation round).
pub fn chunk_frame(req_id: u64, tokens: &[u32], stats: &RoundStats) -> Json {
    frame(
        req_id,
        "chunk",
        vec![
            (
                "tokens",
                Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
            ),
            ("round", Json::Num(stats.round as f64)),
            ("tree_size", Json::Num(stats.tree_size as f64)),
            ("accepted", Json::Num(stats.accepted as f64)),
            (
                "billed_positions",
                Json::Num(stats.billed_positions as f64),
            ),
            (
                "cached_positions",
                Json::Num(stats.cached_positions as f64),
            ),
            ("virtual_secs", Json::Num(stats.virtual_secs)),
        ],
    )
}

/// Aggregate response fields shared by the legacy reply and the done frame.
fn response_fields(resp: &Response, include_tokens: bool) -> Vec<(&'static str, Json)> {
    let mut fields = vec![
        ("id", Json::Num(resp.id as f64)),
        ("worker", Json::Num(resp.worker as f64)),
        ("steps", Json::Num(resp.steps as f64)),
        ("emitted_per_step", Json::Num(resp.emitted_per_step)),
        ("queue_secs", Json::Num(resp.queue_secs)),
        ("gen_secs", Json::Num(resp.gen_secs)),
        ("ttft_secs", Json::Num(resp.ttft_secs)),
        ("virtual_secs", Json::Num(resp.virtual_secs)),
        ("cache_hits", Json::Num(resp.cache_hits as f64)),
        ("finish", Json::Str(resp.finish.name().to_string())),
        ("tokens_total", Json::Num(resp.tokens.len() as f64)),
    ];
    if include_tokens {
        fields.push((
            "tokens",
            Json::Arr(resp.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
        ));
    }
    fields
}

/// Final frame of a request stream. `include_tokens` repeats the full
/// token array (used for non-streamed enveloped requests, where the done
/// frame IS the reply); streamed requests already received every token in
/// chunk frames and only get the count.
pub fn done_frame(req_id: u64, resp: &Response, include_tokens: bool) -> Json {
    frame(req_id, "done", response_fields(resp, include_tokens))
}

/// Terminal error frame for a request that cannot make progress (never
/// started, unknown req_id, worker dropped...).
pub fn error_frame(req_id: u64, msg: &str) -> Json {
    frame(
        req_id,
        "error",
        vec![("error", Json::Str(msg.to_string()))],
    )
}

/// Echo a request's trace id on a v1 frame: a nonzero trace adds a
/// `"trace":"<16-hex>"` field; zero (tracing off) returns the frame
/// untouched, keeping the wire bytes bit-identical to an untraced run
/// (pinned by `tests/obs_differential.rs`).
pub fn with_trace(frame: Json, trace: u64) -> Json {
    if trace == 0 {
        return frame;
    }
    match frame {
        Json::Obj(mut map) => {
            map.insert(
                "trace".into(),
                Json::Str(crate::obs::TraceId(trace).to_hex()),
            );
            Json::Obj(map)
        }
        other => other,
    }
}

/// Legacy one-shot reply (no envelope, full token array).
pub fn response_json(resp: &Response) -> Json {
    Json::obj(response_fields(resp, true))
}

pub fn error_json(msg: &str) -> Json {
    Json::obj(vec![("error", Json::Str(msg.to_string()))])
}

pub fn ok_json() -> Json {
    Json::obj(vec![("ok", Json::Bool(true))])
}

/// Client-side view of one reply frame.
#[derive(Clone, Debug)]
pub struct Frame {
    /// `None` for un-multiplexed replies (legacy reply, stats snapshot).
    pub req_id: Option<u64>,
    /// "chunk" | "done" | "error"; empty for un-multiplexed replies.
    pub event: String,
    pub body: Json,
}

impl Frame {
    pub fn tokens(&self) -> Vec<u32> {
        self.body
            .get("tokens")
            .and_then(Json::as_arr)
            .map(|arr| {
                arr.iter()
                    .filter_map(|t| t.as_usize().map(|v| v as u32))
                    .collect()
            })
            .unwrap_or_default()
    }

    pub fn finish(&self) -> Option<FinishReason> {
        self.body
            .get("finish")
            .and_then(Json::as_str)
            .and_then(FinishReason::parse)
    }

    pub fn error(&self) -> Option<&str> {
        self.body.get("error").and_then(Json::as_str)
    }

    /// The echoed trace id (present only when the server traced the
    /// request), as its 16-hex-digit wire form.
    pub fn trace(&self) -> Option<&str> {
        self.body.get("trace").and_then(Json::as_str)
    }
}

/// Parse one reply line into a [`Frame`].
pub fn parse_frame(line: &str) -> Result<Frame, String> {
    let body = parse(line.trim()).map_err(|e| format!("bad frame: {e}"))?;
    let req_id = body
        .get("req_id")
        .and_then(Json::as_f64)
        .map(|v| v as u64);
    let event = body
        .get("event")
        .and_then(Json::as_str)
        .unwrap_or("")
        .to_string();
    Ok(Frame {
        req_id,
        event,
        body,
    })
}

/// Build a v1 generate envelope (client side).
pub fn generate_envelope(
    req_id: u64,
    prompt: &[u32],
    params: &GenParams,
    stream: bool,
) -> Json {
    let mut fields = vec![
        ("v", Json::Num(PROTOCOL_VERSION as f64)),
        ("cmd", Json::Str("generate".into())),
        ("req_id", Json::Num(req_id as f64)),
        (
            "prompt",
            Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()),
        ),
        (
            "max_new_tokens",
            Json::Num(params.max_new_tokens as f64),
        ),
        ("temperature", Json::Num(params.temperature as f64)),
        ("stream", Json::Bool(stream)),
    ];
    if let Some(seed) = params.seed {
        fields.push(("seed", Json::Num(seed as f64)));
    }
    if !params.stop_tokens.is_empty() {
        fields.push((
            "stop_tokens",
            Json::Arr(
                params
                    .stop_tokens
                    .iter()
                    .map(|&t| Json::Num(t as f64))
                    .collect(),
            ),
        ));
    }
    if let Some(d) = params.drafter {
        fields.push(("drafter", Json::Str(d.name().into())));
    }
    if let Some(cap) = params.token_budget {
        fields.push(("token_budget", Json::Num(cap as f64)));
    }
    Json::obj(fields)
}

/// Build a cancel message (client side).
pub fn cancel_envelope(req_id: u64) -> Json {
    Json::obj(vec![
        ("cmd", Json::Str("cancel".into())),
        ("req_id", Json::Num(req_id as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_legacy_generate() {
        let msg = parse_client_message(
            r#"{"prompt":[1,2,3],"max_new_tokens":16,"temperature":0.5}"#,
        )
        .unwrap();
        match msg {
            ClientMessage::Generate {
                req_id,
                prompt,
                params,
                stream,
            } => {
                assert_eq!(req_id, None);
                assert_eq!(prompt, vec![1, 2, 3]);
                assert_eq!(params.max_new_tokens, 16);
                assert!((params.temperature - 0.5).abs() < 1e-6);
                assert!(!stream);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn parse_legacy_defaults() {
        let msg = parse_client_message(r#"{"prompt":[7]}"#).unwrap();
        match msg {
            ClientMessage::Generate { params, .. } => {
                assert_eq!(params.max_new_tokens, 128);
                assert!((params.temperature - 0.6).abs() < 1e-6);
                assert!(params.seed.is_none());
                assert!(params.stop_tokens.is_empty());
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn parse_v1_envelope_all_params() {
        let msg = parse_client_message(
            r#"{"v":1,"cmd":"generate","req_id":9,"prompt":[4,5],
                "max_new_tokens":32,"temperature":0.7,"seed":42,
                "stop_tokens":[0,2],"drafter":"chain","token_budget":8,
                "stream":true}"#,
        )
        .unwrap();
        match msg {
            ClientMessage::Generate {
                req_id,
                prompt,
                params,
                stream,
            } => {
                assert_eq!(req_id, Some(9));
                assert_eq!(prompt, vec![4, 5]);
                assert_eq!(params.max_new_tokens, 32);
                assert_eq!(params.seed, Some(42));
                assert_eq!(params.stop_tokens, vec![0, 2]);
                assert_eq!(params.drafter, Some(PolicyKind::Chain));
                assert_eq!(params.token_budget, Some(8));
                assert!(stream);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn envelope_builder_round_trips_through_parser() {
        let params = GenParams {
            seed: Some(7),
            stop_tokens: vec![3],
            drafter: Some(PolicyKind::DySpec),
            token_budget: Some(16),
            ..GenParams::simple(24, 0.9)
        };
        let line = generate_envelope(5, &[1, 2], &params, true).to_string();
        match parse_client_message(&line).unwrap() {
            ClientMessage::Generate {
                req_id,
                prompt,
                params: got,
                stream,
            } => {
                assert_eq!(req_id, Some(5));
                assert_eq!(prompt, vec![1, 2]);
                assert_eq!(got, params);
                assert!(stream);
            }
            _ => panic!("wrong variant"),
        }
        assert_eq!(
            parse_client_message(&cancel_envelope(5).to_string()).unwrap(),
            ClientMessage::Cancel { req_id: 5 }
        );
    }

    /// The shim contract: wrong-typed OPTIONAL fields that v0 silently
    /// defaulted must keep defaulting on un-enveloped requests, while the
    /// same input inside a v1 envelope is rejected.
    #[test]
    fn legacy_is_lenient_where_v0_was_v1_is_strict() {
        let legacy = parse_client_message(
            r#"{"prompt":[1],"temperature":"warm","max_new_tokens":null}"#,
        )
        .unwrap();
        match legacy {
            ClientMessage::Generate { params, .. } => {
                assert_eq!(params.max_new_tokens, 128);
                assert!((params.temperature - 0.6).abs() < 1e-6);
            }
            _ => panic!("wrong variant"),
        }
        assert!(parse_client_message(
            r#"{"v":1,"req_id":1,"prompt":[1],"temperature":"warm"}"#
        )
        .is_err());
        assert!(parse_client_message(
            r#"{"v":1,"req_id":1,"prompt":[1],"max_new_tokens":null}"#
        )
        .is_err());
        // Unknown v1-only fields on a legacy line are ignored even when
        // malformed (v0 never read them).
        assert!(parse_client_message(
            r#"{"prompt":[1],"drafter":"warp","token_budget":0}"#
        )
        .is_ok());
    }

    #[test]
    fn unknown_fields_are_tolerated() {
        let msg = parse_client_message(
            r#"{"v":1,"req_id":1,"prompt":[1],"future_knob":{"a":[1,2]},
                "another":"ignored"}"#,
        )
        .unwrap();
        assert!(matches!(msg, ClientMessage::Generate { .. }));
    }

    #[test]
    fn malformed_and_partial_envelopes_error() {
        // Truncated JSON (a partial frame off the wire).
        assert!(parse_client_message(r#"{"v":1,"req_id":1,"pro"#).is_err());
        // Envelope without req_id.
        assert!(parse_client_message(r#"{"v":1,"prompt":[1]}"#).is_err());
        // Streaming without an envelope.
        assert!(
            parse_client_message(r#"{"prompt":[1],"stream":true}"#).is_err()
        );
        // Wrong types.
        assert!(parse_client_message(r#"{"prompt":"abc"}"#).is_err());
        assert!(parse_client_message(r#"{"prompt":[1,"x"]}"#).is_err());
        assert!(parse_client_message(
            r#"{"v":1,"req_id":1,"prompt":[1],"stop_tokens":3}"#
        )
        .is_err());
        assert!(parse_client_message(
            r#"{"v":1,"req_id":1,"prompt":[1],"drafter":"warp"}"#
        )
        .is_err());
        assert!(parse_client_message(
            r#"{"v":1,"req_id":1,"prompt":[1],"token_budget":0}"#
        )
        .is_err());
        // Future protocol version.
        assert!(
            parse_client_message(r#"{"v":2,"req_id":1,"prompt":[1]}"#).is_err()
        );
        // Cancel without req_id.
        assert!(parse_client_message(r#"{"cmd":"cancel"}"#).is_err());
    }

    #[test]
    fn parse_commands_and_errors() {
        assert_eq!(
            parse_client_message(r#"{"cmd":"stats"}"#).unwrap(),
            ClientMessage::Stats
        );
        assert_eq!(
            parse_client_message(r#"{"cmd":"metrics"}"#).unwrap(),
            ClientMessage::Metrics
        );
        assert_eq!(
            parse_client_message(r#"{"cmd":"trace"}"#).unwrap(),
            ClientMessage::Trace
        );
        assert_eq!(
            parse_client_message(r#"{"cmd":"shutdown"}"#).unwrap(),
            ClientMessage::Shutdown
        );
        assert_eq!(
            parse_client_message(r#"{"cmd":"cancel","req_id":3}"#).unwrap(),
            ClientMessage::Cancel { req_id: 3 }
        );
        assert!(parse_client_message(r#"{"cmd":"dance"}"#).is_err());
        assert!(parse_client_message("{}").is_err());
        assert!(parse_client_message("garbage").is_err());
    }

    fn test_response() -> Response {
        Response {
            id: 3,
            worker: 1,
            tokens: vec![4, 5],
            steps: 2,
            emitted_per_step: 1.0,
            queue_secs: 0.1,
            gen_secs: 0.2,
            ttft_secs: 0.15,
            virtual_secs: 0.0,
            cache_hits: 5,
            finish: FinishReason::Length,
        }
    }

    #[test]
    fn legacy_response_round_trip() {
        let json = response_json(&test_response());
        let text = json.to_string();
        let back = parse(&text).unwrap();
        assert_eq!(back.get("id").unwrap().as_usize(), Some(3));
        assert_eq!(back.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(back.get("cache_hits").unwrap().as_usize(), Some(5));
        assert_eq!(back.get("finish").unwrap().as_str(), Some("length"));
    }

    #[test]
    fn frames_round_trip() {
        let stats = RoundStats {
            round: 2,
            tree_size: 8,
            accepted: 3,
            billed_positions: 11,
            cached_positions: 6,
            virtual_secs: 0.01,
        };
        let line = chunk_frame(7, &[9, 8], &stats).to_string();
        let f = parse_frame(&line).unwrap();
        assert_eq!(f.req_id, Some(7));
        assert_eq!(f.event, "chunk");
        assert_eq!(f.tokens(), vec![9, 8]);
        assert_eq!(f.body.get("round").unwrap().as_usize(), Some(2));

        let mut resp = test_response();
        resp.finish = FinishReason::Cancelled;
        let line = done_frame(7, &resp, false).to_string();
        let f = parse_frame(&line).unwrap();
        assert_eq!(f.event, "done");
        assert_eq!(f.finish(), Some(FinishReason::Cancelled));
        assert!(f.tokens().is_empty(), "streamed done repeats tokens");
        assert_eq!(
            f.body.get("tokens_total").unwrap().as_usize(),
            Some(2)
        );
        let line = done_frame(7, &resp, true).to_string();
        assert_eq!(parse_frame(&line).unwrap().tokens(), vec![4, 5]);

        let line = error_frame(4, "queue full").to_string();
        let f = parse_frame(&line).unwrap();
        assert_eq!(f.event, "error");
        assert_eq!(f.req_id, Some(4));
        assert_eq!(f.error(), Some("queue full"));
    }

    /// Trace echo: zero leaves the frame byte-identical; nonzero appends
    /// the 16-hex id, recoverable through the client-side parser.
    #[test]
    fn with_trace_is_identity_at_zero_and_echoes_otherwise() {
        let stats = RoundStats::default();
        let bare = chunk_frame(7, &[9], &stats).to_string();
        assert_eq!(
            with_trace(chunk_frame(7, &[9], &stats), 0).to_string(),
            bare,
            "zero trace must not change the wire bytes"
        );

        let id = crate::obs::TraceId::mint(7);
        let line = with_trace(chunk_frame(7, &[9], &stats), id.0).to_string();
        assert_ne!(line, bare);
        let f = parse_frame(&line).unwrap();
        assert_eq!(f.trace(), Some(id.to_hex().as_str()));
        assert_eq!(f.tokens(), vec![9]);
        assert!(parse_frame(&bare).unwrap().trace().is_none());

        let done = with_trace(done_frame(7, &test_response(), false), id.0);
        assert_eq!(
            parse_frame(&done.to_string()).unwrap().trace(),
            Some(id.to_hex().as_str())
        );
    }

    /// Drain every currently-complete line out of the decoder.
    fn drain(dec: &mut FrameDecoder) -> Vec<String> {
        let mut out = Vec::new();
        while let Some(line) = dec.next_line().expect("decode") {
            out.push(line);
        }
        out
    }

    /// The reactor's framing invariant: however the byte stream is cut
    /// into reads, the decoded line sequence is identical. Exhaustive
    /// over every split point of a multi-frame payload, plus the
    /// one-byte-at-a-time extreme.
    #[test]
    fn decoder_is_split_invariant_at_every_byte_boundary() {
        let payload = concat!(
            r#"{"v":1,"req_id":7,"prompt":[1,2,3],"stream":true}"#,
            "\n",
            r#"{"cmd":"cancel","req_id":7}"#,
            "\r\n",
            "\n", // blank line (skipped by the caller, not the decoder)
            r#"{"cmd":"stats"}"#,
            "\n",
        )
        .as_bytes();
        let want = {
            let mut d = FrameDecoder::default();
            d.push(payload);
            drain(&mut d)
        };
        assert_eq!(want.len(), 4);
        assert_eq!(want[2], "");
        assert!(parse_client_message(&want[0]).is_ok());
        assert!(parse_client_message(&want[3]).is_ok());

        for cut in 0..=payload.len() {
            let mut d = FrameDecoder::default();
            d.push(&payload[..cut]);
            let mut got = drain(&mut d);
            d.push(&payload[cut..]);
            got.extend(drain(&mut d));
            assert_eq!(got, want, "split at byte {cut} diverged");
        }

        let mut d = FrameDecoder::default();
        let mut got = Vec::new();
        for b in payload {
            d.push(&[*b]);
            got.extend(drain(&mut d));
        }
        assert_eq!(got, want, "byte-at-a-time diverged");
        assert_eq!(d.pending(), 0);
    }

    /// Merged frames in one read pop out one by one; a trailing fragment
    /// (garbage or a half-written envelope) stays pending until its
    /// newline arrives — and is NOT misparsed as a line.
    #[test]
    fn decoder_merged_frames_and_trailing_fragment() {
        let mut d = FrameDecoder::default();
        d.push(b"{\"cmd\":\"stats\"}\n{\"cmd\":\"shutdown\"}\ntrailing garb");
        assert_eq!(
            drain(&mut d),
            vec![
                r#"{"cmd":"stats"}"#.to_string(),
                r#"{"cmd":"shutdown"}"#.to_string()
            ]
        );
        assert_eq!(d.pending(), "trailing garb".len());
        // The fragment completes later — possibly across several pushes.
        d.push(b"age");
        assert!(d.next_line().unwrap().is_none());
        d.push(b"\n");
        assert_eq!(d.next_line().unwrap().as_deref(), Some("trailing garbage"));
        assert_eq!(d.pending(), 0);
    }

    /// A peer that streams past the line ceiling without a newline is cut
    /// off deterministically — whether the flood arrives in one push or
    /// many — and an over-long *terminated* line is rejected too.
    #[test]
    fn decoder_oversized_lines_error() {
        let mut d = FrameDecoder::new(16);
        d.push(&[b'x'; 17]);
        assert_eq!(d.next_line(), Err(DecodeError::Oversized(16)));

        let mut d = FrameDecoder::new(16);
        for _ in 0..16 {
            d.push(b"x");
            assert_eq!(d.next_line(), Ok(None));
        }
        d.push(b"x");
        assert_eq!(d.next_line(), Err(DecodeError::Oversized(16)));

        // Newline and payload arriving together: still over the ceiling.
        let mut d = FrameDecoder::new(8);
        d.push(b"123456789\n");
        assert_eq!(d.next_line(), Err(DecodeError::Oversized(8)));

        // Exactly at the ceiling is fine.
        let mut d = FrameDecoder::new(8);
        d.push(b"12345678\n");
        assert_eq!(d.next_line().unwrap().as_deref(), Some("12345678"));

        // The ceiling verdict is split-invariant: a line of exactly
        // `max_line` content bytes terminated by `\r\n` passes no
        // matter where the reads cut it (the pending `\r` must not
        // count against the ceiling), and one content byte more fails
        // at every split too.
        let payload = b"12345678\r\n";
        for cut in 0..=payload.len() {
            let mut d = FrameDecoder::new(8);
            d.push(&payload[..cut]);
            let got = match d.next_line().unwrap() {
                Some(line) => line,
                None => {
                    d.push(&payload[cut..]);
                    d.next_line().unwrap().expect("terminated line")
                }
            };
            assert_eq!(got, "12345678", "split at {cut}");
        }
        let payload = b"123456789\r\n";
        for cut in 0..=payload.len() {
            let mut d = FrameDecoder::new(8);
            d.push(&payload[..cut]);
            let first = d.next_line();
            let verdict = if first.is_err() {
                first
            } else {
                d.push(&payload[cut..]);
                d.next_line()
            };
            assert_eq!(
                verdict,
                Err(DecodeError::Oversized(8)),
                "split at {cut}"
            );
        }
    }

    #[test]
    fn decoder_rejects_invalid_utf8_lines() {
        let mut d = FrameDecoder::default();
        d.push(b"ok line\n\xff\xfe\n");
        assert_eq!(d.next_line().unwrap().as_deref(), Some("ok line"));
        assert_eq!(d.next_line(), Err(DecodeError::Utf8));
    }
}
