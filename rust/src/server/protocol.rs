//! Wire protocol: JSON line encoding/decoding for client/server messages.

use crate::coordinator::Response;
use crate::util::json::{parse, Json};

/// Messages a client may send.
#[derive(Clone, Debug, PartialEq)]
pub enum ClientMessage {
    Generate {
        prompt: Vec<u32>,
        max_new_tokens: usize,
        temperature: f32,
    },
    Stats,
    Shutdown,
}

/// Replies (already JSON-shaped; kept as an alias for readability).
pub type ServerReply = Json;

pub fn parse_client_message(line: &str) -> Result<ClientMessage, String> {
    let doc = parse(line).map_err(|e| format!("bad json: {e}"))?;
    if let Some(cmd) = doc.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "stats" => Ok(ClientMessage::Stats),
            "shutdown" => Ok(ClientMessage::Shutdown),
            other => Err(format!("unknown cmd: {other}")),
        };
    }
    let prompt = doc
        .get("prompt")
        .and_then(Json::as_arr)
        .ok_or("missing prompt")?
        .iter()
        .map(|t| t.as_usize().map(|v| v as u32).ok_or("non-numeric token"))
        .collect::<Result<Vec<u32>, _>>()?;
    let max_new_tokens = doc
        .get("max_new_tokens")
        .and_then(Json::as_usize)
        .unwrap_or(128);
    let temperature = doc
        .get("temperature")
        .and_then(Json::as_f64)
        .unwrap_or(0.6) as f32;
    Ok(ClientMessage::Generate {
        prompt,
        max_new_tokens,
        temperature,
    })
}

pub fn response_json(resp: &Response) -> Json {
    Json::obj(vec![
        ("id", Json::Num(resp.id as f64)),
        ("worker", Json::Num(resp.worker as f64)),
        (
            "tokens",
            Json::Arr(resp.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
        ),
        ("steps", Json::Num(resp.steps as f64)),
        ("emitted_per_step", Json::Num(resp.emitted_per_step)),
        ("queue_secs", Json::Num(resp.queue_secs)),
        ("gen_secs", Json::Num(resp.gen_secs)),
        ("ttft_secs", Json::Num(resp.ttft_secs)),
        ("virtual_secs", Json::Num(resp.virtual_secs)),
        ("cache_hits", Json::Num(resp.cache_hits as f64)),
    ])
}

pub fn error_json(msg: &str) -> Json {
    Json::obj(vec![("error", Json::Str(msg.to_string()))])
}

pub fn ok_json() -> Json {
    Json::obj(vec![("ok", Json::Bool(true))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_generate() {
        let msg = parse_client_message(
            r#"{"prompt":[1,2,3],"max_new_tokens":16,"temperature":0.5}"#,
        )
        .unwrap();
        assert_eq!(
            msg,
            ClientMessage::Generate {
                prompt: vec![1, 2, 3],
                max_new_tokens: 16,
                temperature: 0.5
            }
        );
    }

    #[test]
    fn parse_defaults() {
        let msg = parse_client_message(r#"{"prompt":[7]}"#).unwrap();
        match msg {
            ClientMessage::Generate {
                max_new_tokens,
                temperature,
                ..
            } => {
                assert_eq!(max_new_tokens, 128);
                assert!((temperature - 0.6).abs() < 1e-6);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn parse_commands_and_errors() {
        assert_eq!(
            parse_client_message(r#"{"cmd":"stats"}"#).unwrap(),
            ClientMessage::Stats
        );
        assert_eq!(
            parse_client_message(r#"{"cmd":"shutdown"}"#).unwrap(),
            ClientMessage::Shutdown
        );
        assert!(parse_client_message(r#"{"cmd":"dance"}"#).is_err());
        assert!(parse_client_message("{}").is_err());
        assert!(parse_client_message("garbage").is_err());
    }

    #[test]
    fn response_round_trip() {
        let resp = Response {
            id: 3,
            worker: 1,
            tokens: vec![4, 5],
            steps: 2,
            emitted_per_step: 1.0,
            queue_secs: 0.1,
            gen_secs: 0.2,
            ttft_secs: 0.15,
            virtual_secs: 0.0,
            cache_hits: 5,
        };
        let json = response_json(&resp);
        let text = json.to_string();
        let back = parse(&text).unwrap();
        assert_eq!(back.get("id").unwrap().as_usize(), Some(3));
        assert_eq!(back.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(back.get("cache_hits").unwrap().as_usize(), Some(5));
    }
}
