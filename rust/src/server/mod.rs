//! TCP serving frontend + blocking client.
//!
//! Line-delimited JSON protocol (one request / one response per line):
//!
//!   -> {"prompt":[1,2,3],"max_new_tokens":128,"temperature":0.6}
//!   <- {"id":1,"tokens":[...],"steps":12,"emitted_per_step":4.2,
//!       "queue_secs":0.001,"gen_secs":0.8}
//!   -> {"cmd":"stats"}
//!   <- {"admitted":...,"completed":...,...}
//!   -> {"cmd":"shutdown"}        (stops the accept loop)
//!
//! Errors come back as {"error":"..."} — including "queue full"
//! backpressure rejections.

pub mod client;
pub mod protocol;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::coordinator::Coordinator;
use crate::{log_info, log_warn};

pub use client::Client;
pub use protocol::{ClientMessage, ServerReply};

/// Serve `coordinator` on `addr` until a shutdown command arrives.
/// Returns the bound local address once listening (port 0 supported).
pub struct Server {
    listener: TcpListener,
    coordinator: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
}

impl Server {
    pub fn bind(addr: &str, coordinator: Coordinator) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        Ok(Self {
            listener,
            coordinator: Arc::new(coordinator),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> std::io::Result<std::net::SocketAddr> {
        self.listener.local_addr()
    }

    /// Accept loop: one thread per connection (connections are few and
    /// long-lived in this workload; the worker pool bounds real concurrency).
    pub fn run(&self) -> std::io::Result<()> {
        log_info!("serving on {}", self.local_addr()?);
        for stream in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(stream) => {
                    let coord = self.coordinator.clone();
                    let stop = self.stop.clone();
                    std::thread::spawn(move || {
                        if let Err(e) = handle_conn(stream, &coord, &stop) {
                            log_warn!("connection error: {e}");
                        }
                    });
                }
                Err(e) => log_warn!("accept error: {e}"),
            }
        }
        Ok(())
    }
}

fn handle_conn(
    stream: TcpStream,
    coord: &Coordinator,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match protocol::parse_client_message(&line) {
            Ok(ClientMessage::Generate {
                prompt,
                max_new_tokens,
                temperature,
            }) => match coord.generate(prompt, max_new_tokens, temperature) {
                Ok(resp) => protocol::response_json(&resp),
                Err(e) => protocol::error_json(&e),
            },
            Ok(ClientMessage::Stats) => coord.metrics.snapshot(),
            Ok(ClientMessage::Shutdown) => {
                stop.store(true, Ordering::SeqCst);
                // Poke the accept loop awake.
                if let Ok(addr) = writer.local_addr() {
                    let _ = TcpStream::connect(addr);
                }
                protocol::ok_json()
            }
            Err(e) => protocol::error_json(&e),
        };
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if stop.load(Ordering::SeqCst) {
            break;
        }
    }
    log_info!("peer {peer} disconnected");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::ModelFactory;
    use crate::models::sim::{SimModel, SimSpec};
    use crate::models::LogitModel;

    fn start_server() -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let factory: ModelFactory = Arc::new(|| {
            let spec = SimSpec::new(64, 2.0, 0.5, 9);
            let (d, t) = SimModel::pair(spec);
            (
                Box::new(d) as Box<dyn LogitModel>,
                Box::new(t) as Box<dyn LogitModel>,
            )
        });
        let mut cfg = Config::new();
        cfg.server.workers = 2;
        cfg.engine.tree_budget = 8;
        let coord = Coordinator::start(cfg, factory);
        let server = Server::bind("127.0.0.1:0", coord).unwrap();
        let addr = server.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let _ = server.run();
        });
        (addr, handle)
    }

    #[test]
    fn end_to_end_generate_stats_shutdown() {
        let (addr, handle) = start_server();
        let mut client = Client::connect(&addr.to_string()).unwrap();
        let tokens = client.generate(&[1, 2, 3], 12, 0.6).unwrap();
        assert_eq!(tokens.len(), 12);

        let stats = client.stats().unwrap();
        assert_eq!(stats.get("completed").unwrap().as_usize(), Some(1));

        client.shutdown().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn malformed_line_returns_error() {
        let (addr, handle) = start_server();
        let mut client = Client::connect(&addr.to_string()).unwrap();
        let reply = client.send_raw("this is not json").unwrap();
        assert!(reply.get("error").is_some());
        client.shutdown().unwrap();
        handle.join().unwrap();
    }
}
